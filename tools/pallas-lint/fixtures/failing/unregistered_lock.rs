// Failing fixture: `gamma` is not registered in locks.toml.
use std::sync::Mutex;

pub struct State {
    pub gamma: Mutex<Vec<u32>>,
}

impl State {
    pub fn len(&self) -> usize {
        self.gamma.lock().map(|g| g.len()).unwrap_or(0)
    }
}
