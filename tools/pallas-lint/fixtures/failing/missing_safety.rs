// Failing fixture: `unsafe` with no SAFETY comment anywhere nearby.
pub fn read_first(bytes: &[u8]) -> u8 {
    unsafe { *bytes.get_unchecked(0) }
}
