// Failing fixture: beta (rank 20) acquired before alpha (rank 10) —
// the classic inversion the hierarchy exists to prevent.
use std::sync::Mutex;

pub struct State {
    pub alpha: Mutex<Vec<u32>>,
    pub beta: Mutex<Vec<u32>>,
}

impl State {
    pub fn drain(&self) -> usize {
        let mut moved = 0;
        if let Ok(mut b) = self.beta.lock() {
            if let Ok(mut a) = self.alpha.lock() {
                moved = a.len();
                b.append(&mut a);
            }
        }
        moved
    }
}
