// Failing fixture: Acquire/Release with no ordering rationale.
use std::sync::atomic::{AtomicBool, Ordering};

pub static READY: AtomicBool = AtomicBool::new(false);

pub fn publish() {
    READY.store(true, Ordering::Release);
}

pub fn ready() -> bool {
    READY.load(Ordering::Acquire)
}
