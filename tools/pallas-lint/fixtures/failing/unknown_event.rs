// Failing fixture: "bogus" is not in events.toml.
pub struct Log;

impl Log {
    pub fn event(&self, _kind: &str) {}
}

pub fn emit(log: &Log) {
    log.event("bogus");
}
