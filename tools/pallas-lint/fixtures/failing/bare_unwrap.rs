// Failing fixture: unwrap/expect in library code with no annotation.
pub fn head(v: &[i32]) -> i32 {
    *v.first().unwrap()
}

pub fn parsed(s: &str) -> i64 {
    s.parse::<i64>().expect("not a number")
}
