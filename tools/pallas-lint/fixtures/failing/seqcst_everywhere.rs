// Failing fixture: SeqCst outside the allowlist. The ordering
// rationale below is present so this file produces exactly one
// violation (the allowlist one), keeping the golden test precise.
use std::sync::atomic::{AtomicBool, Ordering};

pub static FLAG: AtomicBool = AtomicBool::new(false);

pub fn set() {
    // ordering: SeqCst requested out of caution, which is exactly
    // what the allowlist is there to push back on.
    FLAG.store(true, Ordering::SeqCst);
}
