// Clean fixture: nested acquisition in increasing rank order
// (alpha rank 10, then beta rank 20), plus a statement-scoped lock
// that is released at the `;` before the next acquisition.
use std::sync::Mutex;

pub struct State {
    pub alpha: Mutex<Vec<u32>>,
    pub beta: Mutex<Vec<u32>>,
}

impl State {
    pub fn drain(&self) -> usize {
        let mut moved = 0;
        if let Ok(mut a) = self.alpha.lock() {
            if let Ok(mut b) = self.beta.lock() {
                moved = a.len();
                b.append(&mut a);
            }
        }
        moved
    }

    pub fn sizes(&self) -> (usize, usize) {
        // The lexical scan treats let-bound guards as held for the
        // rest of the block, so even transient bindings must follow
        // the rank order: alpha (10) before beta (20).
        let a_len = self.alpha.lock().map(|a| a.len()).unwrap_or(0);
        let b_len = self.beta.lock().map(|b| b.len()).unwrap_or(0);
        (a_len, b_len)
    }
}
