// Clean fixture: unwrap is fine when annotated, or inside cfg(test).
pub fn head(v: &[i32]) -> i32 {
    assert!(!v.is_empty());
    // lint: allow(unwrap) the assert above guarantees non-empty, and
    // a multi-line reason must also satisfy the window because it is
    // measured to the bottom of the comment block.
    *v.first().unwrap()
}

pub fn parsed(s: &str) -> Option<i64> {
    s.parse::<i64>().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_works() {
        assert_eq!(head(&[7, 8]), 7);
        assert_eq!(parsed("42").unwrap(), 42);
    }
}
