// Clean fixture: strong orderings justified, Relaxed needs nothing.
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

pub static FLAG: AtomicBool = AtomicBool::new(false);
pub static COUNT: AtomicUsize = AtomicUsize::new(0);

pub fn publish() {
    COUNT.fetch_add(1, Ordering::Relaxed);
    // ordering: Release pairs with the Acquire in `consume` so the
    // count increment is visible before the flag flips.
    FLAG.store(true, Ordering::Release);
}

pub fn consume() -> Option<usize> {
    // ordering: Acquire pairs with the Release in `publish`.
    if FLAG.load(Ordering::Acquire) {
        Some(COUNT.load(Ordering::Relaxed))
    } else {
        None
    }
}
