// Clean fixture: every `unsafe` carries a SAFETY comment within the
// window, including a multi-line block whose marker sits above it.
pub fn read_first(bytes: &[u8]) -> u8 {
    assert!(!bytes.is_empty());
    // SAFETY: the assert above guarantees the slice is non-empty, so
    // index 0 is in bounds.
    unsafe { *bytes.get_unchecked(0) }
}

pub fn as_str(bytes: &[u8]) -> &str {
    // SAFETY: callers uphold the UTF-8 invariant; this fixture only
    // exercises the comment-window scan, the longer rationale block
    // below the marker line must still satisfy the lint because the
    // window is measured to the bottom of the comment block, not to
    // the marker line itself.
    unsafe { std::str::from_utf8_unchecked(bytes) }
}
