// Clean fixture: this file is on the lint.toml [seqcst] allowlist, so
// SeqCst is legal here — but it still needs an ordering rationale.
use std::sync::atomic::{AtomicBool, Ordering};

pub static HALT: AtomicBool = AtomicBool::new(false);

pub fn halt() {
    // ordering: SeqCst — fixture stands in for an async-signal
    // context where the total order is the point.
    HALT.store(true, Ordering::SeqCst);
}
