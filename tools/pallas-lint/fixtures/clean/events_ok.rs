// Clean fixture: every literal event kind is listed in events.toml,
// and non-literal kinds are out of scope for the lint.
pub struct Log;

impl Log {
    pub fn event(&self, _kind: &str) {}
    pub fn str(&self, _key: &str, _val: &str) {}
}

pub fn count_events(_kind: &str) -> usize {
    0
}

pub fn emit(log: &Log, dynamic_kind: &str) {
    log.event("carve");
    log.str("ev", "gate");
    log.str("other_key", "not_an_event");
    let _ = count_events("gate");
    log.event(dynamic_kind);
}
