//! pallas-lint: the smartdiff-sched tree's in-house static analysis
//! suite — a token-level scanner over Rust sources enforcing the
//! repo-specific correctness contracts that rustc/clippy cannot know
//! about. `python/pallas_lint.py` is a line-for-line mirror (same
//! config files, same messages, same exit codes) usable where no Rust
//! toolchain exists; `python/tests/test_pallas_lint.py` and the CI
//! `lint` job keep the two honest against the shared fixtures.
//!
//! Rule families (see ARCHITECTURE.md "Static analysis & concurrency
//! audit"):
//!
//! * `unsafe-safety` — every `unsafe` carries a `// SAFETY:` comment
//!   within the 5 preceding lines.
//! * `atomic-ordering` — every non-Relaxed atomic `Ordering::` use
//!   carries an `// ordering:` rationale within the 6 preceding lines;
//!   `Ordering::SeqCst` is additionally forbidden outside the
//!   `lint.toml [seqcst]` allowlist.
//! * `unwrap` — `.unwrap()` / `.expect(..)` are banned in non-test
//!   library code unless annotated `// lint: allow(unwrap) <reason>`.
//! * `lock-order` — every `.lock()` receiver must be registered in
//!   `locks.toml`; lexically nested acquisitions must be
//!   rank-increasing.
//! * `telemetry-event` — literal event kinds at `.event("…")`,
//!   `count_events("…")` and `.str("ev", "…")` sites must be listed in
//!   `events.toml`.
//!
//! The scanner blanks string/char-literal contents and comments in
//! place (same byte length, so offsets stay source columns), records
//! per-line comment text and a quote-offset → literal-text table, and
//! the rules run over that blanked view. Annotation windows are
//! comment-block aware: the window bounds the distance from the token
//! to the *bottom* of the comment block, and the block itself may
//! extend further up.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

/// Max lines between an `unsafe` token and the bottom of its
/// `// SAFETY:` comment block.
pub const SAFETY_WINDOW: usize = 5;
/// Max lines between a strong-ordering token and its `// ordering:`
/// rationale (6: the token is often a few lines into a call).
pub const ORDERING_WINDOW: usize = 6;
/// Max lines between an unwrap/expect token and its allow annotation.
pub const ALLOW_WINDOW: usize = 2;

const STRONG_ORDERINGS: [&str; 4] = ["Acquire", "Release", "AcqRel", "SeqCst"];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

// --------------------------------------------------------------------
// toml subset parser (sections, [[array-of-tables]], str/int/str-array
// values, full-line and trailing comments) — enough for the three
// config files, NOT a general TOML implementation.
// --------------------------------------------------------------------

/// A parsed value: string, integer, or a flat list of either.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    List(Vec<TomlValue>),
}

/// A parsed document: top-level keys, `[section]` tables, and
/// `[[name]]` arrays-of-tables.
#[derive(Debug, Default)]
pub struct TomlDoc {
    pub root: BTreeMap<String, TomlValue>,
    pub tables: BTreeMap<String, BTreeMap<String, TomlValue>>,
    pub arrays: BTreeMap<String, Vec<BTreeMap<String, TomlValue>>>,
}

enum Target {
    Root,
    Table(String),
    Array(String),
}

/// Parse the TOML subset. Lines must be pre-joined (see
/// [`load_multiline_toml`]) so every `key = [..]` array is one line.
pub fn parse_toml(text: &str) -> Result<TomlDoc, String> {
    let mut doc = TomlDoc::default();
    let mut target = Target::Root;
    for raw in text.lines() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix("[[") {
            let name = inner
                .strip_suffix("]]")
                .ok_or_else(|| format!("bad array-of-tables header: {raw}"))?
                .trim()
                .to_string();
            doc.arrays.entry(name.clone()).or_default().push(BTreeMap::new());
            target = Target::Array(name);
        } else if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .ok_or_else(|| format!("bad section header: {raw}"))?
                .trim()
                .to_string();
            doc.tables.entry(name.clone()).or_default();
            target = Target::Table(name);
        } else {
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("expected key = value: {raw}"))?;
            let v = parse_value(val.trim())?;
            let k = key.trim().to_string();
            match &target {
                Target::Root => {
                    doc.root.insert(k, v);
                }
                Target::Table(name) => {
                    doc.tables.entry(name.clone()).or_default().insert(k, v);
                }
                Target::Array(name) => {
                    if let Some(last) =
                        doc.arrays.entry(name.clone()).or_default().last_mut()
                    {
                        last.insert(k, v);
                    }
                }
            }
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        if c == '"' {
            in_str = !in_str;
        } else if c == '#' && !in_str {
            return &line[..i];
        }
    }
    line
}

fn parse_value(val: &str) -> Result<TomlValue, String> {
    if let Some(inner) = val.strip_prefix('[') {
        let inner = inner.strip_suffix(']').unwrap_or(inner);
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::List(items));
    }
    if let Some(rest) = val.strip_prefix('"') {
        let body = rest.strip_suffix('"').unwrap_or(rest);
        return Ok(TomlValue::Str(body.to_string()));
    }
    match val.parse::<i64>() {
        Ok(n) => Ok(TomlValue::Int(n)),
        Err(_) => Err(format!("bad toml value: {val}")),
    }
}

/// Read and parse a config file, joining multi-line arrays first
/// (events.toml formats its list one entry per line).
pub fn load_multiline_toml(path: &Path) -> Result<TomlDoc, String> {
    let raw = fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    let mut joined: Vec<String> = Vec::new();
    let mut buf: Option<String> = None;
    for line in raw.lines() {
        let stripped = strip_comment(line).to_string();
        if let Some(acc) = buf.as_mut() {
            acc.push(' ');
            acc.push_str(stripped.trim());
            if stripped.contains(']') {
                if let Some(full) = buf.take() {
                    joined.push(full);
                }
            }
            continue;
        }
        if stripped.contains("= [") && !stripped.contains(']') {
            buf = Some(stripped.trim().to_string());
            continue;
        }
        joined.push(line.to_string());
    }
    parse_toml(&joined.join("\n"))
}

// --------------------------------------------------------------------
// source scanner
// --------------------------------------------------------------------

/// The blanked view of one source file plus its side tables.
pub struct Scan {
    /// Source bytes with string/char-literal contents and comments
    /// blanked to spaces (newlines kept, so offsets and line numbers
    /// match the original).
    pub code: Vec<u8>,
    /// 1-based line → comment texts starting on that line.
    pub comments: BTreeMap<usize, Vec<String>>,
    /// Offset of an opening `"` → the literal's text.
    pub strings: BTreeMap<usize, String>,
    /// Byte offset → 1-based line.
    pub line_of: Vec<usize>,
    line_spans: Vec<(usize, usize)>,
}

impl Scan {
    fn new(
        code: Vec<u8>,
        comments: BTreeMap<usize, Vec<String>>,
        strings: BTreeMap<usize, String>,
        line_of: Vec<usize>,
    ) -> Scan {
        let mut line_spans = Vec::new();
        let mut start = 0usize;
        for (i, b) in code.iter().enumerate() {
            if *b == b'\n' {
                line_spans.push((start, i));
                start = i + 1;
            }
        }
        line_spans.push((start, code.len()));
        Scan { code, comments, strings, line_of, line_spans }
    }

    /// Whether `line` holds a comment and nothing else.
    fn comment_only(&self, line: usize) -> bool {
        if !self.comments.contains_key(&line) {
            return false;
        }
        match self.line_spans.get(line.wrapping_sub(1)) {
            Some(&(a, b)) => {
                self.code[a..b].iter().all(|c| c.is_ascii_whitespace())
            }
            None => false,
        }
    }
}

fn find_bytes(hay: &[u8], needle: &[u8], start: usize) -> Option<usize> {
    if needle.is_empty() {
        return None;
    }
    let mut i = start;
    while i + needle.len() <= hay.len() {
        if &hay[i..i + needle.len()] == needle {
            return Some(i);
        }
        i += 1;
    }
    None
}

fn slice_text(src: &str, start: usize, end: usize) -> String {
    if start >= end {
        return String::new();
    }
    src.get(start..end).unwrap_or_default().to_string()
}

/// Blank strings/comments in place and collect the side tables.
pub fn scan_source(src: &str) -> Scan {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut comments: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    let mut strings: BTreeMap<usize, String> = BTreeMap::new();
    let mut line_of = vec![1usize; n + 1];
    let mut ln = 1usize;
    for (i, byte) in b.iter().enumerate() {
        line_of[i] = ln;
        if *byte == b'\n' {
            ln += 1;
        }
    }
    line_of[n] = ln;

    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            comments
                .entry(line_of[i])
                .or_default()
                .push(slice_text(src, i, j));
            for cell in &mut out[i..j] {
                *cell = b' ';
            }
            i = j;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1i64;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            comments
                .entry(line_of[i])
                .or_default()
                .push(slice_text(src, i, j));
            for cell in &mut out[i..j] {
                if *cell != b'\n' {
                    *cell = b' ';
                }
            }
            i = j;
        } else if c == b'"' {
            let j = string_end(b, i + 1);
            let stop = j.saturating_sub(1);
            strings.insert(i, slice_text(src, i + 1, stop));
            if stop > i + 1 {
                for cell in &mut out[i + 1..stop] {
                    if *cell != b'\n' {
                        *cell = b' ';
                    }
                }
            }
            i = j;
        } else if c == b'r' && raw_string_here(b, i) {
            let mut hashes = 0usize;
            let mut j = i + 1;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            let mut close = vec![b'"'];
            close.extend(std::iter::repeat(b'#').take(hashes));
            let end = match find_bytes(b, &close, j + 1) {
                Some(e) => e + close.len(),
                None => n,
            };
            let stop = end.saturating_sub(1 + hashes);
            strings.insert(j, slice_text(src, j + 1, stop));
            if stop > j + 1 {
                for cell in &mut out[j + 1..stop] {
                    if *cell != b'\n' {
                        *cell = b' ';
                    }
                }
            }
            i = end;
        } else if c == b'\'' {
            let j = char_literal_end(b, i);
            if j > 0 {
                for cell in &mut out[i + 1..j - 1] {
                    *cell = b' ';
                }
                i = j;
            } else {
                i += 1; // lifetime
            }
        } else {
            i += 1;
        }
    }
    Scan::new(out, comments, strings, line_of)
}

fn raw_string_here(b: &[u8], i: usize) -> bool {
    if i > 0 && is_ident(b[i - 1]) {
        return false;
    }
    let mut j = i + 1;
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"'
}

fn string_end(b: &[u8], mut i: usize) -> usize {
    let n = b.len();
    while i < n {
        if b[i] == b'\\' {
            i += 2;
        } else if b[i] == b'"' {
            return i + 1;
        } else {
            i += 1;
        }
    }
    n
}

/// End offset past a char literal starting at `b[i] == '\''`, or 0 if
/// this quote starts a lifetime.
fn char_literal_end(b: &[u8], i: usize) -> usize {
    let n = b.len();
    if i + 1 >= n {
        return 0;
    }
    if b[i + 1] == b'\\' {
        let j = i + 2;
        if j < n && b[j] == b'u' {
            return match find_bytes(b, &[b'\''], j) {
                Some(k) => k + 1,
                None => 0,
            };
        }
        if j + 1 < n && b[j + 1] == b'\'' {
            return j + 2;
        }
        return 0;
    }
    if i + 2 < n && b[i + 2] == b'\'' && b[i + 1] != b'\'' {
        return i + 3;
    }
    0
}

/// Whether `code[i..]` starts with `word` on identifier boundaries.
pub fn word_at(code: &[u8], i: usize, word: &str) -> bool {
    let w = word.as_bytes();
    let end = i + w.len();
    if end > code.len() || &code[i..end] != w {
        return false;
    }
    if i > 0 && is_ident(code[i - 1]) {
        return false;
    }
    end >= code.len() || !is_ident(code[end])
}

/// All boundary-respecting offsets of `word` in `code`.
pub fn find_word(code: &[u8], word: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    let mut start = 0usize;
    while let Some(i) = find_bytes(code, word.as_bytes(), start) {
        if word_at(code, i, word) {
            hits.push(i);
        }
        start = i + 1;
    }
    hits
}

fn skip_ws(code: &[u8], mut i: usize) -> usize {
    while i < code.len() && code[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// Offsets of `.name(` sites as `(name_offset, paren_offset)`,
/// whitespace tolerated around the segments.
pub fn method_call_sites(code: &[u8], name: &str) -> Vec<(usize, usize)> {
    let mut hits = Vec::new();
    for i in find_word(code, name) {
        let mut j = i as i64 - 1;
        while j >= 0 && code[j as usize].is_ascii_whitespace() {
            j -= 1;
        }
        if j < 0 || code[j as usize] != b'.' {
            continue;
        }
        let k = skip_ws(code, i + name.len());
        if k < code.len() && code[k] == b'(' {
            hits.push((i, k));
        }
    }
    hits
}

fn dot_before(code: &[u8], i: usize) -> i64 {
    let mut j = i as i64 - 1;
    while j >= 0 && code[j as usize].is_ascii_whitespace() {
        j -= 1;
    }
    j
}

/// Identifier immediately left of the `.` at offset `dot`.
fn receiver_ident(code: &[u8], dot: i64) -> String {
    let mut j = dot - 1;
    while j >= 0 && code[j as usize].is_ascii_whitespace() {
        j -= 1;
    }
    let end = (j + 1) as usize;
    while j >= 0 && is_ident(code[j as usize]) {
        j -= 1;
    }
    let start = (j + 1) as usize;
    String::from_utf8_lossy(&code[start..end]).into_owned()
}

/// `[start, end)` offset ranges of `#[cfg(test)]`-gated items.
pub fn test_regions(code: &[u8]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut start = 0usize;
    while let Some(i) = find_bytes(code, b"#[cfg(test)]", start) {
        let Some(j) = find_bytes(code, b"{", i) else {
            return regions;
        };
        let mut depth = 0i64;
        let mut k = j;
        while k < code.len() {
            if code[k] == b'{' {
                depth += 1;
            } else if code[k] == b'}' {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            k += 1;
        }
        regions.push((i, k + 1));
        start = k + 1;
    }
    regions
}

fn in_regions(regions: &[(usize, usize)], i: usize) -> bool {
    regions.iter().any(|&(a, b)| a <= i && i < b)
}

/// First line to search for an annotation anchored at `line`: the
/// window bounds the distance to the bottom of the comment block; the
/// block itself may extend further up.
fn search_lo(scan: &Scan, line: usize, window: usize) -> usize {
    let lo = line.saturating_sub(window).max(1);
    for l in lo..=line {
        if scan.comment_only(l) {
            let mut top = l;
            while top > 1 && scan.comment_only(top - 1) {
                top -= 1;
            }
            return lo.min(top);
        }
    }
    lo
}

fn comment_body(text: &str) -> &str {
    text.trim_start_matches(|c| matches!(c, '/' | '!' | '*' | ' ' | '\t'))
}

fn comment_in_window(scan: &Scan, line: usize, window: usize, needle: &str) -> bool {
    for l in search_lo(scan, line, window)..=line {
        if let Some(texts) = scan.comments.get(&l) {
            for text in texts {
                if comment_body(text).starts_with(needle) {
                    return true;
                }
            }
        }
    }
    false
}

fn allow_annotation(scan: &Scan, line: usize, what: &str) -> bool {
    let marker = format!("lint: allow({what})");
    for l in search_lo(scan, line, ALLOW_WINDOW)..=line {
        if let Some(texts) = scan.comments.get(&l) {
            for text in texts {
                let body = comment_body(text);
                if let Some(reason) = body.strip_prefix(&marker) {
                    if !reason.trim().is_empty() {
                        return true;
                    }
                }
            }
        }
    }
    false
}

// --------------------------------------------------------------------
// config + rules
// --------------------------------------------------------------------

/// One declared lock in the hierarchy registry.
#[derive(Debug, Clone)]
pub struct LockEntry {
    pub name: String,
    pub field: String,
    pub file: String,
    pub rank: i64,
}

/// The three config files, loaded.
pub struct Config {
    pub seqcst_allow: Vec<String>,
    pub unwrap_allow: Vec<String>,
    pub locks: Vec<LockEntry>,
    pub events: BTreeSet<String>,
}

fn str_list(v: Option<&TomlValue>) -> Vec<String> {
    let mut items = Vec::new();
    if let Some(TomlValue::List(list)) = v {
        for it in list {
            if let TomlValue::Str(s) = it {
                items.push(s.clone());
            }
        }
    }
    items
}

fn str_key(t: &BTreeMap<String, TomlValue>, key: &str) -> Option<String> {
    match t.get(key) {
        Some(TomlValue::Str(s)) => Some(s.clone()),
        _ => None,
    }
}

fn int_key(t: &BTreeMap<String, TomlValue>, key: &str) -> Option<i64> {
    match t.get(key) {
        Some(TomlValue::Int(n)) => Some(*n),
        _ => None,
    }
}

impl Config {
    /// Load `lint.toml`, `locks.toml` and `events.toml` from `dir`.
    pub fn load(dir: &Path) -> Result<Config, String> {
        let lint = load_multiline_toml(&dir.join("lint.toml"))?;
        let locks = load_multiline_toml(&dir.join("locks.toml"))?;
        let events = load_multiline_toml(&dir.join("events.toml"))?;
        let mut lock_entries = Vec::new();
        if let Some(list) = locks.arrays.get("lock") {
            for entry in list {
                let name = str_key(entry, "name")
                    .ok_or("locks.toml entry missing `name`")?;
                let field = str_key(entry, "field")
                    .ok_or("locks.toml entry missing `field`")?;
                let rank = int_key(entry, "rank")
                    .ok_or("locks.toml entry missing `rank`")?;
                let file = str_key(entry, "file").unwrap_or_default();
                lock_entries.push(LockEntry { name, field, file, rank });
            }
        }
        Ok(Config {
            seqcst_allow: str_list(
                lint.tables.get("seqcst").and_then(|t| t.get("allow")),
            ),
            unwrap_allow: str_list(
                lint.tables.get("unwrap").and_then(|t| t.get("allow")),
            ),
            locks: lock_entries,
            events: str_list(events.root.get("events")).into_iter().collect(),
        })
    }
}

/// One rule violation, ready to print as `path:line: [rule] msg`.
#[derive(Debug, Clone)]
pub struct Violation {
    pub path: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

fn path_allowed(path: &str, suffixes: &[String]) -> bool {
    let norm = path.replace('\\', "/");
    suffixes.iter().any(|s| norm.ends_with(s.as_str()))
}

fn lock_entry<'a>(
    locks: &'a [LockEntry],
    path: &str,
    recv: &str,
) -> Option<&'a LockEntry> {
    let norm = path.replace('\\', "/");
    locks
        .iter()
        .find(|e| e.field == recv && norm.contains(e.file.as_str()))
}

fn is_let_bound(code: &[u8], i: usize) -> bool {
    let mut j = i;
    while j > 0 && !matches!(code[j], b';' | b'{' | b'}') {
        j -= 1;
    }
    let mut k = j;
    while k < i {
        if is_ident(code[k]) {
            let mut end = k;
            while end < i && is_ident(code[end]) {
                end += 1;
            }
            if &code[k..end] == b"let" {
                return true;
            }
            k = end;
        } else {
            k += 1;
        }
    }
    false
}

/// Run all five rule families over one file.
pub fn check_file(path: &str, src: &str, cfg: &Config) -> Vec<Violation> {
    let scan = scan_source(src);
    let code = &scan.code;
    let regions = test_regions(code);
    let mut out: Vec<Violation> = Vec::new();

    let line_at = |offset: usize| scan.line_of[offset.min(scan.line_of.len() - 1)];

    // unsafe-safety --------------------------------------------------
    for i in find_word(code, "unsafe") {
        let line = line_at(i);
        if !comment_in_window(&scan, line, SAFETY_WINDOW, "SAFETY:") {
            out.push(Violation {
                path: path.to_string(),
                line,
                rule: "unsafe-safety",
                msg: "`unsafe` without a `// SAFETY:` comment".to_string(),
            });
        }
    }

    // atomic-ordering ------------------------------------------------
    for i in find_word(code, "Ordering") {
        let j = i + "Ordering".len();
        if j + 2 > code.len() || &code[j..j + 2] != b"::" {
            continue;
        }
        let k = j + 2;
        let mut end = k;
        while end < code.len() && is_ident(code[end]) {
            end += 1;
        }
        let variant = String::from_utf8_lossy(&code[k..end]).into_owned();
        if !STRONG_ORDERINGS.contains(&variant.as_str()) {
            continue;
        }
        let line = line_at(i);
        if variant == "SeqCst" && !path_allowed(path, &cfg.seqcst_allow) {
            out.push(Violation {
                path: path.to_string(),
                line,
                rule: "atomic-ordering",
                msg: "`Ordering::SeqCst` outside the lint.toml [seqcst] \
                      allowlist"
                    .to_string(),
            });
        }
        if !comment_in_window(&scan, line, ORDERING_WINDOW, "ordering:") {
            out.push(Violation {
                path: path.to_string(),
                line,
                rule: "atomic-ordering",
                msg: format!(
                    "`Ordering::{variant}` without an `// ordering:` rationale"
                ),
            });
        }
    }

    // unwrap ---------------------------------------------------------
    if !path_allowed(path, &cfg.unwrap_allow) {
        for name in ["unwrap", "expect"] {
            for (i, _paren) in method_call_sites(code, name) {
                if in_regions(&regions, i) {
                    continue;
                }
                if allow_annotation(&scan, line_at(i), "unwrap") {
                    continue;
                }
                out.push(Violation {
                    path: path.to_string(),
                    line: line_at(i),
                    rule: "unwrap",
                    msg: format!(
                        "`.{name}(...)` in library code without \
                         `// lint: allow(unwrap) <reason>`"
                    ),
                });
            }
        }
    }

    // lock-order -----------------------------------------------------
    let mut sites: BTreeMap<usize, String> = BTreeMap::new();
    for (i, _paren) in method_call_sites(code, "lock") {
        if in_regions(&regions, i) {
            continue;
        }
        sites.insert(i, receiver_ident(code, dot_before(code, i)));
    }
    // (name, rank, depth, is_let)
    let mut held: Vec<(String, i64, i64, bool)> = Vec::new();
    let mut depth = 0i64;
    for (i, c) in code.iter().enumerate() {
        match *c {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                held.retain(|h| h.2 <= depth);
            }
            b';' => held.retain(|h| h.3 || h.2 != depth),
            _ => {}
        }
        if let Some(recv) = sites.get(&i) {
            let Some(entry) = lock_entry(&cfg.locks, path, recv) else {
                out.push(Violation {
                    path: path.to_string(),
                    line: line_at(i),
                    rule: "lock-order",
                    msg: format!(
                        "`.lock()` receiver `{recv}` is not in locks.toml"
                    ),
                });
                continue;
            };
            for (hname, hrank, _, _) in &held {
                if entry.rank < *hrank {
                    out.push(Violation {
                        path: path.to_string(),
                        line: line_at(i),
                        rule: "lock-order",
                        msg: format!(
                            "acquires `{}` (rank {}) while holding `{hname}` \
                             (rank {hrank})",
                            entry.name, entry.rank
                        ),
                    });
                }
            }
            held.push((
                entry.name.clone(),
                entry.rank,
                depth,
                is_let_bound(code, i),
            ));
        }
    }

    // telemetry-event ------------------------------------------------
    let mut event_sites: Vec<usize> = Vec::new();
    for (_i, paren) in method_call_sites(code, "event") {
        let j = skip_ws(code, paren + 1);
        if j < code.len() && code[j] == b'"' {
            event_sites.push(j);
        }
    }
    for i in find_word(code, "count_events") {
        let mut j = skip_ws(code, i + "count_events".len());
        if j < code.len() && code[j] == b'(' {
            j = skip_ws(code, j + 1);
            if j < code.len() && code[j] == b'"' {
                event_sites.push(j);
            }
        }
    }
    for (_i, paren) in method_call_sites(code, "str") {
        let j = skip_ws(code, paren + 1);
        if scan.strings.get(&j).map(|s| s.as_str()) != Some("ev") {
            continue;
        }
        let mut k = skip_ws(code, j + 2 + "ev".len());
        if k < code.len() && code[k] == b',' {
            k = skip_ws(code, k + 1);
            if k < code.len() && code[k] == b'"' {
                event_sites.push(k);
            }
        }
    }
    for offset in event_sites {
        if let Some(lit) = scan.strings.get(&offset) {
            if !cfg.events.contains(lit) {
                out.push(Violation {
                    path: path.to_string(),
                    line: line_at(offset),
                    rule: "telemetry-event",
                    msg: format!(
                        "event kind \"{lit}\" is not in events.toml"
                    ),
                });
            }
        }
    }

    out
}

// --------------------------------------------------------------------
// driver
// --------------------------------------------------------------------

/// Expand files/directories into a sorted list of `.rs` files.
pub fn rust_files(paths: &[String]) -> Vec<PathBuf> {
    let mut files = Vec::new();
    for p in paths {
        let pb = PathBuf::from(p);
        if pb.is_file() {
            files.push(pb);
            continue;
        }
        walk(&pb, &mut files);
    }
    files.sort();
    files
}

fn walk(dir: &Path, files: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    let mut children: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    children.sort();
    for path in children {
        if path.is_dir() {
            walk(&path, files);
        } else if path.extension().is_some_and(|e| e == "rs") {
            files.push(path);
        }
    }
}

/// Lint every `.rs` file reachable from `paths` with the config in
/// `config_dir`; returns violations sorted by `(path, line)`.
pub fn run(config_dir: &Path, paths: &[String]) -> Result<Vec<Violation>, String> {
    let cfg = Config::load(config_dir)?;
    let mut violations = Vec::new();
    for path in rust_files(paths) {
        let src = fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let shown = path.display().to_string();
        violations.extend(check_file(&shown, &src, &cfg));
    }
    violations.sort_by_key(|v| (v.path.clone(), v.line));
    Ok(violations)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_subset_round_trip() {
        let doc = parse_toml(
            "top = 3\n[sec]\nallow = [\"a.rs\", \"b.rs\"]\n[[lock]]\nname = \"x\"\nrank = 10\n",
        )
        .unwrap();
        assert_eq!(doc.root.get("top"), Some(&TomlValue::Int(3)));
        let sec = doc.tables.get("sec").unwrap();
        assert_eq!(
            sec.get("allow"),
            Some(&TomlValue::List(vec![
                TomlValue::Str("a.rs".to_string()),
                TomlValue::Str("b.rs".to_string()),
            ]))
        );
        let lock = &doc.arrays.get("lock").unwrap()[0];
        assert_eq!(lock.get("rank"), Some(&TomlValue::Int(10)));
    }

    #[test]
    fn scanner_blanks_strings_and_comments() {
        let scan = scan_source("let x = \"unsafe\"; // unsafe here\n");
        assert!(find_word(&scan.code, "unsafe").is_empty());
        assert_eq!(
            scan.strings.get(&8).map(|s| s.as_str()),
            Some("unsafe")
        );
        assert_eq!(scan.comments.get(&1).map(|v| v.len()), Some(1));
    }

    #[test]
    fn scanner_handles_lifetimes_and_char_literals() {
        let scan = scan_source("fn f<'a>(x: &'a str) -> char { ';' }\n");
        // The char literal body is blanked; the lifetime is untouched.
        assert!(!String::from_utf8_lossy(&scan.code).contains("';'"));
        assert!(String::from_utf8_lossy(&scan.code).contains("'a"));
    }

    #[test]
    fn method_sites_require_a_dot() {
        let code = scan_source("fn lock() {}\nfn f(m: &M) { m.lock(); }\n").code;
        assert_eq!(method_call_sites(&code, "lock").len(), 1);
    }

    #[test]
    fn test_region_detection() {
        let code =
            scan_source("fn a() {}\n#[cfg(test)]\nmod t {\n fn b() {}\n}\n")
                .code;
        let regions = test_regions(&code);
        assert_eq!(regions.len(), 1);
        let b_at = find_word(&code, "b")[0];
        assert!(in_regions(&regions, b_at));
        let a_at = find_word(&code, "a")[0];
        assert!(!in_regions(&regions, a_at));
    }
}
