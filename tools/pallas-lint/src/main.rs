//! CLI driver: `pallas-lint [--config-dir DIR] PATH...`
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage/config error —
//! identical to the `python/pallas_lint.py` mirror.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut config_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut paths: Vec<String> = Vec::new();
    let mut argv = std::env::args().skip(1);
    while let Some(arg) = argv.next() {
        if arg == "--config-dir" {
            match argv.next() {
                Some(dir) => config_dir = PathBuf::from(dir),
                None => {
                    eprintln!("pallas-lint: --config-dir needs a value");
                    return ExitCode::from(2);
                }
            }
        } else if arg == "--help" || arg == "-h" {
            println!("usage: pallas-lint [--config-dir DIR] PATH...");
            return ExitCode::SUCCESS;
        } else {
            paths.push(arg);
        }
    }
    if paths.is_empty() {
        eprintln!("usage: pallas-lint [--config-dir DIR] PATH...");
        return ExitCode::from(2);
    }
    match pallas_lint::run(&config_dir, &paths) {
        Ok(violations) => {
            for v in &violations {
                println!("{}:{}: [{}] {}", v.path, v.line, v.rule, v.msg);
            }
            if violations.is_empty() {
                ExitCode::SUCCESS
            } else {
                println!("pallas-lint: {} violation(s)", violations.len());
                ExitCode::from(1)
            }
        }
        Err(err) => {
            eprintln!("pallas-lint: {err}");
            ExitCode::from(2)
        }
    }
}
