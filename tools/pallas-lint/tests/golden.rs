//! Golden tests: every clean fixture lints clean, every failing
//! fixture trips exactly the rule it was written for, and the crate
//! plus the main tree stay self-clean under the real configs.

use std::path::{Path, PathBuf};

use pallas_lint::{check_file, run, Config, Violation};

fn crate_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_cfg() -> Config {
    Config::load(&crate_dir().join("fixtures/config"))
        .expect("fixture config loads")
}

fn lint_fixture(cfg: &Config, rel: &str) -> Vec<Violation> {
    let path = crate_dir().join(rel);
    let src = std::fs::read_to_string(&path).expect("fixture readable");
    check_file(&path.display().to_string(), &src, cfg)
}

fn rules(violations: &[Violation]) -> Vec<&'static str> {
    violations.iter().map(|v| v.rule).collect()
}

#[test]
fn clean_fixtures_are_clean() {
    let cfg = fixture_cfg();
    for name in [
        "safety.rs",
        "ordering.rs",
        "allowed_seqcst.rs",
        "unwrap_ok.rs",
        "locks_ok.rs",
        "events_ok.rs",
    ] {
        let v = lint_fixture(&cfg, &format!("fixtures/clean/{name}"));
        assert!(v.is_empty(), "{name}: unexpected violations: {v:?}");
    }
}

#[test]
fn missing_safety_trips_unsafe_rule() {
    let v = lint_fixture(&fixture_cfg(), "fixtures/failing/missing_safety.rs");
    assert_eq!(rules(&v), ["unsafe-safety"]);
}

#[test]
fn seqcst_outside_allowlist_trips_ordering_rule() {
    let v = lint_fixture(&fixture_cfg(), "fixtures/failing/seqcst_everywhere.rs");
    assert_eq!(rules(&v), ["atomic-ordering"]);
    assert!(v[0].msg.contains("allowlist"), "msg: {}", v[0].msg);
}

#[test]
fn unjustified_strong_orderings_trip_ordering_rule() {
    let v =
        lint_fixture(&fixture_cfg(), "fixtures/failing/unjustified_ordering.rs");
    assert_eq!(rules(&v), ["atomic-ordering", "atomic-ordering"]);
    assert!(v.iter().any(|x| x.msg.contains("Release")));
    assert!(v.iter().any(|x| x.msg.contains("Acquire")));
}

#[test]
fn bare_unwrap_and_expect_trip_unwrap_rule() {
    let v = lint_fixture(&fixture_cfg(), "fixtures/failing/bare_unwrap.rs");
    assert_eq!(rules(&v), ["unwrap", "unwrap"]);
}

#[test]
fn lock_inversion_reports_both_ranks() {
    let v = lint_fixture(&fixture_cfg(), "fixtures/failing/lock_inversion.rs");
    assert_eq!(rules(&v), ["lock-order"]);
    assert_eq!(
        v[0].msg,
        "acquires `alpha` (rank 10) while holding `beta` (rank 20)"
    );
}

#[test]
fn unregistered_receiver_trips_lock_rule() {
    let v = lint_fixture(&fixture_cfg(), "fixtures/failing/unregistered_lock.rs");
    assert_eq!(rules(&v), ["lock-order"]);
    assert!(v[0].msg.contains("`gamma`"), "msg: {}", v[0].msg);
}

#[test]
fn unknown_event_trips_telemetry_rule() {
    let v = lint_fixture(&fixture_cfg(), "fixtures/failing/unknown_event.rs");
    assert_eq!(rules(&v), ["telemetry-event"]);
    assert!(v[0].msg.contains("\"bogus\""), "msg: {}", v[0].msg);
}

#[test]
fn linter_source_is_self_clean() {
    let src_dir = crate_dir().join("src").display().to_string();
    let v = run(&crate_dir(), &[src_dir]).expect("self-lint runs");
    assert!(v.is_empty(), "self-lint violations: {v:?}");
}

#[test]
fn main_tree_is_clean_under_real_config() {
    let tree = crate_dir().join("../../rust/src");
    if !Path::new(&tree).is_dir() {
        return;
    }
    let v = run(&crate_dir(), &[tree.display().to_string()])
        .expect("tree lint runs");
    assert!(v.is_empty(), "rust/src violations: {v:?}");
}
