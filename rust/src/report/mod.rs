//! Telemetry log analysis (paper §IX: "a read-only analysis notebook
//! that reproduces all tables/curves from logs; analysis is
//! reproducible from logs without exposing proprietary code").
//!
//! `analyze` re-derives every job-level statistic — p50/p95 latency
//! (row-weighted), throughput, (b,k) trajectory, reconfig/mitigation
//! counts, queue-depth and RSS curves — purely from a JSON-lines
//! telemetry file, and renders text curves. `smartdiff-sched analyze
//! run.jsonl` is the CLI entry.

use crate::api::error::SchedError;
use crate::metrics::quantile::weighted_quantile;
use crate::util::json::{parse, Json};

/// One parsed batch record.
#[derive(Debug, Clone)]
pub struct BatchRec {
    pub shard: i64,
    pub submitted: f64,
    pub finished: f64,
    pub latency: f64,
    pub rows: f64,
    pub rss_peak: f64,
    pub b: i64,
    pub k: i64,
    pub queue: i64,
    /// Per-stage pipeline nanoseconds (0 for logs predating the
    /// pipelined-execution fields — they parse as absent).
    pub read_ns: i64,
    pub decode_ns: i64,
    pub align_ns: i64,
    pub diff_ns: i64,
    pub stall_ns: i64,
    /// Control-loop overhead attributed to this batch's round (ns).
    pub sched_ns: i64,
    pub ok: bool,
}

/// The full log, split by record kind.
#[derive(Debug, Default)]
pub struct TelemetryLog {
    pub batches: Vec<BatchRec>,
    pub events: Vec<(String, String, f64)>,
    pub summary: Option<Json>,
}

impl TelemetryLog {
    pub fn parse_str(text: &str) -> Result<TelemetryLog, SchedError> {
        let mut log = TelemetryLog::default();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = parse(line).map_err(|e| {
                SchedError::parse("telemetry", format!("line {}: {e}", i + 1))
            })?;
            let ev = v.get("ev").and_then(|e| e.as_str()).ok_or_else(|| {
                SchedError::parse("telemetry", format!("line {}: missing ev", i + 1))
            })?;
            match ev {
                "batch" => {
                    let f = |k: &str| v.get(k).and_then(|x| x.as_f64());
                    let n = |k: &str| v.get(k).and_then(|x| x.as_i64());
                    log.batches.push(BatchRec {
                        shard: n("shard").unwrap_or(-1),
                        submitted: f("submitted").unwrap_or(0.0),
                        finished: f("finished").unwrap_or(0.0),
                        latency: f("latency").unwrap_or(0.0),
                        rows: f("rows").unwrap_or(0.0),
                        rss_peak: f("rss_peak").unwrap_or(0.0),
                        b: n("b").unwrap_or(0),
                        k: n("k").unwrap_or(0),
                        queue: n("queue").unwrap_or(0),
                        read_ns: n("read_ns").unwrap_or(0),
                        decode_ns: n("decode_ns").unwrap_or(0),
                        align_ns: n("align_ns").unwrap_or(0),
                        diff_ns: n("diff_ns").unwrap_or(0),
                        stall_ns: n("stall_ns").unwrap_or(0),
                        sched_ns: n("sched_ns").unwrap_or(0),
                        ok: v.get("ok").and_then(|x| x.as_bool()).unwrap_or(false),
                    });
                }
                "summary" => log.summary = v.get("job").cloned(),
                kind => log.events.push((
                    kind.to_string(),
                    v.get("detail")
                        .and_then(|d| d.as_str())
                        .unwrap_or("")
                        .to_string(),
                    v.get("t").and_then(|t| t.as_f64()).unwrap_or(0.0),
                )),
            }
        }
        Ok(log)
    }

    pub fn load(path: &str) -> Result<TelemetryLog, SchedError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| SchedError::io(path, format!("read: {e}")))?;
        Self::parse_str(&text)
    }

    /// Row-weighted job-level quantile of batch latency (§V protocol).
    pub fn latency_quantile(&self, q: f64) -> Option<f64> {
        let samples: Vec<(f64, f64)> = self
            .batches
            .iter()
            .filter(|b| b.ok)
            .map(|b| (b.latency, b.rows))
            .collect();
        weighted_quantile(&samples, q)
    }

    pub fn makespan(&self) -> f64 {
        let lo = self
            .batches
            .iter()
            .map(|b| b.submitted)
            .fold(f64::INFINITY, f64::min);
        let hi = self.batches.iter().map(|b| b.finished).fold(0.0, f64::max);
        (hi - lo).max(0.0)
    }

    pub fn throughput_rows_per_s(&self) -> f64 {
        let rows: f64 = self.batches.iter().filter(|b| b.ok).map(|b| b.rows).sum();
        let m = self.makespan();
        if m > 0.0 {
            rows / m
        } else {
            0.0
        }
    }

    pub fn count_events(&self, kind: &str) -> usize {
        self.events.iter().filter(|(k, _, _)| k == kind).count()
    }

    /// Final cumulative total carried by the last event of `kind` with a
    /// `total=N` detail — the chunk-cache counters (`chunk_hit`,
    /// `chunk_spill`, …) log cumulative values, so the last record is
    /// the job-level figure. None if the kind never fired (e.g. the
    /// cache was off or the log predates it).
    pub fn last_event_total(&self, kind: &str) -> Option<u64> {
        self.events
            .iter()
            .rev()
            .find(|(k, _, _)| k == kind)
            .and_then(|(_, d, _)| d.strip_prefix("total="))
            .and_then(|v| v.parse().ok())
    }

    /// Summed pipeline-stage nanoseconds over accepted batches:
    /// `(read, decode, align, diff, stall)`. All zero for logs written
    /// before stage-level telemetry existed.
    pub fn stage_totals(&self) -> (i64, i64, i64, i64, i64) {
        let mut t = (0i64, 0i64, 0i64, 0i64, 0i64);
        for b in self.batches.iter().filter(|b| b.ok) {
            t.0 += b.read_ns;
            t.1 += b.decode_ns;
            t.2 += b.align_ns;
            t.3 += b.diff_ns;
            t.4 += b.stall_ns;
        }
        t
    }

    /// Measured ingest/compute overlap: `1 - stall / (read + decode)`,
    /// clamped to [0, 1]. 0.0 when no I/O time was recorded (fully
    /// in-memory job, or a pre-pipeline log).
    pub fn overlap_ratio(&self) -> f64 {
        let (read, decode, _, _, stall) = self.stage_totals();
        let io = (read + decode) as f64;
        if io <= 0.0 {
            return 0.0;
        }
        (1.0 - stall as f64 / io).clamp(0.0, 1.0)
    }

    /// Total control-loop (scheduler) overhead across all batch rounds,
    /// in seconds — the "overhead" half of the overhead/useful-work
    /// decomposition.
    pub fn sched_overhead_s(&self) -> f64 {
        self.batches.iter().map(|b| b.sched_ns).sum::<i64>() as f64 / 1e9
    }
}

/// Unicode sparkline of a series (the "curves" of §IX, in text form).
pub fn sparkline(values: &[f64], width: usize) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if values.is_empty() || width == 0 {
        return String::new();
    }
    // Downsample to `width` buckets by mean.
    let chunk = (values.len() as f64 / width as f64).max(1.0);
    let mut series = Vec::new();
    let mut i = 0.0;
    while (i as usize) < values.len() && series.len() < width {
        let lo = i as usize;
        let hi = ((i + chunk) as usize).min(values.len()).max(lo + 1);
        series.push(values[lo..hi].iter().sum::<f64>() / (hi - lo) as f64);
        i += chunk;
    }
    let max = series.iter().cloned().fold(f64::MIN, f64::max);
    let min = series.iter().cloned().fold(f64::MAX, f64::min);
    let span = (max - min).max(1e-12);
    series
        .iter()
        .map(|v| BARS[(((v - min) / span) * 7.0).round() as usize])
        .collect()
}

/// Render the full analysis report.
pub fn analyze(log: &TelemetryLog) -> String {
    let mut out = String::new();
    let ok: Vec<&BatchRec> = log.batches.iter().filter(|b| b.ok).collect();
    out.push_str(&format!(
        "batches: {} ok / {} total | makespan: {:.3}s | throughput: {:.0} rows/s\n",
        ok.len(),
        log.batches.len(),
        log.makespan(),
        log.throughput_rows_per_s()
    ));
    out.push_str(&format!(
        "latency: p50={:.4}s p95={:.4}s (row-weighted)\n",
        log.latency_quantile(0.50).unwrap_or(0.0),
        log.latency_quantile(0.95).unwrap_or(0.0)
    ));
    out.push_str(&format!(
        "events: {} reconfigs, {} speculations, {} splits (+{} in-run), \
         {} ooms, gate: {}\n",
        log.count_events("reconfig"),
        log.count_events("speculate"),
        log.count_events("split"),
        log.count_events("split_in_run"),
        log.count_events("oom"),
        log.events
            .iter()
            .find(|(k, _, _)| k == "gate")
            .map(|(_, d, _)| d.as_str())
            .unwrap_or("-")
    ));
    let cache_seen = log.count_events("chunk_hit")
        + log.count_events("chunk_miss")
        + log.count_events("chunk_spill")
        + log.count_events("chunk_unspill")
        + log.count_events("chunk_evict");
    if cache_seen > 0 {
        out.push_str(&format!(
            "cache: hits={} misses={} spills={} unspills={} evicts={}\n",
            log.last_event_total("chunk_hit").unwrap_or(0),
            log.last_event_total("chunk_miss").unwrap_or(0),
            log.last_event_total("chunk_spill").unwrap_or(0),
            log.last_event_total("chunk_unspill").unwrap_or(0),
            log.last_event_total("chunk_evict").unwrap_or(0),
        ));
    }
    let (read, decode, align, diff, stall) = log.stage_totals();
    if read + decode + align + diff + stall > 0 {
        out.push_str(&format!(
            "pipeline: read={:.3}s decode={:.3}s align={:.3}s diff={:.3}s \
             stall={:.3}s overlap={:.2}\n",
            read as f64 / 1e9,
            decode as f64 / 1e9,
            align as f64 / 1e9,
            diff as f64 / 1e9,
            stall as f64 / 1e9,
            log.overlap_ratio()
        ));
    }
    let sched_s = log.sched_overhead_s();
    if sched_s > 0.0 {
        let useful: f64 = ok.iter().map(|b| b.finished - b.submitted).sum();
        out.push_str(&format!(
            "sched_overhead: {:.4}s control-loop vs {:.3}s batch time \
             ({:.2}% of makespan)\n",
            sched_s,
            useful,
            if log.makespan() > 0.0 { 100.0 * sched_s / log.makespan() } else { 0.0 }
        ));
    }
    if !ok.is_empty() {
        let lat: Vec<f64> = ok.iter().map(|b| b.latency).collect();
        let rss: Vec<f64> = ok.iter().map(|b| b.rss_peak).collect();
        let bb: Vec<f64> = ok.iter().map(|b| b.b as f64).collect();
        let kk: Vec<f64> = ok.iter().map(|b| b.k as f64).collect();
        let qq: Vec<f64> = ok.iter().map(|b| b.queue as f64).collect();
        out.push_str(&format!("latency  {}\n", sparkline(&lat, 60)));
        out.push_str(&format!("rss/batch{}\n", sparkline(&rss, 60)));
        out.push_str(&format!(
            "b        {}  ({} -> {})\n",
            sparkline(&bb, 60),
            bb.first().map(|x| *x as i64).unwrap_or(0),
            bb.last().map(|x| *x as i64).unwrap_or(0)
        ));
        out.push_str(&format!(
            "k        {}  ({} -> {})\n",
            sparkline(&kk, 60),
            kk.first().map(|x| *x as i64).unwrap_or(0),
            kk.last().map(|x| *x as i64).unwrap_or(0)
        ));
        out.push_str(&format!("queue    {}\n", sparkline(&qq, 60)));
    }
    if let Some(s) = &log.summary {
        out.push_str(&format!("summary: {}\n", s.to_string()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_log() -> String {
        let mut lines = Vec::new();
        lines.push(
            r#"{"ev":"gate","detail":"backend=inmem ws=1.0GB thr=2.0GB","t":0}"#
                .to_string(),
        );
        for i in 0..10 {
            lines.push(format!(
                r#"{{"ev":"batch","shard":{i},"submitted":{},"finished":{},"latency":{},"rows":1000,"rss_peak":{},"b":500,"k":2,"queue":1,"ok":true}}"#,
                i as f64,
                i as f64 + 1.5,
                1.5,
                1_000_000 + i * 1000
            ));
        }
        lines.push(r#"{"ev":"reconfig","detail":"b 500->600 k 2->2 (increase-b)","t":5}"#.to_string());
        lines.push(r#"{"ev":"summary","job":{"batches":10}}"#.to_string());
        lines.join("\n")
    }

    #[test]
    fn parses_and_rederives_stats() {
        let log = TelemetryLog::parse_str(&demo_log()).unwrap();
        assert_eq!(log.batches.len(), 10);
        assert_eq!(log.count_events("reconfig"), 1);
        assert!((log.latency_quantile(0.95).unwrap() - 1.5).abs() < 1e-9);
        assert!((log.makespan() - 10.5).abs() < 1e-9);
        assert!((log.throughput_rows_per_s() - 10_000.0 / 10.5).abs() < 1.0);
        assert!(log.summary.is_some());
    }

    #[test]
    fn analyze_renders_curves() {
        let log = TelemetryLog::parse_str(&demo_log()).unwrap();
        let report = analyze(&log);
        assert!(report.contains("p95=1.5"));
        assert!(report.contains("1 reconfigs"));
        assert!(report.contains("backend=inmem"));
        assert!(report.contains("latency  "));
    }

    #[test]
    fn sparkline_shapes() {
        assert_eq!(sparkline(&[], 10), "");
        let s = sparkline(&[0.0, 1.0, 2.0, 3.0], 4);
        assert_eq!(s.chars().count(), 4);
        assert!(s.starts_with('▁'));
        assert!(s.ends_with('█'));
        // Downsampling long series.
        let long: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        assert_eq!(sparkline(&long, 60).chars().count(), 60);
    }

    #[test]
    fn pre_pipeline_logs_parse_with_zero_stage_fields() {
        // Logs written before stage-level telemetry have no *_ns keys.
        let log = TelemetryLog::parse_str(&demo_log()).unwrap();
        assert_eq!(log.stage_totals(), (0, 0, 0, 0, 0));
        assert_eq!(log.overlap_ratio(), 0.0);
        assert_eq!(log.sched_overhead_s(), 0.0);
        // And analyze() omits the pipeline/overhead lines entirely.
        let report = analyze(&log);
        assert!(!report.contains("pipeline:"));
        assert!(!report.contains("sched_overhead:"));
    }

    #[test]
    fn analyze_renders_pipeline_decomposition() {
        let mut lines = Vec::new();
        for i in 0..4 {
            lines.push(format!(
                r#"{{"ev":"batch","shard":{i},"submitted":{},"finished":{},"latency":1.0,"rows":500,"rss_peak":1000,"b":100,"k":2,"queue":0,"read_ns":400000000,"decode_ns":100000000,"align_ns":50000000,"diff_ns":300000000,"stall_ns":125000000,"sched_ns":2000000,"ok":true}}"#,
                i as f64,
                i as f64 + 1.0
            ));
        }
        let log = TelemetryLog::parse_str(&lines.join("\n")).unwrap();
        let (read, decode, _, _, stall) = log.stage_totals();
        assert_eq!(read, 1_600_000_000);
        assert_eq!(decode, 400_000_000);
        assert_eq!(stall, 500_000_000);
        // overlap = 1 - 0.5s / 2.0s = 0.75
        assert!((log.overlap_ratio() - 0.75).abs() < 1e-9);
        assert!((log.sched_overhead_s() - 0.008).abs() < 1e-12);
        let report = analyze(&log);
        assert!(report.contains("overlap=0.75"), "{report}");
        assert!(report.contains("sched_overhead: 0.0080s"), "{report}");
    }

    #[test]
    fn cache_counters_rederive_from_cumulative_events() {
        let lines = [
            r#"{"ev":"chunk_miss","detail":"total=4","t":1}"#,
            r#"{"ev":"chunk_hit","detail":"total=2","t":2}"#,
            r#"{"ev":"chunk_hit","detail":"total=9","t":3}"#,
            r#"{"ev":"chunk_spill","detail":"total=1","t":3}"#,
        ];
        let log = TelemetryLog::parse_str(&lines.join("\n")).unwrap();
        // Cumulative: the *last* record carries the job-level figure.
        assert_eq!(log.last_event_total("chunk_hit"), Some(9));
        assert_eq!(log.last_event_total("chunk_miss"), Some(4));
        assert_eq!(log.last_event_total("chunk_evict"), None);
        let report = analyze(&log);
        assert!(
            report.contains("cache: hits=9 misses=4 spills=1"),
            "{report}"
        );
        // A cache-off log renders no cache line at all.
        let off = TelemetryLog::parse_str(&demo_log()).unwrap();
        assert!(!analyze(&off).contains("cache:"));
    }

    #[test]
    fn bad_lines_error_with_location() {
        let err = TelemetryLog::parse_str("not json").unwrap_err();
        assert!(err.to_string().contains("line 1"));
    }
}
