//! Network-facing diff service: a long-lived daemon exposing one
//! [`DiffSession`](crate::api::DiffSession) over TCP.
//!
//! The crate stays zero-dependency: transport is `std::net`, framing is
//! line-delimited JSON built on [`crate::util::json`], and SIGINT
//! handling declares libc's `signal(2)` directly. Submodules:
//!
//! * [`protocol`] — versioned frame grammar, codecs, [`protocol::FrameReader`].
//! * [`server`] — the daemon: accept loop, per-connection threads, job
//!   registry, event forwarding, drain-on-shutdown.
//! * [`client`] — blocking client used by the `submit`/`status`
//!   subcommands and the end-to-end tests.
//! * [`signal`] — std-only Ctrl-C flag shared by `daemon` and long `run`s.
#![warn(missing_docs)]

pub mod client;
pub mod protocol;
pub mod server;
pub mod signal;
