//! Wire protocol for the diff service: line-delimited JSON frames.
//!
//! One frame per `\n`-terminated line, each a single JSON object with a
//! `v` version field. Three frame families:
//!
//! * **Requests** (client → server): `{"v":1,"id":N,"verb":"…",…}`.
//!   `id` is a client-chosen correlation id echoed back in the
//!   response. Verbs: `submit`, `cancel`, `status`, `health`,
//!   `subscribe`, `shutdown`.
//! * **Responses** (server → client): `{"v":1,"re":N,"ok":true,…}` on
//!   success or `{"v":1,"re":N,"ok":false,"error":{…}}` with a typed
//!   [`WireError`]. `re` echoes the request's `id`.
//! * **Events** (server → client, unsolicited): job lifecycle frames
//!   `{"v":1,"ev":"job","job":J,"kind":"…","data":{…}}` mirroring
//!   [`JobEvent`] one-to-one, and one terminal
//!   `{"v":1,"ev":"result","job":J,"ok":…,…}` per subscribed job
//!   carrying the full diff report JSON.
//!
//! Frames longer than [`MAX_FRAME_BYTES`], invalid UTF-8, truncated
//! JSON, wrong versions, and structurally-unknown shapes all decode to
//! a typed [`ProtocolError`] — the server answers them with an error
//! frame instead of dropping the connection. Encoding uses the crate's
//! self-contained JSON writer ([`crate::util::json`]); the crate stays
//! zero-dependency.
#![warn(missing_docs)]

use std::fmt;
use std::io::Read;

use crate::api::error::SchedError;
use crate::api::events::JobEvent;
use crate::util::json::{self, Json, ObjWriter};

/// Protocol version spoken by this build. Frames carrying any other
/// version are rejected with [`ProtocolError::Version`].
pub const PROTOCOL_VERSION: i64 = 1;

/// Hard per-frame size cap. A line that grows past this is discarded
/// through its terminating newline and reported as
/// [`ProtocolError::Oversized`]; the connection survives.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Typed decode failure. Every variant maps to an error frame the
/// server sends back (`WireError::from_protocol`), so a misbehaving
/// client learns *why* its frame was rejected.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtocolError {
    /// The line exceeded [`MAX_FRAME_BYTES`] before its newline.
    Oversized {
        /// Bytes seen before the frame was abandoned.
        len: usize,
    },
    /// The frame bytes are not valid UTF-8.
    Utf8,
    /// The frame is not parseable JSON (includes truncated documents).
    Parse {
        /// Parser diagnostic.
        message: String,
    },
    /// Parsed JSON, but the `v` field is missing or not
    /// [`PROTOCOL_VERSION`].
    Version {
        /// The version the frame carried, if any.
        got: Option<i64>,
    },
    /// Valid versioned JSON that is not a known frame shape (missing
    /// `id`/`verb`/`re`/`ev`, unknown verb, wrong field types…).
    Malformed {
        /// What was wrong.
        message: String,
    },
}

impl ProtocolError {
    /// Stable lowercase tag (doubles as the wire error `kind`).
    pub fn kind(&self) -> &'static str {
        match self {
            ProtocolError::Oversized { .. } => "oversized",
            ProtocolError::Utf8 => "utf8",
            ProtocolError::Parse { .. } => "parse",
            ProtocolError::Version { .. } => "version",
            ProtocolError::Malformed { .. } => "malformed",
        }
    }
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolError::Oversized { len } => {
                write!(f, "frame exceeds {MAX_FRAME_BYTES} bytes (got {len})")
            }
            ProtocolError::Utf8 => write!(f, "frame is not valid utf-8"),
            ProtocolError::Parse { message } => {
                write!(f, "frame is not valid json: {message}")
            }
            ProtocolError::Version { got: Some(v) } => {
                write!(f, "unsupported protocol version {v} (want {PROTOCOL_VERSION})")
            }
            ProtocolError::Version { got: None } => {
                write!(f, "missing protocol version field \"v\"")
            }
            ProtocolError::Malformed { message } => {
                write!(f, "malformed frame: {message}")
            }
        }
    }
}

impl std::error::Error for ProtocolError {}

/// A typed error carried inside an error response frame:
/// `{"kind":…,"message":…,"field":…}`. `kind` is either a
/// [`SchedError`] variant tag (`invalid_config`, `parse`,
/// `schema_align`, `runtime`, `io`, `shard_failed`, `cancelled`,
/// `unsupported`), a [`ProtocolError`] tag (`oversized`, `utf8`,
/// `parse`, `version`, `malformed`), or a service condition
/// (`unknown_job`, `draining`, `busy`, `idle_timeout`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    /// Stable lowercase error class (see type docs).
    pub kind: String,
    /// Human-readable message.
    pub message: String,
    /// Config field path, present iff `kind == "invalid_config"`.
    pub field: Option<String>,
}

impl WireError {
    /// An error with the given class and message.
    pub fn new(kind: impl Into<String>, message: impl Into<String>) -> Self {
        WireError { kind: kind.into(), message: message.into(), field: None }
    }

    /// Encode a [`SchedError`] for the wire, preserving the variant tag
    /// and (for `InvalidConfig`) the offending field path.
    pub fn from_sched(e: &SchedError) -> Self {
        let kind = match e {
            SchedError::InvalidConfig { .. } => "invalid_config",
            SchedError::Parse { .. } => "parse",
            SchedError::SchemaAlign { .. } => "schema_align",
            SchedError::Runtime { .. } => "runtime",
            SchedError::Io { .. } => "io",
            SchedError::ShardFailed { .. } => "shard_failed",
            SchedError::Cancelled => "cancelled",
            SchedError::Unsupported { .. } => "unsupported",
        };
        WireError {
            kind: kind.into(),
            message: e.to_string(),
            field: e.field().map(str::to_string),
        }
    }

    /// Encode a [`ProtocolError`] for the wire.
    pub fn from_protocol(e: &ProtocolError) -> Self {
        WireError { kind: e.kind().into(), message: e.to_string(), field: None }
    }

    /// Best-effort reconstruction of a [`SchedError`] on the client
    /// side. `invalid_config` and `cancelled` round-trip exactly;
    /// everything else lands in the variant matching its tag with the
    /// transported message (source chains do not cross the wire).
    pub fn to_sched(&self) -> SchedError {
        match self.kind.as_str() {
            "invalid_config" => SchedError::invalid(
                self.field.clone().unwrap_or_default(),
                self.message.clone(),
            ),
            "cancelled" => SchedError::Cancelled,
            "parse" => SchedError::parse("<wire>", self.message.clone()),
            "schema_align" => SchedError::schema(self.message.clone()),
            "io" => SchedError::io("<wire>", self.message.clone()),
            "unsupported" => SchedError::unsupported(self.message.clone()),
            _ => SchedError::runtime(format!("{}: {}", self.kind, self.message)),
        }
    }

    fn to_json_str(&self) -> String {
        let mut w = ObjWriter::new()
            .str("kind", &self.kind)
            .str("message", &self.message);
        if let Some(f) = &self.field {
            w = w.str("field", f);
        }
        w.finish()
    }

    fn from_json(v: &Json) -> Result<Self, ProtocolError> {
        Ok(WireError {
            kind: req_str(v, "kind")?.to_string(),
            message: req_str(v, "message")?.to_string(),
            field: v.get("field").and_then(|f| f.as_str()).map(str::to_string),
        })
    }
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.field {
            Some(field) => {
                write!(f, "{}: {} ({})", self.kind, self.message, field)
            }
            None => write!(f, "{}: {}", self.kind, self.message),
        }
    }
}

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Job description carried by a `submit` frame. Exactly one source must
/// be given: synthetic (`rows` + `seed`, the generator workload the
/// `run` subcommand uses) or CSV (`csv_a` + `csv_b` + `schema`, paths
/// resolved on the *daemon's* filesystem). The remaining fields
/// override the daemon's base [`crate::config::SchedulerConfig`] per
/// job.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WireJobSpec {
    /// Synthetic workload: row count.
    pub rows: Option<usize>,
    /// Synthetic workload seed (default 0).
    pub seed: u64,
    /// CSV workload: A-side path on the daemon's filesystem.
    pub csv_a: Option<String>,
    /// CSV workload: B-side path on the daemon's filesystem.
    pub csv_b: Option<String>,
    /// CSV column spec, `name[:key]:type,…` (see `Schema::parse_spec`).
    pub schema: Option<String>,
    /// Backend override (`auto`/`inmem`/`dask`).
    pub backend: Option<String>,
    /// Controller lower batch bound override.
    pub b_min: Option<usize>,
    /// Prefetch override.
    pub prefetch: Option<bool>,
    /// Chunk-cache override (decode-once columnar cache with spill).
    pub cache: Option<bool>,
}

/// A decoded request verb with its arguments.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Submit a job; `subscribe` additionally streams its events and
    /// terminal result to this connection.
    Submit {
        /// What to diff and how.
        spec: WireJobSpec,
        /// Stream events + result to the submitting connection.
        subscribe: bool,
    },
    /// Cooperatively cancel a job by wire id.
    Cancel {
        /// Wire job id (as returned by `submit`).
        job: u64,
    },
    /// Full daemon snapshot: session budget/grants, per-job progress,
    /// accept/dispatch overhead counters.
    Status,
    /// Cheap liveness probe.
    Health,
    /// Stream an existing job's events (history replayed first) and its
    /// terminal result to this connection.
    Subscribe {
        /// Wire job id.
        job: u64,
    },
    /// Ask the daemon to drain and exit (same path as SIGINT).
    Shutdown,
}

/// A request frame: correlation id + verb.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    /// Client-chosen id, echoed as `re` in the response.
    pub id: u64,
    /// The verb and its arguments.
    pub req: Request,
}

/// Encode a request frame as one JSON line (no trailing newline).
pub fn encode_request(frame: &RequestFrame) -> String {
    let w = ObjWriter::new()
        .int("v", PROTOCOL_VERSION)
        .int("id", frame.id as i64);
    match &frame.req {
        Request::Submit { spec, subscribe } => {
            let mut w = w.str("verb", "submit").bool("subscribe", *subscribe);
            if let Some(rows) = spec.rows {
                w = w.int("rows", rows as i64).int("seed", spec.seed as i64);
            }
            if let Some(a) = &spec.csv_a {
                w = w.str("csv_a", a);
            }
            if let Some(b) = &spec.csv_b {
                w = w.str("csv_b", b);
            }
            if let Some(s) = &spec.schema {
                w = w.str("schema", s);
            }
            if let Some(b) = &spec.backend {
                w = w.str("backend", b);
            }
            if let Some(m) = spec.b_min {
                w = w.int("b_min", m as i64);
            }
            if let Some(p) = spec.prefetch {
                w = w.bool("prefetch", p);
            }
            if let Some(c) = spec.cache {
                w = w.bool("cache", c);
            }
            w.finish()
        }
        Request::Cancel { job } => {
            w.str("verb", "cancel").int("job", *job as i64).finish()
        }
        Request::Status => w.str("verb", "status").finish(),
        Request::Health => w.str("verb", "health").finish(),
        Request::Subscribe { job } => {
            w.str("verb", "subscribe").int("job", *job as i64).finish()
        }
        Request::Shutdown => w.str("verb", "shutdown").finish(),
    }
}

/// Decode one request line. All failure modes are typed
/// ([`ProtocolError`]); the caller answers them with an error frame.
pub fn decode_request(line: &str) -> Result<RequestFrame, ProtocolError> {
    let v = parse_versioned(line)?;
    let id = req_u64(&v, "id")?;
    let verb = req_str(&v, "verb")?;
    let req = match verb {
        "submit" => {
            let spec = WireJobSpec {
                rows: opt_usize(&v, "rows")?,
                seed: opt_u64(&v, "seed")?.unwrap_or(0),
                csv_a: opt_string(&v, "csv_a")?,
                csv_b: opt_string(&v, "csv_b")?,
                schema: opt_string(&v, "schema")?,
                backend: opt_string(&v, "backend")?,
                b_min: opt_usize(&v, "b_min")?,
                prefetch: opt_bool(&v, "prefetch")?,
                cache: opt_bool(&v, "cache")?,
            };
            let subscribe = opt_bool(&v, "subscribe")?.unwrap_or(false);
            Request::Submit { spec, subscribe }
        }
        "cancel" => Request::Cancel { job: req_u64(&v, "job")? },
        "status" => Request::Status,
        "health" => Request::Health,
        "subscribe" => Request::Subscribe { job: req_u64(&v, "job")? },
        "shutdown" => Request::Shutdown,
        other => {
            return Err(ProtocolError::Malformed {
                message: format!("unknown verb {other:?}"),
            })
        }
    };
    Ok(RequestFrame { id, req })
}

// ---------------------------------------------------------------------------
// Server frames (responses + events)
// ---------------------------------------------------------------------------

/// A decoded server → client frame.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerFrame {
    /// Success response to request `re`; `body` is the verb-specific
    /// payload object.
    Ok {
        /// Echoed request id.
        re: u64,
        /// Verb-specific payload.
        body: Json,
    },
    /// Error response to request `re` (`re == 0` when the request id
    /// could not be recovered from a malformed frame).
    Err {
        /// Echoed request id, or 0.
        re: u64,
        /// The typed error.
        error: WireError,
    },
    /// One streamed [`JobEvent`].
    Event {
        /// Wire job id the event belongs to.
        job: u64,
        /// The decoded event.
        event: JobEvent,
    },
    /// Terminal frame for a subscribed job: success carries the diff
    /// report JSON (bit-identical to `JobReport::to_json`) and a stats
    /// object; failure carries the typed error.
    Result {
        /// Wire job id.
        job: u64,
        /// Whether the job succeeded.
        ok: bool,
        /// Diff report (present iff `ok`).
        report: Option<Json>,
        /// Scheduler stats (present iff `ok`).
        stats: Option<Json>,
        /// Error (present iff `!ok`).
        error: Option<WireError>,
    },
}

/// Encode a success response (no trailing newline). `body_json` must be
/// a serialized JSON object — it is embedded raw, so report payloads
/// round-trip byte-identically.
pub fn encode_ok(re: u64, body_json: &str) -> String {
    ObjWriter::new()
        .int("v", PROTOCOL_VERSION)
        .int("re", re as i64)
        .bool("ok", true)
        .raw("body", body_json)
        .finish()
}

/// Encode an error response (no trailing newline).
pub fn encode_err(re: u64, error: &WireError) -> String {
    ObjWriter::new()
        .int("v", PROTOCOL_VERSION)
        .int("re", re as i64)
        .bool("ok", false)
        .raw("error", &error.to_json_str())
        .finish()
}

/// Encode one job event frame (no trailing newline).
pub fn encode_event(job: u64, ev: &JobEvent) -> String {
    ObjWriter::new()
        .int("v", PROTOCOL_VERSION)
        .str("ev", "job")
        .int("job", job as i64)
        .str("kind", ev.kind())
        .raw("data", &event_data_json(ev))
        .finish()
}

/// Encode a job's terminal result frame (no trailing newline).
/// `report_json`/`stats_json` are embedded raw (see [`encode_ok`]).
pub fn encode_result(
    job: u64,
    outcome: &Result<(String, String), SchedError>,
) -> String {
    let w = ObjWriter::new()
        .int("v", PROTOCOL_VERSION)
        .str("ev", "result")
        .int("job", job as i64);
    match outcome {
        Ok((report_json, stats_json)) => w
            .bool("ok", true)
            .raw("report", report_json)
            .raw("stats", stats_json)
            .finish(),
        Err(e) => w
            .bool("ok", false)
            .raw("error", &WireError::from_sched(e).to_json_str())
            .finish(),
    }
}

/// Decode one server → client line into a typed [`ServerFrame`].
pub fn decode_server_frame(line: &str) -> Result<ServerFrame, ProtocolError> {
    let v = parse_versioned(line)?;
    if let Some(ev) = v.get("ev").and_then(|e| e.as_str()) {
        let job = req_u64(&v, "job")?;
        return match ev {
            "job" => {
                let kind = req_str(&v, "kind")?;
                let data = v.get("data").cloned().unwrap_or(Json::Null);
                let event = decode_job_event(kind, &data)?;
                Ok(ServerFrame::Event { job, event })
            }
            "result" => {
                let ok = v
                    .get("ok")
                    .and_then(|b| b.as_bool())
                    .ok_or_else(|| malformed("result frame missing ok"))?;
                if ok {
                    Ok(ServerFrame::Result {
                        job,
                        ok,
                        report: v.get("report").cloned(),
                        stats: v.get("stats").cloned(),
                        error: None,
                    })
                } else {
                    let error = v
                        .get("error")
                        .ok_or_else(|| malformed("failed result missing error"))
                        .and_then(WireError::from_json)?;
                    Ok(ServerFrame::Result {
                        job,
                        ok,
                        report: None,
                        stats: None,
                        error: Some(error),
                    })
                }
            }
            other => Err(malformed(&format!("unknown event class {other:?}"))),
        };
    }
    let re = req_u64(&v, "re")?;
    let ok = v
        .get("ok")
        .and_then(|b| b.as_bool())
        .ok_or_else(|| malformed("response missing ok"))?;
    if ok {
        let body = v.get("body").cloned().unwrap_or(Json::Null);
        Ok(ServerFrame::Ok { re, body })
    } else {
        let error = v
            .get("error")
            .ok_or_else(|| malformed("error response missing error"))
            .and_then(WireError::from_json)?;
        Ok(ServerFrame::Err { re, error })
    }
}

/// Serialize a [`JobEvent`]'s payload fields (everything `kind()` does
/// not carry) as a JSON object.
fn event_data_json(ev: &JobEvent) -> String {
    match ev {
        JobEvent::Gated { ws_bytes, available_bytes } => ObjWriter::new()
            .int("ws_bytes", *ws_bytes as i64)
            .int("available_bytes", *available_bytes as i64)
            .finish(),
        JobEvent::Admitted { ws_bytes, granted_bytes, concurrent } => {
            ObjWriter::new()
                .int("ws_bytes", *ws_bytes as i64)
                .int("granted_bytes", *granted_bytes as i64)
                .int("concurrent", *concurrent as i64)
                .finish()
        }
        JobEvent::MemGrant { from_bytes, to_bytes } => ObjWriter::new()
            .int("from_bytes", *from_bytes as i64)
            .int("to_bytes", *to_bytes as i64)
            .finish(),
        JobEvent::Reconfig { b_from, b_to, k_from, k_to, reason } => {
            ObjWriter::new()
                .int("b_from", *b_from as i64)
                .int("b_to", *b_to as i64)
                .int("k_from", *k_from as i64)
                .int("k_to", *k_to as i64)
                .str("reason", reason)
                .finish()
        }
        JobEvent::Backpressure { queue_depth } => ObjWriter::new()
            .int("queue_depth", *queue_depth as i64)
            .finish(),
        JobEvent::Speculation { shard_id } => {
            ObjWriter::new().int("shard_id", *shard_id as i64).finish()
        }
        JobEvent::Split { shard_id, in_run } => ObjWriter::new()
            .int("shard_id", *shard_id as i64)
            .bool("in_run", *in_run)
            .finish(),
        JobEvent::Done { ok } => ObjWriter::new().bool("ok", *ok).finish(),
    }
}

/// Reconstruct a [`JobEvent`] from its wire `kind` tag + data object.
/// Inverse of [`encode_event`]; the round-trip is exact.
pub fn decode_job_event(kind: &str, data: &Json) -> Result<JobEvent, ProtocolError> {
    let u = |key: &str| req_u64(data, key);
    let us = |key: &str| req_u64(data, key).map(|x| x as usize);
    match kind {
        "gated" => Ok(JobEvent::Gated {
            ws_bytes: u("ws_bytes")?,
            available_bytes: u("available_bytes")?,
        }),
        "admitted" => Ok(JobEvent::Admitted {
            ws_bytes: u("ws_bytes")?,
            granted_bytes: u("granted_bytes")?,
            concurrent: us("concurrent")?,
        }),
        "mem_grant" => Ok(JobEvent::MemGrant {
            from_bytes: u("from_bytes")?,
            to_bytes: u("to_bytes")?,
        }),
        "reconfig" => Ok(JobEvent::Reconfig {
            b_from: us("b_from")?,
            b_to: us("b_to")?,
            k_from: us("k_from")?,
            k_to: us("k_to")?,
            reason: req_str(data, "reason")?.to_string(),
        }),
        "backpressure" => {
            Ok(JobEvent::Backpressure { queue_depth: us("queue_depth")? })
        }
        "speculation" => Ok(JobEvent::Speculation { shard_id: u("shard_id")? }),
        "split" => Ok(JobEvent::Split {
            shard_id: u("shard_id")?,
            in_run: data
                .get("in_run")
                .and_then(|b| b.as_bool())
                .ok_or_else(|| malformed("split missing in_run"))?,
        }),
        "done" => Ok(JobEvent::Done {
            ok: data
                .get("ok")
                .and_then(|b| b.as_bool())
                .ok_or_else(|| malformed("done missing ok"))?,
        }),
        other => Err(malformed(&format!("unknown event kind {other:?}"))),
    }
}

/// Best-effort extraction of the request id from a line that failed to
/// decode, so the error frame can still correlate (`0` if unrecoverable).
pub fn salvage_request_id(line: &str) -> u64 {
    json::parse(line)
        .ok()
        .and_then(|v| v.get("id").and_then(|x| x.as_i64()))
        .and_then(|x| u64::try_from(x).ok())
        .unwrap_or(0)
}

// ---------------------------------------------------------------------------
// JSON field helpers
// ---------------------------------------------------------------------------

fn malformed(message: &str) -> ProtocolError {
    ProtocolError::Malformed { message: message.into() }
}

fn parse_versioned(line: &str) -> Result<Json, ProtocolError> {
    if line.len() > MAX_FRAME_BYTES {
        return Err(ProtocolError::Oversized { len: line.len() });
    }
    let v = json::parse(line)
        .map_err(|message| ProtocolError::Parse { message })?;
    if !matches!(v, Json::Obj(_)) {
        return Err(malformed("frame is not a json object"));
    }
    match v.get("v").and_then(|x| x.as_i64()) {
        Some(PROTOCOL_VERSION) => Ok(v),
        got => Err(ProtocolError::Version { got }),
    }
}

fn req_u64(v: &Json, key: &str) -> Result<u64, ProtocolError> {
    v.get(key)
        .and_then(|x| x.as_i64())
        .and_then(|x| u64::try_from(x).ok())
        .ok_or_else(|| malformed(&format!("missing/invalid field {key:?}")))
}

fn req_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, ProtocolError> {
    v.get(key)
        .and_then(|x| x.as_str())
        .ok_or_else(|| malformed(&format!("missing/invalid field {key:?}")))
}

fn opt_u64(v: &Json, key: &str) -> Result<Option<u64>, ProtocolError> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(_) => req_u64(v, key).map(Some),
    }
}

fn opt_usize(v: &Json, key: &str) -> Result<Option<usize>, ProtocolError> {
    Ok(opt_u64(v, key)?.map(|x| x as usize))
}

fn opt_string(v: &Json, key: &str) -> Result<Option<String>, ProtocolError> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => x
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| malformed(&format!("field {key:?} must be a string"))),
    }
}

fn opt_bool(v: &Json, key: &str) -> Result<Option<bool>, ProtocolError> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => x
            .as_bool()
            .map(Some)
            .ok_or_else(|| malformed(&format!("field {key:?} must be a bool"))),
    }
}

// ---------------------------------------------------------------------------
// Frame reader
// ---------------------------------------------------------------------------

/// Outcome of one [`FrameReader::read_frame`] call.
#[derive(Debug, PartialEq, Eq)]
pub enum ReadOutcome {
    /// One complete line (newline stripped, UTF-8 validated).
    Frame(String),
    /// The peer closed the stream cleanly (no buffered partial frame).
    Eof,
    /// No complete frame arrived before the reader's timeout (the
    /// socket's read timeout, when set). The connection is still alive.
    Timeout,
}

/// Incremental newline-delimited frame reader over any [`Read`].
///
/// Enforces [`MAX_FRAME_BYTES`] with resynchronization: an oversized
/// line is reported once as [`ProtocolError::Oversized`] and its
/// remaining bytes are discarded through the terminating newline, after
/// which reading resumes normally — one hostile frame cannot take the
/// connection down. Invalid UTF-8 and truncated trailing frames are
/// typed errors too; the stream stays consumable after each.
pub struct FrameReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
    /// Inside an oversized line, discarding until its newline.
    discarding: bool,
}

impl<R: Read> FrameReader<R> {
    /// Wrap a byte stream.
    pub fn new(inner: R) -> Self {
        FrameReader { inner, buf: Vec::new(), discarding: false }
    }

    /// Read until one complete frame, EOF, or timeout. `Err` values are
    /// per-frame (the next call continues with the following frame).
    pub fn read_frame(&mut self) -> Result<ReadOutcome, ProtocolError> {
        loop {
            // Resync: drop bytes of an oversized line through its '\n'.
            if self.discarding {
                match self.buf.iter().position(|&b| b == b'\n') {
                    Some(i) => {
                        self.buf.drain(..=i);
                        self.discarding = false;
                    }
                    None => self.buf.clear(),
                }
            }
            if !self.discarding {
                if let Some(i) = self.buf.iter().position(|&b| b == b'\n') {
                    let mut line: Vec<u8> = self.buf.drain(..=i).collect();
                    line.pop(); // '\n'
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    if line.is_empty() {
                        continue; // blank keep-alive line
                    }
                    if line.len() > MAX_FRAME_BYTES {
                        return Err(ProtocolError::Oversized { len: line.len() });
                    }
                    return match String::from_utf8(line) {
                        Ok(s) => Ok(ReadOutcome::Frame(s)),
                        Err(_) => Err(ProtocolError::Utf8),
                    };
                }
                if self.buf.len() > MAX_FRAME_BYTES {
                    let len = self.buf.len();
                    self.buf.clear();
                    self.discarding = true;
                    return Err(ProtocolError::Oversized { len });
                }
            }
            let mut chunk = [0u8; 8192];
            match self.inner.read(&mut chunk) {
                Ok(0) => {
                    if self.buf.is_empty() || self.discarding {
                        return Ok(ReadOutcome::Eof);
                    }
                    self.buf.clear();
                    return Err(ProtocolError::Parse {
                        message: "truncated frame at end of stream".into(),
                    });
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) => match e.kind() {
                    std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut => {
                        return Ok(ReadOutcome::Timeout)
                    }
                    std::io::ErrorKind::Interrupted => continue,
                    _ => return Ok(ReadOutcome::Eof),
                },
            }
        }
    }
}
