//! Std-only SIGINT hook: a process-wide flag the drive/accept loops poll.
//!
//! The crate is zero-dependency, so instead of a signal-handling crate
//! this declares libc's `signal(2)` directly — `std` already links
//! libc on unix, no new dependency is introduced. The handler only
//! stores to an `AtomicBool` (async-signal-safe); everything else
//! (session cancel, daemon drain, exit code 130) happens on normal
//! threads that poll [`interrupted`].
//!
//! On non-unix targets installation is a no-op and [`interrupted`]
//! never fires; Ctrl-C then terminates the process the default way.
#![warn(missing_docs)]

use std::sync::atomic::{AtomicBool, Ordering};

/// Set by the SIGINT handler; never cleared except by [`reset`].
static INTERRUPTED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    use super::{AtomicBool, Ordering, INTERRUPTED};

    const SIGINT: i32 = 2;

    extern "C" {
        // Returns the previous disposition, which may be SIG_DFL (0) or
        // SIG_IGN (1) — typed usize, not a fn pointer, on purpose.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    extern "C" fn on_sigint(_signum: i32) {
        // ordering: SeqCst kept deliberately (allowlisted). This store
        // runs in async-signal context where the usual happens-before
        // reasoning is murky; the flag is cold, so the strongest
        // ordering buys simplicity at no measurable cost.
        INTERRUPTED.store(true, Ordering::SeqCst);
    }

    pub(super) fn install() {
        static ONCE: AtomicBool = AtomicBool::new(false);
        // Relaxed: pure idempotence latch. Nothing is published by
        // winning the swap — `signal(2)` does its own synchronization —
        // and double-install would be harmless anyway.
        if !ONCE.swap(true, Ordering::Relaxed) {
            // SAFETY: `signal` is the libc prototype declared above;
            // SIGINT is a valid signal number and `on_sigint` is an
            // `extern "C" fn(i32)` that only performs an async-signal-
            // safe atomic store. std links libc on every unix target,
            // so the symbol resolves. The returned previous disposition
            // is deliberately discarded (it may be SIG_DFL/SIG_IGN,
            // not a callable pointer).
            let _ = unsafe { signal(SIGINT, on_sigint) };
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub(super) fn install() {}
}

/// Install the SIGINT handler (idempotent). Call once at the top of a
/// long-running subcommand; afterwards [`interrupted`] turns true when
/// the user hits Ctrl-C.
pub fn install_sigint() {
    imp::install();
}

/// Whether SIGINT has fired since [`install_sigint`] (or [`reset`]).
pub fn interrupted() -> bool {
    // ordering: SeqCst to pair with the handler's store (allowlisted
    // file — see lint.toml); the flag is polled at 100ms granularity,
    // so strength is free.
    INTERRUPTED.load(Ordering::SeqCst)
}

/// Clear the flag (test support).
pub fn reset() {
    // ordering: SeqCst to match the handler/poll pair above.
    INTERRUPTED.store(false, Ordering::SeqCst);
}

/// Conventional shell exit code for "terminated by SIGINT" (128 + 2).
pub const SIGINT_EXIT_CODE: i32 = 130;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_starts_clear_and_resets() {
        install_sigint();
        reset();
        assert!(!interrupted());
    }
}
