//! Std-only SIGINT hook: a process-wide flag the drive/accept loops poll.
//!
//! The crate is zero-dependency, so instead of a signal-handling crate
//! this declares libc's `signal(2)` directly — `std` already links
//! libc on unix, no new dependency is introduced. The handler only
//! stores to an `AtomicBool` (async-signal-safe); everything else
//! (session cancel, daemon drain, exit code 130) happens on normal
//! threads that poll [`interrupted`].
//!
//! On non-unix targets installation is a no-op and [`interrupted`]
//! never fires; Ctrl-C then terminates the process the default way.
#![warn(missing_docs)]

use std::sync::atomic::{AtomicBool, Ordering};

/// Set by the SIGINT handler; never cleared except by [`reset`].
static INTERRUPTED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    use super::{AtomicBool, Ordering, INTERRUPTED};

    const SIGINT: i32 = 2;

    extern "C" {
        // Returns the previous disposition, which may be SIG_DFL (0) or
        // SIG_IGN (1) — typed usize, not a fn pointer, on purpose.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    extern "C" fn on_sigint(_signum: i32) {
        INTERRUPTED.store(true, Ordering::SeqCst);
    }

    pub(super) fn install() {
        static ONCE: AtomicBool = AtomicBool::new(false);
        if !ONCE.swap(true, Ordering::SeqCst) {
            let _ = unsafe { signal(SIGINT, on_sigint) };
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub(super) fn install() {}
}

/// Install the SIGINT handler (idempotent). Call once at the top of a
/// long-running subcommand; afterwards [`interrupted`] turns true when
/// the user hits Ctrl-C.
pub fn install_sigint() {
    imp::install();
}

/// Whether SIGINT has fired since [`install_sigint`] (or [`reset`]).
pub fn interrupted() -> bool {
    INTERRUPTED.load(Ordering::SeqCst)
}

/// Clear the flag (test support).
pub fn reset() {
    INTERRUPTED.store(false, Ordering::SeqCst);
}

/// Conventional shell exit code for "terminated by SIGINT" (128 + 2).
pub const SIGINT_EXIT_CODE: i32 = 130;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_starts_clear_and_resets() {
        install_sigint();
        reset();
        assert!(!interrupted());
    }
}
