//! The diff daemon: a TCP accept loop exposing one [`DiffSession`] to
//! remote clients over the line-delimited JSON protocol.
//!
//! Thread anatomy (all plain `std::thread`, zero dependencies):
//!
//! * **accept loop** (the thread calling [`Daemon::run`]) — nonblocking
//!   accept + capacity check. Its per-iteration work time is accounted
//!   in `accept_ns` with idle sleeps excluded, the same
//!   overhead-vs-wait split the scheduler loop uses for `sched_ns`
//!   (arXiv 2010.11105: the control plane itself must be measured).
//! * **per connection**: a *reader* thread (frame decode + verb
//!   dispatch; its handling time accrues to `dispatch_ns`) and a
//!   *writer* thread draining an mpsc channel of encoded frames, so
//!   responses, streamed events, and terminal results from many threads
//!   serialize onto the socket without interleaving.
//! * **per job**: a *monitor* thread that joins the [`JobHandle`] and
//!   records the terminal result frame in the registry, and one
//!   *forwarder* thread per subscription streaming every
//!   [`JobEvent`](crate::api::JobEvent) (history replayed first, so a
//!   subscriber arriving after admission still sees `Gated`/`Admitted`)
//!   followed by the result frame.
//!
//! Lifecycle: malformed frames are answered with typed error frames
//! (never a dropped connection); idle connections without active
//! subscriptions are closed after `service.idle_timeout_secs`; shutdown
//! (SIGINT or the `shutdown` verb) drains — stop accepting, refuse new
//! submits with a `draining` error, cancel or await running jobs per
//! `service.drain`, and join every monitor/forwarder so no submitted
//! job goes un-answered.
#![warn(missing_docs)]

use std::collections::BTreeMap;
use std::io::Write as _;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{DiffSession, JobBuilder, JobControl, JobSpec};
use crate::api::error::SchedError;
use crate::api::events::JobState;
use crate::config::{BackendChoice, DrainPolicy, SchedulerConfig};
use crate::data::generator::{generate_pair, GenSpec};
use crate::data::io::{CsvFileSource, InMemorySource, TableSource};
use crate::data::schema::Schema;
use crate::sched::scheduler::JobStats;
use crate::sched::telemetry::Telemetry;
use crate::service::protocol::{
    decode_request, encode_err, encode_event, encode_ok, encode_result,
    salvage_request_id, FrameReader, ReadOutcome, Request, RequestFrame,
    WireError, WireJobSpec,
};
use crate::util::json::ObjWriter;

/// Accept-loop poll interval while no connection is pending (excluded
/// from `accept_ns`, mirroring the scheduler loop's wait exclusion).
const ACCEPT_POLL: Duration = Duration::from_millis(5);
/// Reader-side socket timeout: the tick at which idle/shutdown checks run.
const READ_TICK: Duration = Duration::from_millis(200);

/// Lifetime counters a drained daemon reports.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DaemonSummary {
    /// Connections accepted over the daemon's lifetime.
    pub connections_served: u64,
    /// Jobs submitted over the wire.
    pub jobs_submitted: u64,
    /// Jobs answered with a terminal result frame (equals
    /// `jobs_submitted` after a clean drain).
    pub jobs_completed: u64,
    /// Accept-loop work time, idle sleeps excluded (nanoseconds).
    pub accept_ns: u64,
    /// Summed request-handling time across all connections (nanoseconds).
    pub dispatch_ns: u64,
}

/// One wire-visible job in the registry.
struct JobEntry {
    control: Arc<JobControl>,
    /// Encoded terminal `result` frame, set by the job's monitor thread.
    result_frame: Option<String>,
}

/// State shared by the accept loop and every per-connection/per-job thread.
struct Shared {
    cfg: SchedulerConfig,
    session: DiffSession,
    /// Set by SIGINT, the `shutdown` verb, or [`Daemon::shutdown_flag`]
    /// holders; the accept loop exits on the next poll.
    shutdown: Arc<AtomicBool>,
    /// Refuse new submits (set at the start of the drain, and by the
    /// `shutdown` verb so in-flight connections see it immediately).
    draining: AtomicBool,
    /// Drain has finished with jobs; readers should close their
    /// connections on the next tick.
    closing: AtomicBool,
    jobs: Mutex<BTreeMap<u64, JobEntry>>,
    /// Signals `result_frame` publications to waiting forwarders.
    result_cv: Condvar,
    conn_count: AtomicUsize,
    connections_served: AtomicU64,
    jobs_submitted: AtomicU64,
    jobs_completed: AtomicU64,
    accept_ns: AtomicU64,
    dispatch_ns: AtomicU64,
    monitors: Mutex<Vec<JoinHandle<()>>>,
    forwarders: Mutex<Vec<JoinHandle<()>>>,
}

/// A bound, not-yet-running daemon. [`Daemon::bind`] validates the
/// config and claims the socket; [`Daemon::run`] blocks serving it
/// until the shutdown flag is raised, then drains.
pub struct Daemon {
    listener: TcpListener,
    local_addr: SocketAddr,
    shared: Arc<Shared>,
}

impl Daemon {
    /// Validate `cfg`, bind `cfg.service.bind_addr`, and build the
    /// session owning `cfg.caps`. Port 0 binds an ephemeral port —
    /// check [`Daemon::local_addr`] (how the tests avoid collisions).
    pub fn bind(cfg: SchedulerConfig) -> Result<Daemon, SchedError> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.service.bind_addr)
            .map_err(|e| {
                SchedError::io(cfg.service.bind_addr.clone(), format!("bind: {e}"))
            })?;
        listener.set_nonblocking(true).map_err(|e| {
            SchedError::io(cfg.service.bind_addr.clone(), format!("nonblock: {e}"))
        })?;
        let local_addr = listener.local_addr().map_err(|e| {
            SchedError::io(cfg.service.bind_addr.clone(), format!("addr: {e}"))
        })?;
        let session = DiffSession::new(cfg.caps);
        Ok(Daemon {
            listener,
            local_addr,
            shared: Arc::new(Shared {
                cfg,
                session,
                shutdown: Arc::new(AtomicBool::new(false)),
                draining: AtomicBool::new(false),
                closing: AtomicBool::new(false),
                jobs: Mutex::new(BTreeMap::new()),
                result_cv: Condvar::new(),
                conn_count: AtomicUsize::new(0),
                connections_served: AtomicU64::new(0),
                jobs_submitted: AtomicU64::new(0),
                jobs_completed: AtomicU64::new(0),
                accept_ns: AtomicU64::new(0),
                dispatch_ns: AtomicU64::new(0),
                monitors: Mutex::new(Vec::new()),
                forwarders: Mutex::new(Vec::new()),
            }),
        })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Shared shutdown flag: store `true` (e.g. from a SIGINT watcher)
    /// and [`Daemon::run`] begins its drain on the next accept poll.
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shared.shutdown)
    }

    /// Serve until the shutdown flag is raised, then drain and return
    /// the lifetime counters. A clean drain answers every submitted job
    /// (`jobs_completed == jobs_submitted`).
    pub fn run(self) -> Result<DaemonSummary, SchedError> {
        let mut conns: Vec<(JoinHandle<()>, JoinHandle<()>)> = Vec::new();
        loop {
            // Relaxed: shutdown/draining/closing are latch flags polled
            // on sleep/timeout loops; they publish no data (all job
            // state moves through mutexes/channels) so eventual
            // visibility is sufficient everywhere they are touched.
            if self.shared.shutdown.load(Ordering::Relaxed) {
                break;
            }
            let t0 = Instant::now();
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    self.admit_connection(stream, &mut conns);
                    self.accrue_accept(t0);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    self.accrue_accept(t0);
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => {
                    self.accrue_accept(t0);
                    std::thread::sleep(ACCEPT_POLL);
                }
            }
        }

        // --- drain ---
        let shared = &self.shared;
        // Relaxed: a submit racing this flag is handled by the second
        // drain pass below, not by ordering strength.
        shared.draining.store(true, Ordering::Relaxed);
        // Two passes: a submit that raced the draining flag may add a
        // monitor/forwarder after the first join sweep; the second pass
        // (after the readers are gone and no submit can race) catches it.
        for _pass in 0..2 {
            if shared.cfg.service.drain == DrainPolicy::Cancel {
                // lint: allow(unwrap) jobs-registry sections are plain
                // map ops; a poisoned registry is a torn daemon state
                // where failing fast beats serving wrong answers
                let jobs = shared.jobs.lock().unwrap();
                for entry in jobs.values() {
                    if entry.result_frame.is_none() {
                        entry.control.request_cancel();
                    }
                }
            }
            join_all(&shared.monitors);
            join_all(&shared.forwarders);
            shared.closing.store(true, Ordering::Relaxed);
        }
        for (reader, writer) in conns {
            let _ = reader.join();
            let _ = writer.join();
        }
        join_all(&shared.monitors);
        join_all(&shared.forwarders);

        let summary = DaemonSummary {
            connections_served: shared.connections_served.load(Ordering::Relaxed),
            jobs_submitted: shared.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: shared.jobs_completed.load(Ordering::Relaxed),
            accept_ns: shared.accept_ns.load(Ordering::Relaxed),
            dispatch_ns: shared.dispatch_ns.load(Ordering::Relaxed),
        };
        // Control-plane telemetry: one `service` record beside the job
        // telemetry (own file — job sinks truncate-on-open the shared
        // path, so the daemon must not reopen it).
        if let Some(p) = &shared.cfg.telemetry_path {
            if let Ok(mut t) = Telemetry::to_file(&format!("{p}.service")) {
                t.service(
                    &ObjWriter::new()
                        .int("connections", summary.connections_served as i64)
                        .int("jobs_submitted", summary.jobs_submitted as i64)
                        .int("jobs_completed", summary.jobs_completed as i64)
                        .int("accept_ns", summary.accept_ns as i64)
                        .int("dispatch_ns", summary.dispatch_ns as i64)
                        .finish(),
                );
                t.flush();
            }
        }
        Ok(summary)
    }

    fn accrue_accept(&self, t0: Instant) {
        self.shared
            .accept_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Capacity-check an accepted socket; over the limit it is answered
    /// with a typed `busy` frame and closed instead of silently dropped.
    fn admit_connection(
        &self,
        stream: TcpStream,
        conns: &mut Vec<(JoinHandle<()>, JoinHandle<()>)>,
    ) {
        let shared = &self.shared;
        // Relaxed: conn_count is an approximate admission gauge — the
        // accept loop is the only incrementer-reader pair that matters
        // and it is single-threaded; reader-exit decrements may lag a
        // poll tick, which only delays re-admission.
        if shared.conn_count.load(Ordering::Relaxed)
            >= shared.cfg.service.max_connections
        {
            let mut s = stream;
            let frame = encode_err(
                0,
                &WireError::new("busy", "connection limit reached, retry later"),
            );
            let _ = s.write_all(frame.as_bytes());
            let _ = s.write_all(b"\n");
            let _ = s.shutdown(Shutdown::Both);
            return;
        }
        if let Ok(pair) = spawn_connection(Arc::clone(shared), stream) {
            conns.push(pair);
        }
    }
}

/// Join and drop every handle currently in `slot` (more may be pushed
/// concurrently; callers sweep again once pushers are quiesced).
fn join_all(slot: &Mutex<Vec<JoinHandle<()>>>) {
    loop {
        // lint: allow(unwrap) slot sections are a bare Vec push/pop and
        // cannot panic, so the mutex cannot be poisoned
        let handle = slot.lock().unwrap().pop();
        match handle {
            Some(h) => {
                let _ = h.join();
            }
            None => break,
        }
    }
}

/// Start the reader/writer thread pair for one accepted connection.
fn spawn_connection(
    shared: Arc<Shared>,
    stream: TcpStream,
) -> std::io::Result<(JoinHandle<()>, JoinHandle<()>)> {
    stream.set_read_timeout(Some(READ_TICK))?;
    let write_half = stream.try_clone()?;
    shared.conn_count.fetch_add(1, Ordering::Relaxed);
    shared.connections_served.fetch_add(1, Ordering::Relaxed);

    // Writer: single consumer of this connection's outgoing frames, so
    // concurrent producers (reader responses, forwarder events) never
    // interleave bytes on the socket.
    let (out_tx, out_rx) = mpsc::channel::<String>();
    let writer = std::thread::spawn(move || {
        let mut w = std::io::BufWriter::new(write_half);
        for frame in out_rx {
            if w.write_all(frame.as_bytes()).is_err()
                || w.write_all(b"\n").is_err()
                || w.flush().is_err()
            {
                break;
            }
        }
        if let Ok(s) = w.into_inner() {
            let _ = s.shutdown(Shutdown::Both);
        }
    });

    let reader = std::thread::spawn(move || {
        reader_loop(&shared, stream, out_tx);
        shared.conn_count.fetch_sub(1, Ordering::Relaxed);
    });
    Ok((reader, writer))
}

/// Per-connection frame loop: decode, dispatch, answer. Protocol errors
/// are answered with typed error frames and the loop continues — one
/// hostile frame never takes the connection down.
fn reader_loop(shared: &Arc<Shared>, stream: TcpStream, out: mpsc::Sender<String>) {
    let idle_limit = Duration::from_secs(shared.cfg.service.idle_timeout_secs);
    let active_subs = Arc::new(AtomicUsize::new(0));
    let mut frames = FrameReader::new(stream);
    let mut last_activity = Instant::now();
    loop {
        match frames.read_frame() {
            Ok(ReadOutcome::Frame(line)) => {
                last_activity = Instant::now();
                let t0 = Instant::now();
                handle_frame(shared, &line, &out, &active_subs);
                shared
                    .dispatch_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            Ok(ReadOutcome::Timeout) => {
                // Relaxed: drain/idle latches checked once per 200ms
                // read tick; see the accept loop's rationale.
                if shared.closing.load(Ordering::Relaxed) {
                    break;
                }
                if shared.cfg.service.idle_timeout_secs > 0
                    && active_subs.load(Ordering::Relaxed) == 0
                    && last_activity.elapsed() >= idle_limit
                {
                    let _ = out.send(encode_err(
                        0,
                        &WireError::new("idle_timeout", "closing idle connection"),
                    ));
                    break;
                }
            }
            Ok(ReadOutcome::Eof) => break,
            Err(pe) => {
                last_activity = Instant::now();
                let _ = out.send(encode_err(0, &WireError::from_protocol(&pe)));
            }
        }
    }
}

fn unknown_job(job: u64) -> WireError {
    WireError::new("unknown_job", format!("no job {job} in this daemon"))
}

/// Decode and dispatch one request frame.
fn handle_frame(
    shared: &Arc<Shared>,
    line: &str,
    out: &mpsc::Sender<String>,
    active_subs: &Arc<AtomicUsize>,
) {
    let RequestFrame { id, req } = match decode_request(line) {
        Ok(f) => f,
        Err(pe) => {
            let _ = out.send(encode_err(
                salvage_request_id(line),
                &WireError::from_protocol(&pe),
            ));
            return;
        }
    };
    match req {
        Request::Submit { spec, subscribe } => {
            // Relaxed: refusing submits during drain is best-effort by
            // design — the drain's second join pass catches the race, so
            // flag visibility needs no ordering.
            if shared.draining.load(Ordering::Relaxed) {
                let _ = out.send(encode_err(
                    id,
                    &WireError::new(
                        "draining",
                        "daemon is draining and not accepting jobs",
                    ),
                ));
                return;
            }
            match submit_job(shared, &spec) {
                Ok(job) => {
                    // Response before the forwarder spawns, so the
                    // submit ack always precedes the job's event frames.
                    let _ = out.send(encode_ok(
                        id,
                        &ObjWriter::new().int("job", job as i64).finish(),
                    ));
                    if subscribe {
                        spawn_forwarder(shared, job, out.clone(), active_subs);
                    }
                }
                Err(e) => {
                    let _ = out.send(encode_err(id, &WireError::from_sched(&e)));
                }
            }
        }
        Request::Cancel { job } => {
            let control = shared
                .jobs
                .lock()
                // lint: allow(unwrap) registry poison ⇒ fail fast (see
                // drain pass)
                .unwrap()
                .get(&job)
                .map(|e| Arc::clone(&e.control));
            match control {
                Some(c) => {
                    c.request_cancel();
                    let _ = out.send(encode_ok(
                        id,
                        &ObjWriter::new()
                            .int("job", job as i64)
                            .bool("cancel_requested", true)
                            .finish(),
                    ));
                }
                None => {
                    let _ = out.send(encode_err(id, &unknown_job(job)));
                }
            }
        }
        Request::Status => {
            let _ = out.send(encode_ok(id, &status_json(shared)));
        }
        Request::Health => {
            let body = ObjWriter::new()
                .bool("healthy", true)
                .bool("draining", shared.draining.load(Ordering::Relaxed))
                .int("active_jobs", shared.session.active_jobs() as i64)
                .finish();
            let _ = out.send(encode_ok(id, &body));
        }
        Request::Subscribe { job } => {
            // lint: allow(unwrap) registry poison ⇒ fail fast (see
            // drain pass)
            let known = shared.jobs.lock().unwrap().contains_key(&job);
            if known {
                let _ = out.send(encode_ok(
                    id,
                    &ObjWriter::new()
                        .int("job", job as i64)
                        .bool("subscribed", true)
                        .finish(),
                ));
                spawn_forwarder(shared, job, out.clone(), active_subs);
            } else {
                let _ = out.send(encode_err(id, &unknown_job(job)));
            }
        }
        Request::Shutdown => {
            let _ = out.send(encode_ok(
                id,
                &ObjWriter::new().bool("draining", true).finish(),
            ));
            // Relaxed: latch stores; the accept loop picks them up on
            // its next 5ms poll.
            shared.draining.store(true, Ordering::Relaxed);
            shared.shutdown.store(true, Ordering::Relaxed);
        }
    }
}

/// Build sources + per-job config overrides from a wire spec, submit to
/// the session, register the job, and start its monitor thread.
fn submit_job(shared: &Arc<Shared>, w: &WireJobSpec) -> Result<u64, SchedError> {
    let spec = build_job_spec(&shared.cfg, w)?;
    let mut handle = shared.session.submit(spec)?;
    let job = handle.id();
    // lint: allow(unwrap) registry poison ⇒ fail fast (see drain pass)
    shared.jobs.lock().unwrap().insert(
        job,
        JobEntry { control: handle.control(), result_frame: None },
    );
    shared.jobs_submitted.fetch_add(1, Ordering::Relaxed);

    let shared_cl = Arc::clone(shared);
    let monitor = std::thread::spawn(move || {
        let outcome = handle
            .join()
            .map(|r| (r.report.to_json(), stats_json(&r.stats)));
        let frame = encode_result(job, &outcome);
        {
            // lint: allow(unwrap) registry poison ⇒ fail fast (see
            // drain pass)
            let mut jobs = shared_cl.jobs.lock().unwrap();
            if let Some(entry) = jobs.get_mut(&job) {
                entry.result_frame = Some(frame);
            }
        }
        shared_cl.jobs_completed.fetch_add(1, Ordering::Relaxed);
        shared_cl.result_cv.notify_all();
    });
    // lint: allow(unwrap) monitor-slot sections are a bare Vec
    // push/pop and cannot panic, so the mutex cannot be poisoned
    shared.monitors.lock().unwrap().push(monitor);
    Ok(job)
}

/// Translate a wire job spec into a validated [`JobSpec`]. Exactly one
/// source: synthetic (`rows`) or CSV paths on the daemon's filesystem.
fn build_job_spec(
    base: &SchedulerConfig,
    w: &WireJobSpec,
) -> Result<JobSpec, SchedError> {
    let mut cfg = base.clone();
    if let Some(b) = &w.backend {
        cfg.backend = BackendChoice::parse(b)?;
    }
    if let Some(b_min) = w.b_min {
        cfg.policy.b_min = b_min;
    }
    if let Some(p) = w.prefetch {
        cfg.prefetch = p;
    }
    if let Some(c) = w.cache {
        cfg.cache.enabled = c;
    }
    cfg.seed = w.seed;
    let (a, b): (Arc<dyn TableSource>, Arc<dyn TableSource>) =
        match (w.rows, &w.csv_a, &w.csv_b) {
            (Some(rows), None, None) => {
                let (ta, tb, _) = generate_pair(&GenSpec {
                    rows,
                    seed: w.seed,
                    ..GenSpec::default()
                });
                (
                    Arc::new(InMemorySource::new(ta)),
                    Arc::new(InMemorySource::new(tb)),
                )
            }
            (None, Some(pa), Some(pb)) => {
                let spec = w.schema.as_deref().ok_or_else(|| {
                    SchedError::invalid("schema", "csv jobs need a schema spec")
                })?;
                let schema = Schema::parse_spec(spec)?;
                (
                    Arc::new(CsvFileSource::open(Path::new(pa), schema.clone())?),
                    Arc::new(CsvFileSource::open(Path::new(pb), schema)?),
                )
            }
            _ => {
                return Err(SchedError::invalid(
                    "submit",
                    "exactly one job source: rows (synthetic) or csv_a+csv_b",
                ))
            }
        };
    JobBuilder::from_config(cfg, a, b).build()
}

/// Stream one job's events (history replay + live) and then its
/// terminal result frame to one connection.
fn spawn_forwarder(
    shared: &Arc<Shared>,
    job: u64,
    out: mpsc::Sender<String>,
    active_subs: &Arc<AtomicUsize>,
) {
    // lint: allow(unwrap) registry poison ⇒ fail fast (see drain pass)
    let control = match shared.jobs.lock().unwrap().get(&job) {
        Some(e) => Arc::clone(&e.control),
        None => return,
    };
    // Relaxed: active_subs is a gauge read by the idle-timeout check;
    // its only consequence is when an idle connection closes.
    active_subs.fetch_add(1, Ordering::Relaxed);
    let subs = Arc::clone(active_subs);
    let shared_cl = Arc::clone(shared);
    let handle = std::thread::spawn(move || {
        let rx = control.subscribe();
        let mut saw_done = false;
        while let Ok(ev) = rx.recv() {
            let done = ev.kind() == "done";
            if out.send(encode_event(job, &ev)).is_err() {
                // Client gone; writer is down. Nothing left to stream.
                subs.fetch_sub(1, Ordering::Relaxed);
                return;
            }
            if done {
                saw_done = true;
                break;
            }
        }
        if saw_done {
            // The Done event precedes the monitor's join returning; wait
            // for the result frame to be recorded, then deliver it.
            // lint: allow(unwrap) registry poison ⇒ fail fast (see
            // drain pass)
            let mut jobs = shared_cl.jobs.lock().unwrap();
            loop {
                if let Some(frame) =
                    jobs.get(&job).and_then(|e| e.result_frame.clone())
                {
                    let _ = out.send(frame);
                    break;
                }
                let (guard, _) = shared_cl
                    .result_cv
                    .wait_timeout(jobs, Duration::from_millis(200))
                    // lint: allow(unwrap) wait_timeout errs only if the
                    // registry mutex is poisoned ⇒ fail fast
                    .unwrap();
                jobs = guard;
            }
        }
        subs.fetch_sub(1, Ordering::Relaxed);
    });
    // lint: allow(unwrap) forwarder-slot critical sections are a bare
    // Vec push/pop and cannot panic, so the mutex cannot be poisoned
    shared.forwarders.lock().unwrap().push(handle);
}

fn state_name(s: JobState) -> &'static str {
    match s {
        JobState::Pending => "pending",
        JobState::Gated => "gated",
        JobState::Running => "running",
        JobState::Done => "done",
        JobState::Failed => "failed",
        JobState::Cancelled => "cancelled",
    }
}

/// Serialize the wire subset of [`JobStats`] for result frames.
fn stats_json(s: &JobStats) -> String {
    ObjWriter::new()
        .str("backend", &s.backend)
        .str("policy", &s.policy)
        .num("makespan_secs", s.makespan_secs)
        .num("p50_latency", s.p50_latency)
        .num("p95_latency", s.p95_latency)
        .int("peak_rss_bytes", s.peak_rss_bytes as i64)
        .num("throughput_rows_per_s", s.throughput_rows_per_s)
        .int("reconfigs", s.reconfigs as i64)
        .int("ooms", s.ooms as i64)
        .int("carved_shards", s.carved_shards as i64)
        .int("batches", s.batches as i64)
        .int("sched_overhead_ns", s.sched_overhead_ns as i64)
        .int("cache_hits", s.cache_hits as i64)
        .int("cache_misses", s.cache_misses as i64)
        .int("cache_spills", s.cache_spills as i64)
        .int("cache_unspills", s.cache_unspills as i64)
        .int("cache_evicts", s.cache_evicts as i64)
        .int("source_reads", s.source_reads as i64)
        .finish()
}

/// The `status` snapshot: session budget/grants, per-job state +
/// progress (incl. `staged_bytes`), and control-plane overhead counters.
fn status_json(shared: &Shared) -> String {
    let mut grants = String::from("[");
    for (i, (job, bytes)) in shared.session.mem_grants().iter().enumerate() {
        if i > 0 {
            grants.push(',');
        }
        grants.push_str(
            &ObjWriter::new()
                .int("job", *job as i64)
                .int("grant_bytes", *bytes as i64)
                .finish(),
        );
    }
    grants.push(']');

    let mut jobs_json = String::from("[");
    {
        // lint: allow(unwrap) registry poison ⇒ fail fast (see drain
        // pass)
        let jobs = shared.jobs.lock().unwrap();
        for (i, (id, entry)) in jobs.iter().enumerate() {
            if i > 0 {
                jobs_json.push(',');
            }
            let p = entry.control.progress();
            let progress = ObjWriter::new()
                .int("rows_total", p.rows_total as i64)
                .int("rows_done", p.rows_done as i64)
                .int("batches", p.batches as i64)
                .int("current_b", p.current_b as i64)
                .int("current_k", p.current_k as i64)
                .int("rss_bytes", p.rss_bytes as i64)
                .int("staged_bytes", p.staged_bytes as i64)
                .int("peak_rss_bytes", p.peak_rss_bytes as i64)
                .int("reconfigs", p.reconfigs as i64)
                .int("cache_hits", p.cache_hits as i64)
                .int("cache_misses", p.cache_misses as i64)
                .int("cache_resident_bytes", p.cache_resident_bytes as i64)
                .str("backend", &p.backend)
                .finish();
            jobs_json.push_str(
                &ObjWriter::new()
                    .int("job", *id as i64)
                    .str("state", state_name(entry.control.state()))
                    .bool("answered", entry.result_frame.is_some())
                    .raw("progress", &progress)
                    .finish(),
            );
        }
    }
    jobs_json.push(']');

    ObjWriter::new()
        // Relaxed: status is an observability snapshot; every field is
        // allowed to be a poll-tick stale.
        .bool("draining", shared.draining.load(Ordering::Relaxed))
        .int("connections", shared.conn_count.load(Ordering::Relaxed) as i64)
        .int(
            "jobs_submitted",
            shared.jobs_submitted.load(Ordering::Relaxed) as i64,
        )
        .int(
            "jobs_completed",
            shared.jobs_completed.load(Ordering::Relaxed) as i64,
        )
        .int("active_jobs", shared.session.active_jobs() as i64)
        .int("mem_budget_bytes", shared.session.mem_budget() as i64)
        .int("committed_bytes", shared.session.committed_bytes() as i64)
        .raw("mem_grants", &grants)
        .int("accept_ns", shared.accept_ns.load(Ordering::Relaxed) as i64)
        .int("dispatch_ns", shared.dispatch_ns.load(Ordering::Relaxed) as i64)
        .raw("jobs", &jobs_json)
        .finish()
}
