//! Blocking client for the diff daemon's wire protocol.
//!
//! One [`ServiceClient`] owns one TCP connection. Requests are
//! correlated by id; event and result frames that arrive while a
//! response is awaited are buffered and replayed in order by
//! [`ServiceClient::next_event`], so interleaved streams never drop
//! frames. Used by the `submit`/`status` CLI subcommands and the
//! end-to-end tests; the smoke job talks the same protocol from python.
#![warn(missing_docs)]

use std::collections::VecDeque;
use std::io::Write as _;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::api::error::SchedError;
use crate::api::events::JobEvent;
use crate::service::protocol::{
    decode_server_frame, encode_request, FrameReader, ReadOutcome, Request,
    RequestFrame, ServerFrame, WireError, WireJobSpec,
};
use crate::util::json::Json;

/// How long a single request waits for its response before giving up.
const RESPONSE_DEADLINE: Duration = Duration::from_secs(30);

/// A subscribed job's full wire-side outcome.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Every streamed event, in emission order (history replay included).
    pub events: Vec<JobEvent>,
    /// Whether the job succeeded.
    pub ok: bool,
    /// Diff report (present iff `ok`), bit-identical to the in-process
    /// `JobReport::to_json` output.
    pub report: Option<Json>,
    /// Scheduler stats object (present iff `ok`).
    pub stats: Option<Json>,
    /// Typed error (present iff `!ok`).
    pub error: Option<WireError>,
}

/// A blocking connection to a running daemon.
pub struct ServiceClient {
    stream: TcpStream,
    frames: FrameReader<TcpStream>,
    next_id: u64,
    pending: VecDeque<ServerFrame>,
}

impl ServiceClient {
    /// Connect to `addr` (e.g. `127.0.0.1:7711`).
    pub fn connect(addr: &str) -> Result<ServiceClient, SchedError> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| SchedError::io(addr, format!("connect: {e}")))?;
        stream
            .set_read_timeout(Some(Duration::from_millis(200)))
            .map_err(|e| SchedError::io(addr, format!("timeout: {e}")))?;
        let read_half = stream
            .try_clone()
            .map_err(|e| SchedError::io(addr, format!("clone: {e}")))?;
        Ok(ServiceClient {
            stream,
            frames: FrameReader::new(read_half),
            next_id: 1,
            pending: VecDeque::new(),
        })
    }

    /// Submit a job; returns the wire job id. With `subscribe` the
    /// daemon streams the job's events + result to this connection
    /// (collect them with [`ServiceClient::wait_result`]).
    pub fn submit(
        &mut self,
        spec: WireJobSpec,
        subscribe: bool,
    ) -> Result<u64, SchedError> {
        let body = self.request(Request::Submit { spec, subscribe })?;
        body.get("job")
            .and_then(|j| j.as_i64())
            .map(|j| j as u64)
            .ok_or_else(|| SchedError::runtime("submit response missing job id"))
    }

    /// Request cooperative cancellation of `job`.
    pub fn cancel(&mut self, job: u64) -> Result<(), SchedError> {
        self.request(Request::Cancel { job }).map(|_| ())
    }

    /// Fetch the daemon's full status snapshot.
    pub fn status(&mut self) -> Result<Json, SchedError> {
        self.request(Request::Status)
    }

    /// Cheap liveness probe.
    pub fn health(&mut self) -> Result<Json, SchedError> {
        self.request(Request::Health)
    }

    /// Subscribe to an existing job's event stream (history replayed
    /// first) and terminal result.
    pub fn subscribe(&mut self, job: u64) -> Result<(), SchedError> {
        self.request(Request::Subscribe { job }).map(|_| ())
    }

    /// Ask the daemon to drain and exit.
    pub fn shutdown_server(&mut self) -> Result<(), SchedError> {
        self.request(Request::Shutdown).map(|_| ())
    }

    /// Pop the next streamed frame (event or result) if one is buffered
    /// or arrives within one read tick; `None` means nothing yet.
    pub fn next_event(&mut self) -> Result<Option<ServerFrame>, SchedError> {
        if let Some(f) = self.pending.pop_front() {
            return Ok(Some(f));
        }
        match self.read_one()? {
            Some(ServerFrame::Err { error, .. }) => Err(error.to_sched()),
            other => Ok(other),
        }
    }

    /// Drain `job`'s stream until its terminal result frame, returning
    /// the ordered events plus the outcome.
    pub fn wait_result(
        &mut self,
        job: u64,
        timeout: Duration,
    ) -> Result<JobOutcome, SchedError> {
        let deadline = Instant::now() + timeout;
        let mut events = Vec::new();
        loop {
            let frame = match self.next_event()? {
                Some(f) => f,
                None => {
                    if Instant::now() >= deadline {
                        return Err(SchedError::runtime(format!(
                            "timed out waiting for job {job} result"
                        )));
                    }
                    continue;
                }
            };
            match frame {
                ServerFrame::Event { job: j, event } if j == job => {
                    events.push(event);
                }
                ServerFrame::Result { job: j, ok, report, stats, error }
                    if j == job =>
                {
                    return Ok(JobOutcome { events, ok, report, stats, error });
                }
                other => self.pending.push_back(other),
            }
        }
    }

    /// Send one request and wait for its correlated response body.
    fn request(&mut self, req: Request) -> Result<Json, SchedError> {
        let id = self.next_id;
        self.next_id += 1;
        let line = encode_request(&RequestFrame { id, req });
        self.stream
            .write_all(line.as_bytes())
            .and_then(|_| self.stream.write_all(b"\n"))
            .map_err(|e| SchedError::runtime(format!("send: {e}")))?;
        let deadline = Instant::now() + RESPONSE_DEADLINE;
        loop {
            match self.read_one()? {
                Some(ServerFrame::Ok { re, body }) if re == id => {
                    return Ok(body);
                }
                // re == 0 covers connection-level rejections (busy,
                // malformed-frame answers) that cannot echo our id.
                Some(ServerFrame::Err { re, error }) if re == id || re == 0 => {
                    return Err(error.to_sched());
                }
                Some(other) => self.pending.push_back(other),
                None => {
                    if Instant::now() >= deadline {
                        return Err(SchedError::runtime(
                            "timed out waiting for daemon response",
                        ));
                    }
                }
            }
        }
    }

    /// Read one frame off the socket; `None` on a quiet read tick.
    fn read_one(&mut self) -> Result<Option<ServerFrame>, SchedError> {
        match self.frames.read_frame() {
            Ok(ReadOutcome::Frame(line)) => decode_server_frame(&line)
                .map(Some)
                .map_err(|e| SchedError::parse("server frame", e.to_string())),
            Ok(ReadOutcome::Timeout) => Ok(None),
            Ok(ReadOutcome::Eof) => {
                Err(SchedError::runtime("daemon closed the connection"))
            }
            Err(e) => Err(SchedError::parse("server frame", e.to_string())),
        }
    }
}
