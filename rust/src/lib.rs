//! smartdiff-sched: adaptive execution scheduler for the SmartDiff
//! differencing engine (CS.DC 2025 reproduction).
pub mod config;
pub mod data;
pub mod engine;
pub mod exec;
pub mod runtime;
pub mod metrics;
pub mod sched;
pub mod baselines;
pub mod sim;
pub mod bench;
pub mod cli;
pub mod report;
pub mod util;
