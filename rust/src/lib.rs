//! smartdiff-sched: adaptive execution scheduler for the SmartDiff
//! differencing engine (CS.DC 2025 reproduction), exposed as a
//! multi-job service.
//!
//! # The service API: `DiffSession` + `JobBuilder`
//!
//! The crate's public surface is the [`api`] module. A [`api::DiffSession`]
//! is a long-lived facade owning one machine budget ([`config::Caps`]:
//! memory + CPU caps); jobs are described with the validating
//! [`api::JobBuilder`] and admitted concurrently against that budget:
//!
//! ```no_run
//! use std::sync::Arc;
//! use smartdiff_sched::api::{DiffSession, JobBuilder};
//! use smartdiff_sched::config::Caps;
//! use smartdiff_sched::data::generator::{generate_pair, GenSpec};
//! use smartdiff_sched::data::io::InMemorySource;
//!
//! let session = DiffSession::new(Caps { mem_cap_bytes: 4_000_000_000, cpu_cap: 8 });
//! let (a, b, _) = generate_pair(&GenSpec { rows: 50_000, ..GenSpec::default() });
//! let job = JobBuilder::new(
//!     Arc::new(InMemorySource::new(a)),
//!     Arc::new(InMemorySource::new(b)),
//! )
//! .atol(1e-9)
//! .build()?;
//!
//! let mut handle = session.submit(job)?;          // non-blocking
//! let progress = handle.progress();               // rows done, (b,k), RSS
//! for event in handle.events() {                  // typed decisions
//!     println!("{event}");                        // Admitted/Gated/Reconfig/...
//! }
//! let result = handle.join()?;                    // Result<JobResult, SchedError>
//! # Ok::<(), smartdiff_sched::api::SchedError>(())
//! ```
//!
//! Admission reuses the paper's working-set estimate (Eq. 1) per job: a
//! job whose estimate does not fit the budget left by running jobs
//! waits in the `Gated` state, so N concurrent jobs share one memory
//! cap with zero accounted OOMs. The session re-partitions its budget
//! as jobs enter and leave — CPU shares through `Backend::set_workers`,
//! and **elastic memory grants** through `Backend::set_mem_budget`:
//! every admit/completion (and any runtime
//! [`api::DiffSession::set_mem_budget`] resize) shrinks running jobs'
//! grants toward their admission charges or re-expands them, with the
//! per-instant sum of grants never exceeding the budget. A scheduler
//! loop that observes a shrunken grant mid-flight tightens its safety
//! envelope immediately (down-stepping the batch size when needed),
//! drains accounted usage under the new grant, and only then re-caps
//! the backend's accounting ledger — cap changes without accounted
//! OOMs. All fallible entry points return the typed [`api::SchedError`]
//! (no stringly-typed errors on the public surface).
//!
//! The historical one-shot entry point `sched::scheduler::run_job` is
//! **deprecated-but-stable**: it now opens a single-job session,
//! submits, and joins — a solo job receives the full budget, preserving
//! the legacy behaviour bit-for-bit.
//!
//! # Engine
//!
//! The per-shard Δ work is columnar end-to-end (typed gathers,
//! vectorized alignment hashing, per-worker scratch reuse) so the
//! adaptive layer tunes real work rather than per-cell dispatch and
//! allocator churn — see the "Engine hot path" notes in [`engine`].

// Style lints are silenced crate-wide so `cargo clippy -- -D warnings`
// (CI) enforces only the correctness-relevant classes in this
// numeric-kernel-heavy codebase.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::type_complexity,
    clippy::field_reassign_with_default,
    clippy::collapsible_else_if,
    clippy::manual_flatten
)]

pub mod api;
pub mod config;
pub mod data;
pub mod engine;
pub mod exec;
pub mod runtime;
pub mod metrics;
pub mod sched;
pub mod service;
pub mod baselines;
pub mod sim;
pub mod bench;
pub mod cli;
pub mod report;
pub mod util;
