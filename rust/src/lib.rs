//! smartdiff-sched: adaptive execution scheduler for the SmartDiff
//! differencing engine (CS.DC 2025 reproduction).
//!
//! The per-shard Δ work is columnar end-to-end (typed gathers,
//! vectorized alignment hashing, per-worker scratch reuse) so the
//! adaptive layer tunes real work rather than per-cell dispatch and
//! allocator churn — see the "Engine hot path" notes in [`engine`].
pub mod config;
pub mod data;
pub mod engine;
pub mod exec;
pub mod runtime;
pub mod metrics;
pub mod sched;
pub mod baselines;
pub mod sim;
pub mod bench;
pub mod cli;
pub mod report;
pub mod util;
