//! §V baseline policies: fixed grid and the two-stage warm-up heuristic.
//! Both implement `TuningPolicy` so every policy runs through the exact
//! same scheduler loop — differences in Tables I–III come from the
//! policy alone, not from harness asymmetry.

use crate::sched::controller::{PolicyEnv, PolicyStep, Signals, TuningPolicy};

/// Fixed (b, k) for the whole job — the paper's fixed-grid baseline.
/// Deliberately safety-unaware: an aggressive fixed config can OOM,
/// which is part of what Table II/§VI measure.
pub struct FixedPolicy {
    pub b: usize,
    pub k: usize,
}

impl FixedPolicy {
    pub fn new(b: usize, k: usize) -> Self {
        FixedPolicy { b, k }
    }
    /// The paper's fixed grid: b ∈ {25k, 50k, 100k, 250k} × k ∈ {4, 8, 16}.
    pub fn paper_grid() -> Vec<(usize, usize)> {
        let mut grid = Vec::new();
        for b in [25_000, 50_000, 100_000, 250_000] {
            for k in [4, 8, 16] {
                grid.push((b, k));
            }
        }
        grid
    }
}

impl TuningPolicy for FixedPolicy {
    fn name(&self) -> &'static str {
        "fixed"
    }
    fn initial(&mut self, _env: &PolicyEnv) -> (usize, usize) {
        (self.b, self.k)
    }
    fn step(&mut self, _s: &Signals, _env: &PolicyEnv) -> PolicyStep {
        PolicyStep { b: self.b, k: self.k, changed: false, clamped: false, reason: "fixed" }
    }
}

/// Two-stage warm-up heuristic (paper §V: "warm-up grid then best"):
/// probe each grid configuration for `probe_batches` completions, score
/// it by mean latency per row, then lock the winner for the rest of the
/// job. Reacts once; cannot adapt to drift or memory pressure.
pub struct HeuristicPolicy {
    grid: Vec<(usize, usize)>,
    probe_batches: u64,
    /// (config index, completions seen in it, sum of per-row latencies).
    cursor: usize,
    seen_in_config: u64,
    scores: Vec<f64>,
    samples: Vec<u64>,
    locked: Option<(usize, usize)>,
    last_completed: u64,
}

impl HeuristicPolicy {
    pub fn new(grid: Vec<(usize, usize)>, probe_batches: u64) -> Self {
        let n = grid.len();
        HeuristicPolicy {
            grid,
            probe_batches: probe_batches.max(1),
            cursor: 0,
            seen_in_config: 0,
            scores: vec![0.0; n],
            samples: vec![0; n],
            locked: None,
            last_completed: 0,
        }
    }

    pub fn paper_default() -> Self {
        // Probe a sub-grid (the paper's warm-up is "tuned": coarse grid,
        // short probes).
        let grid = vec![
            (25_000, 8),
            (50_000, 8),
            (100_000, 8),
            (100_000, 16),
            (250_000, 16),
        ];
        HeuristicPolicy::new(grid, 3)
    }

    pub fn locked_config(&self) -> Option<(usize, usize)> {
        self.locked
    }

    fn lock_best(&mut self) -> (usize, usize) {
        let mut best = 0;
        let mut best_score = f64::INFINITY;
        for (i, (&sum, &n)) in self.scores.iter().zip(&self.samples).enumerate() {
            if n == 0 {
                continue;
            }
            let score = sum / n as f64;
            if score < best_score {
                best_score = score;
                best = i;
            }
        }
        let cfg = self.grid[best];
        self.locked = Some(cfg);
        cfg
    }
}

impl TuningPolicy for HeuristicPolicy {
    fn name(&self) -> &'static str {
        "heuristic"
    }
    fn initial(&mut self, _env: &PolicyEnv) -> (usize, usize) {
        self.grid[0]
    }
    fn step(&mut self, s: &Signals, _env: &PolicyEnv) -> PolicyStep {
        if let Some((b, k)) = self.locked {
            return PolicyStep { b, k, changed: false, clamped: false, reason: "locked" };
        }
        // Score the active config with the latest window p50 (per-batch
        // latency normalized by the probe's batch size).
        let new_completions = s.completed.saturating_sub(self.last_completed);
        self.last_completed = s.completed;
        if new_completions > 0 && s.p50 > 0.0 {
            let (b, _) = self.grid[self.cursor];
            self.scores[self.cursor] += (s.p50 / b as f64) * new_completions as f64;
            self.samples[self.cursor] += new_completions;
            self.seen_in_config += new_completions;
        }
        if self.seen_in_config >= self.probe_batches {
            self.seen_in_config = 0;
            self.cursor += 1;
            if self.cursor >= self.grid.len() {
                let (b, k) = self.lock_best();
                return PolicyStep { b, k, changed: true, clamped: false, reason: "lock-best" };
            }
            let (b, k) = self.grid[self.cursor];
            return PolicyStep { b, k, changed: true, clamped: false, reason: "probe-next" };
        }
        let (b, k) = self.grid[self.cursor];
        PolicyStep { b, k, changed: false, clamped: false, reason: "probing" }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Caps, Policy};

    fn env() -> PolicyEnv {
        PolicyEnv {
            caps: Caps::default(),
            policy: Policy::default(),
            b_max_safe: 1_000_000,
            base_rss: 0.0,
            job_rows: 100_000_000,
            b_hint: 100_000,
        }
    }

    fn sig(completed: u64, p50: f64) -> Signals {
        Signals { completed, p50, p95: p50 * 1.2, ..Default::default() }
    }

    #[test]
    fn fixed_never_changes() {
        let mut p = FixedPolicy::new(50_000, 8);
        assert_eq!(p.initial(&env()), (50_000, 8));
        for i in 0..20 {
            let s = p.step(&sig(i, 1.0), &env());
            assert!(!s.changed);
            assert_eq!((s.b, s.k), (50_000, 8));
        }
    }

    #[test]
    fn paper_grid_is_4x3() {
        assert_eq!(FixedPolicy::paper_grid().len(), 12);
    }

    #[test]
    fn heuristic_probes_then_locks_best() {
        let grid = vec![(10_000, 4), (20_000, 4), (40_000, 4)];
        let mut p = HeuristicPolicy::new(grid, 2);
        let e = env();
        assert_eq!(p.initial(&e), (10_000, 4));
        // Feed per-batch p50s that make the middle config the best per
        // row: 10k->0.2s (20µs/row), 20k->0.2s (10µs/row), 40k->0.8s
        // (20µs/row).
        let mut completed = 0;
        let p50s = [0.2, 0.2, 0.8];
        let mut cursor = 0;
        loop {
            completed += 1;
            let step = p.step(&sig(completed, p50s[cursor.min(2)]), &e);
            if step.reason == "probe-next" {
                cursor += 1;
            }
            if step.reason == "lock-best" {
                assert_eq!((step.b, step.k), (20_000, 4));
                break;
            }
            assert!(completed < 50, "never locked");
        }
        // Stays locked forever after.
        let s = p.step(&sig(completed + 1, 9.9), &e);
        assert_eq!(s.reason, "locked");
        assert_eq!(p.locked_config(), Some((20_000, 4)));
    }
}
