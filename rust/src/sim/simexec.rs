//! Discrete-event simulated backend: a 32-core / 64 GB / SSD virtual
//! testbed (paper §V hardware; substitution documented in DESIGN.md
//! §4.2). Implements `exec::Backend`, so the scheduler under test runs
//! its real control loop against a machine this container does not
//! have. Batch cost follows the same Eq. 2/Eq. 3 family the paper
//! posits, with constants calibrated from the real engine's
//! microbenchmarks, plus lognormal noise and straggler injection.

use std::collections::VecDeque;

use crate::engine::delta::ShardMemStats;
use crate::engine::microbench::CostConstants;
use crate::engine::verdict::{BatchOutcome, RowCounts, VerdictCounts};
use crate::exec::backend::{Backend, BatchError, BatchReport, ShardSpec};
use crate::util::rng::Rng;

/// Which backend the simulator is imitating (same trade-offs as the
/// real `exec` backends: inmem = low overhead / shared memory pool;
/// dask-like = task-graph overhead / per-worker arenas / chunked peaks).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SimProfile {
    InMem,
    DaskLike { chunk_rows: usize },
}

#[derive(Debug, Clone)]
pub struct SimParams {
    pub cores: usize,
    pub mem_cap: u64,
    /// Aggregate read bandwidth, shared by concurrent readers (bytes/s).
    pub read_bw: f64,
    /// Bytes per aligned row (both sides).
    pub w_hat: f64,
    pub ncols: f64,
    pub consts: CostConstants,
    pub base_rss: u64,
    /// Lognormal sigma on batch duration.
    pub noise_sigma: f64,
    /// Straggler injection probability and multiplier range.
    pub straggler_p: f64,
    pub straggler_mult: (f64, f64),
    /// Memory-model coefficients (Eq. 3 family).
    pub mem_beta0: f64,
    pub mem_alpha: f64,
    pub profile: SimProfile,
    pub seed: u64,
}

impl SimParams {
    /// The paper's testbed with defaults calibrated from the real engine.
    pub fn paper_testbed(
        w_hat: f64,
        ncols: f64,
        consts: CostConstants,
        profile: SimProfile,
        seed: u64,
    ) -> Self {
        SimParams {
            cores: 32,
            mem_cap: 64_000_000_000,
            read_bw: 2.5e9,
            w_hat,
            ncols,
            consts,
            base_rss: 200_000_000,
            // Calibrated so the paper's τ=2, m=2 policy sees its
            // reported reconfig rate (5–10/job): occasional 2–4×
            // stragglers over ~10% lognormal jitter.
            noise_sigma: 0.10,
            straggler_p: 0.012,
            straggler_mult: (2.0, 4.0),
            mem_beta0: 16.0e6,
            mem_alpha: 1.6,
            profile,
            seed,
        }
    }
}

#[derive(Debug, Clone)]
struct Running {
    spec: ShardSpec,
    submitted_at: f64,
    started_at: f64,
    finish_at: f64,
    rss: u64,
    io_bytes: u64,
    oom: Option<(u64, u64)>,
    worker_id: usize,
}

pub struct SimBackend {
    p: SimParams,
    clock: f64,
    k: usize,
    queue: VecDeque<(ShardSpec, f64)>,
    running: Vec<Running>,
    done: Vec<BatchReport>,
    rng: Rng,
    busy_coretime: f64,
    util_last_t: f64,
    util_last_busy: f64,
    total_completed: u64,
}

impl SimBackend {
    pub fn new(params: SimParams, initial_workers: usize) -> Self {
        let seed = params.seed;
        SimBackend {
            k: initial_workers.clamp(1, params.cores),
            p: params,
            clock: 0.0,
            queue: VecDeque::new(),
            running: Vec::new(),
            done: Vec::new(),
            rng: Rng::new(seed ^ 0x51B),
            busy_coretime: 0.0,
            util_last_t: 0.0,
            util_last_busy: 0.0,
            total_completed: 0,
        }
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Duration + peak RSS of one batch on one virtual core.
    fn batch_cost(&mut self, spec: &ShardSpec, active_readers: usize) -> (f64, u64, u64) {
        let rows = spec.rows() as f64;
        let c = &self.p.consts;
        let io_bytes = rows * self.p.w_hat;

        // Eq. 2 terms. Read bandwidth is shared across active readers.
        let bw = self.p.read_bw / active_readers.max(1) as f64;
        let t_read = io_bytes / bw;
        let t_prep = io_bytes * c.decode_ns_per_byte * 1e-9
            + rows * c.align_ns_per_row * 1e-9;
        // Column mix: ~70% numeric-path, 30% native comparators.
        let per_cell =
            0.7 * c.delta_numeric_ns_per_cell + 0.3 * c.delta_native_ns_per_cell;
        let t_delta = rows * self.p.ncols * per_cell * 1e-9;

        let (t_overhead, peak_rows) = match self.p.profile {
            SimProfile::InMem => (c.sched_ns_per_batch * 1e-9, rows),
            SimProfile::DaskLike { chunk_rows } => {
                let chunks = (rows / chunk_rows as f64).ceil().max(1.0);
                // Task-graph bookkeeping per chunk + a larger fixed cost.
                (
                    3.0 * c.sched_ns_per_batch * 1e-9
                        + chunks * 1.5 * c.sched_ns_per_batch * 1e-9,
                    (chunk_rows as f64).min(rows),
                )
            }
        };
        let t_merge = c.merge_ns_per_batch * 1e-9;

        let mut dur = t_read + t_prep + t_delta + t_overhead + t_merge;
        dur *= self.rng.lognormal(self.p.noise_sigma);
        if self.rng.chance(self.p.straggler_p) {
            let (lo, hi) = self.p.straggler_mult;
            dur *= self.rng.uniform(lo, hi);
        }

        let peak = self.p.mem_beta0 + self.p.mem_alpha * peak_rows * self.p.w_hat;
        (dur.max(1e-6), peak as u64, io_bytes as u64)
    }

    fn free_worker_id(&self) -> usize {
        // Lowest id not in use.
        let used: Vec<usize> = self.running.iter().map(|r| r.worker_id).collect();
        (0..self.p.cores).find(|i| !used.contains(i)).unwrap_or(0)
    }

    fn dispatch(&mut self) {
        while self.running.len() < self.k {
            let Some((spec, submitted_at)) = self.queue.pop_front() else {
                break;
            };
            let active = self.running.len() + 1;
            let (dur, rss, io_bytes) = self.batch_cost(&spec, active);

            // Memory admission: shared pool (inmem) vs per-worker arena
            // (dask-like). Violations become OOM failures mid-flight.
            let oom = match self.p.profile {
                SimProfile::InMem => {
                    let current: u64 =
                        self.running.iter().map(|r| r.rss).sum::<u64>()
                            + self.p.base_rss;
                    let needed = current + rss;
                    (needed > self.p.mem_cap).then_some((needed, self.p.mem_cap))
                }
                SimProfile::DaskLike { .. } => {
                    let arena =
                        (self.p.mem_cap - self.p.base_rss.min(self.p.mem_cap))
                            / self.k.max(1) as u64;
                    (rss > arena).then_some((rss, arena))
                }
            };
            let finish_at = self.clock + if oom.is_some() { dur * 0.5 } else { dur };
            let worker_id = self.free_worker_id();
            self.running.push(Running {
                spec,
                submitted_at,
                started_at: self.clock,
                finish_at,
                rss,
                io_bytes,
                oom,
                worker_id,
            });
        }
    }

    fn complete_due(&mut self) {
        let clock = self.clock;
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].finish_at <= clock + 1e-12 {
                let r = self.running.swap_remove(i);
                let result = match r.oom {
                    Some((needed, cap)) => Err(BatchError::Oom {
                        needed_bytes: needed,
                        cap_bytes: cap,
                    }),
                    None => Ok(synth_outcome(&r.spec, self.p.ncols as usize)),
                };
                self.total_completed += 1;
                self.done.push(BatchReport {
                    shard: r.spec,
                    worker_id: r.worker_id,
                    submitted_at: r.submitted_at,
                    started_at: r.started_at,
                    finished_at: r.finish_at,
                    result,
                    mem: ShardMemStats {
                        decode_bytes: r.rss as usize,
                        align_bytes: 0,
                        scratch_bytes: 0,
                    },
                    worker_rss_peak: r.rss,
                    io_bytes: r.io_bytes,
                    stages: crate::exec::backend::StageNanos::default(),
                });
            } else {
                i += 1;
            }
        }
    }

    /// Advance the virtual clock to the earliest completion.
    fn advance(&mut self) {
        self.dispatch();
        let Some(next) = self
            .running
            .iter()
            .map(|r| r.finish_at)
            // finish_at is clock + a finite service time, but keep the
            // comparator total so a rogue NaN degrades the pick instead
            // of panicking mid-simulation.
            .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
        else {
            return;
        };
        let dt = (next - self.clock).max(0.0);
        self.busy_coretime += dt * self.running.len() as f64;
        self.clock = next;
        self.complete_due();
        self.dispatch();
    }
}

/// Synthetic no-diff outcome for a simulated batch (sim runs measure the
/// scheduler, not the diff; merge invariance is covered by the real
/// backends).
fn synth_outcome(spec: &ShardSpec, ncols: usize) -> BatchOutcome {
    let aligned = spec.a_len.min(spec.b_len) as u64;
    let removed = (spec.a_len as u64).saturating_sub(aligned);
    let added = (spec.b_len as u64).saturating_sub(aligned);
    BatchOutcome {
        shard_id: spec.shard_id,
        rows_a: spec.a_len as u64,
        rows_b: spec.b_len as u64,
        cells: VerdictCounts {
            equal: aligned * ncols as u64,
            added: added * ncols as u64,
            removed: removed * ncols as u64,
            ..Default::default()
        },
        rows: RowCounts { aligned, added, removed, changed_rows: 0 },
        columns: Vec::new(),
        diff_keys: Vec::new(),
        diff_keys_truncated: false,
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        match self.p.profile {
            SimProfile::InMem => "sim-inmem",
            SimProfile::DaskLike { .. } => "sim-dasklike",
        }
    }
    fn submit(&mut self, shard: ShardSpec) {
        self.queue.push_back((shard, self.clock));
        self.dispatch();
    }
    fn poll(&mut self) -> Vec<BatchReport> {
        self.complete_due();
        self.dispatch();
        std::mem::take(&mut self.done)
    }
    fn wait_any(&mut self) -> Vec<BatchReport> {
        if self.done.is_empty() {
            self.advance();
        }
        std::mem::take(&mut self.done)
    }
    fn set_workers(&mut self, k: usize) {
        self.k = k.clamp(1, self.p.cores);
        self.dispatch();
    }
    fn workers(&self) -> usize {
        self.k
    }
    fn set_mem_budget(&mut self, bytes: u64) {
        // The virtual machine's RAM shrinks/expands; admission checks at
        // dispatch time use the new cap for subsequently started batches.
        self.p.mem_cap = bytes.max(1);
    }
    fn mem_budget(&self) -> u64 {
        self.p.mem_cap
    }
    fn queue_depth(&self) -> usize {
        self.queue.len()
    }
    fn inflight(&self) -> usize {
        self.queue.len() + self.running.len()
    }
    fn now(&self) -> f64 {
        self.clock
    }
    fn current_rss(&self) -> u64 {
        self.p.base_rss + self.running.iter().map(|r| r.rss).sum::<u64>()
    }
    fn utilization_sample(&mut self, cpu_cap: usize) -> f64 {
        let dt = self.clock - self.util_last_t;
        if dt <= 0.0 {
            return (self.running.len() as f64 / cpu_cap.max(1) as f64)
                .clamp(0.0, 1.0);
        }
        let db = self.busy_coretime - self.util_last_busy;
        self.util_last_t = self.clock;
        self.util_last_busy = self.busy_coretime;
        (db / (dt * cpu_cap.max(1) as f64)).clamp(0.0, 1.0)
    }
    fn cancel(&mut self, shard_id: u64) {
        let clock = self.clock;
        let mut cancelled = Vec::new();
        self.queue.retain(|(spec, submitted_at)| {
            if spec.shard_id == shard_id {
                cancelled.push((*spec, *submitted_at));
                false
            } else {
                true
            }
        });
        for (spec, submitted_at) in cancelled {
            self.done.push(BatchReport {
                shard: spec,
                worker_id: 0,
                submitted_at,
                started_at: clock,
                finished_at: clock,
                result: Err(BatchError::Cancelled),
                mem: ShardMemStats::default(),
                worker_rss_peak: 0,
                io_bytes: 0,
                stages: crate::exec::backend::StageNanos::default(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(profile: SimProfile) -> SimParams {
        // Paper-engine constants: compute-bound, the regime the paper's
        // scheduler operates in.
        SimParams::paper_testbed(
            4_000.0,
            16.0,
            CostConstants::paper_engine(),
            profile,
            1,
        )
    }

    fn spec(id: u64, rows: usize) -> ShardSpec {
        ShardSpec {
            shard_id: id,
            attempt: 0,
            a_offset: id as usize * rows,
            a_len: rows,
            b_offset: id as usize * rows,
            b_len: rows,
            a_occ_base: 0,
            b_occ_base: 0,
        }
    }

    #[test]
    fn executes_and_advances_virtual_time() {
        let mut b = SimBackend::new(params(SimProfile::InMem), 4);
        for i in 0..8 {
            b.submit(spec(i, 100_000));
        }
        let mut done = 0;
        while done < 8 {
            let got = b.wait_any();
            for r in &got {
                assert!(r.result.is_ok());
                assert!(r.finished_at > r.started_at);
            }
            done += got.len();
        }
        assert!(b.clock() > 0.0);
        assert_eq!(b.inflight(), 0);
    }

    #[test]
    fn parallelism_shortens_makespan() {
        let run = |k: usize| {
            let mut b = SimBackend::new(params(SimProfile::InMem), k);
            for i in 0..32 {
                b.submit(spec(i, 200_000));
            }
            let mut done = 0;
            while done < 32 {
                done += b.wait_any().len();
            }
            b.clock()
        };
        let t1 = run(1);
        let t8 = run(8);
        // Compute-bound regime: close to linear scaling.
        assert!(t8 < t1 / 3.0, "k=8 {t8} vs k=1 {t1}");
    }

    #[test]
    fn inmem_shared_pool_ooms_on_oversized_total() {
        let mut p = params(SimProfile::InMem);
        p.mem_cap = 2_000_000_000;
        let mut b = SimBackend::new(p, 8);
        // 8 concurrent * 1.6 * 500k * 4000B = 25.6 GB >> 2 GB.
        for i in 0..8 {
            b.submit(spec(i, 500_000));
        }
        let mut saw_oom = false;
        let mut done = 0;
        while done < 8 {
            for r in b.wait_any() {
                if r.is_oom() {
                    saw_oom = true;
                }
                done += 1;
            }
        }
        assert!(saw_oom);
    }

    #[test]
    fn dasklike_chunking_caps_per_batch_peak() {
        let pi = params(SimProfile::InMem);
        let pd = params(SimProfile::DaskLike { chunk_rows: 16_384 });
        let mut bi = SimBackend::new(pi, 1);
        let mut bd = SimBackend::new(pd, 1);
        bi.submit(spec(0, 1_000_000));
        bd.submit(spec(0, 1_000_000));
        let ri = loop {
            let v = bi.wait_any();
            if !v.is_empty() {
                break v;
            }
        };
        let rd = loop {
            let v = bd.wait_any();
            if !v.is_empty() {
                break v;
            }
        };
        assert!(rd[0].worker_rss_peak < ri[0].worker_rss_peak / 10);
        // ... at the cost of more overhead (longer duration).
        assert!(rd[0].exec_time() > ri[0].exec_time());
    }

    #[test]
    fn deterministic_for_same_seed() {
        let run = || {
            let mut b = SimBackend::new(params(SimProfile::InMem), 4);
            for i in 0..16 {
                b.submit(spec(i, 100_000));
            }
            let mut fins = Vec::new();
            while fins.len() < 16 {
                for r in b.wait_any() {
                    fins.push((r.shard.shard_id, r.finished_at));
                }
            }
            fins
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn cancel_queued_reports_cancelled() {
        let mut b = SimBackend::new(params(SimProfile::InMem), 1);
        b.submit(spec(0, 100_000));
        b.submit(spec(1, 100_000)); // queued behind worker 0
        b.cancel(1);
        let got = b.poll();
        assert!(got
            .iter()
            .any(|r| matches!(r.result, Err(BatchError::Cancelled))));
        assert_eq!(b.queue_depth(), 0);
    }

    #[test]
    fn utilization_reflects_busy_workers() {
        // Disable noise/stragglers so the drain tail doesn't skew the
        // long-run average away from the steady-state 16/32.
        let mut p = params(SimProfile::InMem);
        p.noise_sigma = 0.0;
        p.straggler_p = 0.0;
        let mut b = SimBackend::new(p, 16);
        for i in 0..64 {
            b.submit(spec(i, 200_000));
        }
        let mut done = 0;
        while done < 64 {
            done += b.wait_any().len();
        }
        let u = b.utilization_sample(32);
        assert!(u > 0.3, "16 busy workers of 32 cores -> ~0.5, got {u}");
    }
}
