//! Virtual table sources for the simulated testbed: they describe a
//! workload's shape (rows, width, keys) without materializing data.
//! The sim backend never decodes rows, so `read_range` is unreachable
//! by construction (it returns a typed `Unsupported` error to make any
//! misuse loud without panicking a worker).

use crate::api::error::SchedError;
use crate::data::io::{ReadMeter, TableSource};
use crate::data::schema::Schema;
use crate::data::table::mixed_schema;

pub struct VirtualSource {
    schema: Schema,
    nrows: usize,
    /// Simulated bytes/row on this side.
    row_bytes: f64,
    resident: u64,
    meter: ReadMeter,
}

impl VirtualSource {
    /// Keyed, key-sorted virtual table (keys 2·row, like the generator).
    pub fn new(nrows: usize, row_bytes: f64, cols: usize) -> Self {
        VirtualSource {
            schema: mixed_schema(cols.saturating_sub(1)),
            nrows,
            row_bytes,
            resident: 0,
            meter: ReadMeter::default(),
        }
    }
}

impl TableSource for VirtualSource {
    fn schema(&self) -> &Schema {
        &self.schema
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn read_range(
        &self,
        offset: usize,
        len: usize,
    ) -> Result<crate::data::table::Table, SchedError> {
        Err(SchedError::unsupported(format!(
            "virtual source cannot decode rows ({offset}+{len})"
        )))
    }
    fn key_at(&self, row: usize) -> Option<i64> {
        if row < self.nrows {
            Some(2 * row as i64)
        } else {
            None
        }
    }
    fn occ_at(&self, _row: usize) -> u32 {
        0 // virtual keys are unique: every run has length 1
    }
    fn storage_bytes(&self) -> u64 {
        (self.nrows as f64 * self.row_bytes) as u64
    }
    fn resident_bytes(&self) -> u64 {
        self.resident
    }
    fn meter(&self) -> &ReadMeter {
        &self.meter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_sorted_and_bounded() {
        let s = VirtualSource::new(100, 400.0, 8);
        assert_eq!(s.key_at(0), Some(0));
        assert_eq!(s.key_at(99), Some(198));
        assert_eq!(s.key_at(100), None);
        assert_eq!(s.nrows(), 100);
        assert_eq!(s.storage_bytes(), 40_000);
        assert_eq!(s.resident_bytes(), 0);
    }

    #[test]
    fn read_range_is_a_typed_error() {
        let s = VirtualSource::new(10, 100.0, 4);
        match s.read_range(0, 1) {
            Err(SchedError::Unsupported { message }) => {
                assert!(message.contains("virtual source"), "{message}");
            }
            other => panic!("expected Unsupported, got {other:?}"),
        }
    }
}
