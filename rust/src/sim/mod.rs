//! Simulated testbed (DESIGN.md S24): run the *real* scheduler loop
//! against a discrete-event model of the paper's 32-core / 64 GB / SSD
//! machine. Used by the bench harness to regenerate Tables I–III and
//! the ablations at paper scale on this 1-core container.

pub mod simexec;
pub mod source;

use crate::api::error::SchedError;
use crate::config::{BackendChoice, PolicyKind, SchedulerConfig};
use crate::engine::microbench::CostConstants;
use crate::sched::controller::AdaptiveController;
use crate::sched::preflight::PreflightProfile;
use crate::sched::scheduler::{drive, DriveInputs, JobResult};
use crate::sched::telemetry::Telemetry;
use crate::sched::working_set::{gate_backend, WorkingSetModel};
use simexec::{SimBackend, SimParams, SimProfile};
use source::VirtualSource;

/// One simulated workload (paper §V: {1, 5, 10, 20}M rows per side of
/// wide mixed-type rows).
#[derive(Debug, Clone, Copy)]
pub struct SimWorkload {
    pub rows: usize,
    /// Bytes per aligned row, both sides (paper rows are wide — several
    /// KB — which is what makes 10M/20M exceed the κ·M_cap gate).
    pub w_hat: f64,
    pub ncols: usize,
    pub seed: u64,
}

impl SimWorkload {
    pub fn paper(rows: usize, seed: u64) -> Self {
        SimWorkload { rows, w_hat: 4_000.0, ncols: 16, seed }
    }
}

/// Run one simulated job under `cfg` (policy, caps, policy params all
/// honored; `cfg.backend` overrides gating if not Auto).
pub fn run_sim_job(
    cfg: &SchedulerConfig,
    wl: &SimWorkload,
    consts: &CostConstants,
) -> Result<JobResult, SchedError> {
    let profile = PreflightProfile {
        w_hat: wl.w_hat,
        b_read: 2.5e9,
        rows_a: wl.rows,
        rows_b: wl.rows,
        sampled_rows: wl.rows.min(1_000_000),
        ncols: wl.ncols,
    };
    let gate = gate_backend(
        &WorkingSetModel::default(),
        &profile,
        &cfg.caps,
        &cfg.policy,
    );
    let choice = match cfg.backend {
        BackendChoice::Auto => gate.backend,
        BackendChoice::Sim => gate.backend,
        other => other,
    };
    let sim_profile = match choice {
        BackendChoice::InMem => SimProfile::InMem,
        BackendChoice::DaskLike => SimProfile::DaskLike {
            // Coarse Dask partitions sized off the memory budget: ~1/64
            // of the cap per task (≈1 GB at the paper's 64 GB), so task
            // peaks always fit the per-worker arena even under
            // tightened-cap ablations.
            chunk_rows: ((cfg.caps.mem_cap_bytes as f64 / 64.0 / wl.w_hat)
                as usize)
                .clamp(4_096, 1_000_000),
        },
        _ => unreachable!(),
    };
    let params = SimParams {
        cores: cfg.caps.cpu_cap,
        mem_cap: cfg.caps.mem_cap_bytes,
        ..SimParams::paper_testbed(
            wl.w_hat,
            wl.ncols as f64,
            *consts,
            sim_profile,
            wl.seed,
        )
    };
    let k0 = (cfg.caps.cpu_cap / 4).max(cfg.policy.k_min);
    let mut backend = SimBackend::new(params, k0);

    let a = VirtualSource::new(wl.rows, wl.w_hat / 2.0, wl.ncols);
    let b = VirtualSource::new(wl.rows, wl.w_hat / 2.0, wl.ncols);

    let mut policy: Box<dyn crate::sched::controller::TuningPolicy> =
        match cfg.policy_kind {
            PolicyKind::Adaptive => Box::new(AdaptiveController::new()),
            PolicyKind::Fixed { b, k } => {
                Box::new(crate::baselines::FixedPolicy::new(b, k))
            }
            PolicyKind::Heuristic => {
                Box::new(crate::baselines::HeuristicPolicy::paper_default())
            }
        };

    let mut telemetry = match &cfg.telemetry_path {
        Some(p) => Telemetry::to_file(p)?,
        None => Telemetry::disabled(),
    };
    let mut inputs = DriveInputs {
        cfg,
        profile,
        gate: Some(gate),
        telemetry: &mut telemetry,
        consts: *consts,
        control: None,
    };
    drive(&mut backend, &a, &b, policy.as_mut(), &mut inputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SchedulerConfig {
        SchedulerConfig::default() // 64 GB, 32 cores, paper policy
    }

    fn consts() -> CostConstants {
        CostConstants::paper_engine()
    }

    #[test]
    fn paper_gating_by_workload_size() {
        // 1M/5M -> inmem; 10M/20M -> dask (paper §VI backend decisions).
        for (rows, want) in [
            (1_000_000, "sim-inmem"),
            (5_000_000, "sim-inmem"),
            (10_000_000, "sim-dasklike"),
            (20_000_000, "sim-dasklike"),
        ] {
            let wl = SimWorkload::paper(rows, 7);
            let r = run_sim_job(&cfg(), &wl, &consts()).unwrap();
            assert_eq!(r.stats.backend, want, "{rows}");
        }
    }

    #[test]
    fn adaptive_sim_run_completes_with_zero_ooms() {
        let wl = SimWorkload::paper(1_000_000, 3);
        let r = run_sim_job(&cfg(), &wl, &consts()).unwrap();
        assert_eq!(r.stats.ooms, 0);
        assert!(r.stats.batches > 10);
        assert!(r.stats.makespan_secs > 0.0);
        assert!(r.stats.throughput_rows_per_s > 0.0);
        assert!(r.stats.p95_latency >= r.stats.p50_latency);
        // Sim covered every row exactly once.
        assert_eq!(r.report.rows_a, 1_000_000);
        assert_eq!(r.report.rows_b, 1_000_000);
    }

    #[test]
    fn aggressive_fixed_config_ooms_adaptive_does_not() {
        // A deliberately oversized fixed b on the inmem backend must blow
        // the shared pool; the adaptive controller on the same workload
        // must not (this is the paper's zero-OOM claim in miniature).
        let wl = SimWorkload::paper(20_000_000, 11);
        let mut c = cfg();
        c.backend = BackendChoice::InMem;
        c.policy_kind = PolicyKind::Fixed { b: 2_000_000, k: 16 };
        c.policy.b_max = 4_000_000;
        let r_fixed = run_sim_job(&c, &wl, &consts()).unwrap();
        assert!(r_fixed.stats.ooms > 0, "2M rows x 4KB x 1.6 x 16 >> 64GB");

        let mut c2 = cfg();
        c2.backend = BackendChoice::InMem;
        let r_adaptive = run_sim_job(&c2, &wl, &consts()).unwrap();
        assert_eq!(r_adaptive.stats.ooms, 0);
        assert!(r_adaptive.stats.peak_rss_bytes < c2.caps.mem_cap_bytes);
    }

    #[test]
    fn deterministic_given_seed() {
        let wl = SimWorkload::paper(1_000_000, 5);
        let r1 = run_sim_job(&cfg(), &wl, &consts()).unwrap();
        let r2 = run_sim_job(&cfg(), &wl, &consts()).unwrap();
        assert_eq!(r1.stats.p95_latency, r2.stats.p95_latency);
        assert_eq!(r1.stats.makespan_secs, r2.stats.makespan_secs);
        assert_eq!(r1.stats.reconfigs, r2.stats.reconfigs);
    }

    #[test]
    fn adaptive_beats_untuned_fixed_on_p95() {
        let wl = SimWorkload::paper(1_000_000, 9);
        let r_ad = run_sim_job(&cfg(), &wl, &consts()).unwrap();
        // Oversized fixed b: stragglers inflate the tail; undersized k
        // wastes the machine.
        let mut c = cfg();
        c.backend = BackendChoice::InMem;
        c.policy_kind = PolicyKind::Fixed { b: 250_000, k: 4 };
        let r_fx = run_sim_job(&c, &wl, &consts()).unwrap();
        assert!(
            r_ad.stats.p95_latency < r_fx.stats.p95_latency,
            "adaptive p95 {:.2}s vs fixed p95 {:.2}s",
            r_ad.stats.p95_latency,
            r_fx.stats.p95_latency
        );
        assert!(
            r_ad.stats.makespan_secs < r_fx.stats.makespan_secs,
            "adaptive {:.2}s vs fixed {:.2}s makespan",
            r_ad.stats.makespan_secs,
            r_fx.stats.makespan_secs
        );
    }
}
