//! The scheduler main loop (contribution 3): submit shards, collect
//! per-batch metrics, update the online models, drive the tuning policy,
//! enforce the safety envelope continuously, and apply backpressure and
//! straggler mitigation — all generic over `exec::Backend`, so the same
//! loop drives the real backends and the discrete-event testbed.

use std::sync::Arc;

use crate::api::error::SchedError;
use crate::api::events::JobEvent;
use crate::api::session::JobControl;
use crate::api::{DiffSession, JobBuilder};
use crate::config::{PolicyKind, SchedulerConfig};
use crate::data::io::TableSource;
use crate::engine::merge::{JobReport, Merger};
use crate::exec::backend::{Backend, BatchError, ShardSpec};
use crate::exec::partition::Partitioner;
use crate::metrics::quantile::{weighted_quantile, RollingWindow};
use crate::sched::backpressure::Backpressure;
use crate::sched::controller::{PolicyEnv, Signals, TuningPolicy};
use crate::sched::cost_model::CostModel;
use crate::sched::ewma::Ewma;
use crate::sched::memory_model::MemoryModel;
use crate::sched::preflight::PreflightProfile;
use crate::sched::straggler::{Mitigation, StragglerTracker};
use crate::sched::telemetry::Telemetry;
use crate::sched::working_set::GateDecision;

/// Job-level statistics (the raw material for Tables I–III).
#[derive(Debug, Clone)]
pub struct JobStats {
    /// Executing backend name ("inmem" / "dasklike" / "sim-…").
    pub backend: String,
    /// Tuning policy name ("adaptive" / "fixed" / "heuristic").
    pub policy: String,
    /// First submission to last completion (backend-clock seconds).
    pub makespan_secs: f64,
    /// Job-level p50 batch latency, row-weighted (paper §V).
    pub p50_latency: f64,
    /// Job-level p95 batch latency, row-weighted (paper §V).
    pub p95_latency: f64,
    /// Peak accounted job RSS (bytes) — Table II's metric.
    pub peak_rss_bytes: u64,
    /// max(|A|,|B|) rows / makespan — Table III's metric.
    pub throughput_rows_per_s: f64,
    /// Applied (b,k) changes — Table III "reconfigs/job".
    pub reconfigs: u64,
    /// Accounted-OOM batch failures (0 whenever the envelope holds).
    pub ooms: u64,
    /// Accepted batch completions.
    pub batches: u64,
    /// Speculative duplicates launched for stragglers.
    pub speculations: u64,
    /// Straggling shards split into key-aligned halves.
    pub splits: u64,
    /// Of `splits`, how many cut *inside* a duplicate-key run (the
    /// occurrence-indexed path: single-run straggler shards used to be
    /// unsplittable). Telemetry for observing the new path.
    pub splits_in_run: u64,
    /// Carved add-range shards emitted by the partitioner (`a_len = 0`
    /// shards of pure B surplus — B-dominant skew). Non-zero means the
    /// completed-run / last-shard arms deferred an over-batch surplus
    /// to batch-bounded added-range shards.
    pub carved_shards: u64,
    /// Queue-depth backpressure pauses (the paper's statistic;
    /// memory-grant drain pauses are counted separately and surface in
    /// telemetry as `mem_pause` events).
    pub backpressure_pauses: u64,
    /// Chunk-cache lookups served without touching the source (resident
    /// hits). 0 with the cache off.
    pub cache_hits: u64,
    /// Chunk-cache lookups that fell through to a source read.
    pub cache_misses: u64,
    /// Chunks written to spill files (eviction under grant pressure or
    /// direct spill of an over-carve-out chunk).
    pub cache_spills: u64,
    /// Spilled chunks decoded back on a later hit.
    pub cache_unspills: u64,
    /// Chunks pushed out of cache residency.
    pub cache_evicts: u64,
    /// Metered source range reads over the job (the true decode count —
    /// `ReadMeter::ops` delta). With the cache on and re-execution
    /// present, this is strictly below the cache-off count; cache hits
    /// never meter.
    pub source_reads: u64,
    /// Batch size in force when the job finished.
    pub final_b: usize,
    /// Worker count in force when the job finished.
    pub final_k: usize,
    /// The Eq. 1 backend gate decision (None for pre-gated runs).
    pub gate: Option<GateDecision>,
    /// Fraction of candidate actions kept by the envelope (§VIII).
    pub actions_kept: f64,
    /// Per-stage time decomposition summed over accepted batches
    /// (read / decode / align / diff / stall). With prefetch active,
    /// `stall_ns < read_ns + decode_ns` is the signature of successful
    /// ingest/compute overlap; `stages.overlap_ratio()` quantifies it.
    pub stages: crate::exec::backend::StageNanos,
    /// Control-loop time spent in `drive` outside of blocking waits —
    /// the scheduler-overhead half of the overhead/useful-work
    /// decomposition (after the Dask overhead studies).
    pub sched_overhead_ns: u64,
    /// Summed worker execution time over accepted batches (the useful
    /// half of the decomposition).
    pub useful_work_ns: u64,
}

/// What a finished job returns: the merged diff plus scheduler stats.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// The merged diff report (row/cell verdicts, per-column aggregates).
    pub report: JobReport,
    /// Scheduler-level statistics for the run.
    pub stats: JobStats,
}

/// Coverage ledger: accept each key-range exactly once (speculation and
/// splitting can produce overlapping completions; first wins).
#[derive(Debug, Default)]
struct Coverage {
    /// Accepted A intervals (start -> end), non-overlapping.
    a_intervals: std::collections::BTreeMap<usize, usize>,
    /// Accepted B intervals for shards with a_len == 0.
    b_intervals: std::collections::BTreeMap<usize, usize>,
}

impl Coverage {
    fn try_accept(&mut self, spec: &ShardSpec) -> bool {
        if spec.a_len > 0 {
            Self::insert_if_free(&mut self.a_intervals, spec.a_offset, spec.a_len)
        } else if spec.b_len > 0 {
            Self::insert_if_free(&mut self.b_intervals, spec.b_offset, spec.b_len)
        } else {
            true // empty shard (degenerate); harmless
        }
    }
    fn insert_if_free(
        map: &mut std::collections::BTreeMap<usize, usize>,
        start: usize,
        len: usize,
    ) -> bool {
        let end = start + len;
        // Previous interval must end at/before start.
        if let Some((_, &pend)) = map.range(..=start).next_back() {
            if pend > start {
                return false;
            }
        }
        // Next interval must begin at/after end.
        if let Some((&nstart, _)) = map.range(start..).next() {
            if nstart < end {
                return false;
            }
        }
        map.insert(start, end);
        true
    }
}

/// Occurrence-aligned split of a shard into two halves: the A side is
/// bisected at `a_len / 2` — anywhere, *including inside a
/// duplicate-key run* — and the B boundary is re-derived so a mid-run
/// cut stops the B side at the same occurrence ordinal. Both halves
/// then resume with equal occurrence bases (recorded in the specs), so
/// their local positional pairings compose into exactly the unsplit
/// pairing. Single-run straggler shards — the shards run snapping made
/// unsplittable — now bisect like any other. Keyless shards split
/// positionally at the same offset on both sides (pair-aligned).
///
/// Returns the halves plus whether the cut landed inside a key run (the
/// `splits_in_run` statistic). The detector emits `Split` for shards
/// with `a_len >= 2` — and for carved add-range shards (`a_len = 0`,
/// `b_len >= 2`), which bisect on the B side instead: every carved row
/// is pure Added, so any positional B cut is safe, and the right half
/// resumes at its source occurrence base.
///
/// `hint` is the chunk cache's preferred left-half row count (the
/// length of the longest cache-resident strict prefix of the bisected
/// side, from `Backend::cache_split_hint`): cutting there makes the
/// re-executed left half a pure cache hit instead of a fresh decode.
/// Out-of-range hints fall back to the midpoint bisection, so the cut
/// rule (occurrence-bounded B boundary re-derivation) is identical
/// either way and the merged report cannot depend on cache state.
fn split_spec(
    a: &dyn TableSource,
    b: &dyn TableSource,
    spec: ShardSpec,
    hint: Option<usize>,
) -> (ShardSpec, ShardSpec, bool) {
    let keyed = a.nrows() > 0
        && a.key_at(0).is_some()
        && b.nrows() > 0
        && b.key_at(0).is_some();
    // A usable hint leaves at least one row on each side of the cut.
    let pick = |len: usize| match hint {
        Some(h) if h >= 1 && h < len => h,
        _ => (len / 2).max(1),
    };
    if spec.a_len == 0 {
        debug_assert!(spec.b_len >= 2, "detector splits only b_len >= 2 carves");
        let half = pick(spec.b_len);
        let b_mid = spec.b_offset + half;
        let in_run = keyed && b.key_at(b_mid - 1).is_some()
            && b.key_at(b_mid - 1) == b.key_at(b_mid);
        let left = ShardSpec { b_len: half, ..spec };
        let right = ShardSpec {
            b_offset: b_mid,
            b_len: spec.b_len - half,
            a_occ_base: 0,
            b_occ_base: if keyed { b.occ_at(b_mid) } else { 0 },
            ..spec
        };
        return (left, right, in_run);
    }
    debug_assert!(spec.a_len >= 2, "detector splits only a_len >= 2 shards");
    let half = pick(spec.a_len);
    let cut = spec.a_offset + half;
    let a_end = spec.a_offset + spec.a_len;
    let b_end = spec.b_offset + spec.b_len;
    let (b_mid, in_run) = if !keyed {
        // Positional: cut B at the same pair-aligned offset.
        (spec.b_offset + half.min(spec.b_len), false)
    } else if cut >= a_end {
        (b_end, false)
    } else {
        let boundary = a.key_at(cut - 1).unwrap_or(i64::MAX);
        let (occ_cut, in_run) =
            crate::exec::partition::occ_cut_at(a, cut - 1, boundary);
        (
            crate::exec::partition::upper_bound_key_occ_in(
                b, spec.b_offset, b_end, boundary, occ_cut,
            ),
            in_run,
        )
    };
    let left = ShardSpec {
        a_len: half,
        b_len: b_mid - spec.b_offset,
        ..spec
    };
    let right = ShardSpec {
        a_offset: cut,
        a_len: spec.a_len - half,
        b_offset: b_mid,
        b_len: b_end - b_mid,
        a_occ_base: if keyed && cut < a_end { a.occ_at(cut) } else { 0 },
        b_occ_base: if keyed && b_mid < b_end { b.occ_at(b_mid) } else { 0 },
        ..spec
    };
    (left, right, in_run)
}

/// Everything `drive` needs beyond the backend and sources.
pub struct DriveInputs<'a> {
    /// Full scheduler configuration (caps, policy, engine, seeds).
    pub cfg: &'a SchedulerConfig,
    /// Pre-flight profile (Ŵ, B̂_read, row counts) the models start from.
    pub profile: PreflightProfile,
    /// The Eq. 1 gate decision, recorded into stats/telemetry.
    pub gate: Option<GateDecision>,
    /// Telemetry sink (JSON lines; may be disabled).
    pub telemetry: &'a mut Telemetry,
    /// Cost constants describing the engine actually executing batches
    /// (microbench-calibrated for the real engine; paper-engine for the
    /// simulated testbed).
    pub consts: crate::engine::microbench::CostConstants,
    /// Session bridge for jobs driven through `DiffSession`: progress
    /// snapshots, typed events, cooperative cancellation, and the
    /// session's CPU-share re-partitioning. `None` for standalone runs
    /// (the simulator testbed).
    pub control: Option<Arc<JobControl>>,
}

/// The scheduler loop. Returns the merged report + stats. An OOM aborts
/// the job (recorded in stats); transient failures retry once; a
/// permanent shard failure or a handle cancellation returns a typed
/// error. Re-entrant per job: all state is local, so one loop runs per
/// admitted job on its own session thread.
///
/// Under a `DiffSession` (`inputs.control` present), the loop also
/// applies the session's elastic re-partitioning mid-flight: CPU-share
/// changes through `Backend::set_workers`, and memory-grant changes
/// through `Backend::set_mem_budget` — a shrunken grant immediately
/// tightens the Eq. 4 envelope (forcing a batch-size down-step when the
/// current b is no longer safe), pauses submission while accounted
/// usage drains, and only then re-caps the backend's ledger, so the cap
/// change cannot fail inflight batches.
pub fn drive(
    backend: &mut dyn Backend,
    a: &dyn TableSource,
    b: &dyn TableSource,
    policy: &mut dyn TuningPolicy,
    inputs: &mut DriveInputs,
) -> Result<JobResult, SchedError> {
    let cfg = inputs.cfg;
    let pol = &cfg.policy;
    let caps = &cfg.caps;
    let base_rss = (a.resident_bytes() + b.resident_bytes()) as f64;

    // --- online models ---
    let mut mem_model = MemoryModel::new(
        inputs.profile.w_hat,
        base_rss,
        pol.rho_smooth,
        pol.delta_m_window,
        pol.z_alpha,
    );
    let mut cost_model = CostModel::new(inputs.consts, &inputs.profile, pol.rho_smooth);
    // With the double-buffered prefetcher active each worker keeps up to
    // two shards' buffers resident (the one diffing + the staged next),
    // so Eq. 3–4 and the pruned action space must budget for 2·b rows
    // per worker.
    mem_model.set_resident_shards(if backend.prefetch_active() {
        2.0
    } else {
        1.0
    });

    // --- policy init ---
    let mut env = PolicyEnv {
        caps: *caps,
        policy: *pol,
        b_max_safe: mem_model
            .safe_b_max(pol.k_min.max(caps.cpu_cap / 4), pol.eta, caps.mem_cap_bytes)
            .max(pol.b_min),
        base_rss,
        job_rows: a.nrows().max(b.nrows()),
        b_hint: cost_model.overhead_balanced_b(3.0),
    };
    // Session CPU allowance: the session re-partitions `cpu_cap` across
    // running jobs; the loop tracks the published share and applies it
    // through `set_workers` (0 = no session constraint).
    let mut cpu_allow = caps.cpu_cap;
    if let Some(c) = &inputs.control {
        let share = c.cpu_share();
        if share > 0 {
            cpu_allow = share.min(caps.cpu_cap).max(1);
        }
    }
    // Session memory grant (elastic): `mem_allow` is the grant currently
    // in force — the safety envelope prunes against it from the moment
    // it changes. `mem_applied` is the budget the backend's accounting
    // ledger enforces; a *shrink* is only pushed down once accounted
    // usage has drained below the new grant (clamping the ledger under
    // live usage would fail inflight batches), while an expansion is
    // pushed immediately. `grant_clamp` records that the session has
    // re-partitioned at least once; from then on every policy proposal
    // (including the memory-blind baselines) is pruned against the
    // grant, because the grant — not the admission-time cap — is the
    // binding contract.
    let mut mem_allow = caps.mem_cap_bytes;
    let mut mem_applied = caps.mem_cap_bytes;
    let mut grant_clamp = false;
    // k_min is validated <= cpu_cap on the session path, but clamp
    // defensively (the sim testbed runs unvalidated configs).
    let k_lo = pol.k_min.min(caps.cpu_cap);
    let (mut b_cur, mut k_cur) = policy.initial(&env);
    b_cur = b_cur.clamp(pol.b_min, pol.b_max);
    k_cur = k_cur.clamp(k_lo, caps.cpu_cap).min(cpu_allow).max(1);
    backend.set_workers(k_cur);

    // --- loop state ---
    let mut part = Partitioner::new(a, b);
    let mut merger = Merger::new();
    let mut coverage = Coverage::default();
    let mut stragglers = StragglerTracker::new();
    let mut backpressure = Backpressure::new(pol.backpressure_depth);
    let mut lat_window = RollingWindow::new(pol.window);
    let mut rss_window = RollingWindow::new(pol.window);
    let mut util_window = RollingWindow::new(pol.window);
    let mut rss_ewma = Ewma::new(pol.rho_smooth);
    let mut cpu_ewma = Ewma::new(pol.rho_smooth);
    let mut p95_ewma = Ewma::new(pol.rho_smooth);
    let mut all_latencies: Vec<(f64, f64)> = Vec::new();
    let mut retries: std::collections::HashMap<u64, u32> = Default::default();
    // Split lineage: half-id -> original id, original id -> half ids.
    // Halves get fresh shard ids so cancelling one never hits its
    // sibling (coverage guarantees correctness; cancels are economy).
    let mut split_parent: std::collections::HashMap<u64, u64> = Default::default();
    let mut split_children: std::collections::HashMap<u64, Vec<u64>> = Default::default();
    let mut next_split_id: u64 = 1 << 40;

    let mut stats = JobStats {
        backend: backend.name().to_string(),
        policy: policy.name().to_string(),
        makespan_secs: 0.0,
        p50_latency: 0.0,
        p95_latency: 0.0,
        peak_rss_bytes: 0,
        throughput_rows_per_s: 0.0,
        reconfigs: 0,
        ooms: 0,
        batches: 0,
        speculations: 0,
        splits: 0,
        splits_in_run: 0,
        carved_shards: 0,
        backpressure_pauses: 0,
        cache_hits: 0,
        cache_misses: 0,
        cache_spills: 0,
        cache_unspills: 0,
        cache_evicts: 0,
        source_reads: 0,
        final_b: b_cur,
        final_k: k_cur,
        gate: inputs.gate,
        actions_kept: 1.0,
        stages: crate::exec::backend::StageNanos::default(),
        sched_overhead_ns: 0,
        useful_work_ns: 0,
    };
    // Baseline for the job's true decode count: `ReadMeter::ops` is
    // process-cumulative per source, so the job's source reads are the
    // delta from here (cache hits never meter, so with the cache on and
    // re-execution present this lands strictly below the cache-off run).
    let read_ops0 = a.meter().ops() + b.meter().ops();
    let mut completed: u64 = 0;
    let mut t_first_submit: Option<f64> = None;
    let mut t_last_finish: f64 = 0.0;
    let mut aborted = false;
    let mut cancelled = false;
    let mut actions_total: u64 = 0;
    let mut actions_kept: u64 = 0;
    let mut rows_done: u64 = 0;
    let mut bp_pauses_seen: u64 = 0;
    let mut mem_pauses_seen: u64 = 0;
    // Shard ids submitted and not yet reported — the cancellation
    // broadcast set.
    let mut inflight_ids: std::collections::HashSet<u64> = Default::default();
    // Scheduler-overhead decomposition: wall time spent in this control
    // loop, minus time blocked waiting for workers. `last_round` is what
    // telemetry attributes to the batches of the following round.
    let mut sched_ns_total: u64 = 0;
    let mut last_round_sched_ns: u64 = 0;

    if let Some(c) = &inputs.control {
        let backend_name = backend.name().to_string();
        let total = a.nrows().max(b.nrows()) as u64;
        c.update_progress(|p| {
            p.backend = backend_name;
            p.rows_total = total;
            p.current_b = b_cur;
            p.current_k = k_cur;
        });
    }

    if let Some(g) = &inputs.gate {
        inputs.telemetry.event(
            "gate",
            &format!(
                "backend={} ws={:.2}GB thr={:.2}GB",
                backend.name(),
                g.ws_bytes / 1e9,
                g.threshold_bytes / 1e9
            ),
            backend.now(),
        );
    }

    loop {
        let iter_t0 = std::time::Instant::now();
        let mut wait_ns: u64 = 0;
        // Chunk-cache gauge snapshot for this round: resident chunk
        // bytes share the grant with batch buffers, so every safe-b
        // computation below prunes against the allowance net of them
        // (all-zero — and bit-identical to the historical envelope —
        // when no cache is attached).
        let cache_now = backend.cache_stats();
        let mem_for_batches =
            mem_allow.saturating_sub(cache_now.resident_bytes);
        // --- session bridge: cancellation + CPU-share re-partitioning ---
        if let Some(c) = &inputs.control {
            if !cancelled && c.cancel_requested() {
                cancelled = true;
                aborted = true;
                for id in &inflight_ids {
                    backend.cancel(*id);
                }
                inputs.telemetry.event("cancel", "handle", backend.now());
            }
            let share = c.cpu_share();
            if share > 0 {
                let new_allow = share.min(caps.cpu_cap).max(1);
                if new_allow != cpu_allow {
                    cpu_allow = new_allow;
                    if k_cur > cpu_allow {
                        let k_from = k_cur;
                        k_cur = cpu_allow;
                        backend.set_workers(k_cur);
                        stats.reconfigs += 1;
                        inputs.telemetry.event(
                            "reconfig",
                            &format!("k {k_from}->{k_cur} (session-budget)"),
                            backend.now(),
                        );
                        c.push_event(JobEvent::Reconfig {
                            b_from: b_cur,
                            b_to: b_cur,
                            k_from,
                            k_to: k_cur,
                            reason: "session-budget".into(),
                        });
                    }
                }
            }
            // Elastic memory grant: react to session re-partitioning.
            let grant = c.mem_grant();
            if grant > 0 && grant != mem_allow {
                let from = mem_allow;
                mem_allow = grant;
                env.caps.mem_cap_bytes = grant;
                grant_clamp = true;
                c.push_event(JobEvent::MemGrant {
                    from_bytes: from,
                    to_bytes: grant,
                });
                inputs.telemetry.event(
                    "mem_grant",
                    &format!("{from}->{grant}"),
                    backend.now(),
                );
                if grant >= mem_applied {
                    // Expansion: the ledger can widen immediately.
                    backend.set_mem_budget(grant);
                    mem_applied = grant;
                } else if b_cur > pol.b_min {
                    // Shrink: force a batch-size down-step right now if
                    // the current b is no longer inside the envelope at
                    // the shrunken grant (overshoot would otherwise be
                    // guaranteed before the policy's next step).
                    let safe_b = mem_model
                        .safe_b_max(k_cur, pol.eta, mem_for_batches)
                        .max(pol.b_min);
                    if b_cur > safe_b {
                        let b_from = b_cur;
                        b_cur = safe_b;
                        stats.reconfigs += 1;
                        inputs.telemetry.event(
                            "reconfig",
                            &format!("b {b_from}->{b_cur} (mem-grant)"),
                            backend.now(),
                        );
                        c.push_event(JobEvent::Reconfig {
                            b_from,
                            b_to: b_cur,
                            k_from: k_cur,
                            k_to: k_cur,
                            reason: "mem-grant".into(),
                        });
                    }
                }
            }
        }
        // Deferred shrink application: push the shrunken grant into the
        // backend's hard accounting cap only once the pipeline has fully
        // drained (no queued or executing shard sized at the pre-shrink
        // b remains — a picked-up shard allocates incrementally, so an
        // rss check alone could re-cap under a shard that is about to
        // allocate past the new cap) and accounted usage fits under the
        // new grant. Until then the envelope bounds all *new* work at
        // the shrunken grant, so accounted usage stays within the old,
        // wider cap without overshooting the target for long.
        if mem_applied > mem_allow
            && backend.inflight() == 0
            && backend.current_rss() <= mem_allow
        {
            backend.set_mem_budget(mem_allow);
            mem_applied = mem_allow;
        }

        // --- submission (paper: pause when queue grows / guard active;
        // plus the memory gate: drain instead of overshooting a
        // shrunken grant). The memory gate only arms once the session
        // has re-partitioned this job's grant — legacy solo/sim runs
        // (and memory-blind baselines) keep their historical submission
        // behavior bit-for-bit. ---
        let queue_ok = backpressure.update(backend.queue_depth(), k_cur);
        let mem_ok = !grant_clamp
            || backpressure.update_mem(
                backend.current_rss(),
                mem_allow,
                backend.inflight(),
            );
        let allow = queue_ok && mem_ok && !aborted;
        if backpressure.pause_count() > bp_pauses_seen {
            bp_pauses_seen = backpressure.pause_count();
            if let Some(c) = &inputs.control {
                c.push_event(JobEvent::Backpressure {
                    queue_depth: backend.queue_depth(),
                });
            }
        }
        // Memory-drain pauses are telemetry-only: they can legitimately
        // cycle once per batch while a tight grant trickles work
        // through, which would flood the handle's event stream and
        // corrupt the queue-backpressure statistic.
        if backpressure.mem_pause_count() > mem_pauses_seen {
            mem_pauses_seen = backpressure.mem_pause_count();
            inputs.telemetry.event(
                "mem_pause",
                &format!("rss over grant {mem_allow}"),
                backend.now(),
            );
        }
        while allow
            && backend.queue_depth() < k_cur.max(1)
            && backend.inflight() < 2 * k_cur.max(1)
            && !part.done()
        {
            if let Some(spec) = part.next(b_cur) {
                let now = backend.now();
                t_first_submit.get_or_insert(now);
                // Carved add-range shard (B-dominant surplus): surface
                // it in stats + telemetry so reports show when the
                // carving path fired.
                if part.carved_shards() > stats.carved_shards {
                    stats.carved_shards = part.carved_shards();
                    inputs.telemetry.event(
                        "carve",
                        &format!("shard={} b_rows={}", spec.shard_id, spec.b_len),
                        now,
                    );
                }
                stragglers.on_submit(spec, now);
                inflight_ids.insert(spec.shard_id);
                backend.submit(spec);
            }
        }

        // --- collect completions ---
        // When all work is carved and inflight is zero, drain any
        // reports still in the channel (completion is visible in two
        // steps: report first, then the inflight decrement) before
        // deciding the job is done.
        let reports = if part.done() && backend.inflight() == 0 {
            let leftovers = backend.poll();
            if leftovers.is_empty() {
                break;
            }
            leftovers
        } else {
            let w0 = std::time::Instant::now();
            let got = backend.wait_any();
            wait_ns = w0.elapsed().as_nanos() as u64;
            got
        };
        let now = backend.now();
        stats.peak_rss_bytes = stats.peak_rss_bytes.max(backend.current_rss());

        for r in &reports {
            stragglers.on_complete(r.shard.shard_id);
            inflight_ids.remove(&r.shard.shard_id);
            match &r.result {
                Ok(outcome) => {
                    if !coverage.try_accept(&r.shard) {
                        continue; // lost the speculation race
                    }
                    // Cancel clones of this shard, the split original (if
                    // this is a half), and pending halves (if this is an
                    // original that outran its split).
                    backend.cancel(r.shard.shard_id);
                    if let Some(parent) = split_parent.get(&r.shard.shard_id) {
                        backend.cancel(*parent);
                    }
                    if let Some(children) = split_children.get(&r.shard.shard_id) {
                        for c in children.clone() {
                            backend.cancel(c);
                        }
                    }
                    merger.push(outcome.clone());
                    completed += 1;
                    stats.batches += 1;
                    rows_done += r.shard.rows() as u64;
                    t_last_finish = t_last_finish.max(r.finished_at);

                    // model + signal updates
                    let rows = r.shard.rows();
                    lat_window.push(r.latency());
                    rss_window.push(r.worker_rss_peak as f64);
                    all_latencies.push((r.latency(), rows as f64));
                    mem_model.observe(rows, r.worker_rss_peak as f64);
                    cost_model.observe(rows, k_cur, 0.0, r.exec_time());
                    stats.stages.add(&r.stages);
                    stats.useful_work_ns +=
                        (r.exec_time().max(0.0) * 1e9) as u64;
                    inputs.telemetry.batch(
                        r,
                        b_cur,
                        k_cur,
                        backend.queue_depth(),
                        last_round_sched_ns,
                    );
                }
                Err(BatchError::Cancelled) => {}
                Err(BatchError::Oom { needed_bytes, cap_bytes }) => {
                    stats.ooms += 1;
                    aborted = true;
                    inputs.telemetry.event(
                        "oom",
                        &format!("needed={needed_bytes} cap={cap_bytes}"),
                        now,
                    );
                }
                Err(err @ BatchError::Failed { .. }) => {
                    let n = retries.entry(r.shard.shard_id).or_insert(0);
                    if *n < 1 {
                        *n += 1;
                        let retry = ShardSpec {
                            attempt: r.shard.attempt + 1,
                            ..r.shard
                        };
                        stragglers.on_submit(retry, now);
                        inflight_ids.insert(retry.shard_id);
                        backend.submit(retry);
                        inputs.telemetry.event("retry", &err.to_string(), now);
                    } else {
                        return Err(SchedError::ShardFailed {
                            shard_id: r.shard.shard_id,
                            source: err.clone(),
                        });
                    }
                }
            }
        }

        // --- progress snapshot for the job handle ---
        if !reports.is_empty() {
            if let Some(c) = &inputs.control {
                let rss_now = backend.current_rss();
                let staged_now = backend.staged_bytes();
                c.update_progress(|p| {
                    p.rows_done = rows_done;
                    p.batches = stats.batches;
                    p.current_b = b_cur;
                    p.current_k = k_cur;
                    p.rss_bytes = rss_now;
                    p.staged_bytes = staged_now;
                    p.peak_rss_bytes = stats.peak_rss_bytes;
                    p.reconfigs = stats.reconfigs;
                    p.cache_hits = cache_now.hits;
                    p.cache_misses = cache_now.misses;
                    p.cache_resident_bytes = cache_now.resident_bytes;
                });
            }
        }

        // --- chunk-cache telemetry: one event per kind per round the
        // counter moved, with the cumulative total as detail (per-lookup
        // events would dominate the log on chunked backends). All
        // counters stay zero with the cache off, so cache-off telemetry
        // is byte-identical to the historical stream. ---
        for (kind, total, seen) in [
            ("chunk_hit", cache_now.hits, stats.cache_hits),
            ("chunk_miss", cache_now.misses, stats.cache_misses),
            ("chunk_spill", cache_now.spills, stats.cache_spills),
            ("chunk_unspill", cache_now.unspills, stats.cache_unspills),
            ("chunk_evict", cache_now.evicts, stats.cache_evicts),
        ] {
            if total > seen {
                inputs
                    .telemetry
                    .event(kind, &format!("total={total}"), now);
            }
        }
        stats.cache_hits = cache_now.hits;
        stats.cache_misses = cache_now.misses;
        stats.cache_spills = cache_now.spills;
        stats.cache_unspills = cache_now.unspills;
        stats.cache_evicts = cache_now.evicts;

        // --- control signals (EWMA-smoothed rolling p95s, §II) ---
        let util = backend.utilization_sample(caps.cpu_cap);
        util_window.push(util);
        let rss_p95 =
            rss_ewma.update(rss_window.p95().unwrap_or(0.0));
        let cpu_p95 = cpu_ewma.update(util_window.p95().unwrap_or(util));
        let p95_raw = lat_window.p95().unwrap_or(0.0);
        let signals = Signals {
            p50: lat_window.p50().unwrap_or(0.0),
            p95: p95_raw,
            p95_smooth: if p95_raw > 0.0 {
                p95_ewma.update(p95_raw)
            } else {
                0.0
            },
            rss_p95_batch: rss_p95,
            mem_signal: base_rss + k_cur as f64 * rss_p95,
            cpu_p95,
            queue_depth: backend.queue_depth(),
            inflight: backend.inflight(),
            completed,
        };

        // --- policy step, pruned by the envelope (Eq. 4, continuous) ---
        if !aborted && completed > 0 && !reports.is_empty() {
            env.b_max_safe = mem_model
                .safe_b_max(k_cur, pol.eta, mem_for_batches)
                .max(pol.b_min);
            let step = policy.step(&signals, &env);
            actions_total += 1;
            let mut nb = step.b;
            let mut nk = step.k;
            let mut clamped = step.clamped;
            if matches!(cfg.policy_kind, PolicyKind::Adaptive) || grant_clamp {
                // Continuous envelope enforcement: re-clamp the proposal
                // against the safe set at the *proposed* k. Baselines
                // are deliberately memory-blind, but once the session
                // has re-partitioned the grant mid-job, the grant binds
                // every policy (legacy solo runs never take this path).
                let safe_b = mem_model
                    .safe_b_max(nk, pol.eta, mem_for_batches)
                    .max(pol.b_min);
                if nb > safe_b {
                    nb = safe_b;
                    clamped = true;
                }
                nk = nk.clamp(k_lo, caps.cpu_cap);
            }
            // Session budget wins over any policy proposal.
            nk = nk.min(cpu_allow).max(1);
            if !clamped {
                actions_kept += 1;
            }
            if nb != b_cur || nk != k_cur {
                stats.reconfigs += 1;
                inputs.telemetry.event(
                    "reconfig",
                    &format!("b {b_cur}->{nb} k {k_cur}->{nk} ({})", step.reason),
                    now,
                );
                if let Some(c) = &inputs.control {
                    c.push_event(JobEvent::Reconfig {
                        b_from: b_cur,
                        b_to: nb,
                        k_from: k_cur,
                        k_to: nk,
                        reason: step.reason.to_string(),
                    });
                }
                if nk != k_cur {
                    backend.set_workers(nk);
                }
                b_cur = nb;
                k_cur = nk;
            }
        }

        // --- straggler mitigation ---
        if !aborted {
            for m in stragglers.detect(
                backend.now(),
                lat_window.p50(),
                pol.straggler_factor,
                pol.b_min,
            ) {
                match m {
                    Mitigation::Speculate(spec) => {
                        stats.speculations += 1;
                        inputs.telemetry.event(
                            "speculate",
                            &format!("shard={}", spec.shard_id),
                            now,
                        );
                        if let Some(c) = &inputs.control {
                            c.push_event(JobEvent::Speculation {
                                shard_id: spec.shard_id,
                            });
                        }
                        inflight_ids.insert(spec.shard_id);
                        backend.submit(spec);
                    }
                    Mitigation::Split(spec) => {
                        // Occurrence-indexed boundaries make every
                        // straggler shard with >= 2 A rows splittable —
                        // including a shard spanned by one key run, the
                        // case run snapping had to skip. When the chunk
                        // cache already holds a strict prefix of the
                        // bisected side, cut there: the left half
                        // re-executes as a pure cache hit.
                        let hint = if spec.a_len > 0 {
                            backend.cache_split_hint(
                                crate::data::chunkstore::Side::A,
                                spec.a_offset,
                                spec.a_len,
                            )
                        } else {
                            backend.cache_split_hint(
                                crate::data::chunkstore::Side::B,
                                spec.b_offset,
                                spec.b_len,
                            )
                        };
                        let (mut l, mut rgt, in_run) =
                            split_spec(a, b, spec, hint);
                        stats.splits += 1;
                        if in_run {
                            stats.splits_in_run += 1;
                        }
                        l.shard_id = next_split_id;
                        rgt.shard_id = next_split_id + 1;
                        next_split_id += 2;
                        split_parent.insert(l.shard_id, spec.shard_id);
                        split_parent.insert(rgt.shard_id, spec.shard_id);
                        split_children
                            .insert(spec.shard_id, vec![l.shard_id, rgt.shard_id]);
                        // Every split emits "split" (so the historical
                        // event count stays comparable); an in-run cut
                        // additionally emits the "split_in_run" marker.
                        inputs.telemetry.event(
                            "split",
                            &format!("shard={} -> {}+{}", spec.shard_id, l.a_len, rgt.a_len),
                            now,
                        );
                        if in_run {
                            inputs.telemetry.event(
                                "split_in_run",
                                &format!("shard={}", spec.shard_id),
                                now,
                            );
                        }
                        if let Some(c) = &inputs.control {
                            c.push_event(JobEvent::Split {
                                shard_id: spec.shard_id,
                                in_run,
                            });
                        }
                        inflight_ids.insert(l.shard_id);
                        inflight_ids.insert(rgt.shard_id);
                        backend.submit(l);
                        backend.submit(rgt);
                    }
                }
            }
        }

        last_round_sched_ns =
            (iter_t0.elapsed().as_nanos() as u64).saturating_sub(wait_ns);
        sched_ns_total += last_round_sched_ns;

        if aborted && backend.inflight() == 0 {
            break;
        }
    }

    if cancelled {
        inputs.telemetry.flush();
        return Err(SchedError::Cancelled);
    }

    // --- job aggregates (paper §V measurement) ---
    let report = merger.finish();
    stats.backpressure_pauses = backpressure.pause_count();
    stats.final_b = b_cur;
    stats.final_k = k_cur;
    stats.p50_latency = weighted_quantile(&all_latencies, 0.50).unwrap_or(0.0);
    stats.p95_latency = weighted_quantile(&all_latencies, 0.95).unwrap_or(0.0);
    let t0 = t_first_submit.unwrap_or(0.0);
    stats.makespan_secs = (t_last_finish - t0).max(0.0);
    let rows = a.nrows().max(b.nrows()) as f64;
    stats.throughput_rows_per_s = if stats.makespan_secs > 0.0 {
        rows / stats.makespan_secs
    } else {
        0.0
    };
    stats.actions_kept = if actions_total > 0 {
        actions_kept as f64 / actions_total as f64
    } else {
        1.0
    };
    stats.peak_rss_bytes = stats.peak_rss_bytes.max(base_rss as u64);
    stats.sched_overhead_ns = sched_ns_total;
    // Final cache counters (the loop's last snapshot may predate the
    // last completions) and the job's true decode count.
    let cache_final = backend.cache_stats();
    stats.cache_hits = cache_final.hits;
    stats.cache_misses = cache_final.misses;
    stats.cache_spills = cache_final.spills;
    stats.cache_unspills = cache_final.unspills;
    stats.cache_evicts = cache_final.evicts;
    stats.source_reads =
        (a.meter().ops() + b.meter().ops()).saturating_sub(read_ops0);

    inputs.telemetry.summary(&report.to_json());
    inputs.telemetry.flush();
    Ok(JobResult { report, stats })
}

/// One-shot job entry point — retained as a thin, deprecated-but-stable
/// compatibility shim over the [`DiffSession`] service API: it opens a
/// single-job session owning `cfg.caps`, submits, and joins. A solo job
/// in an idle session receives the full budget, so behaviour matches
/// the historical blocking `run_job` for every valid configuration; the
/// one deliberate change is that `cfg` is now validated up front, so
/// out-of-range configs that previously ran unchecked return a typed
/// `SchedError::InvalidConfig` instead.
///
/// New code should use [`crate::api::DiffSession`] +
/// [`crate::api::JobBuilder`] directly: multi-job admission over one
/// budget, elastic per-job memory grants, non-blocking handles with
/// progress snapshots, typed events, and cancellation. The migration is
/// mechanical:
///
/// ```text
/// // before                         // after
/// let r = run_job(&cfg, a, b)?;     let session = DiffSession::new(cfg.caps);
///                                   let job = JobBuilder::from_config(cfg, a, b).build()?;
///                                   let r = session.submit(job)?.join()?;
/// ```
pub fn run_job(
    cfg: &SchedulerConfig,
    a: Arc<dyn TableSource>,
    b: Arc<dyn TableSource>,
) -> Result<JobResult, SchedError> {
    let session = DiffSession::new(cfg.caps);
    let job = JobBuilder::from_config(cfg.clone(), a, b).build()?;
    let mut handle = session.submit(job)?;
    handle.join()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendChoice, DeltaPath};
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;

    fn small_cfg() -> SchedulerConfig {
        let mut cfg = SchedulerConfig::default();
        cfg.caps.cpu_cap = 2;
        cfg.policy.b_min = 200;
        cfg.policy.b_step_min = 50;
        cfg.engine.delta_path = DeltaPath::Native;
        cfg
    }

    fn run_small(
        cfg: &SchedulerConfig,
        rows: usize,
        seed: u64,
    ) -> (JobResult, crate::data::generator::GenTruth) {
        let (a, b, truth) =
            generate_pair(&GenSpec { rows, seed, ..GenSpec::default() });
        let r = run_job(
            cfg,
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
        )
        .unwrap();
        (r, truth)
    }

    #[test]
    fn adaptive_job_produces_correct_diff() {
        let cfg = small_cfg();
        let (r, truth) = run_small(&cfg, 5_000, 11);
        assert_eq!(r.report.rows.aligned as usize, truth.aligned);
        assert_eq!(r.report.rows.added as usize, truth.added);
        assert_eq!(r.report.rows.removed as usize, truth.removed);
        assert_eq!(r.report.rows.changed_rows as usize, truth.changed_rows);
        assert_eq!(r.stats.ooms, 0);
        assert!(r.stats.batches > 0);
        assert!(r.stats.p95_latency >= r.stats.p50_latency);
        assert!(r.stats.peak_rss_bytes > 0);
    }

    #[test]
    fn all_policies_agree_on_the_diff() {
        let mut cfg = small_cfg();
        let (rad, _) = run_small(&cfg, 4_000, 13);
        cfg.policy_kind = PolicyKind::Fixed { b: 500, k: 2 };
        let (rfix, _) = run_small(&cfg, 4_000, 13);
        cfg.policy_kind = PolicyKind::Heuristic;
        let (rheu, _) = run_small(&cfg, 4_000, 13);
        assert!(rad.report.same_diff(&rfix.report));
        assert!(rad.report.same_diff(&rheu.report));
    }

    #[test]
    fn both_backends_agree_on_the_diff() {
        let mut cfg = small_cfg();
        cfg.backend = BackendChoice::InMem;
        let (rm, _) = run_small(&cfg, 4_000, 17);
        cfg.backend = BackendChoice::DaskLike;
        let (rd, _) = run_small(&cfg, 4_000, 17);
        assert!(rm.report.same_diff(&rd.report));
        assert_eq!(rm.stats.backend, "inmem");
        assert_eq!(rd.stats.backend, "dasklike");
    }

    #[test]
    fn gate_selects_inmem_for_tiny_jobs() {
        let cfg = small_cfg();
        let (r, _) = run_small(&cfg, 2_000, 19);
        assert_eq!(r.stats.backend, "inmem");
        let g = r.stats.gate.unwrap();
        assert!(g.ws_bytes < g.threshold_bytes);
    }

    #[test]
    fn varying_b_during_job_preserves_coverage() {
        // The adaptive controller changes b mid-job; the merged row
        // totals must still cover every input row exactly once.
        let mut cfg = small_cfg();
        cfg.policy.b_min = 100;
        let (r, truth) = run_small(&cfg, 8_000, 23);
        assert_eq!(
            r.report.rows.aligned + r.report.rows.removed,
            (truth.aligned + truth.removed) as u64
        );
        assert!(r.stats.reconfigs > 0, "controller should act on an 8k job");
    }

    #[test]
    fn coverage_rejects_overlaps() {
        let mut c = Coverage::default();
        let s = |off: usize, len: usize| ShardSpec {
            shard_id: 0,
            attempt: 0,
            a_offset: off,
            a_len: len,
            b_offset: 0,
            b_len: len,
            a_occ_base: 0,
            b_occ_base: 0,
        };
        assert!(c.try_accept(&s(0, 100)));
        assert!(!c.try_accept(&s(50, 100))); // overlaps
        assert!(!c.try_accept(&s(0, 100))); // duplicate
        assert!(c.try_accept(&s(100, 50))); // adjacent ok
        assert!(!c.try_accept(&s(120, 10))); // inside accepted
        assert!(c.try_accept(&s(150, 10)));
    }

    #[test]
    fn split_spec_bisects_runs_with_matching_occ_bases() {
        use crate::data::schema::{ColumnType, Field, Schema};
        use crate::data::table::TableBuilder;
        let schema = Schema::new(vec![Field::key("id", ColumnType::Int64)]);
        let mk = |keys: &[i64]| {
            let mut tb = TableBuilder::new(schema.clone());
            for &k in keys {
                tb.col(0).push_i64(k);
            }
            InMemorySource::new(tb.finish())
        };
        // The run of 7s straddles the midpoint (a_len 6, half 3): the
        // cut lands inside the run, and B follows to the same
        // occurrence ordinal — occ 1 of key 7 on both sides.
        let a = mk(&[1, 2, 7, 7, 7, 9]);
        let b = mk(&[1, 7, 7, 7, 9, 9]);
        let spec = ShardSpec {
            shard_id: 1,
            attempt: 0,
            a_offset: 0,
            a_len: 6,
            b_offset: 0,
            b_len: 6,
            a_occ_base: 0,
            b_occ_base: 0,
        };
        let (l, r, in_run) = split_spec(&a, &b, spec, None);
        assert!(in_run, "cut at a row 3 is inside the run of 7s");
        assert_eq!(l.a_len + r.a_len, 6);
        assert_eq!(l.b_len + r.b_len, 6);
        // Left: A rows [1, 2, 7] and B rows [1, 7] (occ 0 of key 7 on
        // each side). Right resumes at occ 1 on both sides.
        assert_eq!((l.a_len, l.b_len), (3, 2));
        assert_eq!((r.a_occ_base, r.b_occ_base), (1, 1));
        // A single-run shard — unsplittable under run snapping — now
        // bisects, with both halves resuming at matching bases.
        let one_run_a = mk(&[4, 4, 4, 4]);
        let one_run_b = mk(&[4, 4, 4]);
        let spec = ShardSpec {
            shard_id: 2,
            attempt: 0,
            a_offset: 0,
            a_len: 4,
            b_offset: 0,
            b_len: 3,
            a_occ_base: 0,
            b_occ_base: 0,
        };
        let (l, r, in_run) = split_spec(&one_run_a, &one_run_b, spec, None);
        assert!(in_run);
        assert_eq!((l.a_len, l.b_len), (2, 2));
        assert_eq!((r.a_offset, r.a_len), (2, 2));
        assert_eq!((r.b_offset, r.b_len), (2, 1));
        assert_eq!((r.a_occ_base, r.b_occ_base), (2, 2));
    }

    #[test]
    fn split_spec_key_aligned() {
        let (a, b, _) =
            generate_pair(&GenSpec { rows: 1_000, seed: 3, ..GenSpec::default() });
        let (sa, sb) = (InMemorySource::new(a), InMemorySource::new(b));
        let spec = ShardSpec {
            shard_id: 7,
            attempt: 0,
            a_offset: 100,
            a_len: 400,
            b_offset: 90,
            b_len: 410,
            a_occ_base: 0,
            b_occ_base: 0,
        };
        let (l, r, _) = split_spec(&sa, &sb, spec, None);
        assert_eq!(l.a_len + r.a_len, 400);
        assert_eq!(l.b_len + r.b_len, 410);
        assert_eq!(r.a_offset, l.a_offset + l.a_len);
        assert_eq!(r.b_offset, l.b_offset + l.b_len);
        // Key alignment: last B key of left <= last A key of left < first
        // B key of right.
        let a_boundary = sa.key_at(l.a_offset + l.a_len - 1).unwrap();
        if l.b_len > 0 {
            assert!(sb.key_at(l.b_offset + l.b_len - 1).unwrap() <= a_boundary);
        }
        if r.b_len > 0 {
            assert!(sb.key_at(r.b_offset).unwrap() > a_boundary);
        }
    }
}
