//! Exponentially-weighted moving averages (paper §III: model parameters
//! and control signals smoothed with factor ρ = 0.2).

/// Scalar EWMA: y ← (1-ρ)·y + ρ·x.
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    rho: f64,
    value: Option<f64>,
}

impl Ewma {
    /// An empty EWMA with smoothing factor ρ ∈ [0, 1].
    pub fn new(rho: f64) -> Self {
        assert!((0.0..=1.0).contains(&rho), "rho in [0,1]");
        Ewma { rho, value: None }
    }
    /// Fold in a sample and return the new smoothed value (the first
    /// sample passes through unsmoothed).
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => (1.0 - self.rho) * v + self.rho * x,
        };
        self.value = Some(v);
        v
    }
    /// Current smoothed value, if any sample has been seen.
    pub fn get(&self) -> Option<f64> {
        self.value
    }
    /// Current smoothed value, or `default` before the first sample.
    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
    /// Forget all samples.
    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Windowed residual tracker for the δ_M prediction interval
/// (paper §VIII: calibrated on the last 20 batches).
#[derive(Debug, Clone)]
pub struct ResidualWindow {
    buf: std::collections::VecDeque<f64>,
    cap: usize,
}

impl ResidualWindow {
    /// A window keeping the last `cap` residuals (minimum 2).
    pub fn new(cap: usize) -> Self {
        ResidualWindow {
            buf: std::collections::VecDeque::with_capacity(cap.max(2)),
            cap: cap.max(2),
        }
    }
    /// Record a residual, evicting the oldest when full.
    pub fn push(&mut self, residual: f64) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(residual);
    }
    /// Residuals currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }
    /// Whether no residuals have been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
    /// Half-width of the (z-scaled) prediction interval: z·σ̂ of the
    /// residuals (+ |mean| to absorb bias before the model converges).
    pub fn half_width(&self, z: f64) -> f64 {
        if self.buf.len() < 2 {
            return f64::INFINITY; // no evidence yet: maximally cautious
        }
        let n = self.buf.len() as f64;
        let mean = self.buf.iter().sum::<f64>() / n;
        let var = self
            .buf
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / (n - 1.0);
        z * var.sqrt() + mean.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_first_sample_passthrough() {
        let mut e = Ewma::new(0.2);
        assert_eq!(e.get(), None);
        assert_eq!(e.update(10.0), 10.0);
        let v = e.update(20.0);
        assert!((v - 12.0).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges_to_constant() {
        let mut e = Ewma::new(0.2);
        for _ in 0..200 {
            e.update(5.0);
        }
        assert!((e.get().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn residual_window_infinite_until_two() {
        let mut r = ResidualWindow::new(20);
        assert!(r.half_width(1.96).is_infinite());
        r.push(1.0);
        assert!(r.half_width(1.96).is_infinite());
        r.push(1.2);
        assert!(r.half_width(1.96).is_finite());
    }

    #[test]
    fn residual_window_tracks_spread_and_bias() {
        let mut tight = ResidualWindow::new(20);
        let mut wide = ResidualWindow::new(20);
        for i in 0..20 {
            tight.push(if i % 2 == 0 { 0.1 } else { -0.1 });
            wide.push(if i % 2 == 0 { 5.0 } else { -5.0 });
        }
        assert!(wide.half_width(1.96) > 10.0 * tight.half_width(1.96));
        // Pure bias also widens the interval.
        let mut biased = ResidualWindow::new(20);
        for _ in 0..20 {
            biased.push(3.0);
        }
        assert!(biased.half_width(1.96) >= 3.0);
    }

    #[test]
    fn residual_window_evicts() {
        let mut r = ResidualWindow::new(3);
        for x in [100.0, 100.0, 0.1, 0.1, 0.1] {
            r.push(x);
        }
        assert_eq!(r.len(), 3);
        // Old spikes evicted: hw = 1.96·0 + |0.1|.
        assert!(r.half_width(1.96) < 0.2);
    }
}
