//! Working-set estimation and backend gating (paper Eq. 1, contribution
//! 1): ŴS = α·Ŵ·(|A|+|B|) + β; select inmem iff ŴS ≤ κ·M_cap.

use crate::config::{BackendChoice, Caps, Policy};
use crate::sched::preflight::PreflightProfile;

/// Gating constants. α captures decode/replication overheads on top of
/// raw row bytes (columnar buffers + alignment state + scratch); β is
/// the fixed process/runtime footprint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkingSetModel {
    /// Replication factor on raw row bytes (decode + align + scratch).
    pub alpha: f64,
    /// Fixed process/runtime footprint (bytes).
    pub beta_bytes: f64,
}

impl Default for WorkingSetModel {
    fn default() -> Self {
        // α≈1.6: decode buffers (~1×W) + alignment hash state (~0.4×W on
        // keyed rows) + comparator scratch (~0.2×W). β: client + compiled
        // executables + allocator slack (~150 MB, matching the paper's
        // reported scheduler memory overhead).
        WorkingSetModel { alpha: 1.6, beta_bytes: 150.0e6 }
    }
}

impl WorkingSetModel {
    /// Eq. 1. Ŵ from pre-flight already covers both sides per aligned
    /// row, so the row count here is max(|A|,|B|) — the aligned row
    /// universe — rather than the sum (which would double-count).
    pub fn estimate(&self, profile: &PreflightProfile) -> f64 {
        let rows = profile.rows_a.max(profile.rows_b) as f64;
        self.alpha * profile.w_hat * rows + self.beta_bytes
    }
}

/// Gate decision with its inputs (telemetry/report material).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GateDecision {
    /// Eq. 1 working-set estimate ŴS (bytes).
    pub ws_bytes: f64,
    /// κ·M_cap threshold the estimate was compared against (bytes).
    pub threshold_bytes: f64,
    /// The backend the gate selected.
    pub backend: BackendChoice,
}

/// Select the backend once per job (paper: gating happens once; the
/// controller then tunes (b,k) within the chosen backend).
pub fn gate_backend(
    model: &WorkingSetModel,
    profile: &PreflightProfile,
    caps: &Caps,
    policy: &Policy,
) -> GateDecision {
    let ws = model.estimate(profile);
    let threshold = policy.kappa * caps.mem_cap_bytes as f64;
    let backend = if ws <= threshold {
        BackendChoice::InMem
    } else {
        BackendChoice::DaskLike
    };
    GateDecision { ws_bytes: ws, threshold_bytes: threshold, backend }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(rows: usize, w: f64) -> PreflightProfile {
        PreflightProfile {
            w_hat: w,
            b_read: 1e9,
            rows_a: rows,
            rows_b: rows,
            sampled_rows: 1000,
            ncols: 8,
        }
    }

    fn caps() -> Caps {
        Caps { mem_cap_bytes: 64_000_000_000, cpu_cap: 32 }
    }

    #[test]
    fn small_job_gates_inmem_large_gates_dask() {
        let m = WorkingSetModel::default();
        let p = Policy::default(); // kappa = 0.7 -> threshold 44.8 GB
        // 1M rows * ~200 B/row * 1.6 ≈ 0.32 GB -> inmem.
        let d = gate_backend(&m, &profile(1_000_000, 200.0), &caps(), &p);
        assert_eq!(d.backend, BackendChoice::InMem);
        // 200M rows * 200 B * 1.6 = 64 GB > 44.8 GB -> dask.
        let d = gate_backend(&m, &profile(200_000_000, 200.0), &caps(), &p);
        assert_eq!(d.backend, BackendChoice::DaskLike);
        assert!(d.ws_bytes > d.threshold_bytes);
    }

    #[test]
    fn kappa_moves_the_boundary() {
        // A job right near the default boundary flips with κ (paper §VII
        // working-set ablation).
        let m = WorkingSetModel::default();
        let p = profile(150_000_000, 200.0); // ws = 48 GB
        let mut pol = Policy::default();
        pol.kappa = 0.6; // 38.4 GB -> dask
        assert_eq!(gate_backend(&m, &p, &caps(), &pol).backend,
                   BackendChoice::DaskLike);
        pol.kappa = 0.8; // 51.2 GB -> inmem
        assert_eq!(gate_backend(&m, &p, &caps(), &pol).backend,
                   BackendChoice::InMem);
    }

    #[test]
    fn beta_dominates_tiny_jobs() {
        let m = WorkingSetModel::default();
        let ws = m.estimate(&profile(10, 100.0));
        assert!(ws > 100.0e6, "fixed buffers floor the estimate: {ws}");
    }
}
