//! Online per-batch latency model (paper Eq. 2):
//!
//!   T̂(b,k) = T_read(b) + T_prep(b) + T_Δ(b) + T_overhead(k) − T_overlap
//!
//! Term constants come from the engine microbenchmarks (§III:
//! calibration) and are corrected online by exponential smoothing on the
//! observed/predicted ratio — the multiplicative form keeps the model
//! scale-free as b changes.

use crate::engine::microbench::CostConstants;
use crate::sched::ewma::Ewma;
use crate::sched::preflight::PreflightProfile;

/// Online Eq. 2 latency model: microbench-calibrated constants plus an
/// EWMA-smoothed multiplicative correction from observed batches.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Engine cost constants (microbench-calibrated).
    pub consts: CostConstants,
    /// Ŵ (bytes per aligned row) from pre-flight.
    pub w_hat: f64,
    /// B̂_read (effective read bandwidth, bytes/s) from pre-flight.
    pub b_read: f64,
    /// Columns entering Δ (cells per row ≈ ncols).
    pub ncols: f64,
    /// Online multiplicative correction (obs/pred), EWMA-smoothed.
    correction: Ewma,
    /// Read/compute overlap fraction (T_overlap): the pipeline overlaps
    /// decode with Δ of the previous chunk; 0 = fully serial.
    pub overlap: f64,
}

impl CostModel {
    /// A model seeded from the pre-flight profile, smoothing with ρ.
    pub fn new(consts: CostConstants, profile: &PreflightProfile, rho: f64) -> Self {
        CostModel {
            consts,
            w_hat: profile.w_hat,
            b_read: profile.b_read.max(1.0),
            ncols: profile.ncols as f64,
            correction: Ewma::new(rho),
            overlap: 0.0,
        }
    }

    /// Uncorrected Eq. 2 prediction (seconds).
    fn predict_raw(&self, b: usize, k: usize, overhead_per_batch: f64) -> f64 {
        let b = b as f64;
        let t_read = b * self.w_hat / self.b_read;
        let t_prep = b * self.w_hat * self.consts.decode_ns_per_byte * 1e-9
            + b * self.consts.align_ns_per_row * 1e-9;
        let t_delta = b * self.ncols * self.consts.delta_numeric_ns_per_cell * 1e-9;
        // Scheduler/merge overheads grow mildly with k (contention).
        let t_overhead = overhead_per_batch
            + self.consts.merge_ns_per_batch * 1e-9 * (1.0 + 0.02 * k as f64);
        let t_overlap = self.overlap * t_read.min(t_delta);
        (t_read + t_prep + t_delta + t_overhead - t_overlap).max(1e-9)
    }

    /// Predicted batch execution time in seconds for batch size b under
    /// backend overhead profile `overhead_per_batch` (seconds).
    pub fn predict(&self, b: usize, k: usize, overhead_per_batch: f64) -> f64 {
        self.predict_raw(b, k, overhead_per_batch) * self.correction.get_or(1.0)
    }

    /// Feed an observation; returns the residual (obs − pred_before).
    /// The EWMA tracks obs/raw-prediction, so the correction converges
    /// to the true scale instead of compounding.
    pub fn observe(
        &mut self,
        b: usize,
        k: usize,
        overhead_per_batch: f64,
        observed_secs: f64,
    ) -> f64 {
        let before = self.predict(b, k, overhead_per_batch);
        let raw = self.predict_raw(b, k, overhead_per_batch);
        let ratio = (observed_secs / raw).clamp(1e-4, 1e4);
        self.correction.update(ratio);
        observed_secs - before
    }

    /// Current observed/predicted correction (1.0 before any sample).
    pub fn correction_factor(&self) -> f64 {
        self.correction.get_or(1.0)
    }

    /// Batch size where variable cost ≈ `ratio` × the fixed per-batch
    /// overhead — the knee where larger b stops buying much throughput
    /// but keeps inflating latency. Used for the controller's
    /// `safe_start` (paper: "begin conservatively, climb from below").
    pub fn overhead_balanced_b(&self, ratio: f64) -> usize {
        let c = &self.consts;
        let fixed = (c.merge_ns_per_batch + c.sched_ns_per_batch) * 1e-9;
        let per_row = self.w_hat / self.b_read
            + self.w_hat * c.decode_ns_per_byte * 1e-9
            + c.align_ns_per_row * 1e-9
            + self.ncols * c.delta_numeric_ns_per_cell * 1e-9;
        ((ratio * fixed / per_row.max(1e-12)) as usize).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        let profile = PreflightProfile {
            w_hat: 100.0,
            b_read: 1e9,
            rows_a: 1_000_000,
            rows_b: 1_000_000,
            sampled_rows: 10_000,
            ncols: 8,
        };
        CostModel::new(CostConstants::default(), &profile, 0.2)
    }

    #[test]
    fn monotone_in_b() {
        let m = model();
        let t1 = m.predict(10_000, 4, 0.0);
        let t2 = m.predict(100_000, 4, 0.0);
        assert!(t2 > 5.0 * t1, "{t1} {t2}");
    }

    #[test]
    fn overhead_grows_with_k() {
        let m = model();
        assert!(m.predict(10_000, 32, 0.0) > m.predict(10_000, 1, 0.0));
    }

    #[test]
    fn correction_converges_to_observed_scale() {
        let mut m = model();
        let obs = 3.0 * model().predict(50_000, 4, 0.0);
        for _ in 0..60 {
            m.observe(50_000, 4, 0.0, obs);
        }
        let pred = m.predict(50_000, 4, 0.0);
        assert!((pred / obs - 1.0).abs() < 0.05, "pred {pred} obs {obs}");
        assert!((m.correction_factor() - 3.0).abs() < 0.2);
    }

    #[test]
    fn overlap_reduces_latency() {
        let mut m = model();
        let serial = m.predict(100_000, 4, 0.0);
        m.overlap = 0.8;
        assert!(m.predict(100_000, 4, 0.0) < serial);
    }

    #[test]
    fn residual_sign_matches() {
        let mut m = model();
        let pred = m.predict(10_000, 2, 0.0);
        let r = m.observe(10_000, 2, 0.0, pred * 2.0);
        assert!(r > 0.0);
        let r = m.observe(10_000, 2, 0.0, 1e-9);
        assert!(r < 0.0);
    }
}
