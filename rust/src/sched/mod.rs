//! The adaptive execution scheduler — the paper's contribution
//! (DESIGN.md S12–S22): pre-flight profiling, working-set backend
//! gating (Eq. 1), online cost/memory models (Eq. 2–3), the safety
//! envelope (Eq. 4), the guarded proportional hill-climb controller
//! (Eq. 5–6), backpressure, straggler mitigation, and telemetry.
//!
//! See `ARCHITECTURE.md` at the repository root for the full paper →
//! module map.
#![warn(missing_docs)]

pub mod backpressure;
pub mod controller;
pub mod cost_model;
pub mod ewma;
pub mod memory_model;
pub mod preflight;
pub mod scheduler;
pub mod straggler;
pub mod telemetry;
pub mod working_set;
