//! Batch-level telemetry (paper §IX: "we release batch-level telemetry
//! logs ... analysis is reproducible from logs"). JSON-lines format:
//! one record per accepted batch, plus control/gate events and the job
//! summary.

use std::io::Write;

use crate::api::error::SchedError;
use crate::exec::backend::BatchReport;
use crate::util::json::ObjWriter;

/// JSON-lines telemetry sink (no-op when disabled).
pub struct Telemetry {
    out: Option<std::io::BufWriter<std::fs::File>>,
    lines: u64,
}

impl Telemetry {
    /// A sink that drops every record (zero overhead).
    pub fn disabled() -> Self {
        Telemetry { out: None, lines: 0 }
    }

    /// A sink writing JSON lines to `path` (created/truncated).
    pub fn to_file(path: &str) -> Result<Self, SchedError> {
        let f = std::fs::File::create(path)
            .map_err(|e| SchedError::io(path, format!("create: {e}")))?;
        Ok(Telemetry { out: Some(std::io::BufWriter::new(f)), lines: 0 })
    }

    /// Records emitted so far (0 for a disabled sink).
    pub fn lines_written(&self) -> u64 {
        self.lines
    }

    fn emit(&mut self, line: String) {
        if let Some(out) = &mut self.out {
            let _ = out.write_all(line.as_bytes());
            let _ = out.write_all(b"\n");
            self.lines += 1;
        }
    }

    /// One accepted batch completion. `sched_ns` is the control-loop
    /// overhead attributed to this batch's scheduling round (the
    /// overhead half of the overhead/useful-work decomposition); the
    /// per-stage nanoseconds expose the read/decode/align/diff/stall
    /// pipeline split, where `stall < read + decode` signals overlap.
    pub fn batch(
        &mut self,
        r: &BatchReport,
        b: usize,
        k: usize,
        queue: usize,
        sched_ns: u64,
    ) {
        if self.out.is_none() {
            return;
        }
        let line = ObjWriter::new()
            .str("ev", "batch")
            .int("shard", r.shard.shard_id as i64)
            .int("attempt", r.shard.attempt as i64)
            .int("worker", r.worker_id as i64)
            .num("submitted", r.submitted_at)
            .num("started", r.started_at)
            .num("finished", r.finished_at)
            .num("latency", r.latency())
            .int("rows", r.shard.rows() as i64)
            .int("rss_peak", r.worker_rss_peak as i64)
            .int("io_bytes", r.io_bytes as i64)
            .int("b", b as i64)
            .int("k", k as i64)
            .int("queue", queue as i64)
            .int("read_ns", r.stages.read_ns as i64)
            .int("decode_ns", r.stages.decode_ns as i64)
            .int("align_ns", r.stages.align_ns as i64)
            .int("diff_ns", r.stages.diff_ns as i64)
            .int("stall_ns", r.stages.stall_ns as i64)
            .int("sched_ns", sched_ns as i64)
            .bool("ok", r.result.is_ok())
            .finish();
        self.emit(line);
    }

    /// Control decision / gate / mitigation event.
    pub fn event(&mut self, kind: &str, detail: &str, now: f64) {
        if self.out.is_none() {
            return;
        }
        let line = ObjWriter::new()
            .str("ev", kind)
            .str("detail", detail)
            .num("t", now)
            .finish();
        self.emit(line);
    }

    /// Final job summary (raw JSON payload from the report/stats).
    pub fn summary(&mut self, json_payload: &str) {
        if self.out.is_none() {
            return;
        }
        let line = ObjWriter::new()
            .str("ev", "summary")
            .raw("job", json_payload)
            .finish();
        self.emit(line);
    }

    /// Daemon drain summary (raw JSON payload of control-plane
    /// counters). The daemon writes this to its own `.service` sink —
    /// job sinks truncate-on-open the shared telemetry path.
    pub fn service(&mut self, json_payload: &str) {
        if self.out.is_none() {
            return;
        }
        let line = ObjWriter::new()
            .str("ev", "service")
            .raw("daemon", json_payload)
            .finish();
        self.emit(line);
    }

    /// Flush buffered records to the underlying file.
    pub fn flush(&mut self) {
        if let Some(out) = &mut self.out {
            let _ = out.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::delta::ShardMemStats;
    use crate::exec::backend::{BatchError, ShardSpec};

    fn report() -> BatchReport {
        BatchReport {
            shard: ShardSpec {
                shard_id: 3,
                attempt: 0,
                a_offset: 0,
                a_len: 100,
                b_offset: 0,
                b_len: 100,
                a_occ_base: 0,
                b_occ_base: 0,
            },
            worker_id: 1,
            submitted_at: 0.0,
            started_at: 0.1,
            finished_at: 0.5,
            result: Err(BatchError::Cancelled),
            mem: ShardMemStats::default(),
            worker_rss_peak: 1024,
            io_bytes: 2048,
            stages: crate::exec::backend::StageNanos::default(),
        }
    }

    #[test]
    fn disabled_sink_writes_nothing() {
        let mut t = Telemetry::disabled();
        t.batch(&report(), 100, 2, 0, 0);
        t.event("gate", "inmem", 0.0);
        assert_eq!(t.lines_written(), 0);
    }

    #[test]
    fn file_sink_writes_parseable_json_lines() {
        let path = std::env::temp_dir().join(format!(
            "sdiff_telemetry_{}.jsonl",
            std::process::id()
        ));
        let mut t = Telemetry::to_file(path.to_str().unwrap()).unwrap();
        t.batch(&report(), 100, 2, 5, 1_234);
        t.event("gate", "inmem ws=1.2GB", 0.1);
        t.summary(r#"{"p95":1.5}"#);
        t.flush();
        assert_eq!(t.lines_written(), 3);
        let text = std::fs::read_to_string(&path).unwrap();
        let mut kinds = Vec::new();
        for line in text.lines() {
            let v = crate::util::json::parse(line).unwrap();
            kinds.push(v.get("ev").unwrap().as_str().unwrap().to_string());
        }
        assert_eq!(kinds, vec!["batch", "gate", "summary"]);
        std::fs::remove_file(path).ok();
    }
}
