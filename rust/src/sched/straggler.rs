//! Straggler mitigation (paper §IV): batches that exceed a multiple of
//! the rolling p50 latency trigger shard splitting (large shards) or a
//! speculative duplicate (small shards); the first completion per
//! coverage range wins and the loser is cooperatively cancelled.

use std::collections::HashMap;

use crate::exec::backend::ShardSpec;

/// What to do about a detected straggler.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Mitigation {
    /// Re-submit the same range as one speculative duplicate.
    Speculate(ShardSpec),
    /// Re-submit the range as two half-shards. The *scheduler* performs
    /// the split because the B-side boundary must be re-derived from the
    /// key/occurrence indexes (a positional halve would mis-align rows).
    /// Occurrence-indexed boundaries make every `a_len >= 2` shard
    /// splittable, including one spanned by a single duplicate-key run.
    /// Carved add-range shards (`a_len = 0`, pure B surplus) split too,
    /// bisecting on the B side — any positional cut of an all-Added
    /// range is safe.
    Split(ShardSpec),
}

#[derive(Debug)]
struct Tracked {
    spec: ShardSpec,
    submitted_at: f64,
    mitigated: bool,
}

/// Tracks inflight shards and flags stragglers.
#[derive(Debug, Default)]
pub struct StragglerTracker {
    inflight: HashMap<u64, Tracked>,
    /// Speculative duplicates issued so far.
    pub speculations: u64,
    /// Shard splits issued so far.
    pub splits: u64,
}

impl StragglerTracker {
    /// An empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a submission at backend time `now`. Only primary attempts
    /// are tracked (speculative attempts are themselves the mitigation).
    pub fn on_submit(&mut self, spec: ShardSpec, now: f64) {
        if spec.attempt == 0 {
            self.inflight.insert(
                spec.shard_id,
                Tracked { spec, submitted_at: now, mitigated: false },
            );
        }
    }

    /// Stop tracking a shard that reported (any attempt).
    pub fn on_complete(&mut self, shard_id: u64) {
        self.inflight.remove(&shard_id);
    }

    /// Primary attempts currently tracked.
    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    /// Scan for stragglers. `factor` is the policy's straggler multiple,
    /// `p50` the rolling median batch latency, `b_min` the minimum batch
    /// size (splitting below 2·b_min degenerates to speculation).
    pub fn detect(
        &mut self,
        now: f64,
        p50: Option<f64>,
        factor: f64,
        b_min: usize,
    ) -> Vec<Mitigation> {
        let Some(p50) = p50 else { return Vec::new() };
        if p50 <= 0.0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        for t in self.inflight.values_mut() {
            if t.mitigated {
                continue;
            }
            if now - t.submitted_at > factor * p50 {
                t.mitigated = true;
                let spec = t.spec;
                // Large shards split; small ones speculate. Carved
                // add-range shards (a_len == 0) measure size on the B
                // side, the only side they have.
                let splittable = if spec.a_len > 0 {
                    spec.a_len >= 2 * b_min && spec.a_len >= 2
                } else {
                    spec.b_len >= 2 * b_min && spec.b_len >= 2
                };
                if splittable {
                    self.splits += 1;
                    out.push(Mitigation::Split(ShardSpec {
                        attempt: spec.attempt + 1,
                        ..spec
                    }));
                } else {
                    self.speculations += 1;
                    out.push(Mitigation::Speculate(ShardSpec {
                        attempt: spec.attempt + 1,
                        ..spec
                    }));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(id: u64, a_len: usize) -> ShardSpec {
        ShardSpec {
            shard_id: id,
            attempt: 0,
            a_offset: 100,
            a_len,
            b_offset: 200,
            b_len: a_len,
            a_occ_base: 0,
            b_occ_base: 0,
        }
    }

    #[test]
    fn no_detection_before_threshold() {
        let mut t = StragglerTracker::new();
        t.on_submit(spec(1, 1_000), 0.0);
        assert!(t.detect(1.0, Some(1.0), 4.0, 100).is_empty());
        assert!(t.detect(3.9, Some(1.0), 4.0, 100).is_empty());
    }

    #[test]
    fn small_shard_speculates_large_shard_splits() {
        let mut t = StragglerTracker::new();
        t.on_submit(spec(1, 150), 0.0); // < 2*b_min -> speculate
        t.on_submit(spec(2, 1_000), 0.0); // >= 2*b_min -> split
        let ms = t.detect(10.0, Some(1.0), 4.0, 100);
        assert_eq!(ms.len(), 2);
        let mut spec_n = 0;
        let mut split_n = 0;
        for m in ms {
            match m {
                Mitigation::Speculate(s) => {
                    spec_n += 1;
                    assert_eq!(s.attempt, 1);
                    assert_eq!(s.a_len, 150);
                }
                Mitigation::Split(s) => {
                    split_n += 1;
                    assert_eq!(s.a_len, 1_000);
                    assert_eq!(s.attempt, 1);
                }
            }
        }
        assert_eq!((spec_n, split_n), (1, 1));
        assert_eq!(t.speculations, 1);
        assert_eq!(t.splits, 1);
    }

    #[test]
    fn mitigates_each_shard_once() {
        let mut t = StragglerTracker::new();
        t.on_submit(spec(1, 150), 0.0);
        assert_eq!(t.detect(10.0, Some(1.0), 4.0, 100).len(), 1);
        assert!(t.detect(20.0, Some(1.0), 4.0, 100).is_empty());
    }

    #[test]
    fn completion_clears_tracking() {
        let mut t = StragglerTracker::new();
        t.on_submit(spec(1, 150), 0.0);
        t.on_complete(1);
        assert_eq!(t.inflight(), 0);
        assert!(t.detect(100.0, Some(1.0), 4.0, 100).is_empty());
    }

    #[test]
    fn no_p50_no_detection() {
        let mut t = StragglerTracker::new();
        t.on_submit(spec(1, 150), 0.0);
        assert!(t.detect(100.0, None, 4.0, 100).is_empty());
    }
}
