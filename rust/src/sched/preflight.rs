//! Pre-flight profiler (paper §III): estimate Ŵ (bytes per aligned row)
//! and B̂_read (effective read bandwidth) from a sample of
//! min(10⁶ rows, 1% of the job) before scheduling starts.
//!
//! B̂_read is measured from the sources' [`ReadMeter`]s — the bytes the
//! source actually transferred (file bytes for file-backed sources) —
//! not from decoded heap bytes, which can differ from storage bytes by
//! a large factor and would bias the Eq. 2 read-time term.

use crate::api::error::SchedError;
use crate::data::io::TableSource;

/// What the pre-flight pass learned about a job before scheduling.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PreflightProfile {
    /// Estimated bytes per aligned row (keys + compared attributes,
    /// summed over both sides).
    pub w_hat: f64,
    /// Effective read bandwidth during sampling, bytes/s.
    pub b_read: f64,
    /// Rows in table A.
    pub rows_a: usize,
    /// Rows in table B.
    pub rows_b: usize,
    /// Rows actually sampled across both sides.
    pub sampled_rows: usize,
    /// Numeric/native column counts (cost-model inputs).
    pub ncols: usize,
}

/// Paper defaults: 1e6 rows or 1% of the job, whichever is smaller.
pub fn sample_size(total_rows: usize, max_rows: usize, fraction: f64) -> usize {
    let pct = ((total_rows as f64) * fraction).ceil() as usize;
    pct.min(max_rows).clamp(1, total_rows.max(1))
}

/// Run the pre-flight pass. Samples evenly spaced ranges (not just the
/// head) so skewed string widths don't bias Ŵ. A sample read that fails
/// (e.g. a malformed row in a file source) is a typed error — the job
/// is rejected before admission rather than panicking mid-profile.
pub fn preflight(
    a: &dyn TableSource,
    b: &dyn TableSource,
    max_rows: usize,
    fraction: f64,
) -> Result<PreflightProfile, SchedError> {
    let rows_a = a.nrows();
    let rows_b = b.nrows();
    let total = rows_a.max(rows_b).max(1);
    let sample = sample_size(total, max_rows, fraction);

    let mut w_sum = 0.0;
    let mut sampled = 0usize;
    // Meter snapshots bracket the sampling reads: B̂_read is computed
    // from the *transferred* bytes the sources report (real file bytes
    // for CsvFileSource), not from the decoded heap bytes of the sample
    // tables.
    let meter0 = (a.meter().snapshot(), b.meter().snapshot());
    let t0 = std::time::Instant::now();
    for (src, nrows) in [(a, rows_a), (b, rows_b)] {
        if nrows == 0 {
            continue;
        }
        let per_side = (sample / 2).max(1).min(nrows);
        // Up to 8 evenly spaced probe ranges.
        let chunks = 8.min(per_side);
        let chunk_len = (per_side / chunks).max(1);
        for i in 0..chunks {
            let stride = nrows / chunks;
            let off = (i * stride).min(nrows - chunk_len);
            let t = src.read_range(off, chunk_len)?;
            w_sum += t.measured_row_bytes() * t.nrows() as f64;
            sampled += t.nrows();
        }
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let per_row = if sampled > 0 { w_sum / sampled as f64 } else { 64.0 };
    let meter1 = (a.meter().snapshot(), b.meter().snapshot());
    let bytes = (meter1.0 .0 - meter0.0 .0) + (meter1.1 .0 - meter0.1 .0);
    let nanos = (meter1.0 .1 - meter0.0 .1) + (meter1.1 .1 - meter0.1 .1);
    // In-read time from the meters when available; wall time otherwise.
    let b_read = if nanos > 0 {
        bytes as f64 / (nanos as f64 * 1e-9)
    } else {
        bytes as f64 / elapsed
    };

    Ok(PreflightProfile {
        // Ŵ covers *both sides* of an aligned row (the working set holds
        // A and B buffers simultaneously).
        w_hat: 2.0 * per_row,
        b_read,
        rows_a,
        rows_b,
        sampled_rows: sampled,
        ncols: a.schema().len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;

    #[test]
    fn sample_size_paper_rule() {
        // 1% of 10M = 100k < 1M cap.
        assert_eq!(sample_size(10_000_000, 1_000_000, 0.01), 100_000);
        // 1% of 500M = 5M > 1M cap -> capped.
        assert_eq!(sample_size(500_000_000, 1_000_000, 0.01), 1_000_000);
        assert_eq!(sample_size(50, 1_000_000, 0.01), 1);
    }

    #[test]
    fn w_hat_tracks_row_width() {
        let narrow_pair = generate_pair(&GenSpec {
            rows: 4_000,
            str_len: 8,
            seed: 1,
            ..GenSpec::default()
        });
        let wide_pair = generate_pair(&GenSpec {
            rows: 4_000,
            str_len: 64,
            seed: 1,
            ..GenSpec::default()
        });
        let (na, nb) = (
            InMemorySource::new(narrow_pair.0),
            InMemorySource::new(narrow_pair.1),
        );
        let (wa, wb) = (
            InMemorySource::new(wide_pair.0),
            InMemorySource::new(wide_pair.1),
        );
        let narrow = preflight(&na, &nb, 1_000_000, 0.25).unwrap();
        let wide = preflight(&wa, &wb, 1_000_000, 0.25).unwrap();
        assert!(wide.w_hat > narrow.w_hat + 20.0);
        assert!(narrow.b_read > 0.0);
        assert!(narrow.sampled_rows > 0);
    }

    #[test]
    fn w_hat_close_to_true_heap_ratio() {
        let (a, b, _) = generate_pair(&GenSpec {
            rows: 8_000,
            seed: 2,
            ..GenSpec::default()
        });
        let true_w = (a.heap_bytes() + b.heap_bytes()) as f64
            / a.nrows().max(b.nrows()) as f64;
        let (sa, sb) = (InMemorySource::new(a), InMemorySource::new(b));
        let p = preflight(&sa, &sb, 1_000_000, 0.5).unwrap();
        let ratio = p.w_hat / true_w;
        assert!(
            (0.5..2.0).contains(&ratio),
            "w_hat {} vs true {true_w} (ratio {ratio})",
            p.w_hat
        );
    }
}
