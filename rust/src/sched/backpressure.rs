//! Backpressure gate (paper §IV: "backpressure reduces k or pauses
//! submission when queue depth grows"). Hysteresis: pause above
//! `depth_factor · k`, resume below half of that.

#[derive(Debug, Clone, Copy)]
pub struct Backpressure {
    depth_factor: f64,
    paused: bool,
    pauses: u64,
}

impl Backpressure {
    pub fn new(depth_factor: f64) -> Self {
        Backpressure { depth_factor: depth_factor.max(1.0), paused: false, pauses: 0 }
    }

    /// Update with the current queue depth; returns whether submission
    /// is currently allowed.
    pub fn update(&mut self, queue_depth: usize, k: usize) -> bool {
        let hi = (self.depth_factor * k.max(1) as f64).ceil();
        let lo = (hi / 2.0).floor();
        if self.paused {
            if (queue_depth as f64) <= lo {
                self.paused = false;
            }
        } else if queue_depth as f64 >= hi {
            self.paused = true;
            self.pauses += 1;
        }
        !self.paused
    }

    pub fn is_paused(&self) -> bool {
        self.paused
    }
    pub fn pause_count(&self) -> u64 {
        self.pauses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pauses_and_resumes_with_hysteresis() {
        let mut bp = Backpressure::new(4.0);
        assert!(bp.update(0, 2)); // depth 0 < 8
        assert!(bp.update(7, 2));
        assert!(!bp.update(8, 2)); // hits hi=8 -> pause
        assert!(!bp.update(5, 2)); // still above lo=4
        assert!(bp.update(4, 2)); // resumes at lo
        assert_eq!(bp.pause_count(), 1);
    }

    #[test]
    fn threshold_scales_with_k() {
        let mut bp = Backpressure::new(4.0);
        assert!(bp.update(20, 8)); // hi = 32
        assert!(!bp.update(32, 8));
    }

    #[test]
    fn repeated_cycles_counted() {
        let mut bp = Backpressure::new(2.0);
        for _ in 0..3 {
            assert!(!bp.update(10, 1)); // pause (hi=2)
            assert!(bp.update(0, 1)); // resume
        }
        assert_eq!(bp.pause_count(), 3);
    }
}
