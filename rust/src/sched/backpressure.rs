//! Backpressure gate (paper §IV: "backpressure reduces k or pauses
//! submission when queue depth grows"). Two dimensions gate submission:
//!
//! * **queue depth** — hysteresis: pause above `depth_factor · k`,
//!   resume below half of that;
//! * **memory** — pause while accounted job RSS exceeds the (possibly
//!   elastically shrunken) session grant and there is inflight work to
//!   drain, so a mid-job `set_mem_budget` shrink drains toward the new
//!   cap instead of overshooting it.

/// Submission gate combining queue-depth hysteresis with a memory-drain
/// pause (see the module docs).
#[derive(Debug, Clone, Copy)]
pub struct Backpressure {
    depth_factor: f64,
    paused: bool,
    mem_paused: bool,
    pauses: u64,
    mem_pauses: u64,
}

impl Backpressure {
    /// A gate pausing above `depth_factor · k` queued shards.
    pub fn new(depth_factor: f64) -> Self {
        Backpressure {
            depth_factor: depth_factor.max(1.0),
            paused: false,
            mem_paused: false,
            pauses: 0,
            mem_pauses: 0,
        }
    }

    /// Update with the current queue depth; returns whether submission
    /// is currently allowed by the queue dimension.
    pub fn update(&mut self, queue_depth: usize, k: usize) -> bool {
        let hi = (self.depth_factor * k.max(1) as f64).ceil();
        let lo = (hi / 2.0).floor();
        if self.paused {
            if (queue_depth as f64) <= lo {
                self.paused = false;
            }
        } else if queue_depth as f64 >= hi {
            self.paused = true;
            self.pauses += 1;
        }
        !self.paused
    }

    /// Memory dimension: pause while accounted RSS exceeds the job's
    /// memory budget *and* inflight work exists to drain it; resume once
    /// usage is back under the budget. The `inflight == 0` escape keeps
    /// a job whose irreducible footprint (base tables, warmed scratch)
    /// exceeds a shrunken grant making minimal progress instead of
    /// deadlocking — the budget is then enforced as far as accounting
    /// can without evicting live data.
    pub fn update_mem(
        &mut self,
        rss_bytes: u64,
        budget_bytes: u64,
        inflight: usize,
    ) -> bool {
        if self.mem_paused {
            if rss_bytes <= budget_bytes || inflight == 0 {
                self.mem_paused = false;
            }
        } else if rss_bytes > budget_bytes && inflight > 0 {
            self.mem_paused = true;
            self.mem_pauses += 1;
        }
        !self.mem_paused
    }

    /// Whether either dimension currently pauses submission.
    pub fn is_paused(&self) -> bool {
        self.paused || self.mem_paused
    }
    /// Queue-dimension pause transitions so far (the paper's
    /// backpressure statistic; memory-drain pauses are counted
    /// separately by [`Backpressure::mem_pause_count`]).
    pub fn pause_count(&self) -> u64 {
        self.pauses
    }
    /// Memory-dimension pause transitions so far (grant-drain pauses;
    /// these can legitimately cycle once per batch while a job whose
    /// irreducible footprint exceeds a shrunken grant trickles forward).
    pub fn mem_pause_count(&self) -> u64 {
        self.mem_pauses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pauses_and_resumes_with_hysteresis() {
        let mut bp = Backpressure::new(4.0);
        assert!(bp.update(0, 2)); // depth 0 < 8
        assert!(bp.update(7, 2));
        assert!(!bp.update(8, 2)); // hits hi=8 -> pause
        assert!(!bp.update(5, 2)); // still above lo=4
        assert!(bp.update(4, 2)); // resumes at lo
        assert_eq!(bp.pause_count(), 1);
    }

    #[test]
    fn threshold_scales_with_k() {
        let mut bp = Backpressure::new(4.0);
        assert!(bp.update(20, 8)); // hi = 32
        assert!(!bp.update(32, 8));
    }

    #[test]
    fn memory_gate_pauses_until_drained() {
        let mut bp = Backpressure::new(4.0);
        assert!(bp.update_mem(100, 200, 3)); // under budget
        assert!(!bp.update_mem(250, 200, 3)); // over budget, can drain
        assert!(bp.is_paused());
        assert!(!bp.update_mem(210, 200, 1)); // still draining
        assert!(bp.update_mem(190, 200, 1)); // drained -> resume
        assert_eq!(bp.mem_pause_count(), 1);
        // The dimensions are counted independently: a memory pause does
        // not inflate the paper's queue-backpressure statistic.
        assert_eq!(bp.pause_count(), 0);
        assert!(bp.update(0, 2));
    }

    #[test]
    fn memory_gate_escapes_when_nothing_inflight() {
        let mut bp = Backpressure::new(4.0);
        // Irreducible footprint above the budget with nothing to drain:
        // submission must not deadlock.
        assert!(bp.update_mem(300, 200, 0));
        assert!(!bp.is_paused());
        // Pause engages only when draining is possible, and the escape
        // also releases an engaged pause once inflight hits zero.
        assert!(!bp.update_mem(300, 200, 2));
        assert!(bp.update_mem(300, 200, 0));
    }

    #[test]
    fn repeated_cycles_counted() {
        let mut bp = Backpressure::new(2.0);
        for _ in 0..3 {
            assert!(!bp.update(10, 1)); // pause (hi=2)
            assert!(bp.update(0, 1)); // resume
        }
        assert_eq!(bp.pause_count(), 3);
    }
}
