//! Online memory model and safety envelope (paper Eq. 3–4, contribution
//! 2):
//!
//!   Mem(b,k) ≈ k·(β₀ + β₁·b·Ŵ + β₂·b)            (3)
//!   Mem(b,k) + δ_M ≤ η·M_cap                      (4)
//!
//! β₁ starts from the working-set replication factor and is corrected
//! online by exponential smoothing on observed/predicted per-batch
//! peaks; δ_M is the z-scaled half-width of the residuals over the last
//! `delta_m_window` batches (§VIII). `safe_b_max` inverts Eq. 4 to give
//! the controller its pruned action space.

use crate::sched::ewma::{Ewma, ResidualWindow};

/// Online Eq. 3 memory model with the Eq. 4 safety envelope. The
/// `mem_cap` parameter of [`MemoryModel::is_safe`] /
/// [`MemoryModel::safe_b_max`] is whatever cap currently binds the job —
/// under a `DiffSession` that is the elastic memory grant, which can
/// shrink mid-job.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Per-worker fixed buffers (bytes).
    pub beta0: f64,
    /// Per (row·byte) multiplier (decode replication + align + scratch).
    pub beta1: f64,
    /// Per-row constant (verdict vectors, bookkeeping).
    pub beta2: f64,
    /// Ŵ (bytes per aligned row) from pre-flight.
    pub w_hat: f64,
    /// Baseline job RSS (source tables, runtime) counted against the cap.
    pub base_bytes: f64,
    /// Concurrently-resident shard buffers per worker. 1.0 for serial
    /// execution; 2.0 when the double-buffered prefetcher is active (the
    /// staged next shard is charged alongside the one being diffed), so
    /// Eq. 3–4 and the controller's pruned action space account for
    /// 2·b-worth of resident rows per worker.
    resident_shards: f64,
    correction: Ewma,
    residuals: ResidualWindow,
    z_alpha: f64,
}

impl MemoryModel {
    /// A model seeded with Ŵ from pre-flight and the paper's priors
    /// (β₀ = 16 MB, β₁ = 1.6, β₂ = 16 B/row), corrected online.
    pub fn new(
        w_hat: f64,
        base_bytes: f64,
        rho: f64,
        delta_m_window: usize,
        z_alpha: f64,
    ) -> Self {
        MemoryModel {
            beta0: 16.0e6,
            beta1: 1.6,
            beta2: 16.0,
            w_hat,
            base_bytes,
            resident_shards: 1.0,
            correction: Ewma::new(rho),
            residuals: ResidualWindow::new(delta_m_window),
            z_alpha,
        }
    }

    /// Predicted peak RSS of ONE batch (per worker), bytes.
    pub fn predict_batch(&self, b: usize) -> f64 {
        self.predict_batch_raw(b) * self.correction.get_or(1.0)
    }

    /// Eq. 3: predicted job peak with k concurrent workers, scaled by
    /// the number of concurrently-resident shard buffers per worker
    /// (2 when prefetch overlap is active).
    pub fn predict(&self, b: usize, k: usize) -> f64 {
        self.base_bytes
            + self.resident_shards * k as f64 * self.predict_batch(b)
    }

    /// Set the resident-shards-per-worker factor (≥ 1; 2.0 while the
    /// double-buffered prefetcher is active).
    pub fn set_resident_shards(&mut self, n: f64) {
        self.resident_shards = n.max(1.0);
    }

    /// δ_M: half-width of the prediction interval, scaled to k workers.
    pub fn delta_m(&self, k: usize) -> f64 {
        let hw = self.residuals.half_width(self.z_alpha);
        if hw.is_infinite() {
            // No residual evidence yet: fall back to 25% of prediction —
            // conservative but finite so the job can start.
            return f64::NAN; // callers use delta_m_or(b, k)
        }
        hw * k as f64
    }

    /// δ_M with the cold-start fallback applied.
    pub fn delta_m_or(&self, b: usize, k: usize) -> f64 {
        let d = self.delta_m(k);
        if d.is_nan() {
            0.25 * (self.predict(b, k) - self.base_bytes)
        } else {
            d
        }
    }

    /// Eq. 4 check for an action (b, k).
    pub fn is_safe(&self, b: usize, k: usize, eta: f64, mem_cap: u64) -> bool {
        self.predict(b, k) + self.delta_m_or(b, k) <= eta * mem_cap as f64
    }

    /// Largest safe b for a given k (inverts Eq. 4; 0 if none).
    pub fn safe_b_max(&self, k: usize, eta: f64, mem_cap: u64) -> usize {
        // Solve with the cold-start fallback folded in: with fallback,
        // envelope is base + 1.25·k·pred_batch(b) ≤ η·cap.
        let budget = eta * mem_cap as f64 - self.base_bytes;
        if budget <= 0.0 {
            return 0;
        }
        let hw = self.residuals.half_width(self.z_alpha);
        let (scale, extra) = if hw.is_infinite() {
            (1.25, 0.0)
        } else {
            (1.0, hw * k as f64)
        };
        let per_worker = ((budget - extra)
            / (scale * self.resident_shards * k as f64))
            .max(0.0);
        let corr = self.correction.get_or(1.0);
        let per_row = (self.beta1 * self.w_hat + self.beta2) * corr;
        let b = ((per_worker - self.beta0 * corr) / per_row).floor();
        if b.is_finite() && b > 0.0 {
            b as usize
        } else {
            0
        }
    }

    /// Uncorrected Eq. 3 per-batch term.
    fn predict_batch_raw(&self, b: usize) -> f64 {
        let b = b as f64;
        self.beta0 + self.beta1 * b * self.w_hat + self.beta2 * b
    }

    /// Feed an observed per-batch peak for batch size b. The EWMA tracks
    /// obs/raw-prediction (stable convergence, no compounding).
    pub fn observe(&mut self, b: usize, observed_peak_bytes: f64) {
        let pred = self.predict_batch(b);
        let raw = self.predict_batch_raw(b).max(1.0);
        let ratio = (observed_peak_bytes / raw).clamp(1e-4, 1e4);
        self.correction.update(ratio);
        self.residuals.push(observed_peak_bytes - pred);
    }

    /// Residuals currently backing the δ_M interval.
    pub fn residual_count(&self) -> usize {
        self.residuals.len()
    }
    /// Current observed/predicted correction (1.0 before any sample).
    pub fn correction_factor(&self) -> f64 {
        self.correction.get_or(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> MemoryModel {
        MemoryModel::new(200.0, 1.0e9, 0.2, 20, 1.96)
    }

    #[test]
    fn prediction_scales_with_b_and_k() {
        let m = model();
        assert!(m.predict(100_000, 4) > m.predict(10_000, 4));
        assert!(m.predict(10_000, 8) > m.predict(10_000, 4));
    }

    #[test]
    fn safe_b_max_inverts_eq4() {
        let mut m = model();
        // Warm the residual window so δ_M is finite and small.
        for _ in 0..20 {
            let pred = m.predict_batch(50_000);
            m.observe(50_000, pred * 1.01);
        }
        let cap = 64_000_000_000u64;
        let eta = 0.9;
        for k in [1usize, 4, 16, 32] {
            let bmax = m.safe_b_max(k, eta, cap);
            assert!(bmax > 0);
            assert!(m.is_safe(bmax, k, eta, cap), "k={k} bmax={bmax}");
            // One step beyond must violate (within rounding slack).
            let over = bmax + bmax / 50 + 1_000;
            assert!(
                !m.is_safe(over, k, eta, cap),
                "k={k} over={over} should violate"
            );
        }
        // More workers -> smaller safe b.
        assert!(m.safe_b_max(32, eta, cap) < m.safe_b_max(4, eta, cap));
    }

    #[test]
    fn cold_start_is_conservative() {
        let cold = model();
        let mut warm = model();
        for _ in 0..20 {
            let pred = warm.predict_batch(50_000);
            warm.observe(50_000, pred);
        }
        let cap = 64_000_000_000u64;
        assert!(cold.safe_b_max(8, 0.9, cap) < warm.safe_b_max(8, 0.9, cap));
    }

    #[test]
    fn observation_corrects_underestimates() {
        let mut m = model();
        let before = m.predict_batch(100_000);
        for _ in 0..40 {
            m.observe(100_000, 3.0 * before);
        }
        let after = m.predict_batch(100_000);
        assert!(after > 2.0 * before, "model should learn 3x: {after}");
    }

    #[test]
    fn no_budget_means_zero() {
        let m = MemoryModel::new(200.0, 1.0e12, 0.2, 20, 1.96);
        assert_eq!(m.safe_b_max(4, 0.9, 1_000_000_000), 0);
    }

    #[test]
    fn resident_shards_scales_envelope() {
        let mut m = model();
        let base = m.predict(50_000, 4) - m.base_bytes;
        let b1 = m.safe_b_max(4, 0.9, 64_000_000_000);
        m.set_resident_shards(2.0);
        let doubled = m.predict(50_000, 4) - m.base_bytes;
        assert!((doubled / base - 2.0).abs() < 1e-9, "batch term doubles");
        let b2 = m.safe_b_max(4, 0.9, 64_000_000_000);
        assert!(b2 < b1, "pruned action space shrinks: {b2} !< {b1}");
        // Roughly halves (β₀ offset keeps it from exactly half).
        assert!((b2 as f64) < 0.6 * b1 as f64, "b2={b2} b1={b1}");
        // Values below 1 are clamped back to serial semantics.
        m.set_resident_shards(0.0);
        assert_eq!(m.safe_b_max(4, 0.9, 64_000_000_000), b1);
    }

    #[test]
    fn delta_m_scales_with_k() {
        let mut m = model();
        for i in 0..20 {
            let pred = m.predict_batch(10_000);
            m.observe(10_000, pred + if i % 2 == 0 { 1e6 } else { -1e6 });
        }
        let d4 = m.delta_m(4);
        let d8 = m.delta_m(8);
        assert!((d8 / d4 - 2.0).abs() < 1e-9);
    }
}
