//! The guarded proportional hill-climb controller (paper §IV, Eq. 5–6)
//! and the `TuningPolicy` trait that the scheduler drives — the adaptive
//! controller and the §V baselines (fixed grid, warm-up heuristic) are
//! interchangeable behind it.
//!
//! Decision structure follows the paper's pseudocode exactly:
//!   1. safety-first multiplicative decreases (memory guard / tail
//!      trigger, with m-consecutive hysteresis);
//!   2. CPU over-target → reduce k;
//!   3. otherwise proportional increases driven by whichever resource
//!      has more normalized headroom (ties prefer b);
//!   4. every proposal is pruned by the Eq. 4 envelope and the CPU cap
//!      (the scheduler passes `b_max_safe` from the memory model).

use crate::config::{Caps, Policy};

/// Smoothed control signals computed by the scheduler after each
/// completion round (paper §II instrumentation).
#[derive(Debug, Clone, Copy, Default)]
pub struct Signals {
    /// Rolling-window batch-latency p50 (seconds).
    pub p50: f64,
    /// Rolling-window batch-latency p95 (seconds).
    pub p95: f64,
    /// EWMA-smoothed window p95 (the hill-climb objective signal; raw
    /// p95 is too straggler-noisy to judge single actions against).
    pub p95_smooth: f64,
    /// EWMA-smoothed p95 of per-batch worker RSS peaks (bytes).
    pub rss_p95_batch: f64,
    /// Job-level memory signal: base + k · rss_p95_batch (bytes).
    pub mem_signal: f64,
    /// EWMA-smoothed p95 CPU utilization as a fraction of the CPU cap.
    pub cpu_p95: f64,
    /// Shards submitted but not yet started.
    pub queue_depth: usize,
    /// Shards submitted but not finished (pipeline depth — increases
    /// are judged only after the pre-increase pipeline drains).
    pub inflight: usize,
    /// Accepted batch completions so far.
    pub completed: u64,
}

/// Environment the scheduler provides to a policy step.
#[derive(Debug, Clone, Copy)]
pub struct PolicyEnv {
    /// Resource caps in force. Under a `DiffSession`, `mem_cap_bytes`
    /// tracks the job's *current elastic grant*, not the admission-time
    /// cap — the scheduler loop updates it when the session
    /// re-partitions.
    pub caps: Caps,
    /// Controller/gating policy parameters.
    pub policy: Policy,
    /// Eq. 4 pruning: largest safe b at the *current* k.
    pub b_max_safe: usize,
    /// Base job RSS in bytes (for mem-signal reconstruction if needed).
    pub base_rss: f64,
    /// Aligned-row universe (max(|A|,|B|)) — lets safe_start scale the
    /// initial b so small jobs still get enough batches to adapt over.
    pub job_rows: usize,
    /// Cost-model hint: the overhead-balanced batch size (the knee
    /// where fixed per-batch costs stop dominating).
    pub b_hint: usize,
}

/// One policy decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PolicyStep {
    /// Proposed batch size.
    pub b: usize,
    /// Proposed worker count.
    pub k: usize,
    /// Whether (b, k) differs from the previous decision.
    pub changed: bool,
    /// Whether the Eq. 4 envelope clipped the proposal (the §VIII
    /// "actions kept" statistic counts the complement).
    pub clamped: bool,
    /// Human-readable decision tag (telemetry / `JobEvent::Reconfig`).
    pub reason: &'static str,
}

/// A (b,k) tuning policy.
pub trait TuningPolicy: Send {
    /// Stable policy name ("adaptive" / "fixed" / "heuristic").
    fn name(&self) -> &'static str;
    /// Initial (b, k) before any batch completes.
    fn initial(&mut self, env: &PolicyEnv) -> (usize, usize);
    /// Called after each completion round.
    fn step(&mut self, s: &Signals, env: &PolicyEnv) -> PolicyStep;
}

/// A tentative increase awaiting its objective evaluation.
#[derive(Debug, Clone, Copy)]
struct PendingEval {
    /// Which dimension was increased (true = b, false = k).
    dim_b: bool,
    prev: usize,
    p95_before: f64,
    eval_at: u64,
}

/// How many completions to wait before judging an increase, how much
/// p95 degradation is tolerated, and how long a reverted dimension is
/// blocked. These are the "guarded" part of the guarded hill-climb: the
/// objective is p95, so an increase that degrades it is undone and that
/// direction parked — without this, the headroom-proportional rule
/// grows b monotonically until per-batch latency dominates the tail.
const EVAL_DELAY: u64 = 4;
/// b inflates per-batch latency directly — judge it tightly. k mostly
/// affects queueing/contention — give it more slack before reverting.
const DEGRADE_TOL_B: f64 = 0.20;
const DEGRADE_TOL_K: f64 = 0.25;
const BLOCK_ROUNDS: u64 = 32;
/// Return-to-best: if the smoothed objective drifts this far above the
/// best configuration seen, jump back to it. A wide margin + settle
/// delay keeps this a runaway-drift safety net, not a competing
/// controller (the per-action objective guard does the fine work).
const BEST_DRIFT: f64 = 0.6;
const SETTLE_ROUNDS: u64 = 16;

/// The paper's adaptive controller.
pub struct AdaptiveController {
    b: usize,
    k: usize,
    /// Consecutive decrease-trigger counts (hysteresis, §IV).
    mem_or_tail_triggers: u32,
    cpu_triggers: u32,
    /// Completions remaining before the next increase is allowed
    /// ("increases ... when recent batches are stable").
    cooldown: u32,
    pending: Option<PendingEval>,
    blocked_b_until: u64,
    blocked_k_until: u64,
    /// Best (b, k, smoothed p95) seen so far — hill-climb memory.
    best: Option<(usize, usize, f64)>,
    /// Completion count at the last applied change (settle timer).
    last_change_at: u64,
}

impl AdaptiveController {
    /// A controller in its pre-`initial` state.
    pub fn new() -> Self {
        AdaptiveController {
            b: 0,
            k: 0,
            mem_or_tail_triggers: 0,
            cpu_triggers: 0,
            cooldown: 0,
            pending: None,
            blocked_b_until: 0,
            blocked_k_until: 0,
            best: None,
            last_change_at: 0,
        }
    }
    /// The (b, k) currently held by the controller.
    pub fn bk(&self) -> (usize, usize) {
        (self.b, self.k)
    }

    fn clamp(&self, env: &PolicyEnv, b: usize, k: usize) -> (usize, usize) {
        let p = &env.policy;
        let k = k.clamp(p.k_min, env.caps.cpu_cap);
        let b_hi = env.b_max_safe.max(p.b_min).min(p.b_max);
        let b = b.clamp(p.b_min, b_hi);
        (b, k)
    }
}

impl Default for AdaptiveController {
    fn default() -> Self {
        Self::new()
    }
}

impl TuningPolicy for AdaptiveController {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    /// `safe_start`: begin at a deliberately conservative point — the
    /// controller climbs from below instead of backing off from above.
    fn initial(&mut self, env: &PolicyEnv) -> (usize, usize) {
        let p = &env.policy;
        let k0 = (env.caps.cpu_cap / 4).clamp(p.k_min, env.caps.cpu_cap);
        // Quarter of the cold-start-safe b, further bounded so the job
        // yields enough batches (≥ ~8 per worker) for the hill-climb to
        // observe and act on.
        let by_job = (env.job_rows / (8 * k0)).max(1);
        let b0 = (env.b_max_safe / 4)
            .min(by_job)
            .min(env.b_hint.max(p.b_min))
            .clamp(p.b_min, p.b_max);
        let (b, k) = self.clamp(env, b0, k0);
        self.b = b;
        self.k = k;
        (b, k)
    }

    fn step(&mut self, s: &Signals, env: &PolicyEnv) -> PolicyStep {
        let p = &env.policy;
        let eta_cap = p.eta * env.caps.mem_cap_bytes as f64;
        let (old_b, old_k) = (self.b, self.k);
        let mut reason = "hold";

        // --- hill-climb memory: remember the best configuration ---
        // Only once the window is representative (full pipeline), and
        // keep the record honest while sitting at the best config.
        if s.p95_smooth > 0.0 && s.completed >= env.policy.window as u64 / 2 {
            match self.best {
                Some((bb, bk, bp)) if bb == self.b && bk == self.k => {
                    self.best =
                        Some((bb, bk, 0.8 * bp + 0.2 * s.p95_smooth));
                }
                Some((_, _, bp)) if s.p95_smooth >= bp => {}
                _ => self.best = Some((self.b, self.k, s.p95_smooth)),
            }
        }

        // --- objective guard: judge the last increase against p95 ---
        if let Some(pe) = self.pending {
            if s.completed >= pe.eval_at {
                self.pending = None;
                let tol = if pe.dim_b { DEGRADE_TOL_B } else { DEGRADE_TOL_K };
                if pe.p95_before > 0.0
                    && s.p95_smooth > pe.p95_before * (1.0 + tol)
                {
                    // The increase hurt the objective: revert + park.
                    if pe.dim_b {
                        self.b = pe.prev;
                        self.blocked_b_until = s.completed + BLOCK_ROUNDS;
                        reason = "revert-b";
                    } else {
                        self.k = pe.prev.max(p.k_min);
                        self.blocked_k_until = s.completed + BLOCK_ROUNDS;
                        reason = "revert-k";
                    }
                    let raw_b = self.b;
                    let (b, k) = self.clamp(env, self.b, self.k);
                    self.b = b;
                    self.k = k;
                    if b != old_b || k != old_k {
                        self.last_change_at = s.completed;
                    }
                    return PolicyStep {
                        b,
                        k,
                        changed: b != old_b || k != old_k,
                        clamped: b < raw_b,
                        reason,
                    };
                }
            }
        }

        // --- return-to-best: undo slow upward drift of the objective ---
        if self.pending.is_none()
            && s.completed >= self.last_change_at + SETTLE_ROUNDS
        {
            if let Some((bb, bk, bp)) = self.best {
                if s.p95_smooth > bp * (1.0 + BEST_DRIFT)
                    && (self.b != bb || self.k != bk)
                {
                    self.b = bb;
                    self.k = bk;
                    self.blocked_b_until = s.completed + BLOCK_ROUNDS;
                    self.blocked_k_until = s.completed + BLOCK_ROUNDS / 2;
                    let (b, k) = self.clamp(env, self.b, self.k);
                    self.b = b;
                    self.k = k;
                    if b != old_b || k != old_k {
                        self.last_change_at = s.completed;
                    }
                    return PolicyStep {
                        b,
                        k,
                        changed: b != old_b || k != old_k,
                        clamped: false,
                        reason: "return-to-best",
                    };
                }
            }
        }

        // --- safety-first decreases (hysteresis: m consecutive) ---
        let tail_spike = s.p50 > 0.0 && s.p95 / s.p50 > p.tau;
        let mem_near = s.mem_signal >= eta_cap;
        if mem_near || tail_spike {
            self.pending = None;
            self.mem_or_tail_triggers += 1;
            if self.mem_or_tail_triggers >= p.hysteresis_m {
                // Memory pressure may push b all the way to b_min
                // (safety first); pure tail spikes floor at a fraction
                // of the overhead-balanced point so repeated straggler
                // noise cannot drive the job off the throughput cliff.
                let floor = if mem_near {
                    p.b_min
                } else {
                    p.b_min.max(env.b_hint / 4)
                };
                self.b = ((p.gamma * self.b as f64).floor() as usize).max(floor);
                self.k = self.k.saturating_sub(1).max(p.k_min);
                self.mem_or_tail_triggers = 0;
                self.cooldown = p.hysteresis_m;
                reason = if mem_near { "mem-backoff" } else { "tail-backoff" };
            } else {
                reason = "trigger-armed";
            }
        } else {
            self.mem_or_tail_triggers = 0;
            // --- CPU over target: reduce k first ---
            if s.cpu_p95 > p.rho_star {
                self.cpu_triggers += 1;
                if self.cpu_triggers >= p.hysteresis_m {
                    self.k = self.k.saturating_sub(1).max(p.k_min);
                    self.cpu_triggers = 0;
                    self.cooldown = p.hysteresis_m;
                    reason = "cpu-backoff";
                } else {
                    reason = "cpu-armed";
                }
            } else {
                self.cpu_triggers = 0;
                // --- proportional increases (Eq. 5–6) ---
                if self.cooldown > 0 {
                    self.cooldown -= 1;
                    reason = "cooldown";
                } else if self.pending.is_none() {
                    let h_mem = ((eta_cap - s.mem_signal) / eta_cap).max(0.0);
                    let h_cpu = ((p.rho_star - s.cpu_p95) / p.rho_star).max(0.0);
                    let b_ok = s.completed >= self.blocked_b_until
                        && self.b < env.b_max_safe.min(p.b_max);
                    let k_ok = s.completed >= self.blocked_k_until
                        && self.k < env.caps.cpu_cap;
                    // Increase whichever resource has more normalized
                    // headroom (ties prefer b), skipping parked dims.
                    let grow_b = h_mem > p.eps
                        && b_ok
                        && (!k_ok
                            || h_cpu <= p.eps
                            || h_mem >= h_cpu + p.eps
                            || (h_mem - h_cpu).abs() < p.eps);
                    let grow_k = !grow_b && h_cpu > p.eps && k_ok;
                    if grow_b {
                        // Δb = ⌊λ_b · h_mem · b⌋.
                        let db = ((p.lambda_b * h_mem * self.b as f64)
                            .floor() as usize)
                            .max(p.b_step_min);
                        self.pending = Some(PendingEval {
                            dim_b: true,
                            prev: self.b,
                            p95_before: s.p95_smooth,
                            // Post-increase batches only exist after the
                            // current pipeline drains.
                            eval_at: s.completed + s.inflight as u64 + EVAL_DELAY,
                        });
                        self.b += db;
                        reason = "increase-b";
                        self.cooldown = 1;
                    } else if grow_k {
                        // Δk = ⌈λ_k · h_cpu · k⌉.
                        let dk = ((p.lambda_k * h_cpu * self.k as f64)
                            .ceil() as usize)
                            .max(1);
                        self.pending = Some(PendingEval {
                            dim_b: false,
                            prev: self.k,
                            p95_before: s.p95_smooth,
                            eval_at: s.completed + s.inflight as u64 + EVAL_DELAY,
                        });
                        self.k += dk;
                        reason = "increase-k";
                        self.cooldown = 1;
                    }
                }
            }
        }

        // --- prune by the envelope + caps (Eq. 4) ---
        let raw_b = self.b;
        let (b, k) = self.clamp(env, self.b, self.k);
        self.b = b;
        self.k = k;
        let changed = b != old_b || k != old_k;
        if changed {
            self.last_change_at = s.completed;
        }
        PolicyStep { b, k, changed, clamped: b < raw_b, reason }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(b_max_safe: usize) -> PolicyEnv {
        PolicyEnv {
            caps: Caps::default(), // 64 GB, 32 cores
            policy: Policy::default(),
            b_max_safe,
            base_rss: 0.0,
            job_rows: 100_000_000,
            b_hint: 100_000,
        }
    }

    fn healthy_signals(mem_frac: f64, cpu: f64) -> Signals {
        let cap = 64.0e9;
        Signals {
            p50: 1.0,
            p95: 1.3,
            p95_smooth: 1.3,
            rss_p95_batch: mem_frac * cap / 8.0,
            mem_signal: mem_frac * 0.9 * cap,
            cpu_p95: cpu,
            queue_depth: 0,
            inflight: 0,
            completed: 10,
        }
    }

    #[test]
    fn initial_is_conservative_and_safe() {
        let mut c = AdaptiveController::new();
        let e = env(400_000);
        let (b, k) = c.initial(&e);
        assert_eq!(k, 8); // 32/4
        assert_eq!(b, 100_000); // 400k/4
        assert!(b <= e.b_max_safe);
    }

    #[test]
    fn grows_b_when_memory_headroom_dominates() {
        let mut c = AdaptiveController::new();
        let e = env(2_000_000);
        c.initial(&e);
        let (b0, _) = c.bk();
        // Lots of memory headroom, CPU near target -> b grows. p95 stays
        // flat, so the objective guard keeps every increase.
        let mut s = healthy_signals(0.2, 0.80);
        let mut grew = 0;
        for i in 0..40 {
            s.completed = 10 + i;
            let step = c.step(&s, &e);
            if step.reason == "increase-b" {
                grew += 1;
            }
            assert_ne!(step.reason, "revert-b", "flat p95 must not revert");
        }
        assert!(grew >= 3, "grew={grew}");
        assert!(c.bk().0 > b0);
    }

    #[test]
    fn grows_k_when_cpu_headroom_dominates() {
        let mut c = AdaptiveController::new();
        let e = env(2_000_000);
        c.initial(&e);
        let (_, k0) = c.bk();
        // Memory nearly exhausted relative to guard, CPU idle -> k grows.
        let s = healthy_signals(0.95, 0.10);
        for _ in 0..10 {
            c.step(&s, &e);
        }
        assert!(c.bk().1 > k0);
    }

    #[test]
    fn memory_guard_backoff_with_hysteresis() {
        let mut c = AdaptiveController::new();
        let e = env(1_000_000);
        c.initial(&e);
        let (b0, k0) = c.bk();
        let cap = 64.0e9;
        let s = Signals {
            p50: 1.0,
            p95: 1.2,
            mem_signal: 0.95 * cap, // above η=0.9 guard
            rss_p95_batch: 1e9,
            cpu_p95: 0.5,
            completed: 5,
            ..Default::default()
        };
        // First trigger arms; second fires (m=2).
        let s1 = c.step(&s, &e);
        assert!(!s1.changed);
        assert_eq!(s1.reason, "trigger-armed");
        let s2 = c.step(&s, &e);
        assert_eq!(s2.reason, "mem-backoff");
        assert!(c.bk().0 <= (0.6 * b0 as f64) as usize + 1);
        assert_eq!(c.bk().1, k0 - 1);
    }

    #[test]
    fn tail_spike_backoff() {
        let mut c = AdaptiveController::new();
        let e = env(1_000_000);
        c.initial(&e);
        let s = Signals {
            p50: 1.0,
            p95: 3.0, // p95/p50 = 3 > tau = 2
            mem_signal: 1e9,
            rss_p95_batch: 1e8,
            cpu_p95: 0.5,
            completed: 5,
            ..Default::default()
        };
        c.step(&s, &e);
        let step = c.step(&s, &e);
        assert_eq!(step.reason, "tail-backoff");
    }

    #[test]
    fn cpu_over_target_reduces_k() {
        let mut c = AdaptiveController::new();
        let e = env(1_000_000);
        c.initial(&e);
        let k0 = c.bk().1;
        let s = healthy_signals(0.2, 0.95); // CPU > ρ*=0.85
        c.step(&s, &e);
        let step = c.step(&s, &e);
        assert_eq!(step.reason, "cpu-backoff");
        assert_eq!(c.bk().1, k0 - 1);
    }

    #[test]
    fn proposals_always_within_envelope_and_caps() {
        let mut c = AdaptiveController::new();
        let e = env(50_000);
        c.initial(&e);
        let s = healthy_signals(0.05, 0.05);
        for _ in 0..50 {
            c.step(&s, &e);
            let (b, k) = c.bk();
            assert!(b <= e.b_max_safe.max(e.policy.b_min));
            assert!(k <= e.caps.cpu_cap);
            assert!(b >= e.policy.b_min && k >= e.policy.k_min);
        }
    }

    #[test]
    fn never_below_minimums_under_sustained_backoff() {
        let mut c = AdaptiveController::new();
        let e = env(1_000_000);
        c.initial(&e);
        let s = Signals {
            p50: 1.0,
            p95: 10.0,
            mem_signal: 70e9,
            rss_p95_batch: 1e9,
            cpu_p95: 1.0,
            queue_depth: 100,
            completed: 5,
            ..Default::default()
        };
        for _ in 0..100 {
            c.step(&s, &e);
        }
        assert_eq!(c.bk().0, e.policy.b_min);
        assert_eq!(c.bk().1, e.policy.k_min);
    }
}
