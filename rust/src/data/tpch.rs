//! TPC-H-shaped query-output generator (paper §V: "public TPC-H query
//! outputs of comparable result sizes").
//!
//! The paper diffs *query outputs*, not base tables, so we generate
//! result sets with the schemas and value distributions of three
//! representative TPC-H queries — Q3 (order revenue), Q10 (customer
//! returns) and a Q1-like wide aggregate — at any requested row count,
//! then derive a perturbed B side with the same machinery the synthetic
//! generator uses (substitution documented in DESIGN.md §4.4: no dbgen
//! dependency; what matters to the scheduler is width, type mix and
//! skew, which these reproduce).

use crate::data::generator::GenTruth;
use crate::data::schema::{ColumnType, Field, Schema};
use crate::data::table::{Table, TableBuilder};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TpchQuery {
    /// l_orderkey, revenue, o_orderdate, o_shippriority
    Q3,
    /// c_custkey, c_name, revenue, c_acctbal, n_name, c_address, c_phone,
    /// c_comment — wide, string-heavy.
    Q10,
    /// returnflag/linestatus groups × aggregates — numeric-heavy. Real Q1
    /// returns 4 groups; we emulate a fine-grained GROUP BY (per
    /// supplier) to reach the requested result size, same shape.
    Q1Wide,
}

impl TpchQuery {
    pub fn name(&self) -> &'static str {
        match self {
            TpchQuery::Q3 => "q3",
            TpchQuery::Q10 => "q10",
            TpchQuery::Q1Wide => "q1wide",
        }
    }

    pub fn schema(&self) -> Schema {
        match self {
            TpchQuery::Q3 => Schema::new(vec![
                Field::key("l_orderkey", ColumnType::Int64),
                Field::new("revenue", ColumnType::Decimal { scale: 2 }),
                Field::new("o_orderdate", ColumnType::Date),
                Field::new("o_shippriority", ColumnType::Int64),
            ]),
            TpchQuery::Q10 => Schema::new(vec![
                Field::key("c_custkey", ColumnType::Int64),
                Field::new("c_name", ColumnType::Utf8),
                Field::new("revenue", ColumnType::Decimal { scale: 2 }),
                Field::new("c_acctbal", ColumnType::Float64),
                Field::new("n_name", ColumnType::Utf8),
                Field::new("c_address", ColumnType::Utf8),
                Field::new("c_phone", ColumnType::Utf8),
                Field::new("c_comment", ColumnType::Utf8),
            ]),
            TpchQuery::Q1Wide => Schema::new(vec![
                Field::key("group_key", ColumnType::Int64),
                Field::new("l_returnflag", ColumnType::Utf8),
                Field::new("l_linestatus", ColumnType::Utf8),
                Field::new("sum_qty", ColumnType::Decimal { scale: 2 }),
                Field::new("sum_base_price", ColumnType::Decimal { scale: 2 }),
                Field::new("sum_disc_price", ColumnType::Decimal { scale: 4 }),
                Field::new("sum_charge", ColumnType::Decimal { scale: 6 }),
                Field::new("avg_qty", ColumnType::Float64),
                Field::new("avg_price", ColumnType::Float64),
                Field::new("avg_disc", ColumnType::Float64),
                Field::new("count_order", ColumnType::Int64),
            ]),
        }
    }
}

const NATIONS: [&str; 10] = [
    "ALGERIA", "ARGENTINA", "BRAZIL", "CANADA", "EGYPT", "ETHIOPIA",
    "FRANCE", "GERMANY", "INDIA", "JAPAN",
];

fn push_q3_row(tb: &mut TableBuilder, key: i64, rng: &mut Rng) {
    tb.col(0).push_i64(key);
    // Revenue: lognormal-ish, matches TPC-H's extendedprice*(1-disc) spread.
    let rev = (30_000.0 * rng.lognormal(0.6)) as i128;
    tb.col(1).push_dec(rev);
    tb.col(2).push_date(rng.range_i64(8_000, 9_500) as i32); // ~1992-1996
    tb.col(3).push_i64(0);
}

fn push_q10_row(tb: &mut TableBuilder, key: i64, rng: &mut Rng) {
    tb.col(0).push_i64(key);
    tb.col(1).push_str(&format!("Customer#{key:09}"));
    tb.col(2).push_dec((50_000.0 * rng.lognormal(0.5)) as i128);
    tb.col(3).push_f64(rng.uniform(-999.99, 9999.99));
    tb.col(4).push_str(NATIONS[rng.range_usize(0, NATIONS.len())]);
    let addr_len = 10 + rng.range_usize(0, 30);
    tb.col(5).push_str(&rng.alnum(addr_len));
    tb.col(6).push_str(&format!(
        "{}-{}-{}-{}",
        rng.range_u64(10, 35),
        rng.range_u64(100, 999),
        rng.range_u64(100, 999),
        rng.range_u64(1000, 9999)
    ));
    let comment_len = 20 + rng.range_usize(0, 90);
    tb.col(7).push_str(&rng.alnum(comment_len));
}

fn push_q1_row(tb: &mut TableBuilder, key: i64, rng: &mut Rng) {
    tb.col(0).push_i64(key);
    tb.col(1).push_str(["A", "N", "R"][rng.range_usize(0, 3)]);
    tb.col(2).push_str(["F", "O"][rng.range_usize(0, 2)]);
    let n = rng.range_i64(1_000, 2_000_000);
    tb.col(3).push_dec((n * 2550) as i128 / 100);
    tb.col(4).push_dec((n as f64 * 38_000.0) as i128);
    tb.col(5).push_dec((n as f64 * 36_100.0 * 100.0) as i128);
    tb.col(6).push_dec((n as f64 * 37_544.0 * 10_000.0) as i128);
    tb.col(7).push_f64(rng.uniform(24.0, 26.0));
    tb.col(8).push_f64(rng.uniform(35_000.0, 40_000.0));
    tb.col(9).push_f64(rng.uniform(0.04, 0.06));
    tb.col(10).push_i64(n);
}

/// Generate a query-output table with `rows` result rows.
pub fn generate_output(query: TpchQuery, rows: usize, seed: u64) -> Table {
    let schema = query.schema();
    let mut rng = Rng::new(seed ^ 0x7C9);
    let mut tb = TableBuilder::new(schema);
    for i in 0..rows {
        let key = 2 * i as i64; // even keys; inserts take odd (as generator)
        match query {
            TpchQuery::Q3 => push_q3_row(&mut tb, key, &mut rng),
            TpchQuery::Q10 => push_q10_row(&mut tb, key, &mut rng),
            TpchQuery::Q1Wide => push_q1_row(&mut tb, key, &mut rng),
        }
    }
    tb.finish()
}

/// Generate an (A, B) pair of query outputs: B re-runs the "query" after
/// a simulated upstream change — some aggregates shift (changed), some
/// result rows disappear (removed) or appear (added).
pub fn generate_output_pair(
    query: TpchQuery,
    rows: usize,
    change_rate: f64,
    add_remove_rate: f64,
    seed: u64,
) -> (Table, Table, GenTruth) {
    let a = generate_output(query, rows, seed);
    let schema = query.schema();
    let mut rng = Rng::new(seed ^ 0xB0B);
    let mut tb = TableBuilder::new(schema.clone());
    let mut truth = GenTruth::default();
    for i in 0..rows {
        if rng.chance(add_remove_rate / 2.0) {
            truth.removed += 1;
            continue;
        }
        let perturb = rng.chance(change_rate);
        if perturb {
            // Re-derive the row with jitter on the numeric aggregates.
            for ci in 0..a.ncols() {
                let cell = a.column(ci).cell(i);
                match cell {
                    crate::data::column::Cell::Dec { mantissa, .. } => {
                        let jit = (mantissa as f64 * rng.uniform(0.001, 0.02))
                            as i128;
                        tb.col(ci).push_dec(mantissa + jit.max(1));
                    }
                    crate::data::column::Cell::F64(x) => {
                        tb.col(ci).push_f64(x * rng.uniform(1.001, 1.05));
                    }
                    other => tb.col(ci).push_cell(&other),
                }
            }
            truth.changed_rows += 1;
        } else {
            for ci in 0..a.ncols() {
                tb.col(ci).push_cell(&a.column(ci).cell(i));
            }
        }
        truth.aligned += 1;
        if rng.chance(add_remove_rate / 2.0) {
            let key = 2 * i as i64 + 1;
            match query {
                TpchQuery::Q3 => push_q3_row(&mut tb, key, &mut rng),
                TpchQuery::Q10 => push_q10_row(&mut tb, key, &mut rng),
                TpchQuery::Q1Wide => push_q1_row(&mut tb, key, &mut rng),
            }
            truth.added += 1;
        }
    }
    (a, tb.finish(), truth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::column::Cell;

    #[test]
    fn schemas_have_i64_keys() {
        for q in [TpchQuery::Q3, TpchQuery::Q10, TpchQuery::Q1Wide] {
            let s = q.schema();
            let keys = s.key_indices();
            assert_eq!(keys, vec![0], "{:?}", q);
            assert_eq!(s.fields[0].ty, ColumnType::Int64);
        }
    }

    #[test]
    fn q10_is_string_heavy_and_wider_than_q3() {
        let q3 = generate_output(TpchQuery::Q3, 500, 1);
        let q10 = generate_output(TpchQuery::Q10, 500, 1);
        assert!(q10.measured_row_bytes() > 2.0 * q3.measured_row_bytes());
    }

    #[test]
    fn deterministic() {
        let a = generate_output(TpchQuery::Q1Wide, 300, 5);
        let b = generate_output(TpchQuery::Q1Wide, 300, 5);
        assert_eq!(a, b);
    }

    #[test]
    fn pair_truth_consistent() {
        let (a, b, t) =
            generate_output_pair(TpchQuery::Q3, 2_000, 0.1, 0.04, 3);
        assert_eq!(a.nrows(), 2_000);
        assert_eq!(t.aligned + t.removed, a.nrows());
        assert_eq!(b.nrows(), t.aligned + t.added);
        assert!(t.changed_rows > 50);
    }

    #[test]
    fn keys_sorted() {
        let (_, b, _) =
            generate_output_pair(TpchQuery::Q10, 1_000, 0.1, 0.1, 9);
        let mut prev = i64::MIN;
        for i in 0..b.nrows() {
            match b.column(0).cell(i) {
                Cell::I64(k) => {
                    assert!(k > prev);
                    prev = k;
                }
                other => panic!("{other:?}"),
            }
        }
    }
}
