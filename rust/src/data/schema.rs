//! Schema model: column types, fields, and key designation.

use std::fmt;

/// Column data types supported by the engine. The numeric family
/// (Int64/Float64/Decimal) routes through the PJRT Δ path; the rest are
/// compared natively (DESIGN.md §Hardware-Adaptation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ColumnType {
    Int64,
    Float64,
    /// UTF-8 string.
    Utf8,
    Bool,
    /// Days since Unix epoch.
    Date,
    /// Microseconds since Unix epoch.
    Timestamp,
    /// Fixed-point i128 mantissa with per-column decimal scale.
    Decimal { scale: u8 },
}

impl ColumnType {
    pub fn is_numeric(&self) -> bool {
        matches!(
            self,
            ColumnType::Int64 | ColumnType::Float64 | ColumnType::Decimal { .. }
        )
    }

    /// In-memory bytes per value (excl. null bitmap; Utf8 is the average
    /// payload estimate used only for working-set estimation defaults).
    pub fn value_bytes(&self) -> usize {
        match self {
            ColumnType::Int64 => 8,
            ColumnType::Float64 => 8,
            ColumnType::Utf8 => 16, // offset + avg payload estimate
            ColumnType::Bool => 1,
            ColumnType::Date => 4,
            ColumnType::Timestamp => 8,
            ColumnType::Decimal { .. } => 16,
        }
    }

    /// Loose comparability for schema alignment: numeric types align with
    /// each other; everything else requires an exact type match.
    pub fn comparable_with(&self, other: &ColumnType) -> bool {
        if self == other {
            return true;
        }
        self.is_numeric() && other.is_numeric()
    }

    pub fn name(&self) -> String {
        match self {
            ColumnType::Int64 => "int64".into(),
            ColumnType::Float64 => "float64".into(),
            ColumnType::Utf8 => "utf8".into(),
            ColumnType::Bool => "bool".into(),
            ColumnType::Date => "date".into(),
            ColumnType::Timestamp => "timestamp".into(),
            ColumnType::Decimal { scale } => format!("decimal({scale})"),
        }
    }
}

impl fmt::Display for ColumnType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// A named, typed column slot in a schema.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Field {
    pub name: String,
    pub ty: ColumnType,
    pub nullable: bool,
    /// Part of the row-alignment key f (primary/business key component).
    pub key: bool,
}

impl Field {
    pub fn new(name: &str, ty: ColumnType) -> Self {
        Field { name: name.into(), ty, nullable: true, key: false }
    }
    pub fn key(name: &str, ty: ColumnType) -> Self {
        Field { name: name.into(), ty, nullable: false, key: true }
    }
    pub fn not_null(mut self) -> Self {
        self.nullable = false;
        self
    }
}

#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Schema {
    pub fields: Vec<Field>,
}

impl Schema {
    pub fn new(fields: Vec<Field>) -> Self {
        Schema { fields }
    }
    pub fn len(&self) -> usize {
        self.fields.len()
    }
    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }
    pub fn field(&self, name: &str) -> Option<(usize, &Field)> {
        self.fields
            .iter()
            .enumerate()
            .find(|(_, f)| f.name == name)
    }
    /// Indices of key columns, in declaration order.
    pub fn key_indices(&self) -> Vec<usize> {
        self.fields
            .iter()
            .enumerate()
            .filter(|(_, f)| f.key)
            .map(|(i, _)| i)
            .collect()
    }
    /// Estimated bytes per row (working-set default before pre-flight
    /// refines it with measured string payloads).
    pub fn est_row_bytes(&self) -> usize {
        self.fields.iter().map(|f| f.ty.value_bytes() + 1).sum()
    }

    /// Parse a `name[:key]:type,...` spec (the `--schema` CLI / wire
    /// format) into a schema. Types: `int64`, `float64`, `utf8`,
    /// `bool`, `date`, `timestamp`, `decimal(SCALE)`.
    pub fn parse_spec(spec: &str) -> Result<Self, crate::api::error::SchedError> {
        use crate::api::error::SchedError;
        let mut fields = Vec::new();
        for part in spec.split(',') {
            let bits: Vec<&str> = part.split(':').collect();
            let (name, key, ty_name) = match bits.as_slice() {
                [n, t] => (*n, false, *t),
                [n, "key", t] => (*n, true, *t),
                _ => {
                    return Err(SchedError::parse(
                        "schema",
                        format!("bad schema field {part:?}"),
                    ))
                }
            };
            let ty = match ty_name {
                "int64" => ColumnType::Int64,
                "float64" => ColumnType::Float64,
                "utf8" => ColumnType::Utf8,
                "bool" => ColumnType::Bool,
                "date" => ColumnType::Date,
                "timestamp" => ColumnType::Timestamp,
                other => {
                    if let Some(scale) = other
                        .strip_prefix("decimal(")
                        .and_then(|s| s.strip_suffix(')'))
                    {
                        ColumnType::Decimal {
                            scale: scale.parse().map_err(|_| {
                                SchedError::parse(
                                    "schema",
                                    format!("bad decimal scale {other:?}"),
                                )
                            })?,
                        }
                    } else {
                        return Err(SchedError::parse(
                            "schema",
                            format!("unknown type {other:?}"),
                        ));
                    }
                }
            };
            fields.push(if key {
                Field::key(name, ty)
            } else {
                Field::new(name, ty)
            });
        }
        Ok(Schema::new(fields))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Schema {
        Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("amount", ColumnType::Float64),
            Field::new("name", ColumnType::Utf8),
            Field::new("flag", ColumnType::Bool),
            Field::new("d", ColumnType::Date),
            Field::new("ts", ColumnType::Timestamp),
            Field::new("price", ColumnType::Decimal { scale: 2 }),
        ])
    }

    #[test]
    fn key_indices_and_lookup() {
        let s = demo();
        assert_eq!(s.key_indices(), vec![0]);
        assert_eq!(s.field("amount").unwrap().0, 1);
        assert!(s.field("nope").is_none());
    }

    #[test]
    fn numeric_comparability() {
        assert!(ColumnType::Int64.comparable_with(&ColumnType::Float64));
        assert!(ColumnType::Float64
            .comparable_with(&ColumnType::Decimal { scale: 2 }));
        assert!(!ColumnType::Utf8.comparable_with(&ColumnType::Bool));
        assert!(ColumnType::Utf8.comparable_with(&ColumnType::Utf8));
    }

    #[test]
    fn row_bytes_positive() {
        assert!(demo().est_row_bytes() > 40);
    }

    #[test]
    fn type_names() {
        assert_eq!(ColumnType::Decimal { scale: 3 }.name(), "decimal(3)");
        assert_eq!(ColumnType::Int64.to_string(), "int64");
    }
}
