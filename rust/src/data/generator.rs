//! Synthetic workload generator (paper §V: "synthetic tables with mixed
//! types and sizes {1,5,10,20}M rows per side").
//!
//! Generates a pair (A, B) where B is derived from A by controlled
//! perturbation: cell-level value changes, row deletions (→ REMOVED) and
//! row insertions (→ ADDED). Keys are even integers in A; inserted rows
//! take odd keys so both sides stay key-sorted — the range partitioner
//! relies on that ordering, exactly like SmartDiff's PK-aligned batches.

use crate::data::column::Cell;
use crate::data::schema::{ColumnType, Schema};
use crate::data::table::{mixed_schema, Table, TableBuilder};
use crate::util::rng::Rng;

/// Perturbation + shape spec for a synthetic pair.
#[derive(Debug, Clone)]
pub struct GenSpec {
    /// Rows in table A.
    pub rows: usize,
    /// Payload columns beyond the key (mixed types, see `mixed_schema`).
    pub extra_cols: usize,
    /// Probability a payload cell is NULL.
    pub null_rate: f64,
    /// Probability an aligned row has at least one changed cell.
    pub change_rate: f64,
    /// Fraction of A-rows deleted in B (REMOVED verdicts).
    pub remove_rate: f64,
    /// Inserted rows in B as a fraction of |A| (ADDED verdicts).
    pub add_rate: f64,
    /// Relative magnitude of numeric perturbations.
    pub value_noise: f64,
    /// Mean string payload length (row width Ŵ knob for the κ ablation:
    /// "narrow rows" ≈ 8, wide ≈ 64).
    pub str_len: usize,
    pub seed: u64,
}

impl Default for GenSpec {
    fn default() -> Self {
        GenSpec {
            rows: 10_000,
            extra_cols: 7,
            null_rate: 0.03,
            change_rate: 0.05,
            remove_rate: 0.01,
            add_rate: 0.01,
            value_noise: 0.1,
            str_len: 12,
            seed: 42,
        }
    }
}

impl GenSpec {
    pub fn with_rows(mut self, rows: usize) -> Self {
        self.rows = rows;
        self
    }
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn schema(&self) -> Schema {
        mixed_schema(self.extra_cols)
    }
}

/// Ground-truth outcome counts implied by the generator, used to verify
/// engine correctness end-to-end (row-level, not cell-level).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GenTruth {
    pub aligned: usize,
    pub changed_rows: usize,
    pub removed: usize,
    pub added: usize,
}

fn push_random_payload(
    tb: &mut TableBuilder,
    schema: &Schema,
    rng: &mut Rng,
    spec: &GenSpec,
) {
    for (ci, field) in schema.fields.iter().enumerate().skip(1) {
        if rng.chance(spec.null_rate) {
            tb.col(ci).push_null();
            continue;
        }
        match field.ty {
            ColumnType::Int64 => tb.col(ci).push_i64(rng.range_i64(-1_000_000, 1_000_000)),
            ColumnType::Float64 => tb.col(ci).push_f64(rng.normal_ms(0.0, 100.0)),
            ColumnType::Utf8 => {
                let len = (spec.str_len as f64 * rng.uniform(0.5, 1.5)) as usize;
                let s = rng.alnum(len.max(1));
                tb.col(ci).push_str(&s);
            }
            ColumnType::Bool => tb.col(ci).push_bool(rng.chance(0.5)),
            ColumnType::Date => tb.col(ci).push_date(rng.range_i64(10_000, 20_000) as i32),
            ColumnType::Timestamp => {
                tb.col(ci).push_ts(rng.range_i64(1_500_000_000_000_000, 1_700_000_000_000_000))
            }
            ColumnType::Decimal { .. } => {
                tb.col(ci).push_dec(rng.range_i64(-10_000_000, 10_000_000) as i128)
            }
        }
    }
}

/// Copy row `i` of `src` into `tb`, perturbing payload cells when
/// `perturb` fires (at least one cell is always perturbed then).
fn push_copied_row(
    tb: &mut TableBuilder,
    src: &Table,
    i: usize,
    rng: &mut Rng,
    spec: &GenSpec,
    perturb: bool,
) {
    let ncols = src.ncols();
    // Choose which payload cells to mutate.
    let mut mutate = vec![false; ncols];
    if perturb {
        let target = rng.range_usize(1, ncols);
        mutate[target] = true;
        for m in mutate.iter_mut().skip(1) {
            if rng.chance(0.15) {
                *m = true;
            }
        }
    }
    for ci in 0..ncols {
        let cell = src.column(ci).cell(i);
        if ci == 0 || !mutate[ci] {
            tb.col(ci).push_cell(&cell);
            continue;
        }
        // Mutate: null flip or value change.
        if matches!(cell, Cell::Null) {
            // null -> value
            match src.schema.fields[ci].ty {
                ColumnType::Int64 => tb.col(ci).push_i64(rng.range_i64(0, 1000)),
                ColumnType::Float64 => tb.col(ci).push_f64(rng.normal()),
                ColumnType::Utf8 => tb.col(ci).push_str("filled"),
                ColumnType::Bool => tb.col(ci).push_bool(true),
                ColumnType::Date => tb.col(ci).push_date(12_345),
                ColumnType::Timestamp => tb.col(ci).push_ts(1_600_000_000_000_000),
                ColumnType::Decimal { .. } => tb.col(ci).push_dec(100),
            }
            continue;
        }
        if rng.chance(0.05) {
            tb.col(ci).push_null(); // value -> null
            continue;
        }
        match cell {
            Cell::I64(x) => tb.col(ci).push_i64(x + rng.range_i64(1, 100)),
            Cell::F64(x) => tb
                .col(ci)
                .push_f64(x + spec.value_noise * (x.abs() + 1.0) * (rng.f64() + 0.1)),
            Cell::Str(s) => {
                let mut t = s.to_string();
                t.push('~');
                tb.col(ci).push_str(&t);
            }
            Cell::Bool(b) => tb.col(ci).push_bool(!b),
            Cell::Date(d) => tb.col(ci).push_date(d + rng.range_i64(1, 30) as i32),
            Cell::Ts(t) => tb.col(ci).push_ts(t + rng.range_i64(1_000_000, 3_600_000_000)),
            Cell::Dec { mantissa, .. } => {
                tb.col(ci).push_dec(mantissa + rng.range_i64(1, 10_000) as i128)
            }
            Cell::Null => unreachable!(),
        }
    }
}

/// Generate the (A, B) pair plus ground truth.
pub fn generate_pair(spec: &GenSpec) -> (Table, Table, GenTruth) {
    let schema = spec.schema();
    let mut rng = Rng::new(spec.seed);

    // Table A: keys 0, 2, 4, ... (even), sorted.
    let mut ta = TableBuilder::new(schema.clone());
    for i in 0..spec.rows {
        ta.col(0).push_i64(2 * i as i64);
        push_random_payload(&mut ta, &schema, &mut rng, spec);
    }
    let a = ta.finish();

    // Table B: walk A in key order; delete, copy/perturb, and insert.
    let mut truth = GenTruth::default();
    let mut tb = TableBuilder::new(schema.clone());
    let mut brng = rng.fork(0xB);
    for i in 0..spec.rows {
        if brng.chance(spec.remove_rate) {
            truth.removed += 1;
            continue;
        }
        let perturb = brng.chance(spec.change_rate);
        push_copied_row(&mut tb, &a, i, &mut brng, spec, perturb);
        truth.aligned += 1;
        if perturb {
            truth.changed_rows += 1;
        }
        if brng.chance(spec.add_rate) {
            // Insert a fresh row with the odd key 2i+1 (keeps order).
            tb.col(0).push_i64(2 * i as i64 + 1);
            push_random_payload(&mut tb, &schema, &mut brng, spec);
            truth.added += 1;
        }
    }
    let b = tb.finish();
    (a, b, truth)
}

/// Generate a single standalone table (profiling / io tests).
pub fn generate_table(spec: &GenSpec) -> Table {
    generate_pair(spec).0
}

/// The paper's four synthetic workload sizes, in rows per side.
pub const PAPER_WORKLOADS: [(&str, usize); 4] = [
    ("1M", 1_000_000),
    ("5M", 5_000_000),
    ("10M", 10_000_000),
    ("20M", 20_000_000),
];

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> GenSpec {
        GenSpec { rows: 2_000, seed: 7, ..GenSpec::default() }
    }

    #[test]
    fn deterministic_given_seed() {
        let (a1, b1, t1) = generate_pair(&small());
        let (a2, b2, t2) = generate_pair(&small());
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn truth_accounts_for_all_rows() {
        let spec = small();
        let (a, b, t) = generate_pair(&spec);
        assert_eq!(a.nrows(), spec.rows);
        assert_eq!(t.aligned + t.removed, a.nrows());
        assert_eq!(b.nrows(), t.aligned + t.added);
        assert!(t.changed_rows > 0 && t.removed > 0 && t.added > 0);
    }

    #[test]
    fn keys_sorted_both_sides() {
        let (a, b, _) = generate_pair(&small());
        for t in [&a, &b] {
            let col = t.column(0);
            let mut prev = i64::MIN;
            for i in 0..t.nrows() {
                let k = match col.cell(i) {
                    Cell::I64(k) => k,
                    other => panic!("bad key {other:?}"),
                };
                assert!(k > prev, "keys must be strictly increasing");
                prev = k;
            }
        }
    }

    #[test]
    fn unperturbed_rows_identical() {
        let mut spec = small();
        spec.change_rate = 0.0;
        spec.remove_rate = 0.0;
        spec.add_rate = 0.0;
        let (a, b, t) = generate_pair(&spec);
        assert_eq!(a, b);
        assert_eq!(t.changed_rows, 0);
    }

    #[test]
    fn str_len_controls_width() {
        let narrow = generate_table(&GenSpec { str_len: 8, rows: 500, ..small() });
        let wide = generate_table(&GenSpec { str_len: 64, rows: 500, ..small() });
        assert!(wide.measured_row_bytes() > narrow.measured_row_bytes() + 20.0);
    }
}
