//! Synthetic workload generator (paper §V: "synthetic tables with mixed
//! types and sizes {1,5,10,20}M rows per side").
//!
//! Generates a pair (A, B) where B is derived from A by controlled
//! perturbation: cell-level value changes, row deletions (→ REMOVED) and
//! row insertions (→ ADDED). Keys are even integers in A; inserted rows
//! take odd keys so both sides stay key-sorted — the range partitioner
//! relies on that ordering, exactly like SmartDiff's PK-aligned batches.

use crate::data::column::Cell;
use crate::data::schema::{ColumnType, Schema};
use crate::data::table::{mixed_schema, Table, TableBuilder};
use crate::util::rng::Rng;

/// Perturbation + shape spec for a synthetic pair.
#[derive(Debug, Clone)]
pub struct GenSpec {
    /// Rows in table A.
    pub rows: usize,
    /// Payload columns beyond the key (mixed types, see `mixed_schema`).
    pub extra_cols: usize,
    /// Probability a payload cell is NULL.
    pub null_rate: f64,
    /// Probability an aligned row has at least one changed cell.
    pub change_rate: f64,
    /// Fraction of A-rows deleted in B (REMOVED verdicts).
    pub remove_rate: f64,
    /// Inserted rows in B as a fraction of |A| (ADDED verdicts).
    pub add_rate: f64,
    /// Relative magnitude of numeric perturbations.
    pub value_noise: f64,
    /// Mean string payload length (row width Ŵ knob for the κ ablation:
    /// "narrow rows" ≈ 8, wide ≈ 64).
    pub str_len: usize,
    pub seed: u64,
}

impl Default for GenSpec {
    fn default() -> Self {
        GenSpec {
            rows: 10_000,
            extra_cols: 7,
            null_rate: 0.03,
            change_rate: 0.05,
            remove_rate: 0.01,
            add_rate: 0.01,
            value_noise: 0.1,
            str_len: 12,
            seed: 42,
        }
    }
}

impl GenSpec {
    pub fn with_rows(mut self, rows: usize) -> Self {
        self.rows = rows;
        self
    }
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn schema(&self) -> Schema {
        mixed_schema(self.extra_cols)
    }
}

/// Ground-truth outcome counts implied by the generator, used to verify
/// engine correctness end-to-end (row-level, not cell-level).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GenTruth {
    pub aligned: usize,
    pub changed_rows: usize,
    pub removed: usize,
    pub added: usize,
}

fn push_random_payload(
    tb: &mut TableBuilder,
    schema: &Schema,
    rng: &mut Rng,
    spec: &GenSpec,
) {
    for (ci, field) in schema.fields.iter().enumerate().skip(1) {
        if rng.chance(spec.null_rate) {
            tb.col(ci).push_null();
            continue;
        }
        match field.ty {
            ColumnType::Int64 => tb.col(ci).push_i64(rng.range_i64(-1_000_000, 1_000_000)),
            ColumnType::Float64 => tb.col(ci).push_f64(rng.normal_ms(0.0, 100.0)),
            ColumnType::Utf8 => {
                let len = (spec.str_len as f64 * rng.uniform(0.5, 1.5)) as usize;
                let s = rng.alnum(len.max(1));
                tb.col(ci).push_str(&s);
            }
            ColumnType::Bool => tb.col(ci).push_bool(rng.chance(0.5)),
            ColumnType::Date => tb.col(ci).push_date(rng.range_i64(10_000, 20_000) as i32),
            ColumnType::Timestamp => {
                tb.col(ci).push_ts(rng.range_i64(1_500_000_000_000_000, 1_700_000_000_000_000))
            }
            ColumnType::Decimal { .. } => {
                tb.col(ci).push_dec(rng.range_i64(-10_000_000, 10_000_000) as i128)
            }
        }
    }
}

/// Copy row `i` of `src` into `tb`, perturbing payload cells when
/// `perturb` fires (at least one cell is always perturbed then).
fn push_copied_row(
    tb: &mut TableBuilder,
    src: &Table,
    i: usize,
    rng: &mut Rng,
    spec: &GenSpec,
    perturb: bool,
) {
    let ncols = src.ncols();
    // Choose which payload cells to mutate.
    let mut mutate = vec![false; ncols];
    if perturb {
        let target = rng.range_usize(1, ncols);
        mutate[target] = true;
        for m in mutate.iter_mut().skip(1) {
            if rng.chance(0.15) {
                *m = true;
            }
        }
    }
    for ci in 0..ncols {
        let cell = src.column(ci).cell(i);
        if ci == 0 || !mutate[ci] {
            tb.col(ci).push_cell(&cell);
            continue;
        }
        // Mutate: null flip or value change.
        if matches!(cell, Cell::Null) {
            // null -> value
            match src.schema.fields[ci].ty {
                ColumnType::Int64 => tb.col(ci).push_i64(rng.range_i64(0, 1000)),
                ColumnType::Float64 => tb.col(ci).push_f64(rng.normal()),
                ColumnType::Utf8 => tb.col(ci).push_str("filled"),
                ColumnType::Bool => tb.col(ci).push_bool(true),
                ColumnType::Date => tb.col(ci).push_date(12_345),
                ColumnType::Timestamp => tb.col(ci).push_ts(1_600_000_000_000_000),
                ColumnType::Decimal { .. } => tb.col(ci).push_dec(100),
            }
            continue;
        }
        if rng.chance(0.05) {
            tb.col(ci).push_null(); // value -> null
            continue;
        }
        match cell {
            Cell::I64(x) => tb.col(ci).push_i64(x + rng.range_i64(1, 100)),
            Cell::F64(x) => tb
                .col(ci)
                .push_f64(x + spec.value_noise * (x.abs() + 1.0) * (rng.f64() + 0.1)),
            Cell::Str(s) => {
                let mut t = s.to_string();
                t.push('~');
                tb.col(ci).push_str(&t);
            }
            Cell::Bool(b) => tb.col(ci).push_bool(!b),
            Cell::Date(d) => tb.col(ci).push_date(d + rng.range_i64(1, 30) as i32),
            Cell::Ts(t) => tb.col(ci).push_ts(t + rng.range_i64(1_000_000, 3_600_000_000)),
            Cell::Dec { mantissa, .. } => {
                tb.col(ci).push_dec(mantissa + rng.range_i64(1, 10_000) as i128)
            }
            Cell::Null => unreachable!(),
        }
    }
}

/// Generate the (A, B) pair plus ground truth.
pub fn generate_pair(spec: &GenSpec) -> (Table, Table, GenTruth) {
    let schema = spec.schema();
    let mut rng = Rng::new(spec.seed);

    // Table A: keys 0, 2, 4, ... (even), sorted.
    let mut ta = TableBuilder::new(schema.clone());
    for i in 0..spec.rows {
        ta.col(0).push_i64(2 * i as i64);
        push_random_payload(&mut ta, &schema, &mut rng, spec);
    }
    let a = ta.finish();

    // Table B: walk A in key order; delete, copy/perturb, and insert.
    let mut truth = GenTruth::default();
    let mut tb = TableBuilder::new(schema.clone());
    let mut brng = rng.fork(0xB);
    for i in 0..spec.rows {
        if brng.chance(spec.remove_rate) {
            truth.removed += 1;
            continue;
        }
        let perturb = brng.chance(spec.change_rate);
        push_copied_row(&mut tb, &a, i, &mut brng, spec, perturb);
        truth.aligned += 1;
        if perturb {
            truth.changed_rows += 1;
        }
        if brng.chance(spec.add_rate) {
            // Insert a fresh row with the odd key 2i+1 (keeps order).
            tb.col(0).push_i64(2 * i as i64 + 1);
            push_random_payload(&mut tb, &schema, &mut brng, spec);
            truth.added += 1;
        }
    }
    let b = tb.finish();
    (a, b, truth)
}

/// Generate a single standalone table (profiling / io tests).
pub fn generate_table(spec: &GenSpec) -> Table {
    generate_pair(spec).0
}

/// Shape of an extreme-join-skew workload: duplicate-key runs whose
/// lengths follow a Zipf law, with a configurable fraction of all rows
/// concentrated on the single hottest key. `hot_key_mass = 1.0` is the
/// adversarial case — one key spanning every row — that run-snapped
/// partitioning could not subdivide (ROADMAP "extreme join skew").
#[derive(Debug, Clone)]
pub struct SkewSpec {
    /// Rows in table A.
    pub rows: usize,
    /// Fraction of A's rows carried by the hottest key (0.0..=1.0).
    pub hot_key_mass: f64,
    /// Zipf exponent shaping the remaining keys' run lengths (s ≠ 1).
    pub zipf_s: f64,
    /// Distinct keys besides the hot one (ignored when
    /// `hot_key_mass >= 1.0`).
    pub cold_keys: usize,
    /// Payload columns beyond the key (mixed types).
    pub extra_cols: usize,
    /// Probability a copied row gets perturbed payload cells.
    pub change_rate: f64,
    /// Per-run length jitter on the B side (adds/removes occurrences,
    /// producing added/removed rows *inside* runs).
    pub run_jitter: f64,
    /// B-dominant skew knob: mass of *pure surplus* added rows —
    /// `(rows × b_surplus_mass)` B rows on a single key with **no A
    /// counterpart** — appended after A's key range. `0.0` (the
    /// default) is a bitwise no-op on the generated pair; a large value
    /// makes one key's B-only added run dwarf `|A|`, the add-range
    /// carving workload (see `exec/partition.rs`).
    pub b_surplus_mass: f64,
    pub seed: u64,
}

impl Default for SkewSpec {
    fn default() -> Self {
        SkewSpec {
            rows: 10_000,
            hot_key_mass: 0.3,
            zipf_s: 1.2,
            cold_keys: 500,
            extra_cols: 3,
            change_rate: 0.05,
            run_jitter: 0.2,
            b_surplus_mass: 0.0,
            seed: 42,
        }
    }
}

/// Generate a key-sorted (A, B) pair with Zipf-hot-key duplicate runs.
///
/// A's hottest key (key 0) carries `hot_key_mass` of the rows; the rest
/// spread over `cold_keys` keys with Zipf-drawn run lengths. B copies
/// A's runs with `run_jitter`-probability length changes (so added and
/// removed rows land *inside* runs) and `change_rate` payload
/// perturbation. Returns (A, B, longest A-side run length) — the run
/// length is what skew scenarios compare against the memory grant.
pub fn generate_skewed_pair(spec: &SkewSpec) -> (Table, Table, usize) {
    let schema = mixed_schema(spec.extra_cols);
    let mut rng = Rng::new(spec.seed);

    // Per-key A-side run lengths, keys ascending. Key 0 is the hot key.
    let hot = ((spec.rows as f64 * spec.hot_key_mass.clamp(0.0, 1.0)) as usize)
        .min(spec.rows);
    let mut runs: Vec<(i64, usize)> = Vec::new();
    if hot > 0 {
        runs.push((0, hot));
    }
    let mut remaining = spec.rows - hot;
    let mut key = 1i64;
    while remaining > 0 {
        // Zipf rank → run length: rank 0 is the longest cold run.
        let rank = rng.zipf(spec.cold_keys.max(1), spec.zipf_s);
        let len = (spec.cold_keys.max(1) / (rank + 1)).clamp(1, 64).min(remaining);
        runs.push((key, len));
        key += 1;
        remaining -= len;
    }
    let longest_run = runs.iter().map(|&(_, n)| n).max().unwrap_or(0);

    // Table A.
    let a_gspec = GenSpec {
        rows: spec.rows,
        extra_cols: spec.extra_cols,
        seed: spec.seed,
        ..GenSpec::default()
    };
    let mut ta = TableBuilder::new(schema.clone());
    for &(k, n) in &runs {
        for _ in 0..n {
            ta.col(0).push_i64(k);
            push_random_payload(&mut ta, &schema, &mut rng, &a_gspec);
        }
    }
    let a = ta.finish();

    // Table B: walk A's runs in key order, jittering run lengths and
    // perturbing payloads. A shortened run removes tail occurrences; a
    // lengthened run appends fresh occurrences (added rows) — both land
    // inside the run, exercising cross-fragment pairing.
    let gspec = GenSpec {
        rows: spec.rows,
        extra_cols: spec.extra_cols,
        change_rate: spec.change_rate,
        seed: spec.seed,
        ..GenSpec::default()
    };
    let mut brng = rng.fork(0xB);
    let mut tb = TableBuilder::new(schema.clone());
    let mut a_row = 0usize;
    for &(k, n) in &runs {
        let nb = if brng.chance(spec.run_jitter) {
            let delta = 1 + brng.range_usize(0, 1 + n / 8);
            if brng.chance(0.5) {
                n.saturating_sub(delta)
            } else {
                n + delta
            }
        } else {
            n
        };
        for i in 0..nb {
            if i < n {
                let perturb = brng.chance(spec.change_rate);
                push_copied_row(&mut tb, &a, a_row + i, &mut brng, &gspec, perturb);
            } else {
                tb.col(0).push_i64(k);
                push_random_payload(&mut tb, &schema, &mut brng, &gspec);
            }
        }
        a_row += n;
    }
    // B-dominant surplus: one key *past* A's entire key range carrying
    // `rows × b_surplus_mass` pure added rows (keeps B key-sorted). The
    // guard keeps the default a bitwise no-op — no RNG draw happens
    // unless the knob is set, so seeded pairs pinned by earlier tests
    // are unchanged.
    if spec.b_surplus_mass > 0.0 {
        let surplus = (spec.rows as f64 * spec.b_surplus_mass) as usize;
        let surplus_key = runs.last().map(|&(k, _)| k + 1).unwrap_or(0);
        for _ in 0..surplus {
            tb.col(0).push_i64(surplus_key);
            push_random_payload(&mut tb, &schema, &mut brng, &gspec);
        }
    }
    (a, tb.finish(), longest_run)
}

/// Row count of the pure-surplus run `generate_skewed_pair` appends for
/// a given spec (0 when the knob is unset) — the quantity B-dominant
/// scenarios compare against the batch size and the memory grant.
pub fn skew_surplus_rows(spec: &SkewSpec) -> usize {
    if spec.b_surplus_mass > 0.0 {
        (spec.rows as f64 * spec.b_surplus_mass) as usize
    } else {
        0
    }
}

/// The paper's four synthetic workload sizes, in rows per side.
pub const PAPER_WORKLOADS: [(&str, usize); 4] = [
    ("1M", 1_000_000),
    ("5M", 5_000_000),
    ("10M", 10_000_000),
    ("20M", 20_000_000),
];

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> GenSpec {
        GenSpec { rows: 2_000, seed: 7, ..GenSpec::default() }
    }

    #[test]
    fn deterministic_given_seed() {
        let (a1, b1, t1) = generate_pair(&small());
        let (a2, b2, t2) = generate_pair(&small());
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn truth_accounts_for_all_rows() {
        let spec = small();
        let (a, b, t) = generate_pair(&spec);
        assert_eq!(a.nrows(), spec.rows);
        assert_eq!(t.aligned + t.removed, a.nrows());
        assert_eq!(b.nrows(), t.aligned + t.added);
        assert!(t.changed_rows > 0 && t.removed > 0 && t.added > 0);
    }

    #[test]
    fn keys_sorted_both_sides() {
        let (a, b, _) = generate_pair(&small());
        for t in [&a, &b] {
            let col = t.column(0);
            let mut prev = i64::MIN;
            for i in 0..t.nrows() {
                let k = match col.cell(i) {
                    Cell::I64(k) => k,
                    other => panic!("bad key {other:?}"),
                };
                assert!(k > prev, "keys must be strictly increasing");
                prev = k;
            }
        }
    }

    #[test]
    fn unperturbed_rows_identical() {
        let mut spec = small();
        spec.change_rate = 0.0;
        spec.remove_rate = 0.0;
        spec.add_rate = 0.0;
        let (a, b, t) = generate_pair(&spec);
        assert_eq!(a, b);
        assert_eq!(t.changed_rows, 0);
    }

    #[test]
    fn str_len_controls_width() {
        let narrow = generate_table(&GenSpec { str_len: 8, rows: 500, ..small() });
        let wide = generate_table(&GenSpec { str_len: 64, rows: 500, ..small() });
        assert!(wide.measured_row_bytes() > narrow.measured_row_bytes() + 20.0);
    }

    fn skew_keys(t: &Table) -> Vec<i64> {
        (0..t.nrows())
            .map(|i| match t.column(0).cell(i) {
                Cell::I64(k) => k,
                other => panic!("bad key {other:?}"),
            })
            .collect()
    }

    #[test]
    fn skewed_pair_is_sorted_with_hot_key_mass() {
        let spec = SkewSpec { rows: 4_000, hot_key_mass: 0.4, seed: 9, ..SkewSpec::default() };
        let (a, b, longest) = generate_skewed_pair(&spec);
        assert_eq!(a.nrows(), 4_000);
        for t in [&a, &b] {
            let keys = skew_keys(t);
            assert!(keys.windows(2).all(|w| w[0] <= w[1]), "keys sorted");
        }
        // The hot key (0) carries the configured mass on the A side.
        let hot = skew_keys(&a).iter().filter(|&&k| k == 0).count();
        assert_eq!(hot, 1_600);
        assert_eq!(longest, 1_600, "hot run is the longest");
        // B shares the hot key (jitter may shift its length slightly).
        let hot_b = skew_keys(&b).iter().filter(|&&k| k == 0).count();
        assert!(hot_b > 1_000, "hot_b={hot_b}");
    }

    #[test]
    fn skewed_pair_single_key_extreme() {
        // 100% mass: one key spans every row — the workload class the
        // occurrence-indexed partitioner exists to open.
        let spec = SkewSpec { rows: 1_000, hot_key_mass: 1.0, seed: 3, ..SkewSpec::default() };
        let (a, b, longest) = generate_skewed_pair(&spec);
        assert_eq!(longest, 1_000);
        assert!(skew_keys(&a).iter().all(|&k| k == 0));
        assert!(skew_keys(&b).iter().all(|&k| k == 0));
        assert!(b.nrows() > 0);
    }

    #[test]
    fn skewed_pair_deterministic() {
        let spec = SkewSpec { rows: 2_000, seed: 77, ..SkewSpec::default() };
        let (a1, b1, l1) = generate_skewed_pair(&spec);
        let (a2, b2, l2) = generate_skewed_pair(&spec);
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
        assert_eq!(l1, l2);
    }

    #[test]
    fn skewed_pair_b_surplus_appends_pure_added_run() {
        let base = SkewSpec { rows: 2_000, seed: 77, ..SkewSpec::default() };
        let with = SkewSpec { b_surplus_mass: 1.5, ..base.clone() };
        let (a0, b0, _) = generate_skewed_pair(&base);
        let (a1, b1, _) = generate_skewed_pair(&with);
        // The knob never touches A, and B is the no-surplus B plus an
        // appended run — the shared prefix is bitwise unchanged (the
        // surplus path draws from the RNG only after the run walk).
        assert_eq!(a0, a1);
        assert_eq!(skew_surplus_rows(&with), 3_000);
        assert_eq!(b1.nrows(), b0.nrows() + 3_000);
        let k0 = skew_keys(&b0);
        let k1 = skew_keys(&b1);
        assert_eq!(&k1[..k0.len()], &k0[..], "shared prefix changed");
        // The surplus run is one key past A's whole key range: pure
        // added rows with no A counterpart, still key-sorted.
        let a_max = *skew_keys(&a1).iter().max().unwrap();
        let surplus_keys = &k1[k0.len()..];
        assert!(surplus_keys.iter().all(|&k| k == a_max + 1));
        assert!(k1.windows(2).all(|w| w[0] <= w[1]), "B stays sorted");
    }
}
