//! Grant-governed columnar chunk cache with spill-to-disk.
//!
//! A decoded row range persists as a compact columnar chunk so a hot
//! range decodes **once per job** instead of once per shard execution
//! (retries, straggler splits, prefetch fallbacks, and carved-shard
//! re-cuts all re-read the same ranges today). The lifecycle is the
//! buffer-pool shape from the influxdb_iox chunk design (SNIPPETS.md
//! §2–3, `ChunkMetrics.memory_bytes`):
//!
//! ```text
//!   source read ──decode──▶ Resident(Arc<Table>, MemGuard)
//!        ▲                       │ eviction (grant pressure,
//!        │                       │  shrink-before-grow)
//!        │ unreadable /          ▼
//!        │ disk-cap drop    Spilled(chunk file, byte-shuffle + RLE)
//!        │                       │ hit
//!        └───────────────────────┴──decode──▶ reloaded (re-admitted
//!                                             when the grant has room)
//! ```
//!
//! Every resident chunk holds a [`MemGuard`] charged against the
//! store's own [`MemTracker`], whose cap is a carve-out of the owning
//! job's elastic grant — so cached bytes are *accounted* RSS, the Eq. 4
//! envelope sees them, and a grant shrink evicts (spills) chunks before
//! any worker allocation may grow ([`ChunkStore::set_cap`] is the
//! shrink-before-grow edge). Spill files use an in-house byte-shuffle +
//! PackBits-RLE codec over the raw column buffers: zero dependencies,
//! round-trip-exact (bit-identical tables back), and effective on the
//! sorted/low-cardinality buffers columnar data is made of.
//!
//! Spill/unspill I/O is deliberately **not** recorded in the source's
//! [`ReadMeter`](crate::data::io::ReadMeter): preflight's B̂_read must
//! reflect true source reads only (the same segregation PR 6 gave the
//! open-time index scan).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::api::error::SchedError;
use crate::data::column::{Bitmap, Column, StrData, Values};
use crate::data::io::{ReadMeter, ReadScratch, TableSource};
use crate::data::schema::{ColumnType, Schema};
use crate::data::table::Table;
use crate::exec::worker::{MemGuard, MemTracker};

/// Which input the cached range came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Side {
    A,
    B,
}

/// Cache key: a contiguous row range of one side. Ranges are cached at
/// the granularity the workers read them (whole shards for inmem,
/// key-aligned sub-chunks for dasklike), so re-executions of the same
/// cut hit exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ChunkKey {
    pub side: Side,
    pub offset: usize,
    pub len: usize,
}

/// Counter + gauge snapshot (all cumulative except the gauges).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from a resident chunk.
    pub hits: u64,
    /// Lookups that fell through to the source.
    pub misses: u64,
    /// Chunks written to disk (eviction or direct spill).
    pub spills: u64,
    /// Lookups served by decoding a spilled chunk file.
    pub unspills: u64,
    /// Chunks pushed out of residency (spilled or dropped).
    pub evicts: u64,
    /// Gauge: accounted bytes of resident chunks right now.
    pub resident_bytes: u64,
    /// Gauge: on-disk bytes of spilled chunk files right now.
    pub spilled_bytes: u64,
    /// Gauge: resident chunk count.
    pub resident_chunks: u64,
    /// Gauge: spilled chunk count.
    pub spilled_chunks: u64,
}

/// Where a chunk's bytes live. Each state carries its exact gauge —
/// `memory_bytes` while resident (the `MemGuard` charge), and
/// `storage_bytes` while spilled (the encoded file size).
enum Residency {
    Resident {
        table: Arc<Table>,
        /// Charge against the store's tracker; dropping it releases the
        /// accounted bytes (eviction).
        _guard: MemGuard,
        memory_bytes: u64,
    },
    Spilled {
        path: PathBuf,
        storage_bytes: u64,
    },
}

struct Entry {
    state: Residency,
    /// Logical LRU clock value of the last touch.
    last_touch: u64,
}

struct StoreInner {
    map: HashMap<ChunkKey, Entry>,
    /// Sum of spilled chunk file sizes (bounded by `max_disk_bytes`).
    disk_bytes: u64,
    /// Logical LRU clock (bumped per lookup/insert).
    clock: u64,
    /// Spill directory exists on disk.
    dir_ready: bool,
    /// Monotonic chunk-file name counter.
    file_seq: u64,
}

/// Process-wide counter so concurrent stores never share a spill dir.
static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Per-job chunk cache: decoded ranges stay resident under a carve-out
/// of the job's memory grant, spill to compressed chunk files under
/// pressure, and reload on the next hit. See the module docs for the
/// lifecycle and accounting rules.
pub struct ChunkStore {
    /// The cache's own accounting ledger. Its cap is the cache
    /// carve-out of the job grant; `Pool` re-caps it on every elastic
    /// grant change (before worker caps — shrink-before-grow).
    tracker: Arc<MemTracker>,
    chunks: Mutex<StoreInner>,
    spill_dir: PathBuf,
    /// Cap on summed spill-file bytes (0 = unlimited). A chunk that
    /// would exceed it is dropped instead of spilled.
    max_disk_bytes: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    spills: AtomicU64,
    unspills: AtomicU64,
    evicts: AtomicU64,
}

impl ChunkStore {
    /// `cap_bytes` is the initial residency budget (the pool re-caps it
    /// from the live grant); `spill_base` the directory under which the
    /// store creates its own unique subdir (defaults to the system temp
    /// dir); `max_disk_bytes` bounds spill-file bytes (0 = unlimited).
    pub fn new(
        cap_bytes: u64,
        spill_base: Option<PathBuf>,
        max_disk_bytes: u64,
    ) -> Arc<Self> {
        let base = spill_base
            .unwrap_or_else(|| std::env::temp_dir().join("smartdiff-chunks"));
        let unique = format!(
            "sdc-{}-{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        );
        Arc::new(ChunkStore {
            tracker: MemTracker::new(cap_bytes),
            chunks: Mutex::new(StoreInner {
                map: HashMap::new(),
                disk_bytes: 0,
                clock: 0,
                dir_ready: false,
                file_seq: 0,
            }),
            spill_dir: base.join(unique),
            max_disk_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            unspills: AtomicU64::new(0),
            evicts: AtomicU64::new(0),
        })
    }

    fn guard(&self) -> std::sync::MutexGuard<'_, StoreInner> {
        // lint: allow(unwrap) a poisoned store means a panic mid spill
        // or eviction — gauges may be torn, so fail fast
        self.chunks.lock().unwrap()
    }

    /// Accounted bytes of resident chunks (the envelope term).
    pub fn memory_bytes(&self) -> u64 {
        self.tracker.current()
    }

    /// On-disk bytes of spilled chunk files.
    pub fn storage_bytes(&self) -> u64 {
        self.guard().disk_bytes
    }

    /// Re-cap the residency budget, evicting (spilling) LRU chunks
    /// until accounted bytes fit — the shrink half of shrink-before-
    /// grow: the pool applies this *before* re-capping worker ledgers
    /// on a grant change, so cached bytes yield before workers grow.
    pub fn set_cap(&self, cap_bytes: u64) {
        self.tracker.set_cap(cap_bytes);
        let mut inner = self.guard();
        while self.tracker.current() > cap_bytes {
            if !self.evict_one_locked(&mut inner) {
                break;
            }
        }
    }

    /// Full counter + gauge snapshot.
    pub fn stats(&self) -> CacheStats {
        let (resident_chunks, spilled_chunks, disk_bytes) = {
            let inner = self.guard();
            let res = inner
                .map
                .values()
                .filter(|e| matches!(e.state, Residency::Resident { .. }))
                .count() as u64;
            let sp = inner.map.len() as u64 - res;
            (res, sp, inner.disk_bytes)
        };
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            spills: self.spills.load(Ordering::Relaxed),
            unspills: self.unspills.load(Ordering::Relaxed),
            evicts: self.evicts.load(Ordering::Relaxed),
            resident_bytes: self.tracker.current(),
            spilled_bytes: disk_bytes,
            resident_chunks,
            spilled_chunks,
        }
    }

    /// Length of the longest cached strict-prefix chunk of
    /// `(side, offset, len)` — the straggler splitter's cut preference:
    /// bisecting at a cached boundary makes the re-executed halves line
    /// up with chunks already decoded.
    pub fn split_hint(&self, side: Side, offset: usize, len: usize) -> Option<usize> {
        let inner = self.guard();
        inner
            .map
            .keys()
            .filter(|k| k.side == side && k.offset == offset && k.len < len && k.len > 0)
            .map(|k| k.len)
            .max()
    }

    /// Fetch a cached chunk: a resident hit clones the table; a spilled
    /// hit decodes the chunk file (and re-admits residency when the
    /// grant has room). None = miss — the caller reads the source and
    /// [`insert`](Self::insert)s. Spill-file reads never touch any
    /// `ReadMeter`.
    pub fn lookup(&self, key: ChunkKey, schema: &Schema) -> Option<Table> {
        let mut inner = self.guard();
        inner.clock += 1;
        let clock = inner.clock;
        let (path, storage) = match inner.map.get_mut(&key) {
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            Some(e) => {
                e.last_touch = clock;
                match &e.state {
                    Residency::Resident { table, .. } => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        return Some((**table).clone());
                    }
                    Residency::Spilled { path, storage_bytes } => {
                        (path.clone(), *storage_bytes)
                    }
                }
            }
        };
        let decoded = std::fs::read(&path)
            .ok()
            .and_then(|bytes| decode_table(&bytes, schema).ok());
        let Some(table) = decoded else {
            // Unreadable or corrupt chunk file: drop the entry and fall
            // back to the source — the cache is only ever an optimization.
            inner.map.remove(&key);
            inner.disk_bytes = inner.disk_bytes.saturating_sub(storage);
            std::fs::remove_file(&path).ok();
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        self.unspills.fetch_add(1, Ordering::Relaxed);
        // Re-admit residency if the grant has room (evicting colder
        // chunks first); otherwise the chunk stays spilled and this
        // lookup just hands out the decoded copy.
        let bytes = (table.heap_bytes() as u64).max(1);
        if let Some(guard) = self.admit_locked(&mut inner, bytes) {
            inner.disk_bytes = inner.disk_bytes.saturating_sub(storage);
            std::fs::remove_file(&path).ok();
            if let Some(e) = inner.map.get_mut(&key) {
                e.state = Residency::Resident {
                    table: Arc::new(table.clone()),
                    _guard: guard,
                    memory_bytes: bytes,
                };
                e.last_touch = clock;
            }
        }
        Some(table)
    }

    /// Cache a freshly decoded range. Residency is tried first (evicting
    /// LRU chunks under grant pressure — never failing the caller); if
    /// the chunk cannot fit in memory at all it spills straight to disk,
    /// and if the disk cap refuses too the chunk is simply not cached.
    pub fn insert(&self, key: ChunkKey, table: &Table) {
        if table.nrows() == 0 {
            return;
        }
        let mut inner = self.guard();
        if inner.map.contains_key(&key) {
            return;
        }
        inner.clock += 1;
        let clock = inner.clock;
        let bytes = (table.heap_bytes() as u64).max(1);
        let state = match self.admit_locked(&mut inner, bytes) {
            Some(guard) => Residency::Resident {
                table: Arc::new(table.clone()),
                _guard: guard,
                memory_bytes: bytes,
            },
            None => match self.write_chunk_file(&mut inner, table) {
                Some((path, storage_bytes)) => {
                    self.spills.fetch_add(1, Ordering::Relaxed);
                    Residency::Spilled { path, storage_bytes }
                }
                None => return,
            },
        };
        inner.map.insert(key, Entry { state, last_touch: clock });
    }

    /// Charge `bytes` against the residency budget, evicting LRU
    /// residents until it fits. None when it cannot fit even with the
    /// cache empty (chunk larger than the carve-out).
    fn admit_locked(
        &self,
        inner: &mut StoreInner,
        bytes: u64,
    ) -> Option<MemGuard> {
        loop {
            match self.tracker.alloc(bytes) {
                Ok(guard) => return Some(guard),
                Err(_) => {
                    if !self.evict_one_locked(inner) {
                        return None;
                    }
                }
            }
        }
    }

    /// Evict the least-recently-touched resident chunk: spill it if the
    /// disk cap allows, else drop it. False when nothing is resident.
    fn evict_one_locked(&self, inner: &mut StoreInner) -> bool {
        let victim = inner
            .map
            .iter()
            .filter(|(_, e)| matches!(e.state, Residency::Resident { .. }))
            .min_by_key(|(_, e)| e.last_touch)
            .map(|(k, _)| *k);
        let Some(key) = victim else { return false };
        // lint: allow(unwrap) the key was taken out of the map above
        let entry = inner.map.remove(&key).unwrap();
        let (table, guard, touch) = match entry.state {
            Residency::Resident { table, _guard, .. } => {
                (table, _guard, entry.last_touch)
            }
            // Victim selection filtered on Resident.
            Residency::Spilled { .. } => return false,
        };
        self.evicts.fetch_add(1, Ordering::Relaxed);
        if let Some((path, storage_bytes)) =
            self.write_chunk_file(inner, &table)
        {
            self.spills.fetch_add(1, Ordering::Relaxed);
            inner.map.insert(
                key,
                Entry {
                    state: Residency::Spilled { path, storage_bytes },
                    last_touch: touch,
                },
            );
        }
        // Release the memory charge only after the spill completed, so
        // accounted RSS never undercounts bytes still being copied out.
        drop(guard);
        true
    }

    /// Encode and write one chunk file. None when the disk cap refuses
    /// or I/O fails (the chunk is then just not cached).
    fn write_chunk_file(
        &self,
        inner: &mut StoreInner,
        table: &Table,
    ) -> Option<(PathBuf, u64)> {
        let enc = encode_table(table);
        let sz = enc.len() as u64;
        if self.max_disk_bytes > 0 && inner.disk_bytes + sz > self.max_disk_bytes
        {
            return None;
        }
        if !inner.dir_ready {
            std::fs::create_dir_all(&self.spill_dir).ok()?;
            inner.dir_ready = true;
        }
        inner.file_seq += 1;
        let path = self.spill_dir.join(format!("c{:06}.chunk", inner.file_seq));
        std::fs::write(&path, &enc).ok()?;
        inner.disk_bytes += sz;
        Some((path, sz))
    }
}

impl Drop for ChunkStore {
    fn drop(&mut self) {
        // Spill files are strictly job-scoped scratch.
        let created = self.guard().dir_ready;
        if created {
            std::fs::remove_dir_all(&self.spill_dir).ok();
        }
    }
}

// ---------------- chunk codec ----------------
//
// Layout: [u64 nrows][u64 ncols] then per column a validity buffer and
// the type's value buffers. Every buffer is stored as
// [u64 raw_len][u64 enc_len][enc bytes] where `enc` is PackBits RLE
// over the byte-shuffled raw buffer (shuffle width = the element width,
// so all high bytes — near-constant for sorted keys, timestamps, small
// decimals — land contiguously and RLE collapses them). Schemas are
// NOT serialized: the store decodes with the source schema, which is
// also what validates the file shape.

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn get_u64(data: &[u8], pos: &mut usize) -> Result<u64, String> {
    let end = pos.checked_add(8).filter(|&e| e <= data.len());
    let Some(end) = end else {
        return Err("chunk truncated in header".into());
    };
    // lint: allow(unwrap) slice is exactly 8 bytes by construction
    let v = u64::from_le_bytes(data[*pos..end].try_into().unwrap());
    *pos = end;
    Ok(v)
}

/// out[b·n + i] = in[i·w + b]: groups byte-plane b of every element
/// together so RLE sees the near-constant high bytes as long runs.
fn byte_shuffle(data: &[u8], width: usize) -> Vec<u8> {
    debug_assert_eq!(data.len() % width, 0);
    let n = data.len() / width;
    let mut out = vec![0u8; data.len()];
    for b in 0..width {
        for i in 0..n {
            out[b * n + i] = data[i * width + b];
        }
    }
    out
}

fn byte_unshuffle(data: &[u8], width: usize) -> Vec<u8> {
    debug_assert_eq!(data.len() % width, 0);
    let n = data.len() / width;
    let mut out = vec![0u8; data.len()];
    for b in 0..width {
        for i in 0..n {
            out[i * width + b] = data[b * n + i];
        }
    }
    out
}

/// PackBits run-length coder. Control byte c: 0..=127 → literal run of
/// c+1 bytes follows; 129..=255 → the next byte repeats 257−c times
/// (2..=128); 128 is never emitted.
fn rle_encode(data: &[u8]) -> Vec<u8> {
    fn flush_literal(out: &mut Vec<u8>, lit: &mut Vec<u8>) {
        for chunk in lit.chunks(128) {
            out.push((chunk.len() - 1) as u8);
            out.extend_from_slice(chunk);
        }
        lit.clear();
    }
    let mut out = Vec::new();
    let mut lit: Vec<u8> = Vec::new();
    let mut i = 0;
    while i < data.len() {
        let mut run = 1usize;
        while i + run < data.len() && data[i + run] == data[i] && run < 128 {
            run += 1;
        }
        if run >= 3 {
            flush_literal(&mut out, &mut lit);
            out.push((257 - run) as u8);
            out.push(data[i]);
        } else {
            lit.extend_from_slice(&data[i..i + run]);
        }
        i += run;
    }
    flush_literal(&mut out, &mut lit);
    out
}

fn rle_decode(data: &[u8], expect: usize) -> Result<Vec<u8>, String> {
    let mut out = Vec::with_capacity(expect);
    let mut i = 0;
    while i < data.len() {
        let c = data[i];
        i += 1;
        if c < 128 {
            let len = c as usize + 1;
            if i + len > data.len() {
                return Err("RLE literal truncated".into());
            }
            out.extend_from_slice(&data[i..i + len]);
            i += len;
        } else if c == 128 {
            return Err("invalid RLE control byte 128".into());
        } else {
            if i >= data.len() {
                return Err("RLE run truncated".into());
            }
            out.extend(std::iter::repeat(data[i]).take(257 - c as usize));
            i += 1;
        }
    }
    if out.len() != expect {
        return Err(format!("RLE decoded {} bytes, expected {expect}", out.len()));
    }
    Ok(out)
}

/// Shuffle + RLE one raw buffer into the stream.
fn put_buf(out: &mut Vec<u8>, raw: &[u8], width: usize) {
    let shuffled;
    let src: &[u8] = if width > 1 {
        shuffled = byte_shuffle(raw, width);
        &shuffled
    } else {
        raw
    };
    let enc = rle_encode(src);
    put_u64(out, raw.len() as u64);
    put_u64(out, enc.len() as u64);
    out.extend_from_slice(&enc);
}

fn get_buf(
    data: &[u8],
    pos: &mut usize,
    width: usize,
) -> Result<Vec<u8>, String> {
    let raw_len = get_u64(data, pos)? as usize;
    let enc_len = get_u64(data, pos)? as usize;
    let end = pos.checked_add(enc_len).filter(|&e| e <= data.len());
    let Some(end) = end else {
        return Err("chunk buffer truncated".into());
    };
    if width > 0 && raw_len % width != 0 {
        return Err("chunk buffer length not a width multiple".into());
    }
    let flat = rle_decode(&data[*pos..end], raw_len)?;
    *pos = end;
    Ok(if width > 1 { byte_unshuffle(&flat, width) } else { flat })
}

fn le_bytes<const W: usize>(iter: impl Iterator<Item = [u8; W]>, n: usize) -> Vec<u8> {
    let mut raw = Vec::with_capacity(n * W);
    for b in iter {
        raw.extend_from_slice(&b);
    }
    raw
}

fn put_bitmap(out: &mut Vec<u8>, bm: &Bitmap) {
    let raw = le_bytes(bm.words().iter().map(|w| w.to_le_bytes()), bm.words().len());
    put_buf(out, &raw, 8);
}

fn get_bitmap(
    data: &[u8],
    pos: &mut usize,
    len: usize,
) -> Result<Bitmap, String> {
    let raw = get_buf(data, pos, 8)?;
    if raw.len() != len.div_ceil(64) * 8 {
        return Err("bitmap word count mismatch".into());
    }
    let words = raw
        .chunks_exact(8)
        // lint: allow(unwrap) chunks_exact(8) yields 8-byte slices
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Bitmap::from_words(words, len))
}

/// Serialize a table's column buffers (schema NOT included — decode
/// takes it from the caller). Round-trip-exact: `decode_table` returns
/// a table equal to the input.
pub fn encode_table(table: &Table) -> Vec<u8> {
    let mut out = Vec::new();
    put_u64(&mut out, table.nrows() as u64);
    put_u64(&mut out, table.ncols() as u64);
    for col in &table.columns {
        put_bitmap(&mut out, &col.validity);
        match &col.values {
            Values::I64(v) | Values::Ts(v) => {
                put_buf(&mut out, &le_bytes(v.iter().map(|x| x.to_le_bytes()), v.len()), 8)
            }
            Values::F64(v) => put_buf(
                &mut out,
                &le_bytes(v.iter().map(|x| x.to_bits().to_le_bytes()), v.len()),
                8,
            ),
            Values::Date(v) => {
                put_buf(&mut out, &le_bytes(v.iter().map(|x| x.to_le_bytes()), v.len()), 4)
            }
            Values::Dec { mantissa, .. } => put_buf(
                &mut out,
                &le_bytes(mantissa.iter().map(|x| x.to_le_bytes()), mantissa.len()),
                16,
            ),
            Values::Bool(b) => put_bitmap(&mut out, b),
            Values::Str(s) => {
                put_buf(
                    &mut out,
                    &le_bytes(s.offsets.iter().map(|x| x.to_le_bytes()), s.offsets.len()),
                    4,
                );
                put_buf(&mut out, &s.bytes, 1);
            }
        }
    }
    out
}

/// Rebuild a table from [`encode_table`] output and the source schema.
/// Any shape mismatch (wrong column count, truncation, bad lengths) is
/// a typed error — the store treats it as a miss, never a panic.
pub fn decode_table(data: &[u8], schema: &Schema) -> Result<Table, String> {
    let mut pos = 0usize;
    let nrows = get_u64(data, &mut pos)? as usize;
    let ncols = get_u64(data, &mut pos)? as usize;
    if ncols != schema.len() {
        return Err(format!(
            "chunk has {ncols} columns, schema {}",
            schema.len()
        ));
    }
    let mut columns = Vec::with_capacity(ncols);
    for field in &schema.fields {
        let validity = get_bitmap(data, &mut pos, nrows)?;
        let values = match field.ty {
            ColumnType::Int64 | ColumnType::Timestamp => {
                let raw = get_buf(data, &mut pos, 8)?;
                let v: Vec<i64> = raw
                    .chunks_exact(8)
                    // lint: allow(unwrap) chunks_exact(8) yields 8 bytes
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                if field.ty == ColumnType::Int64 {
                    Values::I64(v)
                } else {
                    Values::Ts(v)
                }
            }
            ColumnType::Float64 => Values::F64(
                get_buf(data, &mut pos, 8)?
                    .chunks_exact(8)
                    // lint: allow(unwrap) chunks_exact(8) yields 8 bytes
                    .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
                    .collect(),
            ),
            ColumnType::Date => Values::Date(
                get_buf(data, &mut pos, 4)?
                    .chunks_exact(4)
                    // lint: allow(unwrap) chunks_exact(4) yields 4 bytes
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            ColumnType::Decimal { scale } => Values::Dec {
                mantissa: get_buf(data, &mut pos, 16)?
                    .chunks_exact(16)
                    // lint: allow(unwrap) chunks_exact(16) yields 16 bytes
                    .map(|c| i128::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
                scale,
            },
            ColumnType::Bool => Values::Bool(get_bitmap(data, &mut pos, nrows)?),
            ColumnType::Utf8 => {
                let offsets: Vec<u32> = get_buf(data, &mut pos, 4)?
                    .chunks_exact(4)
                    // lint: allow(unwrap) chunks_exact(4) yields 4 bytes
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let bytes = get_buf(data, &mut pos, 1)?;
                if offsets.len() != nrows + 1
                    || offsets.last().copied().unwrap_or(1) as usize != bytes.len()
                    || offsets.windows(2).any(|w| w[0] > w[1])
                {
                    return Err("chunk string offsets malformed".into());
                }
                if std::str::from_utf8(&bytes).is_err() {
                    return Err("chunk string bytes not UTF-8".into());
                }
                Values::Str(StrData { offsets, bytes })
            }
        };
        if values.len() != nrows {
            return Err(format!(
                "chunk column {} has {} rows, expected {nrows}",
                field.name,
                values.len()
            ));
        }
        columns.push(Column::with_validity(values, validity));
    }
    if pos != data.len() {
        return Err("trailing bytes after chunk payload".into());
    }
    Table::new(schema.clone(), columns)
}

// ---------------- source wrapper ----------------

/// [`TableSource`] wrapper that consults the chunk store before the
/// wrapped source. Both the workers' synchronous reads and the
/// prefetcher's `stage()` go through `read_range_with`, so the whole
/// consume path stages into / hits the store with no special cases.
/// Hit time is booked as `decode_ns` (it *is* decode work for an
/// unspill, and ~a memcpy for a resident hit); `read_ns` stays 0 and
/// the inner `ReadMeter` is untouched, so B̂_read reflects true source
/// reads only.
pub struct CachedSource {
    inner: Arc<dyn TableSource>,
    store: Arc<ChunkStore>,
    side: Side,
}

impl CachedSource {
    pub fn new(
        inner: Arc<dyn TableSource>,
        store: Arc<ChunkStore>,
        side: Side,
    ) -> Self {
        CachedSource { inner, store, side }
    }

    pub fn store(&self) -> &Arc<ChunkStore> {
        &self.store
    }

    fn key(&self, offset: usize, len: usize) -> ChunkKey {
        ChunkKey { side: self.side, offset, len }
    }
}

impl TableSource for CachedSource {
    fn schema(&self) -> &Schema {
        self.inner.schema()
    }
    fn nrows(&self) -> usize {
        self.inner.nrows()
    }
    fn read_range(&self, offset: usize, len: usize) -> Result<Table, SchedError> {
        let mut scratch = ReadScratch::default();
        self.read_range_with(offset, len, &mut scratch)
    }
    fn read_range_with(
        &self,
        offset: usize,
        len: usize,
        scratch: &mut ReadScratch,
    ) -> Result<Table, SchedError> {
        if len == 0 {
            return self.inner.read_range_with(offset, len, scratch);
        }
        let t0 = Instant::now();
        if let Some(t) = self.store.lookup(self.key(offset, len), self.inner.schema())
        {
            scratch.read_ns = 0;
            scratch.decode_ns = t0.elapsed().as_nanos() as u64;
            return Ok(t);
        }
        let t = self.inner.read_range_with(offset, len, scratch)?;
        self.store.insert(self.key(offset, len), &t);
        Ok(t)
    }
    fn decoded_bytes_hint(&self, offset: usize, len: usize) -> u64 {
        self.inner.decoded_bytes_hint(offset, len)
    }
    fn key_at(&self, row: usize) -> Option<i64> {
        self.inner.key_at(row)
    }
    fn occ_at(&self, row: usize) -> u32 {
        self.inner.occ_at(row)
    }
    fn set_read_parallelism(&self, k: usize) {
        self.inner.set_read_parallelism(k)
    }
    fn storage_bytes(&self) -> u64 {
        self.inner.storage_bytes()
    }
    fn resident_bytes(&self) -> u64 {
        // Cached chunk bytes are tracked by the store's own ledger and
        // surfaced through the pool gauge — not double counted here.
        self.inner.resident_bytes()
    }
    fn meter(&self) -> &ReadMeter {
        self.inner.meter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_table, GenSpec};
    use crate::data::io::{write_csv, CsvFileSource, InMemorySource};

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "smartdiff_chunkstore_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// A table exercising every column type, nulls included.
    fn mixed_table(rows: usize, seed: u64) -> Table {
        let t = generate_table(&GenSpec {
            rows,
            seed,
            null_rate: 0.15,
            ..GenSpec::default()
        });
        assert!(t.ncols() >= 5, "generator covers the type families");
        t
    }

    #[test]
    fn rle_roundtrips_and_compresses_runs() {
        for data in [
            vec![],
            vec![7u8],
            vec![1, 2, 3],
            vec![0u8; 1000],
            (0..=255u8).collect::<Vec<_>>(),
            [vec![9u8; 200], (0..50).collect(), vec![9u8; 3]].concat(),
        ] {
            let enc = rle_encode(&data);
            assert_eq!(rle_decode(&enc, data.len()).unwrap(), data);
        }
        // A constant buffer collapses to ~2 bytes per 128.
        let enc = rle_encode(&[0u8; 1024]);
        assert!(enc.len() <= 2 * 1024_usize.div_ceil(128), "{}", enc.len());
        // Wrong expected length and the reserved control byte are typed
        // errors.
        assert!(rle_decode(&rle_encode(&[1, 2, 3]), 5).is_err());
        assert!(rle_decode(&[128, 0], 1).is_err());
        assert!(rle_decode(&[5], 6).is_err());
        assert!(rle_decode(&[255], 2).is_err());
    }

    #[test]
    fn byte_shuffle_roundtrips() {
        let data: Vec<u8> = (0..64u8).collect();
        for width in [1usize, 2, 4, 8, 16] {
            let s = byte_shuffle(&data, width);
            assert_eq!(byte_unshuffle(&s, width), data, "width={width}");
        }
        // Sorted i64 keys: shuffling groups the 7 near-constant high
        // byte planes, so shuffle+RLE beats RLE alone.
        let keys: Vec<u8> = (0..2_000i64)
            .flat_map(|x| x.to_le_bytes())
            .collect();
        let shuffled = rle_encode(&byte_shuffle(&keys, 8));
        let plain = rle_encode(&keys);
        assert!(
            shuffled.len() < plain.len() / 2,
            "shuffle+rle {} vs rle {}",
            shuffled.len(),
            plain.len()
        );
    }

    #[test]
    fn chunk_codec_roundtrips_every_type_bit_exact() {
        for seed in [3u64, 11, 42] {
            let t = mixed_table(333, seed);
            let enc = encode_table(&t);
            let back = decode_table(&enc, &t.schema).unwrap();
            assert_eq!(back, t, "seed={seed}");
        }
        // Empty table and single-row table.
        let t = mixed_table(50, 5);
        let empty = t.slice(0, 0);
        assert_eq!(decode_table(&encode_table(&empty), &t.schema).unwrap(), empty);
        let one = t.slice(7, 1);
        assert_eq!(decode_table(&encode_table(&one), &t.schema).unwrap(), one);
    }

    #[test]
    fn chunk_codec_compresses_generated_data() {
        let t = mixed_table(4_000, 9);
        let enc = encode_table(&t);
        assert!(
            enc.len() < t.heap_bytes(),
            "encoded {} vs heap {}",
            enc.len(),
            t.heap_bytes()
        );
    }

    #[test]
    fn decode_rejects_malformed_chunks() {
        let t = mixed_table(100, 2);
        let enc = encode_table(&t);
        // Truncations at every prefix must error, never panic.
        for cut in [0, 8, 15, 16, 40, enc.len() - 1] {
            assert!(decode_table(&enc[..cut], &t.schema).is_err(), "cut={cut}");
        }
        // Wrong schema (column count mismatch).
        let wrong = Schema::new(vec![crate::data::schema::Field::key(
            "id",
            ColumnType::Int64,
        )]);
        assert!(decode_table(&enc, &wrong).is_err());
        // Trailing garbage.
        let mut padded = enc.clone();
        padded.push(0);
        assert!(decode_table(&padded, &t.schema).is_err());
    }

    #[test]
    fn insert_lookup_hit_and_counters() {
        let store = ChunkStore::new(u64::MAX, Some(tmpdir()), 0);
        let t = mixed_table(200, 1);
        let key = ChunkKey { side: Side::A, offset: 0, len: 200 };
        assert!(store.lookup(key, &t.schema).is_none());
        store.insert(key, &t);
        let got = store.lookup(key, &t.schema).unwrap();
        assert_eq!(got, t);
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.spills), (1, 1, 0));
        assert_eq!(s.resident_chunks, 1);
        assert_eq!(s.resident_bytes, t.heap_bytes() as u64);
        assert_eq!(store.memory_bytes(), t.heap_bytes() as u64);
    }

    #[test]
    fn eviction_spills_and_unspill_roundtrips_byte_exact() {
        let t = mixed_table(400, 8);
        let half = t.heap_bytes() as u64 * 2 / 3;
        // Cap fits one chunk, not two: the second insert evicts+spills
        // the first.
        let store = ChunkStore::new(half.max(1), Some(tmpdir()), 0);
        let k1 = ChunkKey { side: Side::A, offset: 0, len: 200 };
        let k2 = ChunkKey { side: Side::A, offset: 200, len: 200 };
        let t1 = t.slice(0, 200);
        let t2 = t.slice(200, 200);
        store.insert(k1, &t1);
        store.insert(k2, &t2);
        let s = store.stats();
        assert_eq!(s.evicts, 1, "LRU chunk evicted");
        assert_eq!(s.spills, 1, "evicted chunk spilled to disk");
        assert_eq!(s.spilled_chunks, 1);
        assert!(s.spilled_bytes > 0);
        assert!(
            store.memory_bytes() <= half,
            "residency respects the cap"
        );
        // Unspill: bit-exact table back, counted as unspill (its
        // re-admission evicts the other chunk in turn).
        let back = store.lookup(k1, &t.schema).unwrap();
        assert_eq!(back, t1, "spilled chunk round-trips byte-exact");
        assert_eq!(store.stats().unspills, 1);
        assert!(store.memory_bytes() <= half);
    }

    #[test]
    fn set_cap_shrinks_residency_before_growth() {
        let store = ChunkStore::new(u64::MAX, Some(tmpdir()), 0);
        let t = mixed_table(300, 4);
        for i in 0..3 {
            store.insert(
                ChunkKey { side: Side::B, offset: i * 100, len: 100 },
                &t.slice(i * 100, 100),
            );
        }
        assert_eq!(store.stats().resident_chunks, 3);
        let one = t.slice(0, 100).heap_bytes() as u64;
        // Shrink to fit ~one chunk: the two LRU chunks must spill NOW
        // (synchronously, before any caller could grow into the space).
        store.set_cap(one + one / 2);
        let s = store.stats();
        assert!(store.memory_bytes() <= one + one / 2);
        assert_eq!(s.evicts, 2);
        assert_eq!(s.resident_chunks, 1);
        assert_eq!(s.spilled_chunks, 2);
        // Shrink to zero: everything out.
        store.set_cap(0);
        assert_eq!(store.memory_bytes(), 0);
        assert_eq!(store.stats().resident_chunks, 0);
    }

    #[test]
    fn disk_cap_drops_instead_of_spilling() {
        // max_disk_bytes too small for any chunk: eviction drops.
        let store = ChunkStore::new(1, Some(tmpdir()), 8);
        let t = mixed_table(200, 6);
        let key = ChunkKey { side: Side::A, offset: 0, len: 200 };
        store.insert(key, &t);
        let s = store.stats();
        assert_eq!(s.spills, 0, "disk cap refused the spill");
        assert_eq!(s.spilled_bytes, 0);
        assert_eq!(s.resident_chunks + s.spilled_chunks, 0, "chunk dropped");
        // The range still reads correctly from the source next time —
        // a drop is invisible to correctness.
        assert!(store.lookup(key, &t.schema).is_none());
    }

    #[test]
    fn split_hint_prefers_cached_prefix() {
        let store = ChunkStore::new(u64::MAX, Some(tmpdir()), 0);
        let t = mixed_table(500, 3);
        store.insert(ChunkKey { side: Side::A, offset: 0, len: 120 }, &t.slice(0, 120));
        store.insert(ChunkKey { side: Side::A, offset: 0, len: 250 }, &t.slice(0, 250));
        store.insert(ChunkKey { side: Side::A, offset: 120, len: 80 }, &t.slice(120, 80));
        // Longest strict prefix of (A, 0, 500) is the 250-row chunk.
        assert_eq!(store.split_hint(Side::A, 0, 500), Some(250));
        // Exact-length chunk is not a *split* hint.
        assert_eq!(store.split_hint(Side::A, 0, 250), Some(120));
        assert_eq!(store.split_hint(Side::B, 0, 500), None);
        assert_eq!(store.split_hint(Side::A, 40, 500), None);
    }

    #[test]
    fn cached_source_hits_skip_the_read_meter() {
        // Satellite: spill/unspill and hit traffic must stay OUT of the
        // source ReadMeter so preflight's B̂_read only sees true source
        // reads (same treatment PR 6 gave the index scan).
        let t = mixed_table(300, 7);
        let path = tmpdir().join("cached_meter.csv");
        write_csv(&t, &path).unwrap();
        let csv: Arc<dyn TableSource> =
            Arc::new(CsvFileSource::open(&path, t.schema.clone()).unwrap());
        let store = ChunkStore::new(u64::MAX, Some(tmpdir()), 0);
        let src = CachedSource::new(Arc::clone(&csv), Arc::clone(&store), Side::A);

        let first = src.read_range(10, 150).unwrap();
        assert_eq!(first, t.slice(10, 150));
        let after_miss = src.meter().snapshot();
        assert!(after_miss.0 > 0, "miss reads the source and meters");

        // Resident hit: zero meter delta.
        let mut scratch = ReadScratch::default();
        let hit = src.read_range_with(10, 150, &mut scratch).unwrap();
        assert_eq!(hit, first);
        assert_eq!(scratch.read_ns, 0, "hit books no read time");
        assert_eq!(
            src.meter().snapshot(),
            after_miss,
            "resident hit leaves the meter untouched"
        );

        // Spill it, then unspill via lookup: still zero meter delta.
        store.set_cap(0);
        assert_eq!(store.stats().spills, 1);
        let unspilled = src.read_range(10, 150).unwrap();
        assert_eq!(unspilled, first, "unspill round-trips byte-exact");
        assert_eq!(store.stats().unspills, 1);
        assert_eq!(
            src.meter().snapshot(),
            after_miss,
            "unspill I/O stays out of the read meter"
        );
        let s = store.stats();
        assert_eq!(s.hits + s.unspills, 2, "both re-reads served by cache");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cached_source_delegates_everything_else() {
        let t = mixed_table(120, 12);
        let nrows = t.nrows();
        let mem: Arc<dyn TableSource> = Arc::new(InMemorySource::new(t));
        let store = ChunkStore::new(u64::MAX, Some(tmpdir()), 0);
        let src = CachedSource::new(Arc::clone(&mem), store, Side::B);
        assert_eq!(src.nrows(), nrows);
        assert_eq!(src.schema(), mem.schema());
        assert_eq!(src.key_at(5), mem.key_at(5));
        assert_eq!(src.occ_at(5), mem.occ_at(5));
        assert_eq!(src.storage_bytes(), mem.storage_bytes());
        assert_eq!(src.resident_bytes(), mem.resident_bytes());
        assert_eq!(src.decoded_bytes_hint(0, 10), mem.decoded_bytes_hint(0, 10));
        // Zero-length reads pass straight through.
        assert_eq!(src.read_range(0, 0).unwrap().nrows(), 0);
        assert_eq!(src.store().stats().misses, 0, "empty range not cached");
    }

    #[test]
    fn spill_dir_is_cleaned_up_on_drop() {
        let base = tmpdir();
        let dir = {
            let store = ChunkStore::new(1, Some(base.clone()), 0);
            let t = mixed_table(150, 13);
            store.insert(ChunkKey { side: Side::A, offset: 0, len: 150 }, &t);
            assert_eq!(store.stats().spills, 1, "cap 1 forces direct spill");
            let dir = store.spill_dir.clone();
            assert!(dir.exists());
            dir
        };
        assert!(!dir.exists(), "store drop removes its spill dir");
    }
}
