//! Typed columnar storage with null bitmaps.
//!
//! Strings use an offsets+bytes arena (not Vec<String>) so that memory
//! accounting is tight and slicing is cheap-ish; everything reports its
//! heap footprint exactly — the scheduler's memory model is calibrated
//! against these numbers.

use crate::data::schema::ColumnType;

/// Packed validity bitmap (1 = present, 0 = null).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Bitmap {
    words: Vec<u64>,
    len: usize,
}

impl Bitmap {
    pub fn new_set(len: usize) -> Self {
        let mut b = Bitmap { words: vec![!0u64; len.div_ceil(64)], len };
        b.trim_tail();
        b
    }
    pub fn new_unset(len: usize) -> Self {
        Bitmap { words: vec![0u64; len.div_ceil(64)], len }
    }
    /// Rebuild a bitmap from backing words (the inverse of [`words`](
    /// Self::words); chunk-file decode). Word count must cover `len`
    /// bits; stray bits past `len` are cleared so equality with the
    /// originally-encoded bitmap is exact.
    pub fn from_words(words: Vec<u64>, len: usize) -> Self {
        assert!(words.len() == len.div_ceil(64), "bitmap word count/len mismatch");
        let mut b = Bitmap { words, len };
        b.trim_tail();
        b
    }
    fn trim_tail(&mut self) {
        let tail = self.len % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        if v {
            self.words[i / 64] |= 1 << (i % 64);
        } else {
            self.words[i / 64] &= !(1 << (i % 64));
        }
    }
    pub fn push(&mut self, v: bool) {
        if self.len % 64 == 0 {
            self.words.push(0);
        }
        self.len += 1;
        self.set(self.len - 1, v);
    }
    pub fn count_set(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }
    /// Backing words (bit i lives at `words[i / 64]`, LSB-first), for
    /// word-at-a-time consumers (popcount scans, `all_set`-style
    /// whole-column tests).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }
    /// True when every bit is set (no nulls): one popcount pass over
    /// the words. The columnar gather/hash loops test this once per
    /// column and take a branch-free dense path when it holds.
    pub fn all_set(&self) -> bool {
        self.count_set() == self.len
    }
    /// Word-level copy: each output word is stitched from at most two
    /// input words instead of 64 per-bit get/set round trips.
    pub fn slice(&self, offset: usize, len: usize) -> Bitmap {
        // Hard assert: fabricating null bits for an out-of-range tail
        // would silently corrupt verdicts; runs once per shard slice.
        assert!(offset + len <= self.len, "bitmap slice out of bounds");
        let mut out = Bitmap::new_unset(len);
        let base = offset / 64;
        let shift = offset % 64;
        let nw = out.words.len();
        for wi in 0..nw {
            let lo = self.words.get(base + wi).copied().unwrap_or(0) >> shift;
            let hi = if shift == 0 {
                0
            } else {
                self.words.get(base + wi + 1).copied().unwrap_or(0)
                    << (64 - shift)
            };
            out.words[wi] = lo | hi;
        }
        out.trim_tail();
        out
    }
    pub fn heap_bytes(&self) -> usize {
        self.words.capacity() * 8
    }
}

/// String arena column: offsets into a shared byte buffer.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StrData {
    pub offsets: Vec<u32>, // len + 1 entries
    pub bytes: Vec<u8>,
}

impl StrData {
    pub fn new() -> Self {
        StrData { offsets: vec![0], bytes: Vec::new() }
    }
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn push(&mut self, s: &str) {
        self.bytes.extend_from_slice(s.as_bytes());
        self.offsets.push(self.bytes.len() as u32);
    }
    #[inline]
    pub fn get(&self, i: usize) -> &str {
        let lo = self.offsets[i] as usize;
        let hi = self.offsets[i + 1] as usize;
        debug_assert!(std::str::from_utf8(&self.bytes[lo..hi]).is_ok());
        // SAFETY: the arena is append-only and every entry arrives via
        // `push(&str)` / `slice` (whole-entry memcpy of already-pushed
        // entries), so `offsets` always splits `bytes` on the original
        // `&str` boundaries and `bytes[lo..hi]` is exactly one pushed
        // string — valid UTF-8 by construction (debug-checked above).
        unsafe { std::str::from_utf8_unchecked(&self.bytes[lo..hi]) }
    }
    /// Byte range of entry `i` in the shared arena.
    #[inline]
    pub fn byte_range(&self, i: usize) -> (usize, usize) {
        (self.offsets[i] as usize, self.offsets[i + 1] as usize)
    }
    /// Raw payload bytes of entry `i` (hot-path view: no UTF-8 check,
    /// no `Cell` construction).
    #[inline]
    pub fn bytes_at(&self, i: usize) -> &[u8] {
        let (lo, hi) = self.byte_range(i);
        &self.bytes[lo..hi]
    }
    /// Bulk copy: one byte-range memcpy plus an offset rebase, instead
    /// of `len` per-element pushes.
    pub fn slice(&self, offset: usize, len: usize) -> StrData {
        let lo = self.offsets[offset] as usize;
        let hi = self.offsets[offset + len] as usize;
        let mut offsets = Vec::with_capacity(len + 1);
        offsets.extend(
            self.offsets[offset..=offset + len]
                .iter()
                .map(|&o| o - lo as u32),
        );
        StrData { offsets, bytes: self.bytes[lo..hi].to_vec() }
    }
    pub fn heap_bytes(&self) -> usize {
        self.offsets.capacity() * 4 + self.bytes.capacity()
    }
}

/// Typed column values (parallel to `ColumnType`).
#[derive(Debug, Clone, PartialEq)]
pub enum Values {
    I64(Vec<i64>),
    F64(Vec<f64>),
    Str(StrData),
    Bool(Bitmap),
    Date(Vec<i32>),
    Ts(Vec<i64>),
    Dec { mantissa: Vec<i128>, scale: u8 },
}

impl Values {
    pub fn len(&self) -> usize {
        match self {
            Values::I64(v) => v.len(),
            Values::F64(v) => v.len(),
            Values::Str(s) => s.len(),
            Values::Bool(b) => b.len(),
            Values::Date(v) => v.len(),
            Values::Ts(v) => v.len(),
            Values::Dec { mantissa, .. } => mantissa.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn column_type(&self) -> ColumnType {
        match self {
            Values::I64(_) => ColumnType::Int64,
            Values::F64(_) => ColumnType::Float64,
            Values::Str(_) => ColumnType::Utf8,
            Values::Bool(_) => ColumnType::Bool,
            Values::Date(_) => ColumnType::Date,
            Values::Ts(_) => ColumnType::Timestamp,
            Values::Dec { scale, .. } => ColumnType::Decimal { scale: *scale },
        }
    }
    pub fn heap_bytes(&self) -> usize {
        match self {
            Values::I64(v) => v.capacity() * 8,
            Values::F64(v) => v.capacity() * 8,
            Values::Str(s) => s.heap_bytes(),
            Values::Bool(b) => b.heap_bytes(),
            Values::Date(v) => v.capacity() * 4,
            Values::Ts(v) => v.capacity() * 8,
            Values::Dec { mantissa, .. } => mantissa.capacity() * 16,
        }
    }
    // Typed slice views. Callers on the Δ hot path match on the column
    // type ONCE, grab the typed slice, and run a tight loop over rows —
    // instead of constructing a `Cell` enum per cell. Each returns None
    // when the variant does not match.
    #[inline]
    pub fn as_i64(&self) -> Option<&[i64]> {
        match self {
            Values::I64(v) => Some(v),
            _ => None,
        }
    }
    #[inline]
    pub fn as_f64(&self) -> Option<&[f64]> {
        match self {
            Values::F64(v) => Some(v),
            _ => None,
        }
    }
    #[inline]
    pub fn as_date(&self) -> Option<&[i32]> {
        match self {
            Values::Date(v) => Some(v),
            _ => None,
        }
    }
    #[inline]
    pub fn as_ts(&self) -> Option<&[i64]> {
        match self {
            Values::Ts(v) => Some(v),
            _ => None,
        }
    }
    #[inline]
    pub fn as_dec(&self) -> Option<(&[i128], u8)> {
        match self {
            Values::Dec { mantissa, scale } => Some((mantissa, *scale)),
            _ => None,
        }
    }
    #[inline]
    pub fn as_str_data(&self) -> Option<&StrData> {
        match self {
            Values::Str(s) => Some(s),
            _ => None,
        }
    }
    #[inline]
    pub fn as_bool_bitmap(&self) -> Option<&Bitmap> {
        match self {
            Values::Bool(b) => Some(b),
            _ => None,
        }
    }
    pub fn slice(&self, offset: usize, len: usize) -> Values {
        match self {
            Values::I64(v) => Values::I64(v[offset..offset + len].to_vec()),
            Values::F64(v) => Values::F64(v[offset..offset + len].to_vec()),
            Values::Str(s) => Values::Str(s.slice(offset, len)),
            Values::Bool(b) => Values::Bool(b.slice(offset, len)),
            Values::Date(v) => Values::Date(v[offset..offset + len].to_vec()),
            Values::Ts(v) => Values::Ts(v[offset..offset + len].to_vec()),
            Values::Dec { mantissa, scale } => Values::Dec {
                mantissa: mantissa[offset..offset + len].to_vec(),
                scale: *scale,
            },
        }
    }
}

/// A column: typed values + validity bitmap.
#[derive(Debug, Clone, PartialEq)]
pub struct Column {
    pub values: Values,
    pub validity: Bitmap,
}

/// Dynamically-typed cell view (for row sampling, CSV io, debugging —
/// never on the per-cell hot path).
#[derive(Debug, Clone, PartialEq)]
pub enum Cell<'a> {
    Null,
    I64(i64),
    F64(f64),
    Str(&'a str),
    Bool(bool),
    Date(i32),
    Ts(i64),
    Dec { mantissa: i128, scale: u8 },
}

impl Column {
    pub fn new(values: Values) -> Self {
        let n = values.len();
        Column { values, validity: Bitmap::new_set(n) }
    }
    pub fn with_validity(values: Values, validity: Bitmap) -> Self {
        assert_eq!(values.len(), validity.len());
        Column { values, validity }
    }
    pub fn len(&self) -> usize {
        self.values.len()
    }
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
    pub fn column_type(&self) -> ColumnType {
        self.values.column_type()
    }
    #[inline]
    pub fn is_null(&self, i: usize) -> bool {
        !self.validity.get(i)
    }
    pub fn null_count(&self) -> usize {
        self.len() - self.validity.count_set()
    }
    pub fn heap_bytes(&self) -> usize {
        self.values.heap_bytes() + self.validity.heap_bytes()
    }
    pub fn slice(&self, offset: usize, len: usize) -> Column {
        Column {
            values: self.values.slice(offset, len),
            validity: self.validity.slice(offset, len),
        }
    }

    pub fn cell(&self, i: usize) -> Cell<'_> {
        if self.is_null(i) {
            return Cell::Null;
        }
        match &self.values {
            Values::I64(v) => Cell::I64(v[i]),
            Values::F64(v) => Cell::F64(v[i]),
            Values::Str(s) => Cell::Str(s.get(i)),
            Values::Bool(b) => Cell::Bool(b.get(i)),
            Values::Date(v) => Cell::Date(v[i]),
            Values::Ts(v) => Cell::Ts(v[i]),
            Values::Dec { mantissa, scale } => {
                Cell::Dec { mantissa: mantissa[i], scale: *scale }
            }
        }
    }

    /// Numeric view of a cell as f64 (None for null / non-numeric).
    /// This is the coercion the Δ numeric path uses for cross-type
    /// compares (int vs float vs decimal).
    pub fn numeric(&self, i: usize) -> Option<f64> {
        if self.is_null(i) {
            return None;
        }
        match &self.values {
            Values::I64(v) => Some(v[i] as f64),
            Values::F64(v) => Some(v[i]),
            Values::Dec { mantissa, scale } => {
                Some(mantissa[i] as f64 / 10f64.powi(*scale as i32))
            }
            _ => None,
        }
    }

    /// Measured average value payload in bytes (exact for strings; used
    /// by the pre-flight profiler's Ŵ).
    pub fn avg_value_bytes(&self) -> f64 {
        match &self.values {
            Values::Str(s) => {
                if s.len() == 0 {
                    0.0
                } else {
                    s.bytes.len() as f64 / s.len() as f64 + 4.0
                }
            }
            other => other.column_type().value_bytes() as f64,
        }
    }
}

/// Column builder used by generators and CSV decode.
#[derive(Debug)]
pub struct ColumnBuilder {
    ty: ColumnType,
    values: Values,
    validity: Bitmap,
}

impl ColumnBuilder {
    pub fn new(ty: ColumnType) -> Self {
        let values = match ty {
            ColumnType::Int64 => Values::I64(Vec::new()),
            ColumnType::Float64 => Values::F64(Vec::new()),
            ColumnType::Utf8 => Values::Str(StrData::new()),
            ColumnType::Bool => Values::Bool(Bitmap::default()),
            ColumnType::Date => Values::Date(Vec::new()),
            ColumnType::Timestamp => Values::Ts(Vec::new()),
            ColumnType::Decimal { scale } => {
                Values::Dec { mantissa: Vec::new(), scale }
            }
        };
        ColumnBuilder { ty, values, validity: Bitmap::default() }
    }

    pub fn push_null(&mut self) {
        match &mut self.values {
            Values::I64(v) => v.push(0),
            Values::F64(v) => v.push(0.0),
            Values::Str(s) => s.push(""),
            Values::Bool(b) => b.push(false),
            Values::Date(v) => v.push(0),
            Values::Ts(v) => v.push(0),
            Values::Dec { mantissa, .. } => mantissa.push(0),
        }
        self.validity.push(false);
    }

    pub fn push_i64(&mut self, x: i64) {
        match &mut self.values {
            Values::I64(v) => v.push(x),
            _ => panic!("push_i64 on {:?}", self.ty),
        }
        self.validity.push(true);
    }
    pub fn push_f64(&mut self, x: f64) {
        match &mut self.values {
            Values::F64(v) => v.push(x),
            _ => panic!("push_f64 on {:?}", self.ty),
        }
        self.validity.push(true);
    }
    pub fn push_str(&mut self, s: &str) {
        match &mut self.values {
            Values::Str(d) => d.push(s),
            _ => panic!("push_str on {:?}", self.ty),
        }
        self.validity.push(true);
    }
    pub fn push_bool(&mut self, b: bool) {
        match &mut self.values {
            Values::Bool(d) => d.push(b),
            _ => panic!("push_bool on {:?}", self.ty),
        }
        self.validity.push(true);
    }
    pub fn push_date(&mut self, days: i32) {
        match &mut self.values {
            Values::Date(v) => v.push(days),
            _ => panic!("push_date on {:?}", self.ty),
        }
        self.validity.push(true);
    }
    pub fn push_ts(&mut self, us: i64) {
        match &mut self.values {
            Values::Ts(v) => v.push(us),
            _ => panic!("push_ts on {:?}", self.ty),
        }
        self.validity.push(true);
    }
    pub fn push_dec(&mut self, mantissa: i128) {
        match &mut self.values {
            Values::Dec { mantissa: m, .. } => m.push(mantissa),
            _ => panic!("push_dec on {:?}", self.ty),
        }
        self.validity.push(true);
    }

    pub fn push_cell(&mut self, cell: &Cell) {
        match cell {
            Cell::Null => self.push_null(),
            Cell::I64(x) => self.push_i64(*x),
            Cell::F64(x) => self.push_f64(*x),
            Cell::Str(s) => self.push_str(s),
            Cell::Bool(b) => self.push_bool(*b),
            Cell::Date(d) => self.push_date(*d),
            Cell::Ts(t) => self.push_ts(*t),
            Cell::Dec { mantissa, .. } => self.push_dec(*mantissa),
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn finish(self) -> Column {
        Column::with_validity(self.values, self.validity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitmap_set_get_push() {
        let mut b = Bitmap::new_unset(70);
        b.set(0, true);
        b.set(69, true);
        assert!(b.get(0) && b.get(69) && !b.get(35));
        assert_eq!(b.count_set(), 2);
        b.push(true);
        assert_eq!(b.len(), 71);
        assert!(b.get(70));
    }

    #[test]
    fn bitmap_new_set_count() {
        let b = Bitmap::new_set(100);
        assert_eq!(b.count_set(), 100);
        assert!(b.all_set());
        let s = b.slice(10, 50);
        assert_eq!(s.count_set(), 50);
    }

    #[test]
    fn bitmap_slice_matches_per_bit_copy() {
        // Word-level slice must agree with a bit-at-a-time copy across
        // unaligned offsets, word boundaries, and ragged tails.
        let n = 300;
        let mut b = Bitmap::new_unset(n);
        for i in 0..n {
            if i % 3 == 0 || i % 17 == 0 {
                b.set(i, true);
            }
        }
        for &(off, len) in
            &[(0, 64), (1, 64), (63, 65), (64, 128), (70, 130), (5, 0), (200, 100)]
        {
            let s = b.slice(off, len);
            assert_eq!(s.len(), len);
            for i in 0..len {
                assert_eq!(s.get(i), b.get(off + i), "off={off} len={len} i={i}");
            }
            // No stray bits beyond `len` (count over words must match).
            assert_eq!(
                s.count_set(),
                (0..len).filter(|&i| b.get(off + i)).count()
            );
        }
    }

    #[test]
    fn typed_slice_accessors() {
        let mut b = ColumnBuilder::new(ColumnType::Int64);
        b.push_i64(3);
        b.push_i64(-4);
        let c = b.finish();
        assert_eq!(c.values.as_i64(), Some(&[3i64, -4][..]));
        assert!(c.values.as_f64().is_none());
        assert!(c.values.as_str_data().is_none());

        let mut b = ColumnBuilder::new(ColumnType::Decimal { scale: 2 });
        b.push_dec(777);
        let c = b.finish();
        let (m, s) = c.values.as_dec().unwrap();
        assert_eq!((m, s), (&[777i128][..], 2));

        let mut b = ColumnBuilder::new(ColumnType::Utf8);
        b.push_str("ab");
        b.push_str("cde");
        let c = b.finish();
        let sd = c.values.as_str_data().unwrap();
        assert_eq!(sd.byte_range(1), (2, 5));
        assert_eq!(sd.bytes_at(0), b"ab");
        assert_eq!(sd.bytes_at(1), b"cde");
    }

    #[test]
    fn str_arena_roundtrip() {
        let mut s = StrData::new();
        s.push("hello");
        s.push("");
        s.push("wörld");
        assert_eq!(s.get(0), "hello");
        assert_eq!(s.get(1), "");
        assert_eq!(s.get(2), "wörld");
        let sl = s.slice(1, 2);
        assert_eq!(sl.get(1), "wörld");
    }

    #[test]
    fn builder_roundtrip_all_types() {
        let mut b = ColumnBuilder::new(ColumnType::Float64);
        b.push_f64(1.5);
        b.push_null();
        b.push_f64(-2.0);
        let c = b.finish();
        assert_eq!(c.len(), 3);
        assert_eq!(c.cell(0), Cell::F64(1.5));
        assert_eq!(c.cell(1), Cell::Null);
        assert_eq!(c.null_count(), 1);
        assert_eq!(c.numeric(2), Some(-2.0));
        assert_eq!(c.numeric(1), None);

        let mut b = ColumnBuilder::new(ColumnType::Decimal { scale: 2 });
        b.push_dec(12345); // 123.45
        let c = b.finish();
        assert_eq!(c.numeric(0), Some(123.45));

        let mut b = ColumnBuilder::new(ColumnType::Utf8);
        b.push_str("x");
        let c = b.finish();
        assert_eq!(c.cell(0), Cell::Str("x"));
        assert_eq!(c.numeric(0), None);
    }

    #[test]
    fn slice_preserves_nulls_and_values() {
        let mut b = ColumnBuilder::new(ColumnType::Int64);
        for i in 0..100 {
            if i % 7 == 0 {
                b.push_null();
            } else {
                b.push_i64(i);
            }
        }
        let c = b.finish();
        let s = c.slice(10, 20);
        assert_eq!(s.len(), 20);
        for j in 0..20 {
            assert_eq!(s.cell(j), c.cell(10 + j));
        }
    }

    #[test]
    fn heap_bytes_tracks_payload() {
        let mut b = ColumnBuilder::new(ColumnType::Utf8);
        for _ in 0..1000 {
            b.push_str("0123456789");
        }
        let c = b.finish();
        assert!(c.heap_bytes() >= 10_000);
        assert!((c.avg_value_bytes() - 14.0).abs() < 1e-9);
    }
}
