//! Table sources and chunked I/O with read-bandwidth metering.
//!
//! `TableSource` is the engine's only view of input data: batches read
//! contiguous row ranges (the paper's T_read + decode term), the
//! pre-flight profiler samples rows and measures effective read
//! bandwidth (B̂_read). Two implementations:
//!
//! * `InMemorySource` — wraps an Arc<Table>; read = columnar slice copy
//!   (a real decode-buffer allocation, so memory accounting stays honest).
//! * `CsvFileSource` — row-indexed CSV file; read = seek + parse, which
//!   exercises the real parse/normalize cost the cost model fits.
//!
//! # Bounded-memory ingest
//!
//! `CsvFileSource::open` never materializes the file: the row-offset
//! index is built by scanning the file in fixed-size chunks
//! ([`INDEX_CHUNK_BYTES`]) with CSV quote parity carried across chunk
//! boundaries, and the key column is extracted during that same scan by
//! parsing only the key field of each record. The only per-file state
//! that stays resident is the offset index (8 B/row), the key index
//! (8 B/row) and the occurrence index (4 B/row) — reported through
//! `resident_bytes()` and counted against the memory cap as the job's
//! base RSS — so a file larger than RAM opens in O(index) memory and
//! `storage_bytes()` (not resident bytes) is what bounds file-backed
//! jobs at open.
//!
//! # Occurrence index
//!
//! Alongside each row's key, every keyed source records the row's
//! **occurrence ordinal** within its run of equal keys ([`TableSource::
//! occ_at`]: 0 for the first row of a run, 1 for the next, …), computed
//! in the same single pass that builds the key index. The partitioning
//! layer cuts duplicate-key runs *anywhere* and bounds the B side of a
//! mid-run cut at the same occurrence ordinal, so both fragments of a
//! cut run resume with equal global occurrence bases — which is what
//! makes per-shard positional duplicate pairing bit-identical to the
//! solo-shard pairing (see `exec/partition.rs`).
//!
//! All decode paths are typed-fallible: `read_range` returns
//! `Result<Table, SchedError>` and a malformed row, invalid UTF-8, or a
//! short read surfaces as `SchedError::Io` instead of panicking a pool
//! worker.

use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::api::error::SchedError;
use crate::data::column::Cell;
use crate::data::schema::{ColumnType, Schema};
use crate::data::table::{Table, TableBuilder};

/// Chunk size of the streaming open scan (row indexing + key
/// extraction). Any value ≥ 1 is correct — quote parity and the
/// in-progress key field carry across chunk boundaries — this is just
/// the I/O granularity.
pub const INDEX_CHUNK_BYTES: usize = 256 * 1024;

/// Default cap on pooled `read_range` file handles kept open per source
/// (reused across batches instead of a fresh `File::open` per read).
/// Backends resize the cap to their live worker count through
/// [`TableSource::set_read_parallelism`] so k concurrent readers never
/// serialize on handle churn.
const DEFAULT_POOLED_HANDLES: usize = 8;

/// Cumulative read-side counters (shared across worker threads).
///
/// `bytes` counts *transferred* bytes (file bytes for file-backed
/// sources, decoded heap bytes for in-memory ones); `nanos` the time
/// spent inside reads. The pair is kept consistent with a seqlock so a
/// reader never observes bytes from one batch paired with nanos from
/// another (preflight divides one by the other).
#[derive(Debug, Default)]
pub struct ReadMeter {
    /// Seqlock word: even = stable, odd = a writer is mid-update.
    seq: AtomicU64,
    bytes: AtomicU64,
    nanos: AtomicU64,
    /// Completed `record` calls — one per source range read. Outside the
    /// seqlock pair: it is a plain monotone counter (cache hit/miss
    /// deltas), never divided against `bytes`/`nanos`.
    ops: AtomicU64,
}

impl ReadMeter {
    pub fn record(&self, bytes: u64, elapsed_nanos: u64) {
        self.ops.fetch_add(1, Ordering::Relaxed);
        // Writer lock: CAS the seqlock word from even to odd. Contention
        // is one CAS per batch read, so the spin is nearly always free.
        let mut cur = self.seq.load(Ordering::Relaxed);
        loop {
            if cur & 1 == 1 {
                std::hint::spin_loop();
                cur = self.seq.load(Ordering::Relaxed);
                continue;
            }
            // ordering: Acquire on CAS success — taking the write lock
            // must happen-before this writer's data stores so they
            // cannot be reordered ahead of the odd seq becoming
            // visible; Relaxed on failure (we just retry with the
            // reloaded value).
            match self.seq.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Acquire,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        // ordering: Release fence — the data writes below must not
        // become visible before the odd seq value (crossbeam SeqLock
        // write pattern); without it a weakly-ordered CPU could let a
        // reader observe new bytes under an even seq and pass
        // validation torn.
        std::sync::atomic::fence(Ordering::Release);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.nanos.fetch_add(elapsed_nanos, Ordering::Relaxed);
        // ordering: Release — publishes the data stores above; a reader
        // that Acquire-loads this even value sees both counters fully
        // written.
        self.seq.store(cur + 2, Ordering::Release);
    }

    /// Consistent (bytes, nanos) pair: both counters from the same set
    /// of completed `record` calls.
    pub fn snapshot(&self) -> (u64, u64) {
        loop {
            // ordering: Acquire — pairs with the writer's Release store
            // of the even seq, so the counter loads below read values
            // at least as new as that writer's publication.
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 0 {
                let b = self.bytes.load(Ordering::Relaxed);
                let n = self.nanos.load(Ordering::Relaxed);
                // ordering: Acquire fence — orders the counter loads
                // above before the revalidating seq load below (reader
                // half of the SeqLock pattern); without it the second
                // seq load could be satisfied early and a torn read
                // would pass validation.
                std::sync::atomic::fence(Ordering::Acquire);
                if self.seq.load(Ordering::Relaxed) == s1 {
                    return (b, n);
                }
            }
            std::hint::spin_loop();
        }
    }

    pub fn bytes(&self) -> u64 {
        self.snapshot().0
    }

    /// Number of metered source reads so far. With the chunk cache on,
    /// the delta over a job is its true decode count (hits never meter).
    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    /// Effective bandwidth in bytes/sec (None until something was read).
    pub fn bandwidth(&self) -> Option<f64> {
        let (bytes, nanos) = self.snapshot();
        if nanos == 0 {
            return None;
        }
        Some(bytes as f64 / (nanos as f64 * 1e-9))
    }
}

/// Reusable scratch for [`TableSource::read_range_with`]: a byte buffer
/// that survives across reads (file sources fill it instead of
/// allocating per call) plus the read/decode split of the last call.
///
/// `read_ns` covers byte transfer (handle checkout, seek, `read_exact`);
/// `decode_ns` covers turning bytes into a `Table` (UTF-8 validation,
/// CSV parsing, columnar build). Sources that can't split the two put
/// everything in `read_ns`. Both fields are *overwritten* per call.
#[derive(Debug, Default)]
pub struct ReadScratch {
    /// Reused raw-byte buffer (grows to the largest range read).
    pub buf: Vec<u8>,
    /// Transfer time of the last `read_range_with` call, ns.
    pub read_ns: u64,
    /// Decode time of the last `read_range_with` call, ns.
    pub decode_ns: u64,
}

impl ReadScratch {
    /// Heap bytes currently pinned by the scratch buffer.
    pub fn heap_bytes(&self) -> usize {
        self.buf.capacity()
    }
}

/// Abstract input table. Thread-safe: shards read ranges concurrently.
pub trait TableSource: Send + Sync {
    fn schema(&self) -> &Schema;
    fn nrows(&self) -> usize;
    /// Read+decode a contiguous row range into an owned Table (the
    /// per-batch decode buffer). Malformed input, short reads, and I/O
    /// failures are typed errors — never panics — so a bad row fails
    /// the batch (and, after the retry, the job), not the pool worker.
    fn read_range(&self, offset: usize, len: usize) -> Result<Table, SchedError>;
    /// `read_range` variant that reuses caller-owned scratch (byte
    /// buffer) and reports the read/decode time split through it. The
    /// default delegates to `read_range` and books the whole call as
    /// read time; file sources override to fill `scratch.buf` in place
    /// (no per-call allocation) and split transfer from parse.
    fn read_range_with(
        &self,
        offset: usize,
        len: usize,
        scratch: &mut ReadScratch,
    ) -> Result<Table, SchedError> {
        let t0 = Instant::now();
        let out = self.read_range(offset, len);
        scratch.read_ns = t0.elapsed().as_nanos() as u64;
        scratch.decode_ns = 0;
        out
    }
    /// Estimated decoded heap bytes of the range `offset..offset+len` —
    /// the prefetcher charges this against the memory grant *before*
    /// reading, then trues the charge up once the bytes land, so the
    /// estimate only needs to be the right order of magnitude.
    fn decoded_bytes_hint(&self, offset: usize, len: usize) -> u64 {
        let _ = offset;
        let n = self.nrows().max(1) as u128;
        ((self.storage_bytes() as u128 * len as u128) / n) as u64
    }
    /// Primary-key value at `row` (i64 surrogate/PK; the range
    /// partitioner requires key-sorted sources). None if keyless.
    fn key_at(&self, row: usize) -> Option<i64>;
    /// Occurrence ordinal of `row` within its run of equal keys
    /// (0-based: the first row of a duplicate-key run is 0, the next 1,
    /// …). Always 0 for keyless sources and for unique keys. The
    /// partitioner's occurrence-bounded cuts rely on this being O(1).
    fn occ_at(&self, row: usize) -> u32;
    /// Hint that up to `k` threads will call `read_range` concurrently.
    /// File-backed sources size their pooled-handle cap from it (the
    /// worker pool forwards every `set_workers`); in-memory sources
    /// need no handles, so the default is a no-op.
    fn set_read_parallelism(&self, _k: usize) {}
    /// Total on-storage bytes (working-set estimation input).
    fn storage_bytes(&self) -> u64;
    /// Bytes *resident in RAM* for the lifetime of the job (counted
    /// against the memory cap as the base RSS). In-memory sources pin
    /// their whole table plus the occurrence index; file sources pin
    /// their row-offset (8 B/row) and key (8 B/row) indexes plus the
    /// occurrence index. The occurrence index is 4 B/row on every keyed
    /// source — it must stay accounted, because the partitioner's
    /// carve/cut decisions (`occ_at` binary searches) depend on it
    /// being resident for the whole job.
    fn resident_bytes(&self) -> u64;
    /// Read metering for B̂_read estimation.
    fn meter(&self) -> &ReadMeter;
    /// True when re-reading a range is expensive enough that the chunk
    /// cache should sit in front of this source (file-backed decode).
    /// In-memory sources answer false — a "cache" of an in-RAM table
    /// would only duplicate bytes — and so does the cache wrapper
    /// itself, which prevents double-wrapping.
    fn supports_chunk_cache(&self) -> bool {
        false
    }
}

/// In-memory source.
pub struct InMemorySource {
    table: Arc<Table>,
    key_col: Option<usize>,
    /// Per-row occurrence ordinals within runs of equal keys (None when
    /// keyless), computed once at construction.
    occs: Option<Vec<u32>>,
    meter: ReadMeter,
}

/// One pass over a key column: occurrence ordinal of each row within
/// its run of equal keys. Non-i64 (null) key cells never extend a run.
/// Shared with `exec::partition::partition_tables`, which computes the
/// same ordinals locally over decoded fragments — the two must agree.
pub(crate) fn key_occurrences(table: &Table, key_col: usize) -> Vec<u32> {
    let col = table.column(key_col);
    let mut occs = Vec::with_capacity(table.nrows());
    let mut prev: Option<i64> = None;
    let mut run = 0u32;
    for i in 0..table.nrows() {
        let k = match col.cell(i) {
            Cell::I64(v) => Some(v),
            _ => None,
        };
        if k.is_some() && k == prev {
            run += 1;
        } else {
            run = 0;
        }
        occs.push(run);
        prev = k;
    }
    occs
}

impl InMemorySource {
    pub fn new(table: Table) -> Self {
        let key_col = table.schema.key_indices().first().copied();
        let occs = key_col.map(|kc| key_occurrences(&table, kc));
        InMemorySource {
            table: Arc::new(table),
            key_col,
            occs,
            meter: ReadMeter::default(),
        }
    }
    pub fn table(&self) -> &Arc<Table> {
        &self.table
    }
}

impl TableSource for InMemorySource {
    fn schema(&self) -> &Schema {
        &self.table.schema
    }
    fn nrows(&self) -> usize {
        self.table.nrows()
    }
    fn read_range(&self, offset: usize, len: usize) -> Result<Table, SchedError> {
        if offset + len > self.table.nrows() {
            return Err(SchedError::io(
                "<in-memory>",
                format!(
                    "row range {offset}+{len} out of bounds ({} rows)",
                    self.table.nrows()
                ),
            ));
        }
        let t0 = Instant::now();
        let out = self.table.slice(offset, len);
        self.meter
            .record(out.heap_bytes() as u64, t0.elapsed().as_nanos() as u64);
        Ok(out)
    }
    fn key_at(&self, row: usize) -> Option<i64> {
        let kc = self.key_col?;
        match self.table.column(kc).cell(row) {
            Cell::I64(k) => Some(k),
            _ => None,
        }
    }
    fn occ_at(&self, row: usize) -> u32 {
        self.occs.as_ref().map_or(0, |o| o[row])
    }
    fn storage_bytes(&self) -> u64 {
        self.table.heap_bytes() as u64
    }
    fn resident_bytes(&self) -> u64 {
        // Pinned table plus the occurrence index built at construction.
        (self.table.heap_bytes()
            + self.occs.as_ref().map_or(0, |o| o.capacity() * 4)) as u64
    }
    fn meter(&self) -> &ReadMeter {
        &self.meter
    }
}

// ---------------- CSV ----------------

fn needs_quote(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r')
}

fn write_field(out: &mut impl Write, s: &str) -> std::io::Result<()> {
    if needs_quote(s) {
        out.write_all(b"\"")?;
        out.write_all(s.replace('"', "\"\"").as_bytes())?;
        out.write_all(b"\"")
    } else {
        out.write_all(s.as_bytes())
    }
}

/// Write a table as CSV (header = column names; nulls = empty fields;
/// dates/timestamps/decimal mantissas as integers — lossless).
pub fn write_csv(table: &Table, path: &Path) -> std::io::Result<()> {
    let mut out = BufWriter::new(std::fs::File::create(path)?);
    let names: Vec<&str> =
        table.schema.fields.iter().map(|f| f.name.as_str()).collect();
    out.write_all(names.join(",").as_bytes())?;
    out.write_all(b"\n")?;
    let mut buf = String::new();
    for i in 0..table.nrows() {
        for (ci, col) in table.columns.iter().enumerate() {
            if ci > 0 {
                out.write_all(b",")?;
            }
            buf.clear();
            match col.cell(i) {
                Cell::Null => {}
                Cell::I64(x) => buf.push_str(&x.to_string()),
                Cell::F64(x) => {
                    // {:?} prints round-trippable f64.
                    buf.push_str(&format!("{x:?}"));
                }
                Cell::Str(s) => {
                    // Quoted empty ("") distinguishes the empty string
                    // from NULL (bare empty field).
                    if s.is_empty() {
                        out.write_all(b"\"\"")?;
                    } else {
                        write_field(&mut out, s)?;
                    }
                    continue;
                }
                Cell::Bool(b) => buf.push_str(if b { "t" } else { "f" }),
                Cell::Date(d) => buf.push_str(&d.to_string()),
                Cell::Ts(t) => buf.push_str(&t.to_string()),
                Cell::Dec { mantissa, .. } => buf.push_str(&mantissa.to_string()),
            }
            out.write_all(buf.as_bytes())?;
        }
        out.write_all(b"\n")?;
    }
    out.flush()
}

/// Split one CSV record into (field, was_quoted) pairs. The quoted flag
/// lets the decoder distinguish NULL (bare empty) from "" (quoted empty).
fn split_record(line: &str) -> Vec<(String, bool)> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut quoted = false;
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(ch) = chars.next() {
        match ch {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => {
                in_quotes = true;
                quoted = true;
            }
            ',' if !in_quotes => {
                fields.push((std::mem::take(&mut cur), quoted));
                quoted = false;
            }
            c => cur.push(c),
        }
    }
    fields.push((cur, quoted));
    fields
}

fn parse_cell(
    tb: &mut TableBuilder,
    ci: usize,
    ty: ColumnType,
    field: &str,
    quoted: bool,
) -> Result<(), String> {
    if field.is_empty() && !quoted {
        tb.col(ci).push_null();
        return Ok(());
    }
    let err = |e: &str| format!("col {ci}: bad {ty} value {field:?}: {e}");
    match ty {
        ColumnType::Int64 => {
            tb.col(ci).push_i64(field.parse().map_err(|_| err("int"))?)
        }
        ColumnType::Float64 => {
            tb.col(ci).push_f64(field.parse().map_err(|_| err("float"))?)
        }
        ColumnType::Utf8 => tb.col(ci).push_str(field),
        ColumnType::Bool => match field {
            "t" => tb.col(ci).push_bool(true),
            "f" => tb.col(ci).push_bool(false),
            _ => return Err(err("bool")),
        },
        ColumnType::Date => {
            tb.col(ci).push_date(field.parse().map_err(|_| err("date"))?)
        }
        ColumnType::Timestamp => {
            tb.col(ci).push_ts(field.parse().map_err(|_| err("ts"))?)
        }
        ColumnType::Decimal { .. } => {
            tb.col(ci).push_dec(field.parse().map_err(|_| err("dec"))?)
        }
    }
    Ok(())
}

/// Streaming row indexer: fed the file chunk by chunk, it builds the
/// row-offset index and extracts the key column — plus each row's
/// occurrence ordinal within its run of equal keys (the partitioner's
/// cross-shard duplicate-alignment input), all in the same pass — while
/// carrying CSV quote parity (and the in-progress key field) across
/// chunk boundaries. The mirror of this state machine is fuzz-tested
/// against a whole-file reference splitter in
/// `python/tests/test_csv_indexer.py`.
struct RowIndexer {
    /// Which field of each record is the key (None = keyless schema).
    key_col: Option<usize>,
    /// Whether the key is the record's last field (a trailing `\r` from
    /// a CRLF line ending must then be stripped before parsing).
    key_is_last: bool,
    in_quotes: bool,
    /// The previous byte was a `"` that closed a quoted section. A `"`
    /// arriving now is a CSV `""` escape: `split_record` unescapes it
    /// to a literal quote, so the key extractor must too (a literal
    /// quote then fails the i64 parse — consistent with what decoding
    /// the row would do — instead of silently indexing a wrong key).
    quote_just_closed: bool,
    /// Still inside the header line (not a data record).
    in_header: bool,
    /// Absolute byte offset of the next byte to be fed.
    pos: u64,
    /// Absolute byte offset where the current record started.
    record_start: u64,
    /// 0-based field index within the current record.
    field_idx: usize,
    /// Accumulated bytes of the current record's key field.
    key_buf: Vec<u8>,
    row_offsets: Vec<u64>,
    keys: Vec<i64>,
    /// Occurrence ordinal of each row within its run of equal keys
    /// (parallel to `keys`).
    occs: Vec<u32>,
}

impl RowIndexer {
    fn new(schema: &Schema) -> RowIndexer {
        let key_col = schema.key_indices().first().copied();
        RowIndexer {
            key_col,
            key_is_last: key_col == Some(schema.len().saturating_sub(1)),
            in_quotes: false,
            quote_just_closed: false,
            in_header: true,
            pos: 0,
            record_start: 0,
            field_idx: 0,
            key_buf: Vec::new(),
            row_offsets: Vec::new(),
            keys: Vec::new(),
            occs: Vec::new(),
        }
    }

    /// Scan one chunk of the file (any size ≥ 1; boundaries may fall
    /// anywhere, including inside quotes or inside the key field).
    fn feed(&mut self, chunk: &[u8]) -> Result<(), String> {
        for &byte in chunk {
            let was_close = self.quote_just_closed;
            self.quote_just_closed = false;
            match byte {
                b'"' if self.in_quotes => {
                    self.in_quotes = false;
                    self.quote_just_closed = true;
                }
                b'"' => {
                    self.in_quotes = true;
                    // `""` escape: emit the literal quote the decoder
                    // would see (see `quote_just_closed`).
                    if was_close
                        && !self.in_header
                        && self.key_col == Some(self.field_idx)
                    {
                        self.key_buf.push(b'"');
                    }
                }
                b'\n' if !self.in_quotes => {
                    self.end_record()?;
                    self.pos += 1;
                    self.record_start = self.pos;
                    continue;
                }
                b',' if !self.in_quotes => self.field_idx += 1,
                _ => {
                    if !self.in_header && self.key_col == Some(self.field_idx) {
                        self.key_buf.push(byte);
                    }
                }
            }
            self.pos += 1;
        }
        Ok(())
    }

    /// Finalize the record ending at the current position.
    fn end_record(&mut self) -> Result<(), String> {
        if self.in_header {
            self.in_header = false;
        } else {
            self.row_offsets.push(self.record_start);
            if self.key_col.is_some() {
                // CRLF line endings leave a trailing \r on the last
                // field only (mirrors `parse_line`'s strip).
                if self.key_is_last && self.key_buf.last() == Some(&b'\r') {
                    self.key_buf.pop();
                }
                let row = self.keys.len();
                let key = std::str::from_utf8(&self.key_buf)
                    .ok()
                    .and_then(|s| s.parse::<i64>().ok())
                    .ok_or_else(|| format!("row {row}: null/bad key"))?;
                // Occurrence ordinal within the run of equal keys —
                // computed in the same pass, O(1) per row.
                let occ = match self.keys.last() {
                    Some(&prev) if prev == key => {
                        self.occs.last().copied().unwrap_or(0) + 1
                    }
                    _ => 0,
                };
                self.keys.push(key);
                self.occs.push(occ);
            }
        }
        self.field_idx = 0;
        self.key_buf.clear();
        Ok(())
    }

    /// Finish the scan: close a final unterminated record, validate
    /// quote parity, and return (row_offsets with EOF sentinel,
    /// (keys, occurrence ordinals)).
    #[allow(clippy::type_complexity)]
    fn finish(mut self) -> Result<(Vec<u64>, Option<(Vec<i64>, Vec<u32>)>), String> {
        if self.in_quotes {
            return Err("unterminated quoted field at EOF".into());
        }
        if self.record_start < self.pos && !self.in_header {
            // Final record without a trailing newline.
            self.end_record()?;
        }
        self.row_offsets.push(self.pos);
        // The indexes live for the whole job and are what
        // `resident_bytes` charges against the memory cap: drop the
        // push-growth slack.
        self.row_offsets.shrink_to_fit();
        self.keys.shrink_to_fit();
        self.occs.shrink_to_fit();
        let keys = if self.key_col.is_some() {
            Some((self.keys, self.occs))
        } else {
            None
        };
        Ok((self.row_offsets, keys))
    }
}

/// CSV-backed source with a prebuilt row offset index (byte position of
/// every row) so `read_range` is a single seek + sequential parse.
///
/// Opening is bounded-memory: the index and the key column are built in
/// one chunked streaming scan (see the module docs); only the two
/// indexes stay resident. `read_range` reuses a small pool of open file
/// handles instead of reopening the file per batch.
pub struct CsvFileSource {
    path: PathBuf,
    schema: Schema,
    /// Byte offset of row i (data rows; header excluded); last entry = EOF.
    row_offsets: Vec<u64>,
    /// Key column values, extracted during the open scan (alignment /
    /// partitioning state — part of the paper's "alignment state for f"
    /// memory term).
    keys: Option<Vec<i64>>,
    /// Per-row occurrence ordinals within runs of equal keys, built in
    /// the same open scan (cross-shard duplicate alignment input).
    occs: Option<Vec<u32>>,
    /// Reusable read handles (checked out per `read_range`, returned
    /// after; capped at `handle_cap`).
    handles: Mutex<Vec<std::fs::File>>,
    /// Live cap on pooled handles — resized to the worker count via
    /// `set_read_parallelism` so k > 8 readers don't serialize on
    /// handle churn.
    handle_cap: AtomicUsize,
    meter: ReadMeter,
    /// Bytes / nanos of the one-off open-time index scan, kept OUT of
    /// `meter` so B̂_read reflects steady-state `read_range` traffic
    /// only (the scan is a sequential whole-file pass whose rate is not
    /// representative of seek-y batch reads and was inflating the
    /// preflight estimate on small files).
    scan_bytes: u64,
    scan_nanos: u64,
}

impl CsvFileSource {
    /// Open a CSV file, building the row-offset and key indexes in one
    /// chunked streaming scan — the file is never materialized, so a
    /// larger-than-RAM input opens in O(rows × 16 bytes) memory.
    pub fn open(path: &Path, schema: Schema) -> Result<Self, SchedError> {
        Self::open_with_chunk_size(path, schema, INDEX_CHUNK_BYTES)
    }

    /// `open` with an explicit scan-chunk size (any value ≥ 1 yields
    /// identical indexes; exposed for boundary-condition tests).
    pub fn open_with_chunk_size(
        path: &Path,
        schema: Schema,
        chunk_bytes: usize,
    ) -> Result<Self, SchedError> {
        Self::open_inner(path, schema, chunk_bytes.max(1))
            .map_err(|m| SchedError::io(path.display().to_string(), m))
    }

    fn open_inner(
        path: &Path,
        schema: Schema,
        chunk_bytes: usize,
    ) -> Result<Self, String> {
        let mut file =
            std::fs::File::open(path).map_err(|e| format!("open: {e}"))?;
        let mut indexer = RowIndexer::new(&schema);
        let mut buf = vec![0u8; chunk_bytes];
        let t0 = Instant::now();
        let mut scanned = 0u64;
        loop {
            let n = match file.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(format!("read: {e}")),
            };
            scanned += n as u64;
            indexer.feed(&buf[..n])?;
        }
        let (row_offsets, key_index) = indexer.finish()?;
        let (keys, occs) = match key_index {
            Some((k, o)) => (Some(k), Some(o)),
            None => (None, None),
        };
        Ok(CsvFileSource {
            path: path.to_path_buf(),
            schema,
            row_offsets,
            keys,
            occs,
            handles: Mutex::new(vec![file]),
            handle_cap: AtomicUsize::new(DEFAULT_POOLED_HANDLES),
            meter: ReadMeter::default(),
            scan_bytes: scanned,
            scan_nanos: t0.elapsed().as_nanos() as u64,
        })
    }

    /// (bytes, nanos) of the open-time index scan. Kept separate from
    /// [`TableSource::meter`] so preflight's B̂_read never mixes the
    /// sequential whole-file scan rate into the batch-read estimate.
    pub fn index_scan_stats(&self) -> (u64, u64) {
        (self.scan_bytes, self.scan_nanos)
    }

    /// Lock the handle pool, recovering from poisoning instead of
    /// cascading the panic. The pool is just a cache of open file
    /// descriptors — a thread that panicked while holding the lock
    /// cannot have left it logically corrupt, only possibly mid-push —
    /// so on poison we clear the cached handles (they reopen lazily)
    /// and carry on. This keeps one panicked worker from turning every
    /// subsequent batch read into a second panic.
    fn pool_guard(&self) -> std::sync::MutexGuard<'_, Vec<std::fs::File>> {
        match self.handles.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                let mut guard = poisoned.into_inner();
                guard.clear();
                guard
            }
        }
    }

    /// Check a read handle out of the pool (opening a new one only when
    /// the pool is empty).
    fn checkout_handle(&self) -> Result<std::fs::File, String> {
        if let Some(f) = self.pool_guard().pop() {
            return Ok(f);
        }
        std::fs::File::open(&self.path).map_err(|e| format!("open: {e}"))
    }

    fn return_handle(&self, f: std::fs::File) {
        let mut pool = self.pool_guard();
        if pool.len() < self.handle_cap.load(Ordering::Relaxed) {
            pool.push(f);
        }
    }

    fn parse_rows(&self, text: &str, expect: usize) -> Result<Table, String> {
        let mut tb = TableBuilder::new(self.schema.clone());
        let mut in_quotes = false;
        let mut start = 0usize;
        let bytes = text.as_bytes();
        let mut parsed = 0usize;
        for i in 0..bytes.len() {
            match bytes[i] {
                b'"' => in_quotes = !in_quotes,
                b'\n' if !in_quotes => {
                    let line = &text[start..i];
                    start = i + 1;
                    self.parse_line(&mut tb, line)?;
                    parsed += 1;
                }
                _ => {}
            }
        }
        if start < text.len() {
            self.parse_line(&mut tb, &text[start..])?;
            parsed += 1;
        }
        if parsed != expect {
            return Err(format!("expected {expect} rows, parsed {parsed}"));
        }
        Ok(tb.finish())
    }

    fn parse_line(&self, tb: &mut TableBuilder, line: &str) -> Result<(), String> {
        let line = line.strip_suffix('\r').unwrap_or(line);
        let fields = split_record(line);
        if fields.len() != self.schema.len() {
            return Err(format!(
                "row has {} fields, schema {}",
                fields.len(),
                self.schema.len()
            ));
        }
        for (ci, (field, quoted)) in fields.iter().enumerate() {
            parse_cell(tb, ci, self.schema.fields[ci].ty, field, *quoted)?;
        }
        Ok(())
    }
}

impl TableSource for CsvFileSource {
    fn schema(&self) -> &Schema {
        &self.schema
    }
    fn nrows(&self) -> usize {
        self.row_offsets.len() - 1
    }
    fn supports_chunk_cache(&self) -> bool {
        // Every range read is a seek + CSV parse; re-executions benefit
        // from serving the decoded chunk instead.
        true
    }
    fn read_range(&self, offset: usize, len: usize) -> Result<Table, SchedError> {
        let mut scratch = ReadScratch::default();
        self.read_range_with(offset, len, &mut scratch)
    }
    fn read_range_with(
        &self,
        offset: usize,
        len: usize,
        scratch: &mut ReadScratch,
    ) -> Result<Table, SchedError> {
        let path = || self.path.display().to_string();
        scratch.read_ns = 0;
        scratch.decode_ns = 0;
        if offset + len >= self.row_offsets.len() {
            return Err(SchedError::io(
                path(),
                format!(
                    "row range {offset}+{len} out of bounds ({} rows)",
                    self.nrows()
                ),
            ));
        }
        if len == 0 {
            return Ok(Table::empty(self.schema.clone()));
        }
        let t0 = Instant::now();
        let lo = self.row_offsets[offset];
        let hi = self.row_offsets[offset + len];
        let need = (hi - lo) as usize;
        let mut f = self.checkout_handle().map_err(|m| SchedError::io(path(), m))?;
        // Reuse the caller's scratch buffer instead of a fresh
        // allocation per read (the prefetch hot path).
        scratch.buf.resize(need, 0);
        let read = f
            .seek(SeekFrom::Start(lo))
            .map_err(|e| format!("seek: {e}"))
            .and_then(|_| {
                f.read_exact(&mut scratch.buf[..need])
                    .map_err(|e| format!("read {} bytes at {lo}: {e}", hi - lo))
            });
        match read {
            // Only a handle that completed its read cleanly goes back
            // in the pool.
            Ok(()) => self.return_handle(f),
            Err(m) => return Err(SchedError::io(path(), m)),
        }
        scratch.read_ns = t0.elapsed().as_nanos() as u64;
        let t1 = Instant::now();
        let text = std::str::from_utf8(&scratch.buf[..need])
            .map_err(|e| SchedError::io(path(), format!("invalid utf-8: {e}")))?;
        let table = self
            .parse_rows(text, len)
            .map_err(|m| SchedError::io(path(), m))?;
        scratch.decode_ns = t1.elapsed().as_nanos() as u64;
        self.meter.record(hi - lo, scratch.read_ns + scratch.decode_ns);
        Ok(table)
    }
    fn decoded_bytes_hint(&self, offset: usize, len: usize) -> u64 {
        // File-byte span of the range, times a decode-expansion factor
        // (columnar build roughly doubles CSV text). Trued up by the
        // prefetcher once the real table lands.
        let last = self.row_offsets.len() - 1;
        let lo = self.row_offsets[offset.min(last)];
        let hi = self.row_offsets[(offset + len).min(last)];
        (hi - lo).saturating_mul(2)
    }
    fn key_at(&self, row: usize) -> Option<i64> {
        self.keys.as_ref().map(|k| k[row])
    }
    fn occ_at(&self, row: usize) -> u32 {
        self.occs.as_ref().map_or(0, |o| o[row])
    }
    fn set_read_parallelism(&self, k: usize) {
        let cap = k.max(1);
        self.handle_cap.store(cap, Ordering::Relaxed);
        // Shrinks release surplus handles now instead of leaking them
        // until process exit.
        let mut pool = self.pool_guard();
        pool.truncate(cap);
    }
    fn storage_bytes(&self) -> u64 {
        *self.row_offsets.last().unwrap_or(&0)
    }
    fn resident_bytes(&self) -> u64 {
        // Row-offset + key + occurrence indexes stay resident; data is
        // streamed.
        (self.row_offsets.capacity() * 8
            + self.keys.as_ref().map_or(0, |k| k.capacity() * 8)
            + self.occs.as_ref().map_or(0, |o| o.capacity() * 4)) as u64
    }
    fn meter(&self) -> &ReadMeter {
        &self.meter
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_table, GenSpec};

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "smartdiff_io_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip_preserves_table() {
        let spec = GenSpec { rows: 500, str_len: 10, seed: 11, ..GenSpec::default() };
        let t = generate_table(&spec);
        let path = tmpdir().join("roundtrip.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, t.schema.clone()).unwrap();
        assert_eq!(src.nrows(), t.nrows());
        let back = src.read_range(0, t.nrows()).unwrap();
        assert_eq!(back, t);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_range_reads_match_slices() {
        let spec = GenSpec { rows: 300, seed: 12, ..GenSpec::default() };
        let t = generate_table(&spec);
        let path = tmpdir().join("ranges.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, t.schema.clone()).unwrap();
        for (off, len) in [(0usize, 10usize), (50, 100), (290, 10), (299, 1)] {
            assert_eq!(src.read_range(off, len).unwrap(), t.slice(off, len));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn chunked_indexing_is_chunk_size_invariant() {
        // Quote parity and key extraction must carry across chunk
        // boundaries: pathological chunk sizes (1, 2, 3, 7 bytes) must
        // produce the identical index as one big chunk.
        use crate::data::schema::{ColumnType, Field, Schema};
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("s", ColumnType::Utf8),
        ]);
        let mut tb = TableBuilder::new(schema.clone());
        for (i, s) in [
            "plain",
            "comma, inside",
            "quote \" inside",
            "multi\nline\nvalue",
            "trailing\r",
            "",
        ]
        .iter()
        .enumerate()
        {
            tb.col(0).push_i64(3 * i as i64);
            tb.col(1).push_str(s);
        }
        let t = tb.finish();
        let path = tmpdir().join("chunked.csv");
        write_csv(&t, &path).unwrap();
        let big = CsvFileSource::open(&path, schema.clone()).unwrap();
        for chunk in [1usize, 2, 3, 7, 64] {
            let src =
                CsvFileSource::open_with_chunk_size(&path, schema.clone(), chunk)
                    .unwrap();
            assert_eq!(src.row_offsets, big.row_offsets, "chunk={chunk}");
            assert_eq!(src.keys, big.keys, "chunk={chunk}");
            assert_eq!(src.occs, big.occs, "chunk={chunk}");
            assert_eq!(src.read_range(0, t.nrows()).unwrap(), t, "chunk={chunk}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_trailing_newline_still_indexed() {
        use crate::data::schema::{ColumnType, Field, Schema};
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("x", ColumnType::Int64),
        ]);
        let path = tmpdir().join("notrail.csv");
        std::fs::write(&path, "id,x\n1,10\n2,20").unwrap();
        for chunk in [1usize, 4, 1024] {
            let src =
                CsvFileSource::open_with_chunk_size(&path, schema.clone(), chunk)
                    .unwrap();
            assert_eq!(src.nrows(), 2);
            assert_eq!(src.key_at(1), Some(2));
            let t = src.read_range(0, 2).unwrap();
            assert_eq!(t.nrows(), 2);
            assert_eq!(t.column(1).cell(1), Cell::I64(20));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn crlf_line_endings_and_key_last_field() {
        use crate::data::schema::{ColumnType, Field, Schema};
        // Key is the LAST field: the CRLF \r lands at the end of the
        // key bytes and must be stripped before parsing.
        let schema = Schema::new(vec![
            Field::new("x", ColumnType::Int64),
            Field::key("id", ColumnType::Int64),
        ]);
        let path = tmpdir().join("crlf.csv");
        std::fs::write(&path, "x,id\r\n10,1\r\n20,2\r\n").unwrap();
        let src = CsvFileSource::open_with_chunk_size(&path, schema, 3).unwrap();
        assert_eq!(src.nrows(), 2);
        assert_eq!(src.key_at(0), Some(1));
        assert_eq!(src.key_at(1), Some(2));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn open_errors_are_typed() {
        use crate::data::schema::{ColumnType, Field, Schema};
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("x", ColumnType::Int64),
        ]);
        // Bad key (non-integer) fails at open with a typed error.
        let path = tmpdir().join("badkey.csv");
        std::fs::write(&path, "id,x\n1,10\nnope,20\n").unwrap();
        match CsvFileSource::open(&path, schema.clone()) {
            Err(SchedError::Io { message, .. }) => {
                assert!(message.contains("bad key"), "{message}");
            }
            Err(other) => panic!("expected Io error, got {other:?}"),
            Ok(_) => panic!("expected Io error, got Ok"),
        }
        std::fs::remove_file(&path).ok();
        // Escaped quote in the key field unescapes to a literal `"` —
        // rejected at open exactly like the row decoder would reject
        // it (never silently indexed as key 12).
        let path = tmpdir().join("escquote.csv");
        std::fs::write(&path, "id,x\n\"1\"\"2\",5\n").unwrap();
        for chunk in [1usize, 3, 4096] {
            match CsvFileSource::open_with_chunk_size(
                &path,
                schema.clone(),
                chunk,
            ) {
                Err(SchedError::Io { message, .. }) => {
                    assert!(message.contains("bad key"), "{message}");
                }
                Err(other) => panic!("expected Io error, got {other:?}"),
                Ok(_) => panic!("expected Io error, got Ok (chunk={chunk})"),
            }
        }
        std::fs::remove_file(&path).ok();
        // Unterminated quote fails at open.
        let path = tmpdir().join("openquote.csv");
        std::fs::write(&path, "id,x\n1,\"abc\n").unwrap();
        match CsvFileSource::open(&path, schema) {
            Err(SchedError::Io { message, .. }) => {
                assert!(message.contains("unterminated"), "{message}");
            }
            Err(other) => panic!("expected Io error, got {other:?}"),
            Ok(_) => panic!("expected Io error, got Ok"),
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn read_range_errors_are_typed_not_panics() {
        use crate::data::schema::{ColumnType, Field, Schema};
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("x", ColumnType::Int64),
        ]);
        // Key column parses at open, payload column is malformed: the
        // failure must surface from read_range as a typed SchedError.
        let path = tmpdir().join("badrow.csv");
        std::fs::write(&path, "id,x\n1,10\n2,oops\n3,30\n").unwrap();
        let src = CsvFileSource::open(&path, schema).unwrap();
        assert_eq!(src.nrows(), 3);
        assert!(src.read_range(0, 1).is_ok());
        match src.read_range(1, 1) {
            Err(SchedError::Io { message, .. }) => {
                assert!(message.contains("bad"), "{message}");
            }
            other => panic!("expected Io error, got {other:?}"),
        }
        // Out-of-bounds range: typed error, not an assert.
        assert!(src.read_range(2, 5).is_err());
        // The source stays usable after a failed read.
        assert!(src.read_range(2, 1).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn quoted_strings_with_commas_and_newlines() {
        use crate::data::schema::{ColumnType, Field, Schema};
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("s", ColumnType::Utf8),
        ]);
        let mut tb = TableBuilder::new(schema.clone());
        tb.col(0).push_i64(0);
        tb.col(1).push_str("a,b\"c\"\nd");
        tb.col(0).push_i64(2);
        tb.col(1).push_str("plain");
        let t = tb.finish();
        let path = tmpdir().join("quotes.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, schema).unwrap();
        assert_eq!(src.read_range(0, 2).unwrap(), t);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn meter_records_reads() {
        let t = generate_table(&GenSpec { rows: 100, ..GenSpec::default() });
        let src = InMemorySource::new(t);
        let _ = src.read_range(0, 100).unwrap();
        assert!(src.meter().bytes() > 0);
        assert!(src.meter().bandwidth().unwrap_or(0.0) > 0.0);
    }

    #[test]
    fn open_scan_stays_out_of_read_meter() {
        // Preflight's B̂_read divides meter deltas; the open-time index
        // scan is sequential whole-file I/O and must not leak into the
        // steady-state read_range signal.
        let t = generate_table(&GenSpec { rows: 400, ..GenSpec::default() });
        let path = tmpdir().join("scanmeter.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, t.schema.clone()).unwrap();
        assert_eq!(src.meter().snapshot(), (0, 0), "open must not meter");
        let (scan_bytes, _) = src.index_scan_stats();
        assert!(scan_bytes > 0, "scan stats recorded separately");
        let _ = src.read_range(10, 50).unwrap();
        let (bytes, nanos) = src.meter().snapshot();
        assert!(bytes > 0 && nanos > 0, "read_range still meters");
        assert!(bytes < scan_bytes, "range read < whole-file scan");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn read_range_with_reuses_scratch_and_splits_stages() {
        let t = generate_table(&GenSpec { rows: 300, ..GenSpec::default() });
        let path = tmpdir().join("scratch.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, t.schema.clone()).unwrap();
        let mut scratch = ReadScratch::default();
        let a = src.read_range_with(0, 150, &mut scratch).unwrap();
        assert_eq!(a, t.slice(0, 150));
        assert!(scratch.decode_ns > 0, "csv decode time recorded");
        let cap_after_first = scratch.buf.capacity();
        assert!(cap_after_first > 0);
        // A second, smaller read reuses the same buffer allocation.
        let b = src.read_range_with(200, 50, &mut scratch).unwrap();
        assert_eq!(b, t.slice(200, 50));
        assert_eq!(scratch.buf.capacity(), cap_after_first);
        // Default trait impl (in-memory source) books all time as read.
        let mem = InMemorySource::new(t);
        let mut s2 = ReadScratch::default();
        let c = mem.read_range_with(5, 20, &mut s2).unwrap();
        assert_eq!(c.nrows(), 20);
        assert_eq!(s2.decode_ns, 0);
        // Hints are order-of-magnitude decode estimates, nonzero for
        // nonempty ranges on both source kinds.
        assert!(src.decoded_bytes_hint(0, 100) > 0);
        assert!(mem.decoded_bytes_hint(0, 100) > 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn meter_snapshots_are_never_torn() {
        // Writers always record (n, n) pairs; a torn read would observe
        // bytes and nanos from different record() calls and the pair
        // would disagree.
        // Miri interprets ~1000x slower than native; shrink the loops
        // there so the interleaving surface survives but the job
        // finishes. Native keeps the full counts.
        #[cfg(miri)]
        const WRITES: u64 = 50;
        #[cfg(not(miri))]
        const WRITES: u64 = 2_000;
        #[cfg(miri)]
        const READS: u64 = 200;
        #[cfg(not(miri))]
        const READS: u64 = 20_000;
        let meter = Arc::new(ReadMeter::default());
        let mut writers = Vec::new();
        for _ in 0..4 {
            let m = Arc::clone(&meter);
            writers.push(std::thread::spawn(move || {
                for i in 1..=WRITES {
                    m.record(i, i);
                }
            }));
        }
        let reader = {
            let m = Arc::clone(&meter);
            std::thread::spawn(move || {
                for _ in 0..READS {
                    let (b, n) = m.snapshot();
                    assert_eq!(b, n, "torn meter snapshot: bytes={b} nanos={n}");
                }
            })
        };
        for w in writers {
            w.join().unwrap();
        }
        reader.join().unwrap();
        let total = 4 * (WRITES * (WRITES + 1) / 2);
        assert_eq!(meter.snapshot(), (total, total));
    }

    #[test]
    fn keys_available_from_both_sources() {
        let t = generate_table(&GenSpec { rows: 50, ..GenSpec::default() });
        let path = tmpdir().join("keys.csv");
        write_csv(&t, &path).unwrap();
        let csv = CsvFileSource::open(&path, t.schema.clone()).unwrap();
        let mem = InMemorySource::new(t);
        for i in [0usize, 10, 49] {
            assert_eq!(mem.key_at(i), Some(2 * i as i64));
            assert_eq!(csv.key_at(i), Some(2 * i as i64));
            // Generator keys are unique: every occurrence ordinal is 0.
            assert_eq!(mem.occ_at(i), 0);
            assert_eq!(csv.occ_at(i), 0);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn occurrence_ordinals_agree_across_sources() {
        use crate::data::schema::{ColumnType, Field, Schema};
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Int64),
        ]);
        // Sorted duplicate-key runs of lengths 1, 3, 2, 4.
        let keys = [5i64, 7, 7, 7, 9, 9, 12, 12, 12, 12];
        let want_occ = [0u32, 0, 1, 2, 0, 1, 0, 1, 2, 3];
        let mut tb = TableBuilder::new(schema.clone());
        for (i, &k) in keys.iter().enumerate() {
            tb.col(0).push_i64(k);
            tb.col(1).push_i64(i as i64);
        }
        let t = tb.finish();
        let path = tmpdir().join("occs.csv");
        write_csv(&t, &path).unwrap();
        let mem = InMemorySource::new(t);
        for chunk in [1usize, 3, 4096] {
            let csv =
                CsvFileSource::open_with_chunk_size(&path, schema.clone(), chunk)
                    .unwrap();
            for (i, &want) in want_occ.iter().enumerate() {
                assert_eq!(mem.occ_at(i), want, "mem row {i}");
                assert_eq!(csv.occ_at(i), want, "csv row {i} chunk={chunk}");
            }
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn in_memory_resident_bytes_pins_occurrence_index_charge() {
        use crate::data::schema::{ColumnType, Field, Schema};
        // Keyed: the pinned table plus exactly 4 B/row of occurrence
        // index. Regression guard for the accounting the partitioner's
        // carve/cut decisions depend on.
        let t = generate_table(&GenSpec { rows: 257, ..GenSpec::default() });
        let heap = t.heap_bytes() as u64;
        let n = t.nrows() as u64;
        let mem = InMemorySource::new(t);
        assert_eq!(mem.resident_bytes(), heap + 4 * n);

        // Keyless: no key column, no occurrence index, no extra charge.
        let schema = Schema::new(vec![Field::new("v", ColumnType::Int64)]);
        let mut tb = TableBuilder::new(schema);
        for i in 0..100 {
            tb.col(0).push_i64(i);
        }
        let t = tb.finish();
        let heap = t.heap_bytes() as u64;
        let mem = InMemorySource::new(t);
        assert_eq!(mem.resident_bytes(), heap);
    }

    #[test]
    fn csv_resident_bytes_pins_index_charges() {
        use crate::data::schema::{ColumnType, Field, Schema};
        // Keyed: 8 B/row offsets (+ the EOF sentinel), 8 B/row keys,
        // 4 B/row occurrence index — nothing else stays resident.
        let t = generate_table(&GenSpec { rows: 193, ..GenSpec::default() });
        let n = t.nrows() as u64;
        let path = tmpdir().join("resident_keyed.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, t.schema.clone()).unwrap();
        assert_eq!(src.resident_bytes(), (n + 1) * 8 + n * 8 + n * 4);
        std::fs::remove_file(path).ok();

        // Keyless: only the row-offset index.
        let schema = Schema::new(vec![Field::new("v", ColumnType::Int64)]);
        let mut tb = TableBuilder::new(schema.clone());
        for i in 0..64 {
            tb.col(0).push_i64(i);
        }
        let t = tb.finish();
        let n = t.nrows() as u64;
        let path = tmpdir().join("resident_keyless.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, schema).unwrap();
        assert_eq!(src.resident_bytes(), (n + 1) * 8);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn handle_pool_resizes_with_read_parallelism() {
        let t = generate_table(&GenSpec { rows: 200, ..GenSpec::default() });
        let path = tmpdir().join("handles.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, t.schema.clone()).unwrap();
        // Raise the cap past the default: returning 20 handles must keep
        // all 20 pooled (no churn for k > 8 workers).
        src.set_read_parallelism(20);
        let handles: Vec<std::fs::File> = (0..20)
            .map(|_| src.checkout_handle().unwrap())
            .collect();
        for f in handles {
            src.return_handle(f);
        }
        assert_eq!(src.handles.lock().unwrap().len(), 20);
        // Shrinking trims the pool immediately.
        src.set_read_parallelism(2);
        assert_eq!(src.handles.lock().unwrap().len(), 2);
        let f = src.checkout_handle().unwrap();
        src.return_handle(f);
        assert!(src.handles.lock().unwrap().len() <= 2);
        // Reads still work after resizing.
        assert_eq!(src.read_range(0, 5).unwrap(), t.slice(0, 5));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_fields_are_nulls() {
        use crate::data::schema::{ColumnType, Field, Schema};
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("x", ColumnType::Float64),
        ]);
        let mut tb = TableBuilder::new(schema.clone());
        tb.col(0).push_i64(0);
        tb.col(1).push_null();
        let t = tb.finish();
        let path = tmpdir().join("nulls.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, schema).unwrap();
        let back = src.read_range(0, 1).unwrap();
        assert!(back.column(1).is_null(0));
        std::fs::remove_file(path).ok();
    }
}
