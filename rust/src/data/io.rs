//! Table sources and chunked I/O with read-bandwidth metering.
//!
//! `TableSource` is the engine's only view of input data: batches read
//! contiguous row ranges (the paper's T_read + decode term), the
//! pre-flight profiler samples rows and measures effective read
//! bandwidth (B̂_read). Two implementations:
//!
//! * `InMemorySource` — wraps an Arc<Table>; read = columnar slice copy
//!   (a real decode-buffer allocation, so memory accounting stays honest).
//! * `CsvFileSource` — row-indexed CSV file; read = seek + parse, which
//!   exercises the real parse/normalize cost the cost model fits.

use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::api::error::SchedError;
use crate::data::column::Cell;
use crate::data::schema::{ColumnType, Schema};
use crate::data::table::{Table, TableBuilder};

/// Cumulative read-side counters (shared across worker threads).
#[derive(Debug, Default)]
pub struct ReadMeter {
    bytes: AtomicU64,
    nanos: AtomicU64,
}

impl ReadMeter {
    pub fn record(&self, bytes: u64, elapsed_nanos: u64) {
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.nanos.fetch_add(elapsed_nanos, Ordering::Relaxed);
    }
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
    /// Effective bandwidth in bytes/sec (None until something was read).
    pub fn bandwidth(&self) -> Option<f64> {
        let ns = self.nanos.load(Ordering::Relaxed);
        if ns == 0 {
            return None;
        }
        Some(self.bytes.load(Ordering::Relaxed) as f64 / (ns as f64 * 1e-9))
    }
}

/// Abstract input table. Thread-safe: shards read ranges concurrently.
pub trait TableSource: Send + Sync {
    fn schema(&self) -> &Schema;
    fn nrows(&self) -> usize;
    /// Read+decode a contiguous row range into an owned Table (the
    /// per-batch decode buffer).
    fn read_range(&self, offset: usize, len: usize) -> Table;
    /// Primary-key value at `row` (i64 surrogate/PK; the range
    /// partitioner requires key-sorted sources). None if keyless.
    fn key_at(&self, row: usize) -> Option<i64>;
    /// Total on-storage bytes (working-set estimation input).
    fn storage_bytes(&self) -> u64;
    /// Bytes *resident in RAM* for the lifetime of the job (counted
    /// against the memory cap as the base RSS). In-memory sources pin
    /// their whole table; file sources only pin their key index.
    fn resident_bytes(&self) -> u64;
    /// Read metering for B̂_read estimation.
    fn meter(&self) -> &ReadMeter;
}

/// In-memory source.
pub struct InMemorySource {
    table: Arc<Table>,
    key_col: Option<usize>,
    meter: ReadMeter,
}

impl InMemorySource {
    pub fn new(table: Table) -> Self {
        let key_col = table.schema.key_indices().first().copied();
        InMemorySource { table: Arc::new(table), key_col, meter: ReadMeter::default() }
    }
    pub fn table(&self) -> &Arc<Table> {
        &self.table
    }
}

impl TableSource for InMemorySource {
    fn schema(&self) -> &Schema {
        &self.table.schema
    }
    fn nrows(&self) -> usize {
        self.table.nrows()
    }
    fn read_range(&self, offset: usize, len: usize) -> Table {
        let t0 = Instant::now();
        let out = self.table.slice(offset, len);
        self.meter
            .record(out.heap_bytes() as u64, t0.elapsed().as_nanos() as u64);
        out
    }
    fn key_at(&self, row: usize) -> Option<i64> {
        let kc = self.key_col?;
        match self.table.column(kc).cell(row) {
            Cell::I64(k) => Some(k),
            _ => None,
        }
    }
    fn storage_bytes(&self) -> u64 {
        self.table.heap_bytes() as u64
    }
    fn resident_bytes(&self) -> u64 {
        self.table.heap_bytes() as u64
    }
    fn meter(&self) -> &ReadMeter {
        &self.meter
    }
}

// ---------------- CSV ----------------

fn needs_quote(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r')
}

fn write_field(out: &mut impl Write, s: &str) -> std::io::Result<()> {
    if needs_quote(s) {
        out.write_all(b"\"")?;
        out.write_all(s.replace('"', "\"\"").as_bytes())?;
        out.write_all(b"\"")
    } else {
        out.write_all(s.as_bytes())
    }
}

/// Write a table as CSV (header = column names; nulls = empty fields;
/// dates/timestamps/decimal mantissas as integers — lossless).
pub fn write_csv(table: &Table, path: &Path) -> std::io::Result<()> {
    let mut out = BufWriter::new(std::fs::File::create(path)?);
    let names: Vec<&str> =
        table.schema.fields.iter().map(|f| f.name.as_str()).collect();
    out.write_all(names.join(",").as_bytes())?;
    out.write_all(b"\n")?;
    let mut buf = String::new();
    for i in 0..table.nrows() {
        for (ci, col) in table.columns.iter().enumerate() {
            if ci > 0 {
                out.write_all(b",")?;
            }
            buf.clear();
            match col.cell(i) {
                Cell::Null => {}
                Cell::I64(x) => buf.push_str(&x.to_string()),
                Cell::F64(x) => {
                    // {:?} prints round-trippable f64.
                    buf.push_str(&format!("{x:?}"));
                }
                Cell::Str(s) => {
                    // Quoted empty ("") distinguishes the empty string
                    // from NULL (bare empty field).
                    if s.is_empty() {
                        out.write_all(b"\"\"")?;
                    } else {
                        write_field(&mut out, s)?;
                    }
                    continue;
                }
                Cell::Bool(b) => buf.push_str(if b { "t" } else { "f" }),
                Cell::Date(d) => buf.push_str(&d.to_string()),
                Cell::Ts(t) => buf.push_str(&t.to_string()),
                Cell::Dec { mantissa, .. } => buf.push_str(&mantissa.to_string()),
            }
            out.write_all(buf.as_bytes())?;
        }
        out.write_all(b"\n")?;
    }
    out.flush()
}

/// Split one CSV record into (field, was_quoted) pairs. The quoted flag
/// lets the decoder distinguish NULL (bare empty) from "" (quoted empty).
fn split_record(line: &str) -> Vec<(String, bool)> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut quoted = false;
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(ch) = chars.next() {
        match ch {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => {
                in_quotes = true;
                quoted = true;
            }
            ',' if !in_quotes => {
                fields.push((std::mem::take(&mut cur), quoted));
                quoted = false;
            }
            c => cur.push(c),
        }
    }
    fields.push((cur, quoted));
    fields
}

fn parse_cell(
    tb: &mut TableBuilder,
    ci: usize,
    ty: ColumnType,
    field: &str,
    quoted: bool,
) -> Result<(), String> {
    if field.is_empty() && !quoted {
        tb.col(ci).push_null();
        return Ok(());
    }
    let err = |e: &str| format!("col {ci}: bad {ty} value {field:?}: {e}");
    match ty {
        ColumnType::Int64 => {
            tb.col(ci).push_i64(field.parse().map_err(|_| err("int"))?)
        }
        ColumnType::Float64 => {
            tb.col(ci).push_f64(field.parse().map_err(|_| err("float"))?)
        }
        ColumnType::Utf8 => tb.col(ci).push_str(field),
        ColumnType::Bool => match field {
            "t" => tb.col(ci).push_bool(true),
            "f" => tb.col(ci).push_bool(false),
            _ => return Err(err("bool")),
        },
        ColumnType::Date => {
            tb.col(ci).push_date(field.parse().map_err(|_| err("date"))?)
        }
        ColumnType::Timestamp => {
            tb.col(ci).push_ts(field.parse().map_err(|_| err("ts"))?)
        }
        ColumnType::Decimal { .. } => {
            tb.col(ci).push_dec(field.parse().map_err(|_| err("dec"))?)
        }
    }
    Ok(())
}

/// CSV-backed source with a prebuilt row offset index (byte position of
/// every row) so `read_range` is a single seek + sequential parse.
pub struct CsvFileSource {
    path: PathBuf,
    schema: Schema,
    /// Byte offset of row i (data rows; header excluded); last entry = EOF.
    row_offsets: Vec<u64>,
    /// Key column values, loaded once (alignment/partitioning state —
    /// this is part of the paper's "alignment state for f" memory term).
    keys: Option<Vec<i64>>,
    meter: ReadMeter,
}

impl CsvFileSource {
    pub fn open(path: &Path, schema: Schema) -> Result<Self, SchedError> {
        Self::open_inner(path, schema)
            .map_err(|m| SchedError::io(path.display().to_string(), m))
    }

    fn open_inner(path: &Path, schema: Schema) -> Result<Self, String> {
        let text_file =
            std::fs::File::open(path).map_err(|e| format!("open: {e}"))?;
        let mut reader = std::io::BufReader::new(text_file);
        let mut all = String::new();
        reader
            .read_to_string(&mut all)
            .map_err(|e| format!("read: {e}"))?;
        // Index row start offsets. CSV quoting may contain newlines; we
        // track quote parity to only split on record boundaries.
        let bytes = all.as_bytes();
        let mut row_offsets = Vec::new();
        let mut in_quotes = false;
        let mut line_start = 0u64;
        let mut first = true;
        for (i, &b) in bytes.iter().enumerate() {
            match b {
                b'"' => in_quotes = !in_quotes,
                b'\n' if !in_quotes => {
                    if first {
                        first = false; // header line
                    } else {
                        row_offsets.push(line_start);
                    }
                    line_start = i as u64 + 1;
                }
                _ => {}
            }
        }
        if line_start < bytes.len() as u64 && !first {
            row_offsets.push(line_start);
        }
        row_offsets.push(bytes.len() as u64);

        let key_col = schema.key_indices().first().copied();
        let mut src = CsvFileSource {
            path: path.to_path_buf(),
            schema,
            row_offsets,
            keys: None,
            meter: ReadMeter::default(),
        };
        if let Some(kc) = key_col {
            let n = src.nrows();
            if n > 0 {
                let t = src.read_range(0, n);
                let mut keys = Vec::with_capacity(n);
                for i in 0..n {
                    match t.column(kc).cell(i) {
                        Cell::I64(k) => keys.push(k),
                        _ => return Err(format!("row {i}: null/bad key")),
                    }
                }
                src.keys = Some(keys);
            } else {
                src.keys = Some(Vec::new());
            }
        }
        Ok(src)
    }

    fn parse_rows(&self, text: &str, expect: usize) -> Result<Table, String> {
        let mut tb = TableBuilder::new(self.schema.clone());
        let mut in_quotes = false;
        let mut start = 0usize;
        let bytes = text.as_bytes();
        let mut parsed = 0usize;
        for i in 0..bytes.len() {
            match bytes[i] {
                b'"' => in_quotes = !in_quotes,
                b'\n' if !in_quotes => {
                    let line = &text[start..i];
                    start = i + 1;
                    if line.is_empty() {
                        continue;
                    }
                    self.parse_line(&mut tb, line)?;
                    parsed += 1;
                }
                _ => {}
            }
        }
        if start < text.len() {
            self.parse_line(&mut tb, &text[start..])?;
            parsed += 1;
        }
        if parsed != expect {
            return Err(format!("expected {expect} rows, parsed {parsed}"));
        }
        Ok(tb.finish())
    }

    fn parse_line(&self, tb: &mut TableBuilder, line: &str) -> Result<(), String> {
        let line = line.strip_suffix('\r').unwrap_or(line);
        let fields = split_record(line);
        if fields.len() != self.schema.len() {
            return Err(format!(
                "row has {} fields, schema {}",
                fields.len(),
                self.schema.len()
            ));
        }
        for (ci, (field, quoted)) in fields.iter().enumerate() {
            parse_cell(tb, ci, self.schema.fields[ci].ty, field, *quoted)?;
        }
        Ok(())
    }
}

impl TableSource for CsvFileSource {
    fn schema(&self) -> &Schema {
        &self.schema
    }
    fn nrows(&self) -> usize {
        self.row_offsets.len() - 1
    }
    fn read_range(&self, offset: usize, len: usize) -> Table {
        assert!(offset + len < self.row_offsets.len(), "range out of bounds");
        if len == 0 {
            return Table::empty(self.schema.clone());
        }
        let t0 = Instant::now();
        let lo = self.row_offsets[offset];
        let hi = self.row_offsets[offset + len];
        let mut f = std::fs::File::open(&self.path).expect("reopen csv");
        f.seek(SeekFrom::Start(lo)).expect("seek");
        let mut buf = vec![0u8; (hi - lo) as usize];
        f.read_exact(&mut buf).expect("read range");
        let text = String::from_utf8(buf).expect("utf8 csv");
        let table = self
            .parse_rows(&text, len)
            .unwrap_or_else(|e| panic!("csv parse {:?}: {e}", self.path));
        self.meter
            .record(hi - lo, t0.elapsed().as_nanos() as u64);
        table
    }
    fn key_at(&self, row: usize) -> Option<i64> {
        self.keys.as_ref().map(|k| k[row])
    }
    fn storage_bytes(&self) -> u64 {
        *self.row_offsets.last().unwrap_or(&0)
    }
    fn resident_bytes(&self) -> u64 {
        // Row-offset index + key index stay resident; data is streamed.
        (self.row_offsets.capacity() * 8
            + self.keys.as_ref().map_or(0, |k| k.capacity() * 8)) as u64
    }
    fn meter(&self) -> &ReadMeter {
        &self.meter
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_table, GenSpec};

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "smartdiff_io_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip_preserves_table() {
        let spec = GenSpec { rows: 500, str_len: 10, seed: 11, ..GenSpec::default() };
        let t = generate_table(&spec);
        let path = tmpdir().join("roundtrip.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, t.schema.clone()).unwrap();
        assert_eq!(src.nrows(), t.nrows());
        let back = src.read_range(0, t.nrows());
        assert_eq!(back, t);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_range_reads_match_slices() {
        let spec = GenSpec { rows: 300, seed: 12, ..GenSpec::default() };
        let t = generate_table(&spec);
        let path = tmpdir().join("ranges.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, t.schema.clone()).unwrap();
        for (off, len) in [(0usize, 10usize), (50, 100), (290, 10), (299, 1)] {
            assert_eq!(src.read_range(off, len), t.slice(off, len));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn quoted_strings_with_commas_and_newlines() {
        use crate::data::schema::{ColumnType, Field, Schema};
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("s", ColumnType::Utf8),
        ]);
        let mut tb = TableBuilder::new(schema.clone());
        tb.col(0).push_i64(0);
        tb.col(1).push_str("a,b\"c\"\nd");
        tb.col(0).push_i64(2);
        tb.col(1).push_str("plain");
        let t = tb.finish();
        let path = tmpdir().join("quotes.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, schema).unwrap();
        assert_eq!(src.read_range(0, 2), t);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn meter_records_reads() {
        let t = generate_table(&GenSpec { rows: 100, ..GenSpec::default() });
        let src = InMemorySource::new(t);
        let _ = src.read_range(0, 100);
        assert!(src.meter().bytes() > 0);
        assert!(src.meter().bandwidth().unwrap_or(0.0) > 0.0);
    }

    #[test]
    fn keys_available_from_both_sources() {
        let t = generate_table(&GenSpec { rows: 50, ..GenSpec::default() });
        let path = tmpdir().join("keys.csv");
        write_csv(&t, &path).unwrap();
        let csv = CsvFileSource::open(&path, t.schema.clone()).unwrap();
        let mem = InMemorySource::new(t);
        for i in [0usize, 10, 49] {
            assert_eq!(mem.key_at(i), Some(2 * i as i64));
            assert_eq!(csv.key_at(i), Some(2 * i as i64));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_fields_are_nulls() {
        use crate::data::schema::{ColumnType, Field, Schema};
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("x", ColumnType::Float64),
        ]);
        let mut tb = TableBuilder::new(schema.clone());
        tb.col(0).push_i64(0);
        tb.col(1).push_null();
        let t = tb.finish();
        let path = tmpdir().join("nulls.csv");
        write_csv(&t, &path).unwrap();
        let src = CsvFileSource::open(&path, schema).unwrap();
        let back = src.read_range(0, 1);
        assert!(back.column(1).is_null(0));
        std::fs::remove_file(path).ok();
    }
}
