//! Data substrate: columnar tables, schemas, workload generators and
//! metered table sources (DESIGN.md systems S1–S4).

pub mod chunkstore;
pub mod column;
pub mod generator;
pub mod io;
pub mod schema;
pub mod table;
pub mod tpch;
