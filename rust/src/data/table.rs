//! Table: a schema plus equal-length columns, with cheap slicing and
//! exact heap accounting.

use crate::data::column::{Cell, Column, ColumnBuilder};
use crate::data::schema::{ColumnType, Field, Schema};

#[derive(Debug, Clone, PartialEq)]
pub struct Table {
    pub schema: Schema,
    pub columns: Vec<Column>,
    nrows: usize,
}

impl Table {
    pub fn new(schema: Schema, columns: Vec<Column>) -> Result<Self, String> {
        if schema.len() != columns.len() {
            return Err(format!(
                "schema has {} fields but {} columns given",
                schema.len(),
                columns.len()
            ));
        }
        let nrows = columns.first().map_or(0, |c| c.len());
        for (f, c) in schema.fields.iter().zip(&columns) {
            if c.len() != nrows {
                return Err(format!(
                    "column {} has {} rows, expected {nrows}",
                    f.name,
                    c.len()
                ));
            }
            if c.column_type() != f.ty {
                return Err(format!(
                    "column {} is {} but schema says {}",
                    f.name,
                    c.column_type(),
                    f.ty
                ));
            }
        }
        Ok(Table { schema, columns, nrows })
    }

    pub fn empty(schema: Schema) -> Self {
        let columns = schema
            .fields
            .iter()
            .map(|f| ColumnBuilder::new(f.ty).finish())
            .collect();
        Table { schema, columns, nrows: 0 }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn ncols(&self) -> usize {
        self.columns.len()
    }
    pub fn column(&self, i: usize) -> &Column {
        &self.columns[i]
    }
    pub fn column_by_name(&self, name: &str) -> Option<&Column> {
        self.schema.field(name).map(|(i, _)| &self.columns[i])
    }

    /// Exact heap footprint of the column data (the number the working-set
    /// estimator is calibrated against).
    pub fn heap_bytes(&self) -> usize {
        self.columns.iter().map(|c| c.heap_bytes()).sum()
    }

    /// Measured average bytes per row (string payloads included).
    pub fn measured_row_bytes(&self) -> f64 {
        self.columns.iter().map(|c| c.avg_value_bytes() + 0.125).sum()
    }

    /// Copy a contiguous row range into a new table.
    pub fn slice(&self, offset: usize, len: usize) -> Table {
        assert!(offset + len <= self.nrows, "slice out of bounds");
        Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.slice(offset, len)).collect(),
            nrows: len,
        }
    }

    pub fn row_cells(&self, i: usize) -> Vec<Cell<'_>> {
        self.columns.iter().map(|c| c.cell(i)).collect()
    }
}

/// Row-at-a-time table builder.
#[derive(Debug)]
pub struct TableBuilder {
    schema: Schema,
    builders: Vec<ColumnBuilder>,
}

impl TableBuilder {
    pub fn new(schema: Schema) -> Self {
        let builders = schema
            .fields
            .iter()
            .map(|f| ColumnBuilder::new(f.ty))
            .collect();
        TableBuilder { schema, builders }
    }

    pub fn col(&mut self, i: usize) -> &mut ColumnBuilder {
        &mut self.builders[i]
    }

    pub fn nrows(&self) -> usize {
        self.builders.first().map_or(0, |b| b.len())
    }

    pub fn finish(self) -> Table {
        let columns: Vec<Column> =
            self.builders.into_iter().map(|b| b.finish()).collect();
        let nrows = columns.first().map_or(0, |c| c.len());
        for c in &columns {
            assert_eq!(c.len(), nrows, "ragged table builder");
        }
        Table { schema: self.schema, columns, nrows }
    }
}

/// Convenience schema for tests and examples: one key + a mixed-type
/// payload of `extra` columns cycling through all types.
pub fn mixed_schema(extra: usize) -> Schema {
    let mut fields = vec![Field::key("id", ColumnType::Int64)];
    let cycle = [
        ColumnType::Float64,
        ColumnType::Int64,
        ColumnType::Utf8,
        ColumnType::Date,
        ColumnType::Bool,
        ColumnType::Timestamp,
        ColumnType::Decimal { scale: 2 },
    ];
    for i in 0..extra {
        fields.push(Field::new(
            &format!("c{i}"),
            cycle[i % cycle.len()],
        ));
    }
    Schema::new(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_table(n: usize) -> Table {
        let schema = mixed_schema(3); // id, f64, i64, utf8
        let mut tb = TableBuilder::new(schema);
        for i in 0..n {
            tb.col(0).push_i64(i as i64);
            tb.col(1).push_f64(i as f64 * 0.5);
            tb.col(2).push_i64(-(i as i64));
            tb.col(3).push_str(&format!("row{i}"));
        }
        tb.finish()
    }

    #[test]
    fn build_and_read() {
        let t = demo_table(10);
        assert_eq!(t.nrows(), 10);
        assert_eq!(t.ncols(), 4);
        assert_eq!(t.column_by_name("c0").unwrap().numeric(4), Some(2.0));
        assert_eq!(t.row_cells(3)[3], Cell::Str("row3"));
    }

    #[test]
    fn schema_mismatch_rejected() {
        let schema = mixed_schema(0);
        let col = ColumnBuilder::new(ColumnType::Float64).finish();
        assert!(Table::new(schema, vec![col]).is_err());
    }

    #[test]
    fn ragged_rejected() {
        let schema = mixed_schema(1);
        let mut a = ColumnBuilder::new(ColumnType::Int64);
        a.push_i64(1);
        let b = ColumnBuilder::new(ColumnType::Float64);
        assert!(Table::new(schema, vec![a.finish(), b.finish()]).is_err());
    }

    #[test]
    fn slice_rows() {
        let t = demo_table(100);
        let s = t.slice(20, 30);
        assert_eq!(s.nrows(), 30);
        assert_eq!(s.row_cells(0), t.row_cells(20));
        assert_eq!(s.row_cells(29), t.row_cells(49));
    }

    #[test]
    fn heap_accounting_grows_with_rows() {
        let small = demo_table(10).heap_bytes();
        let big = demo_table(1000).heap_bytes();
        assert!(big > 20 * small);
    }

    #[test]
    fn measured_row_bytes_reasonable() {
        let t = demo_table(50);
        let w = t.measured_row_bytes();
        // id(8) + f64(8) + i64(8) + str(~5+4) ≈ 33
        assert!(w > 20.0 && w < 60.0, "{w}");
    }
}
