//! Typed job lifecycle events and progress snapshots.
//!
//! A `JobHandle` exposes two complementary views of a running job:
//! [`JobProgress`] (a point-in-time snapshot — rows done, current
//! (b, k), accounted RSS, backend) and a drained stream of
//! [`JobEvent`]s (the discrete decisions the session and scheduler loop
//! made on the job's behalf: admission, gating, reconfigurations,
//! backpressure pauses, straggler mitigations, completion).

use std::fmt;

/// Lifecycle state of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Submitted; pre-admission work (schema align, preflight) running.
    Pending,
    /// Waiting for budget: the session's admission controller is holding
    /// the job because the committed working sets of running jobs plus
    /// this job's estimate exceed the memory cap.
    Gated,
    /// Admitted and executing on a session-owned scheduler thread.
    Running,
    /// Finished successfully; `join()` returns `Ok(JobResult)`.
    Done,
    /// Finished with an error; `join()` returns the `SchedError`.
    Failed,
    /// Cancelled via `JobHandle::cancel()`.
    Cancelled,
}

/// One typed scheduler/session decision, drained via
/// `JobHandle::events()`. Events are recorded in order; draining is
/// destructive (each event is observed exactly once).
#[derive(Debug, Clone, PartialEq)]
pub enum JobEvent {
    /// Admission held the job: its working-set estimate did not fit the
    /// budget left by already-running jobs.
    Gated { ws_bytes: u64, available_bytes: u64 },
    /// Admission released the job. `granted_bytes` is the memory
    /// allowance the job runs under (the budget unclaimed by other jobs
    /// at admission time); `concurrent` counts running jobs including
    /// this one.
    Admitted { ws_bytes: u64, granted_bytes: u64, concurrent: usize },
    /// The session re-partitioned this job's elastic memory grant
    /// (another job was admitted or completed, or the session budget
    /// was resized). A shrink takes effect on the safety envelope
    /// immediately — forcing batch-size down-steps if the current batch
    /// size is no longer safe — and the backend's hard accounting cap
    /// follows once live usage drains below the new grant.
    MemGrant {
        /// The grant before the re-partition (bytes).
        from_bytes: u64,
        /// The grant now in force (bytes).
        to_bytes: u64,
    },
    /// The controller (or a session budget re-partition) changed (b, k).
    Reconfig {
        b_from: usize,
        b_to: usize,
        k_from: usize,
        k_to: usize,
        reason: String,
    },
    /// Submission paused because the backend queue outgrew the
    /// backpressure threshold.
    Backpressure { queue_depth: usize },
    /// A straggling shard was speculatively re-executed.
    Speculation { shard_id: u64 },
    /// A straggling shard was split into two (key, occurrence)-aligned
    /// halves. `in_run` flags a cut landing *inside* a duplicate-key
    /// run — the occurrence-indexed path that makes single-run
    /// straggler shards splittable (counted separately as
    /// `JobStats::splits_in_run`).
    Split { shard_id: u64, in_run: bool },
    /// The job finished (`ok == false` covers errors and cancellation).
    Done { ok: bool },
}

impl JobEvent {
    /// Stable lowercase tag for matching/telemetry.
    pub fn kind(&self) -> &'static str {
        match self {
            JobEvent::Gated { .. } => "gated",
            JobEvent::Admitted { .. } => "admitted",
            JobEvent::MemGrant { .. } => "mem_grant",
            JobEvent::Reconfig { .. } => "reconfig",
            JobEvent::Backpressure { .. } => "backpressure",
            JobEvent::Speculation { .. } => "speculation",
            JobEvent::Split { .. } => "split",
            JobEvent::Done { .. } => "done",
        }
    }
}

impl fmt::Display for JobEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobEvent::Gated { ws_bytes, available_bytes } => write!(
                f,
                "gated: ws={:.1}MB available={:.1}MB",
                *ws_bytes as f64 / 1e6,
                *available_bytes as f64 / 1e6
            ),
            JobEvent::Admitted { ws_bytes, granted_bytes, concurrent } => {
                write!(
                    f,
                    "admitted: ws={:.1}MB granted={:.1}MB concurrent={concurrent}",
                    *ws_bytes as f64 / 1e6,
                    *granted_bytes as f64 / 1e6
                )
            }
            JobEvent::MemGrant { from_bytes, to_bytes } => write!(
                f,
                "mem_grant: {:.1}MB -> {:.1}MB",
                *from_bytes as f64 / 1e6,
                *to_bytes as f64 / 1e6
            ),
            JobEvent::Reconfig { b_from, b_to, k_from, k_to, reason } => {
                write!(f, "reconfig: b {b_from}->{b_to} k {k_from}->{k_to} ({reason})")
            }
            JobEvent::Backpressure { queue_depth } => {
                write!(f, "backpressure: queue={queue_depth}")
            }
            JobEvent::Speculation { shard_id } => {
                write!(f, "speculation: shard={shard_id}")
            }
            JobEvent::Split { shard_id, in_run } => {
                if *in_run {
                    write!(f, "split: shard={shard_id} (in-run)")
                } else {
                    write!(f, "split: shard={shard_id}")
                }
            }
            JobEvent::Done { ok } => write!(f, "done: ok={ok}"),
        }
    }
}

/// Point-in-time snapshot of a job, via `JobHandle::progress()`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct JobProgress {
    /// Aligned-row universe: max(|A|, |B|).
    pub rows_total: u64,
    /// Rows covered by accepted batches so far.
    pub rows_done: u64,
    /// Accepted batches so far.
    pub batches: u64,
    /// Current batch size b.
    pub current_b: usize,
    /// Current worker count k.
    pub current_k: usize,
    /// Accounted job RSS right now (base tables + live batch buffers +
    /// idle per-worker scratch reservations).
    pub rss_bytes: u64,
    /// Bytes resident in prefetch staging slots right now. Already
    /// charged inside `rss_bytes` (staged reads are grant-charged before
    /// the bytes land); broken out so overlap is observable.
    pub staged_bytes: u64,
    /// Peak accounted RSS so far.
    pub peak_rss_bytes: u64,
    /// Applied (b, k) changes so far.
    pub reconfigs: u64,
    /// Chunk-cache lookups served from cache so far (0 with the cache
    /// off or an in-memory source).
    pub cache_hits: u64,
    /// Chunk-cache lookups that fell through to the source so far.
    pub cache_misses: u64,
    /// Cache-resident chunk bytes right now. Charged against the job's
    /// grant (a carve-out ledger) and already included in `rss_bytes`;
    /// broken out so residency is observable.
    pub cache_resident_bytes: u64,
    /// Executing backend name ("inmem" / "dasklike"); empty before the
    /// job is admitted.
    pub backend: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_kinds_and_display() {
        let evs = [
            JobEvent::Gated { ws_bytes: 1_000_000, available_bytes: 0 },
            JobEvent::Admitted {
                ws_bytes: 1_000_000,
                granted_bytes: 2_000_000,
                concurrent: 2,
            },
            JobEvent::MemGrant { from_bytes: 4_000_000, to_bytes: 2_000_000 },
            JobEvent::Reconfig {
                b_from: 100,
                b_to: 200,
                k_from: 1,
                k_to: 2,
                reason: "increase-b".into(),
            },
            JobEvent::Backpressure { queue_depth: 9 },
            JobEvent::Speculation { shard_id: 4 },
            JobEvent::Split { shard_id: 5, in_run: true },
            JobEvent::Done { ok: true },
        ];
        let kinds: Vec<&str> = evs.iter().map(|e| e.kind()).collect();
        assert_eq!(
            kinds,
            vec![
                "gated",
                "admitted",
                "mem_grant",
                "reconfig",
                "backpressure",
                "speculation",
                "split",
                "done"
            ]
        );
        for e in &evs {
            assert!(e.to_string().starts_with(e.kind()), "{e}");
        }
    }
}
