//! `JobBuilder`: the typed, validating way to describe a diff job.
//!
//! Replaces hand-poking `SchedulerConfig` fields before calling the old
//! one-shot `run_job`. Every knob is a fluent setter; `build()` runs the
//! same validation as `SchedulerConfig::validate()` and rejects invalid
//! configurations with a [`SchedError::InvalidConfig`] naming the exact
//! field — builder and TOML loading share one validation surface.

use std::sync::Arc;

use crate::api::error::SchedError;
use crate::config::{BackendChoice, DeltaPath, PolicyKind, SchedulerConfig};
use crate::data::io::TableSource;

/// A validated, ready-to-submit job: sources + configuration.
///
/// Produced by [`JobBuilder::build`]; consumed by
/// [`DiffSession::submit`](crate::api::DiffSession::submit). The
/// session owns the resource caps — any `caps` carried in the job's
/// config are replaced by the session's budget at admission.
pub struct JobSpec {
    pub(crate) cfg: SchedulerConfig,
    pub(crate) a: Arc<dyn TableSource>,
    pub(crate) b: Arc<dyn TableSource>,
}

impl JobSpec {
    /// The job's effective configuration (caps are superseded by the
    /// session's at submit time).
    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }
    /// Aligned-row universe of the job: max(|A|, |B|).
    pub fn rows(&self) -> usize {
        self.a.nrows().max(self.b.nrows())
    }
}

/// Fluent builder for [`JobSpec`].
///
/// ```
/// use std::sync::Arc;
/// use smartdiff_sched::api::JobBuilder;
/// use smartdiff_sched::config::{DeltaPath, PolicyKind};
/// use smartdiff_sched::data::generator::{generate_pair, GenSpec};
/// use smartdiff_sched::data::io::InMemorySource;
///
/// let (a, b, _) =
///     generate_pair(&GenSpec { rows: 500, seed: 7, ..GenSpec::default() });
/// let job = JobBuilder::new(
///     Arc::new(InMemorySource::new(a)),
///     Arc::new(InMemorySource::new(b)),
/// )
/// .policy(PolicyKind::Adaptive)
/// .delta_path(DeltaPath::Native)
/// .b_min(1_000)
/// .atol(1e-9)
/// .build()?;
/// assert_eq!(job.rows(), 500);
///
/// // Invalid knobs are rejected with the offending field named:
/// let (a, b, _) =
///     generate_pair(&GenSpec { rows: 10, seed: 7, ..GenSpec::default() });
/// let err = JobBuilder::new(
///     Arc::new(InMemorySource::new(a)),
///     Arc::new(InMemorySource::new(b)),
/// )
/// .eta(1.5)
/// .build()
/// .unwrap_err();
/// assert_eq!(err.field(), Some("policy.eta"));
/// # Ok::<(), smartdiff_sched::api::SchedError>(())
/// ```
pub struct JobBuilder {
    cfg: SchedulerConfig,
    a: Arc<dyn TableSource>,
    b: Arc<dyn TableSource>,
}

impl JobBuilder {
    /// Start from the paper-default configuration.
    pub fn new(a: Arc<dyn TableSource>, b: Arc<dyn TableSource>) -> Self {
        JobBuilder { cfg: SchedulerConfig::default(), a, b }
    }

    /// Start from an existing configuration (e.g. loaded from TOML).
    pub fn from_config(
        cfg: SchedulerConfig,
        a: Arc<dyn TableSource>,
        b: Arc<dyn TableSource>,
    ) -> Self {
        JobBuilder { cfg, a, b }
    }

    // --- execution choices ---

    /// Backend selection (`Auto` = working-set gate, Eq. 1).
    pub fn backend(mut self, backend: BackendChoice) -> Self {
        self.cfg.backend = backend;
        self
    }
    /// Tuning policy driving (b, k).
    pub fn policy(mut self, kind: PolicyKind) -> Self {
        self.cfg.policy_kind = kind;
        self
    }
    /// Numeric-Δ execution path (native / PJRT / cross-check).
    pub fn delta_path(mut self, path: DeltaPath) -> Self {
        self.cfg.engine.delta_path = path;
        self
    }
    /// Directory holding AOT PJRT artifacts.
    pub fn artifact_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.engine.artifact_dir = dir.into();
        self
    }
    /// Double-buffered shard prefetch: overlap the next range's
    /// read+decode with the current range's Δ compute (default on).
    /// Staged bytes are charged against the memory grant before the
    /// read starts, so the Eq. 4 envelope is preserved either way.
    pub fn prefetch(mut self, on: bool) -> Self {
        self.cfg.prefetch = on;
        self
    }
    /// Columnar chunk cache: decoded ranges persist (in memory while the
    /// grant has headroom, spilled to disk on eviction) so a hot range
    /// decodes once per job (default on). Cached bytes are charged
    /// against the job's grant via a carve-out, so peak accounted RSS
    /// including cache residency never exceeds the grant. Only
    /// file-backed sources are cached; reports are bit-identical either
    /// way.
    pub fn cache(mut self, on: bool) -> Self {
        self.cfg.cache.enabled = on;
        self
    }

    // --- comparator tolerances ---

    /// Absolute tolerance for numeric comparators (|Δ| ≤ atol is equal).
    pub fn atol(mut self, atol: f64) -> Self {
        self.cfg.engine.atol = atol;
        self
    }
    /// Relative tolerance for numeric comparators.
    pub fn rtol(mut self, rtol: f64) -> Self {
        self.cfg.engine.rtol = rtol;
        self
    }
    /// Case-insensitive string comparison.
    pub fn string_ci(mut self, ci: bool) -> Self {
        self.cfg.engine.string_ci = ci;
        self
    }
    /// Timestamp tolerance in microseconds.
    pub fn ts_tolerance_us(mut self, us: i64) -> Self {
        self.cfg.engine.ts_tolerance_us = us;
        self
    }

    // --- controller / gating knobs (validated ranges) ---

    /// Working-set gate safety factor κ (Eq. 1).
    pub fn kappa(mut self, kappa: f64) -> Self {
        self.cfg.policy.kappa = kappa;
        self
    }
    /// Memory guard η (Eq. 4).
    pub fn eta(mut self, eta: f64) -> Self {
        self.cfg.policy.eta = eta;
        self
    }
    /// Multiplicative backoff γ.
    pub fn gamma(mut self, gamma: f64) -> Self {
        self.cfg.policy.gamma = gamma;
        self
    }
    /// Tail trigger τ (act when p95/p50 > τ).
    pub fn tau(mut self, tau: f64) -> Self {
        self.cfg.policy.tau = tau;
        self
    }
    /// Lower batch-size bound for the controller.
    pub fn b_min(mut self, b_min: usize) -> Self {
        self.cfg.policy.b_min = b_min;
        self
    }
    /// Upper batch-size bound for the controller.
    pub fn b_max(mut self, b_max: usize) -> Self {
        self.cfg.policy.b_max = b_max;
        self
    }
    /// Minimum worker count.
    pub fn k_min(mut self, k_min: usize) -> Self {
        self.cfg.policy.k_min = k_min;
        self
    }

    // --- bookkeeping ---

    /// JSON-lines telemetry sink for this job.
    pub fn telemetry(mut self, path: impl Into<String>) -> Self {
        self.cfg.telemetry_path = Some(path.into());
        self
    }
    /// Deterministic seed for seeded components (simulator, generators).
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }
    /// Pre-flight sample bounds (max rows, fraction of the job).
    pub fn preflight_sample(mut self, max_rows: usize, fraction: f64) -> Self {
        self.cfg.preflight_max_rows = max_rows;
        self.cfg.preflight_fraction = fraction;
        self
    }

    /// Validate and freeze the job. Rejects exactly the configurations
    /// `SchedulerConfig::validate()` rejects, with the same
    /// [`SchedError::InvalidConfig`] field names.
    pub fn build(self) -> Result<JobSpec, SchedError> {
        self.cfg.validate()?;
        Ok(JobSpec { cfg: self.cfg, a: self.a, b: self.b })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;

    fn sources() -> (Arc<InMemorySource>, Arc<InMemorySource>) {
        let (a, b, _) =
            generate_pair(&GenSpec { rows: 100, seed: 1, ..GenSpec::default() });
        (Arc::new(InMemorySource::new(a)), Arc::new(InMemorySource::new(b)))
    }

    #[test]
    fn builder_applies_knobs() {
        let (a, b) = sources();
        let job = JobBuilder::new(a, b)
            .backend(BackendChoice::InMem)
            .policy(PolicyKind::Fixed { b: 500, k: 2 })
            .delta_path(DeltaPath::Native)
            .atol(1e-6)
            .b_min(100)
            .prefetch(false)
            .cache(false)
            .telemetry("x.jsonl")
            .seed(9)
            .build()
            .unwrap();
        let cfg = job.config();
        assert_eq!(cfg.backend, BackendChoice::InMem);
        assert_eq!(cfg.engine.atol, 1e-6);
        assert_eq!(cfg.policy.b_min, 100);
        assert!(!cfg.prefetch);
        assert!(!cfg.cache.enabled);
        assert_eq!(cfg.telemetry_path.as_deref(), Some("x.jsonl"));
        assert_eq!(cfg.seed, 9);
        assert_eq!(job.rows(), 100);
    }

    #[test]
    fn build_rejects_invalid_with_field_name() {
        let (a, b) = sources();
        let err = JobBuilder::new(a, b).eta(1.5).build().unwrap_err();
        assert_eq!(err.field(), Some("policy.eta"));
    }
}
