//! Public service API: [`DiffSession`] (multi-job admission over one
//! CPU/memory budget), [`JobBuilder`] (typed, validating job
//! construction), [`JobHandle`] (non-blocking progress / events /
//! cancel / join), and [`SchedError`] (the typed error surface).
//!
//! ```text
//! let session = DiffSession::new(Caps { mem_cap_bytes: 4e9 as u64, cpu_cap: 8 });
//! let job = JobBuilder::new(a, b).atol(1e-9).build()?;
//! let mut handle = session.submit(job)?;
//! for ev in handle.events() { println!("{ev}"); }
//! let result = handle.join()?;
//! ```
//!
//! The legacy one-shot `sched::scheduler::run_job` remains as a
//! deprecated-but-stable shim: it opens a single-job session, submits,
//! and joins.

pub mod builder;
pub mod error;
pub mod events;
pub mod session;

pub use builder::{JobBuilder, JobSpec};
pub use error::SchedError;
pub use events::{JobEvent, JobProgress, JobState};
pub use session::{DiffSession, JobControl, JobHandle};
