//! Public service API: [`DiffSession`] (multi-job admission and elastic
//! per-job memory grants over one CPU/memory budget), [`JobBuilder`]
//! (typed, validating job construction), [`JobHandle`] (non-blocking
//! progress / events / cancel / join), and [`SchedError`] (the typed
//! error surface).
//!
//! ```
//! use std::sync::Arc;
//! use smartdiff_sched::api::{DiffSession, JobBuilder};
//! use smartdiff_sched::config::{Caps, DeltaPath};
//! use smartdiff_sched::data::generator::{generate_pair, GenSpec};
//! use smartdiff_sched::data::io::InMemorySource;
//!
//! let session =
//!     DiffSession::new(Caps { mem_cap_bytes: 1_000_000_000, cpu_cap: 2 });
//! let (a, b, _) =
//!     generate_pair(&GenSpec { rows: 300, seed: 3, ..GenSpec::default() });
//! let job = JobBuilder::new(
//!     Arc::new(InMemorySource::new(a)),
//!     Arc::new(InMemorySource::new(b)),
//! )
//! .delta_path(DeltaPath::Native)
//! .b_min(100)
//! .atol(1e-9)
//! .build()?;
//! let mut handle = session.submit(job)?;
//! for ev in handle.events() {
//!     println!("{ev}"); // Admitted/Gated/MemGrant/Reconfig/...
//! }
//! let result = handle.join()?;
//! assert_eq!(result.stats.ooms, 0);
//! # Ok::<(), smartdiff_sched::api::SchedError>(())
//! ```
//!
//! The session re-partitions its budget as jobs enter and leave: CPU
//! shares drive `Backend::set_workers`, and elastic memory grants drive
//! `Backend::set_mem_budget` — see [`DiffSession`] and
//! [`JobEvent::MemGrant`].
//!
//! The legacy one-shot `sched::scheduler::run_job` remains as a
//! deprecated-but-stable shim: it opens a single-job session, submits,
//! and joins.
#![warn(missing_docs)]

pub mod builder;
pub mod error;
pub mod events;
pub mod session;

pub use builder::{JobBuilder, JobSpec};
pub use error::SchedError;
pub use events::{JobEvent, JobProgress, JobState};
pub use session::{DiffSession, JobControl, JobHandle};
