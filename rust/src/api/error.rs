//! `SchedError`: the typed error surface of the public API.
//!
//! Every fallible entry point of the crate — config loading and
//! validation, schema alignment, backend/runtime construction, job
//! submission and `JobHandle::join` — returns `SchedError` instead of
//! the stringly-typed `Result<_, String>` the crate grew up with.
//! Variants carry the structured context a service caller needs to
//! dispatch on (which config field, which shard, what cause chain);
//! `Display` renders the human-readable message the old strings held.

use std::error::Error;
use std::fmt;

use crate::exec::backend::BatchError;

/// Typed error for the `DiffSession` service API and everything it
/// composes. Implements [`std::error::Error`] with a `source()` chain
/// (`ShardFailed` chains into [`BatchError`], which can chain further).
#[derive(Debug, Clone)]
pub enum SchedError {
    /// A configuration field failed validation. `field` is the full
    /// TOML-style key path (e.g. `policy.eta`), identical between
    /// `SchedulerConfig::validate()` and `JobBuilder::build()`.
    InvalidConfig { field: String, message: String },
    /// A config file / TOML document / telemetry log failed to parse.
    /// `context` names the input (a path, or `<inline>`).
    Parse { context: String, message: String },
    /// Schema alignment failed (no key mapping / incompatible types).
    SchemaAlign { message: String },
    /// Backend or Δ-runtime construction failed (e.g. PJRT artifacts
    /// missing or the PJRT client unavailable in this build).
    Runtime { message: String },
    /// Filesystem I/O failure (config read, telemetry sink, CSV).
    Io { path: String, message: String },
    /// A shard failed permanently (original attempt and its retry).
    ShardFailed { shard_id: u64, source: BatchError },
    /// The job was cancelled through its `JobHandle`.
    Cancelled,
    /// The operation is not available through this entry point.
    Unsupported { message: String },
}

impl SchedError {
    /// An `InvalidConfig` naming the offending field.
    pub fn invalid(field: impl Into<String>, message: impl Into<String>) -> Self {
        SchedError::InvalidConfig { field: field.into(), message: message.into() }
    }
    /// A `Parse` error naming the input being parsed.
    pub fn parse(context: impl Into<String>, message: impl Into<String>) -> Self {
        SchedError::Parse { context: context.into(), message: message.into() }
    }
    /// A schema-alignment failure.
    pub fn schema(message: impl Into<String>) -> Self {
        SchedError::SchemaAlign { message: message.into() }
    }
    /// A backend/runtime construction or execution failure.
    pub fn runtime(message: impl Into<String>) -> Self {
        SchedError::Runtime { message: message.into() }
    }
    /// A filesystem I/O failure at `path`.
    pub fn io(path: impl Into<String>, message: impl Into<String>) -> Self {
        SchedError::Io { path: path.into(), message: message.into() }
    }
    /// An operation unavailable through this entry point.
    pub fn unsupported(message: impl Into<String>) -> Self {
        SchedError::Unsupported { message: message.into() }
    }

    /// The config field path, when this is an `InvalidConfig`.
    pub fn field(&self) -> Option<&str> {
        match self {
            SchedError::InvalidConfig { field, .. } => Some(field),
            _ => None,
        }
    }
}

impl fmt::Display for SchedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SchedError::InvalidConfig { field, message } => {
                write!(f, "invalid config: {field}: {message}")
            }
            SchedError::Parse { context, message } => {
                write!(f, "parse {context}: {message}")
            }
            SchedError::SchemaAlign { message } => {
                write!(f, "schema alignment: {message}")
            }
            SchedError::Runtime { message } => write!(f, "runtime: {message}"),
            SchedError::Io { path, message } => write!(f, "io {path}: {message}"),
            SchedError::ShardFailed { shard_id, source } => {
                write!(f, "shard {shard_id} failed permanently: {source}")
            }
            SchedError::Cancelled => write!(f, "job cancelled"),
            SchedError::Unsupported { message } => {
                write!(f, "unsupported: {message}")
            }
        }
    }
}

impl Error for SchedError {
    fn source(&self) -> Option<&(dyn Error + 'static)> {
        match self {
            SchedError::ShardFailed { source, .. } => {
                Some(source as &(dyn Error + 'static))
            }
            _ => None,
        }
    }
}

/// Compatibility bridge: lets `?` lift a `SchedError` into the
/// `Result<_, String>` signatures that remain in binary-internal plumbing
/// (the hand-rolled CLI). Library APIs should prefer `SchedError`.
impl From<SchedError> for String {
    fn from(e: SchedError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_field() {
        let e = SchedError::invalid("policy.eta", "1.5 must be in (0, 1)");
        assert_eq!(e.field(), Some("policy.eta"));
        let s = e.to_string();
        assert!(s.contains("policy.eta"), "{s}");
        assert!(s.contains("(0, 1)"), "{s}");
    }

    #[test]
    fn shard_failed_chains_batch_error() {
        let cause = BatchError::failed_with(
            "decode exploded",
            std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"),
        );
        let e = SchedError::ShardFailed { shard_id: 7, source: cause };
        assert!(e.to_string().contains("shard 7"));
        let src = e.source().expect("batch error source");
        assert!(src.to_string().contains("decode exploded"));
        let root = src.source().expect("io source");
        assert!(root.to_string().contains("disk on fire"));
    }

    #[test]
    fn string_bridge_preserves_message() {
        let s: String = SchedError::Cancelled.into();
        assert_eq!(s, "job cancelled");
    }
}
