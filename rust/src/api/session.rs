//! `DiffSession`: a long-lived service facade owning one machine budget
//! (CPU + memory caps) and admitting many concurrent diff jobs into it.
//!
//! The session replaces per-job construction (the old blocking
//! `run_job` free function owned the whole machine for one job) with a
//! scheduler/runtime split:
//!
//! * **Admission control** — every submitted job is pre-flight profiled
//!   and its working set estimated (Eq. 1, the same estimator the
//!   backend gate uses). A job is admitted only while the committed
//!   estimates of running jobs plus its own fit `mem_cap_bytes`;
//!   otherwise it waits in the `Gated` state (FIFO among waiters) and
//!   its handle records a [`JobEvent::Gated`]. Admission bounds the sum
//!   of working-set *charges* by the budget.
//! * **Elastic memory grants** — every admit, completion, and
//!   [`DiffSession::set_mem_budget`] call re-partitions the memory
//!   budget into per-job *grants*: each running job is granted its
//!   admission charge plus an even share of the spare budget, so grants
//!   **never sum past the budget at any instant** (shrunken grants are
//!   published before expanded ones). A job admitted into an idle
//!   session is granted the full budget (legacy `run_job` parity); when
//!   later jobs join, running jobs' grants shrink down toward their
//!   charges, and they re-expand as jobs complete. The scheduler loop
//!   observes grant changes mid-flight ([`JobEvent::MemGrant`]): a
//!   shrink tightens the safety envelope immediately (forcing
//!   batch-size down-steps), pauses submission while accounted usage
//!   drains, and applies the backend's hard accounting cap through
//!   `Backend::set_mem_budget` once usage is under the new grant — so
//!   caps change mid-job without accounted OOMs.
//! * **CPU re-partitioning** — the session divides `cpu_cap` evenly
//!   across running jobs and updates each job's share as jobs enter and
//!   leave; the scheduler loop applies the share through
//!   `Backend::set_workers`.
//! * **Job handles** — `submit` returns immediately with a
//!   [`JobHandle`]: `progress()` snapshots, typed `events()`,
//!   `cancel()`, and `join()` for the final `Result<JobResult,
//!   SchedError>`.
//!
//! A solo job admitted into an idle session receives the full budget
//! and runs the exact legacy `run_job` pipeline — which is why
//! `run_job` survives as a thin one-job shim over `DiffSession`.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

use crate::api::builder::JobSpec;
use crate::api::error::SchedError;
use crate::api::events::{JobEvent, JobProgress, JobState};
use crate::config::{BackendChoice, Caps, PolicyKind};
use crate::data::chunkstore::{CachedSource, ChunkStore, Side};
use crate::engine::delta::JobPlan;
use crate::engine::schema_align::align_schemas;
use crate::exec::backend::{Backend, JobContext};
use crate::exec::dasklike::DaskLikeBackend;
use crate::exec::inmem::InMemBackend;
use crate::sched::controller::{AdaptiveController, TuningPolicy};
use crate::sched::preflight::preflight;
use crate::sched::scheduler::{drive, DriveInputs, JobResult};
use crate::sched::telemetry::Telemetry;
use crate::sched::working_set::{gate_backend, WorkingSetModel};

/// Event fan-out registry behind every `JobControl`: the full event
/// history (so a subscriber arriving after admission still replays
/// `Gated`/`Admitted`) plus the live channels of current subscribers.
/// One lock guards both, so replay-then-register is atomic and no
/// subscriber can miss or double-see an event.
#[derive(Default)]
struct Watchers {
    history: Vec<JobEvent>,
    senders: Vec<mpsc::Sender<JobEvent>>,
}

/// Shared mutable per-job state: the bridge between a `JobHandle` (the
/// caller's side) and the scheduler loop running the job (the session's
/// side). All methods are lock-cheap and safe to call at any time.
pub struct JobControl {
    job_id: u64,
    cancel: AtomicBool,
    /// Session-granted worker allowance (0 = no session constraint).
    cpu_share: AtomicUsize,
    /// Session-granted memory allowance in bytes (0 = not yet granted).
    /// Updated only under the session's ledger lock, so lock-holding
    /// readers observe a consistent partition.
    mem_grant: AtomicU64,
    state: AtomicU8,
    progress: Mutex<JobProgress>,
    events: Mutex<Vec<JobEvent>>,
    watchers: Mutex<Watchers>,
}

impl JobControl {
    fn new(job_id: u64) -> Arc<Self> {
        Arc::new(JobControl {
            job_id,
            cancel: AtomicBool::new(false),
            cpu_share: AtomicUsize::new(0),
            mem_grant: AtomicU64::new(0),
            state: AtomicU8::new(0),
            progress: Mutex::new(JobProgress::default()),
            events: Mutex::new(Vec::new()),
            watchers: Mutex::new(Watchers::default()),
        })
    }

    /// Session-assigned job id (also on the job's [`JobHandle`]).
    pub fn job_id(&self) -> u64 {
        self.job_id
    }
    /// Ask the scheduler loop to stop cooperatively.
    pub fn request_cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
    /// Whether cancellation has been requested.
    pub fn cancel_requested(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
    /// The session's current worker allowance for this job (0 = no
    /// session constraint).
    pub fn cpu_share(&self) -> usize {
        self.cpu_share.load(Ordering::Relaxed)
    }
    pub(crate) fn set_cpu_share(&self, share: usize) {
        self.cpu_share.store(share, Ordering::Relaxed);
    }
    /// The session's current memory grant for this job in bytes (0 =
    /// not yet granted). The scheduler loop polls this every iteration
    /// and reacts to changes mid-flight.
    pub fn mem_grant(&self) -> u64 {
        self.mem_grant.load(Ordering::Relaxed)
    }
    pub(crate) fn set_mem_grant(&self, bytes: u64) {
        self.mem_grant.store(bytes, Ordering::Relaxed);
    }

    /// Lifecycle state right now.
    pub fn state(&self) -> JobState {
        match self.state.load(Ordering::Relaxed) {
            0 => JobState::Pending,
            1 => JobState::Gated,
            2 => JobState::Running,
            3 => JobState::Done,
            4 => JobState::Failed,
            _ => JobState::Cancelled,
        }
    }
    pub(crate) fn set_state(&self, s: JobState) {
        let v = match s {
            JobState::Pending => 0,
            JobState::Gated => 1,
            JobState::Running => 2,
            JobState::Done => 3,
            JobState::Failed => 4,
            JobState::Cancelled => 5,
        };
        self.state.store(v, Ordering::Relaxed);
    }

    /// Point-in-time progress snapshot.
    pub fn progress(&self) -> JobProgress {
        // lint: allow(unwrap) progress/watchers/events sections are
        // clone/push/retain only; poison means a torn event stream, and
        // serving one would silently break subscribers — fail fast
        self.progress.lock().unwrap().clone()
    }
    pub(crate) fn update_progress(&self, f: impl FnOnce(&mut JobProgress)) {
        // lint: allow(unwrap) see progress(): poison ⇒ fail fast
        f(&mut self.progress.lock().unwrap());
    }

    pub(crate) fn push_event(&self, ev: JobEvent) {
        {
            // lint: allow(unwrap) see progress(): poison ⇒ fail fast
            let mut w = self.watchers.lock().unwrap();
            // Dead subscribers (receiver dropped) are pruned on the spot.
            w.senders.retain(|tx| tx.send(ev.clone()).is_ok());
            w.history.push(ev.clone());
        }
        // lint: allow(unwrap) see progress(): poison ⇒ fail fast
        self.events.lock().unwrap().push(ev);
    }
    /// Drain all recorded events (destructive; order preserved). The
    /// non-destructive fan-out view is [`JobControl::subscribe`].
    pub fn drain_events(&self) -> Vec<JobEvent> {
        // lint: allow(unwrap) see progress(): poison ⇒ fail fast
        std::mem::take(&mut *self.events.lock().unwrap())
    }
    /// Subscribe to this job's event stream. The receiver first replays
    /// every event recorded so far (in order), then delivers each new
    /// event as the scheduler pushes it. Subscriptions are independent
    /// of each other and of the destructive [`JobControl::drain_events`]
    /// queue, so any number of observers (e.g. wire-protocol clients)
    /// can watch one job. The channel closes when the job's `Done`
    /// event has been delivered and the control is dropped.
    pub fn subscribe(&self) -> mpsc::Receiver<JobEvent> {
        let (tx, rx) = mpsc::channel();
        // lint: allow(unwrap) see progress(): poison ⇒ fail fast
        let mut w = self.watchers.lock().unwrap();
        for ev in &w.history {
            // A send to our own just-created receiver cannot fail.
            let _ = tx.send(ev.clone());
        }
        w.senders.push(tx);
        rx
    }
}

/// One admitted, still-running job in the session ledger.
struct RunningJob {
    id: u64,
    charge_bytes: u64,
    control: Arc<JobControl>,
}

#[derive(Default)]
struct AdmissionLedger {
    /// Sum of working-set charges of admitted, unfinished jobs.
    committed_bytes: u64,
    running: Vec<RunningJob>,
    /// Gated jobs in arrival order. Admission is FIFO among waiters:
    /// a later (even smaller) job may not bypass the queue head, so a
    /// large gated job cannot be starved by a stream of small ones.
    waiters: std::collections::VecDeque<u64>,
}

struct SessionInner {
    caps: Caps,
    /// Elastic session memory budget in bytes. Starts at
    /// `caps.mem_cap_bytes`; `DiffSession::set_mem_budget` resizes it at
    /// runtime. Written only together with a grant re-partition under
    /// the ledger lock.
    mem_budget: AtomicU64,
    ws_model: WorkingSetModel,
    ledger: Mutex<AdmissionLedger>,
    cv: Condvar,
    next_job: AtomicU64,
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".into())
}

/// Re-partition the session budget across running jobs. Called under
/// the ledger lock on every admit, completion, and budget resize.
///
/// * **CPU** — `cpu_cap` divided evenly (at least 1 worker each); the
///   scheduler loops apply shares via `Backend::set_workers`.
/// * **Memory** — each job is granted its admission charge plus an even
///   share of the spare budget, so a solo job holds the entire budget
///   (legacy `run_job` parity) and grants shrink toward charges as the
///   session fills. If the budget has been resized below the committed
///   charges, grants scale proportionally to charges instead (summing
///   to exactly `max(budget, n)` via cumulative rounding). Shrunken
///   grants are published before expanded ones, so the sum of grants
///   never exceeds the budget at any instant whenever the budget covers
///   at least one byte per running job (the integer spare split may
///   leave up to `n-1` bytes unassigned).
fn repartition(inner: &SessionInner, ledger: &AdmissionLedger) {
    let n = ledger.running.len();
    if n == 0 {
        return;
    }
    let share = (inner.caps.cpu_cap / n).max(1);
    for job in &ledger.running {
        job.control.set_cpu_share(share);
    }

    let budget = inner.mem_budget.load(Ordering::Relaxed);
    let total: u64 = ledger.running.iter().map(|j| j.charge_bytes).sum();
    let grants: Vec<u64> = if total <= budget {
        let spare = (budget - total) / n as u64;
        ledger
            .running
            .iter()
            .map(|j| j.charge_bytes.saturating_add(spare).max(1))
            .collect()
    } else {
        // Over-committed (the budget was resized below the committed
        // charges): one byte per job plus telescoping proportional
        // shares of the rest. The cumulative rounding makes the grants
        // sum to exactly max(budget, n), so the partition stays within
        // the budget whenever it covers a byte per job.
        let eff = budget.max(n as u64) - n as u64;
        let mut prefix: u128 = 0;
        let mut last: u64 = 0;
        ledger
            .running
            .iter()
            .map(|j| {
                prefix += j.charge_bytes as u128;
                let cum = ((eff as u128 * prefix) / (total as u128)) as u64;
                let g = 1 + (cum - last);
                last = cum;
                g
            })
            .collect()
    };
    for pass in 0..2 {
        for (job, &new) in ledger.running.iter().zip(&grants) {
            let old = job.control.mem_grant();
            let shrink = old != 0 && new <= old;
            // Pass 0 publishes shrinks, pass 1 grows (incl. first grants).
            if (pass == 0) == shrink && new != old {
                job.control.set_mem_grant(new);
            }
        }
    }
}

/// Long-lived multi-job diff service. See the module docs.
pub struct DiffSession {
    inner: Arc<SessionInner>,
}

impl DiffSession {
    /// A session owning the given machine budget.
    pub fn new(caps: Caps) -> DiffSession {
        DiffSession {
            inner: Arc::new(SessionInner {
                caps,
                mem_budget: AtomicU64::new(caps.mem_cap_bytes),
                ws_model: WorkingSetModel::default(),
                ledger: Mutex::new(AdmissionLedger::default()),
                cv: Condvar::new(),
                next_job: AtomicU64::new(0),
            }),
        }
    }

    /// Paper-default budget (64 GB / 32 logical cores).
    pub fn with_defaults() -> DiffSession {
        DiffSession::new(Caps::default())
    }

    /// The machine budget this session was created with. The *current*
    /// memory budget may differ after [`DiffSession::set_mem_budget`];
    /// see [`DiffSession::mem_budget`].
    pub fn caps(&self) -> Caps {
        self.inner.caps
    }

    /// The session memory budget currently in force, in bytes.
    pub fn mem_budget(&self) -> u64 {
        self.inner.mem_budget.load(Ordering::Relaxed)
    }

    /// Elastically resize the session's memory budget at runtime (e.g. a
    /// multi-tenant operator reclaiming or returning RAM). Running jobs'
    /// grants are re-partitioned immediately — shrinking toward their
    /// admission charges (proportionally below them if the new budget no
    /// longer covers the committed charges) or re-expanding — and each
    /// affected job observes the change mid-flight through its scheduler
    /// loop ([`JobEvent::MemGrant`]). Gated jobs are re-evaluated against
    /// the new budget. `bytes` is floored at 1.
    pub fn set_mem_budget(&self, bytes: u64) {
        // lint: allow(unwrap) a poisoned ledger means a panic landed
        // mid-admission/release and the grant accounting may be torn;
        // continuing could overcommit the budget — fail fast instead
        let ledger = self.inner.ledger.lock().unwrap();
        self.inner.mem_budget.store(bytes.max(1), Ordering::Relaxed);
        repartition(&self.inner, &ledger);
        drop(ledger);
        self.inner.cv.notify_all();
    }

    /// Snapshot of the current per-job memory grants as `(job_id,
    /// grant_bytes)` pairs. Taken under the ledger lock, so the grants
    /// are a consistent instantaneous partition: their sum never exceeds
    /// [`DiffSession::mem_budget`] as long as the budget covers at least
    /// one byte per running job (grants are floored at one byte each).
    pub fn mem_grants(&self) -> Vec<(u64, u64)> {
        // lint: allow(unwrap) ledger poison ⇒ fail fast (see
        // set_mem_budget)
        let ledger = self.inner.ledger.lock().unwrap();
        ledger
            .running
            .iter()
            .map(|j| (j.id, j.control.mem_grant()))
            .collect()
    }

    /// Number of currently admitted (running) jobs.
    pub fn active_jobs(&self) -> usize {
        // lint: allow(unwrap) ledger poison ⇒ fail fast (see
        // set_mem_budget)
        self.inner.ledger.lock().unwrap().running.len()
    }

    /// Bytes of the memory budget currently committed to running jobs.
    pub fn committed_bytes(&self) -> u64 {
        // lint: allow(unwrap) ledger poison ⇒ fail fast (see
        // set_mem_budget)
        self.inner.ledger.lock().unwrap().committed_bytes
    }

    /// Submit a job. Returns immediately with a [`JobHandle`]; the job
    /// runs on a session-owned thread, waiting in the `Gated` state if
    /// its working-set estimate does not currently fit the budget.
    ///
    /// The session's caps supersede the job config's, so the config is
    /// re-validated against them here (e.g. a `policy.k_min` above the
    /// session's `cpu_cap` is a typed `InvalidConfig`, not a panic on
    /// the job thread).
    ///
    /// # Example
    ///
    /// ```
    /// use std::sync::Arc;
    /// use smartdiff_sched::api::{DiffSession, JobBuilder};
    /// use smartdiff_sched::config::{Caps, DeltaPath};
    /// use smartdiff_sched::data::generator::{generate_pair, GenSpec};
    /// use smartdiff_sched::data::io::InMemorySource;
    ///
    /// let session =
    ///     DiffSession::new(Caps { mem_cap_bytes: 1_000_000_000, cpu_cap: 2 });
    /// let (a, b, _) =
    ///     generate_pair(&GenSpec { rows: 400, seed: 1, ..GenSpec::default() });
    /// let job = JobBuilder::new(
    ///     Arc::new(InMemorySource::new(a)),
    ///     Arc::new(InMemorySource::new(b)),
    /// )
    /// .delta_path(DeltaPath::Native)
    /// .b_min(100)
    /// .build()?;
    ///
    /// let mut handle = session.submit(job)?; // non-blocking
    /// let result = handle.join()?;
    /// assert_eq!(result.stats.ooms, 0);
    /// assert!(handle.events().iter().any(|e| e.kind() == "admitted"));
    /// # Ok::<(), smartdiff_sched::api::SchedError>(())
    /// ```
    pub fn submit(&self, job: JobSpec) -> Result<JobHandle, SchedError> {
        let mut effective = job.cfg.clone();
        effective.caps = self.inner.caps;
        effective.validate()?;
        let id = self.inner.next_job.fetch_add(1, Ordering::Relaxed) + 1;
        let control = JobControl::new(id);
        let inner = Arc::clone(&self.inner);
        let thread_control = Arc::clone(&control);
        let thread = std::thread::Builder::new()
            .name(format!("sdiff-job-{id}"))
            .spawn(move || job_thread(&inner, id, job, &thread_control))
            .map_err(|e| SchedError::runtime(format!("spawn job thread: {e}")))?;
        Ok(JobHandle { id, control, thread: Some(thread) })
    }
}

/// Handle to a submitted job. Dropping the handle does not cancel the
/// job; it keeps running to completion on its session thread.
pub struct JobHandle {
    id: u64,
    control: Arc<JobControl>,
    thread: Option<std::thread::JoinHandle<Result<JobResult, SchedError>>>,
}

impl JobHandle {
    /// Session-assigned job id.
    pub fn id(&self) -> u64 {
        self.id
    }
    /// Point-in-time snapshot (rows done, current b/k, accounted RSS,
    /// backend).
    pub fn progress(&self) -> JobProgress {
        self.control.progress()
    }
    /// Lifecycle state right now.
    pub fn state(&self) -> JobState {
        self.control.state()
    }
    /// Drain the typed event stream recorded so far (admission,
    /// reconfigs, backpressure, mitigations, completion).
    pub fn events(&self) -> Vec<JobEvent> {
        self.control.drain_events()
    }
    /// Subscribe to the job's live event stream: replays all events so
    /// far, then streams new ones. Unlike [`JobHandle::events`] this is
    /// non-destructive and supports any number of concurrent observers
    /// — the fan-out the network service uses to stream `JobEvent`s to
    /// every connected client. See [`JobControl::subscribe`].
    pub fn subscribe(&self) -> mpsc::Receiver<JobEvent> {
        self.control.subscribe()
    }
    /// The shared per-job control block (progress/state/cancel/events),
    /// usable independently of the handle's lifetime — e.g. a job
    /// registry that joins handles on one thread while status snapshots
    /// are served from another.
    pub fn control(&self) -> Arc<JobControl> {
        Arc::clone(&self.control)
    }
    /// Request cooperative cancellation; `join()` then returns
    /// `Err(SchedError::Cancelled)` unless the job already finished.
    pub fn cancel(&self) {
        self.control.request_cancel();
    }
    /// Whether the job's thread has finished (result ready to `join`).
    pub fn is_finished(&self) -> bool {
        self.thread.as_ref().map_or(true, |t| t.is_finished())
    }
    /// Block until the job finishes and take its result. A second call
    /// returns an error (the result is consumed by the first).
    pub fn join(&mut self) -> Result<JobResult, SchedError> {
        match self.thread.take() {
            Some(t) => match t.join() {
                Ok(result) => result,
                Err(payload) => Err(SchedError::runtime(format!(
                    "job thread panicked: {}",
                    panic_message(payload.as_ref())
                ))),
            },
            None => Err(SchedError::runtime("job result already taken")),
        }
    }
}

/// Session-thread body: pre-admission pipeline, admission, execution,
/// release, terminal event/state bookkeeping.
fn job_thread(
    inner: &SessionInner,
    id: u64,
    job: JobSpec,
    control: &Arc<JobControl>,
) -> Result<JobResult, SchedError> {
    let outcome = run_with_admission(inner, id, &job, control);
    match &outcome {
        Ok(r) => {
            control.push_event(JobEvent::Done { ok: r.stats.ooms == 0 });
            control.set_state(JobState::Done);
        }
        Err(SchedError::Cancelled) => {
            control.push_event(JobEvent::Done { ok: false });
            control.set_state(JobState::Cancelled);
        }
        Err(_) => {
            control.push_event(JobEvent::Done { ok: false });
            control.set_state(JobState::Failed);
        }
    }
    outcome
}

fn run_with_admission(
    inner: &SessionInner,
    id: u64,
    job: &JobSpec,
    control: &Arc<JobControl>,
) -> Result<JobResult, SchedError> {
    let a = Arc::clone(&job.a);
    let b = Arc::clone(&job.b);

    // --- pre-admission pipeline (cheap, runs outside the budget) ---
    if matches!(job.cfg.backend, BackendChoice::Sim) {
        return Err(SchedError::unsupported(
            "sim backend is driven via sim::run_sim_job",
        ));
    }
    let aligned = align_schemas(a.schema(), b.schema())?;
    let plan = JobPlan::new(aligned, job.cfg.engine.clone());
    let exec = crate::runtime::make_exec(&job.cfg.engine)?;
    let profile = preflight(
        a.as_ref(),
        b.as_ref(),
        job.cfg.preflight_max_rows,
        job.cfg.preflight_fraction,
    )?;
    control.update_progress(|p| {
        p.rows_total = a.nrows().max(b.nrows()) as u64;
    });

    // --- admission: Eq. 1 working-set estimate vs the shared budget ---
    let ws = inner.ws_model.estimate(&profile);
    let charge =
        (ws.max(1.0) as u64).min(inner.mem_budget.load(Ordering::Relaxed));
    let granted = {
        // lint: allow(unwrap) ledger poison ⇒ fail fast (see
        // set_mem_budget)
        let mut ledger = inner.ledger.lock().unwrap();
        let mut announced_gate = false;
        loop {
            if control.cancel_requested() {
                // Leave the waiter queue so we never block the head slot.
                ledger.waiters.retain(|w| *w != id);
                return Err(SchedError::Cancelled);
            }
            // FIFO among waiters: budget must fit AND nobody older may
            // still be queued (an idle session always admits). The
            // budget is re-read every round — it is elastic.
            let budget = inner.mem_budget.load(Ordering::Relaxed);
            let fits = ledger.running.is_empty()
                || (ledger.committed_bytes + charge <= budget
                    && ledger.waiters.front().map_or(true, |w| *w == id));
            if fits {
                break;
            }
            if !announced_gate {
                announced_gate = true;
                ledger.waiters.push_back(id);
                control.set_state(JobState::Gated);
                control.push_event(JobEvent::Gated {
                    ws_bytes: charge,
                    available_bytes: budget
                        .saturating_sub(ledger.committed_bytes),
                });
            }
            let (l, _) = inner
                .cv
                .wait_timeout(ledger, Duration::from_millis(10))
                // lint: allow(unwrap) wait_timeout errs only if the
                // ledger mutex is poisoned ⇒ fail fast
                .unwrap();
            ledger = l;
        }
        ledger.waiters.retain(|w| *w != id);
        // Admission bounds the sum of *charges* by the budget; the
        // grant re-partition then hands every running job its charge
        // plus an even share of the spare budget, shrinking the others'
        // grants toward their charges to make room. The per-job safety
        // envelope (Eq. 4) keeps each job's accounted usage inside its
        // grant, so accounted OOMs cannot occur. A job admitted alone
        // is granted the full budget (legacy `run_job` parity).
        ledger.committed_bytes += charge;
        ledger.running.push(RunningJob {
            id,
            charge_bytes: charge,
            control: Arc::clone(control),
        });
        repartition(inner, &ledger);
        let granted = control.mem_grant().max(1);
        control.set_state(JobState::Running);
        control.push_event(JobEvent::Admitted {
            ws_bytes: charge,
            granted_bytes: granted,
            concurrent: ledger.running.len(),
        });
        granted
    };

    // Unwind guard: a panic anywhere in backend/policy/drive must not
    // skip the release block below, or the job's charge would leak and
    // gate later jobs forever.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        execute_admitted(inner, job, &a, &b, plan, exec, profile, granted, control)
    }))
    .unwrap_or_else(|payload| {
        Err(SchedError::runtime(format!(
            "job panicked: {}",
            panic_message(payload.as_ref())
        )))
    });

    // Publish the terminal state BEFORE releasing the budget: observers
    // must never see this job Running concurrently with a job the
    // release is about to un-gate.
    control.set_state(match &result {
        Ok(_) => JobState::Done,
        Err(SchedError::Cancelled) => JobState::Cancelled,
        Err(_) => JobState::Failed,
    });

    // --- release: return the charge, re-partition (surviving jobs'
    // grants re-expand), wake gated jobs ---
    {
        // lint: allow(unwrap) ledger poison ⇒ fail fast (see
        // set_mem_budget)
        let mut ledger = inner.ledger.lock().unwrap();
        if let Some(pos) = ledger.running.iter().position(|r| r.id == id) {
            let done = ledger.running.remove(pos);
            ledger.committed_bytes =
                ledger.committed_bytes.saturating_sub(done.charge_bytes);
        }
        repartition(inner, &ledger);
        inner.cv.notify_all();
    }
    result
}

/// Build backend + policy + telemetry for an admitted job and drive it.
#[allow(clippy::too_many_arguments)]
fn execute_admitted(
    inner: &SessionInner,
    job: &JobSpec,
    a: &Arc<dyn crate::data::io::TableSource>,
    b: &Arc<dyn crate::data::io::TableSource>,
    plan: JobPlan,
    exec: Arc<dyn crate::engine::comparators::NumericDeltaExec>,
    profile: crate::sched::preflight::PreflightProfile,
    granted_bytes: u64,
    control: &Arc<JobControl>,
) -> Result<JobResult, SchedError> {
    let mut cfg = job.cfg.clone();
    cfg.caps = Caps {
        mem_cap_bytes: granted_bytes,
        cpu_cap: inner.caps.cpu_cap,
    };

    let gate = gate_backend(&inner.ws_model, &profile, &cfg.caps, &cfg.policy);
    let choice = match cfg.backend {
        BackendChoice::Auto => gate.backend,
        other => other,
    };

    // Chunk cache: wrap file-backed sources so a decoded range persists
    // (resident, or spilled on eviction) and re-executions of the same
    // range skip the source read + decode entirely. One store serves
    // both sides; its capacity starts at 0 and the pool carves the real
    // cap out of the job's grant before any worker runs
    // (shrink-before-grow), so cached bytes always stay inside the
    // grant. Sources that are already in memory opt out via
    // `supports_chunk_cache`.
    let mut src_a = Arc::clone(a);
    let mut src_b = Arc::clone(b);
    let mut store = None;
    if cfg.cache.enabled
        && (src_a.supports_chunk_cache() || src_b.supports_chunk_cache())
    {
        let spill_base = if cfg.cache.spill_dir.is_empty() {
            None
        } else {
            Some(std::path::PathBuf::from(&cfg.cache.spill_dir))
        };
        let s = ChunkStore::new(0, spill_base, cfg.cache.max_disk_bytes);
        if src_a.supports_chunk_cache() {
            src_a = Arc::new(CachedSource::new(src_a, Arc::clone(&s), Side::A));
        }
        if src_b.supports_chunk_cache() {
            src_b = Arc::new(CachedSource::new(src_b, Arc::clone(&s), Side::B));
        }
        store = Some(s);
    }
    let ctx = match store {
        Some(s) => JobContext::with_chunk_store(
            Arc::clone(&src_a),
            Arc::clone(&src_b),
            plan,
            exec,
            cfg.caps.mem_cap_bytes,
            s,
        ),
        None => JobContext::new(
            Arc::clone(&src_a),
            Arc::clone(&src_b),
            plan,
            exec,
            cfg.caps.mem_cap_bytes,
        ),
    };
    let k0 = (cfg.caps.cpu_cap / 4).max(cfg.policy.k_min);
    let mut backend: Box<dyn Backend> = match choice {
        BackendChoice::InMem => {
            Box::new(InMemBackend::new(ctx, k0, cfg.caps.cpu_cap, cfg.prefetch))
        }
        BackendChoice::DaskLike => {
            // Sub-chunk so one task's decode buffer is ~64 MB at Ŵ.
            let chunk = ((64.0e6 / profile.w_hat.max(1.0)) as usize)
                .clamp(4_096, 1_000_000);
            Box::new(DaskLikeBackend::new(
                ctx,
                k0,
                cfg.caps.cpu_cap,
                chunk,
                cfg.prefetch,
            ))
        }
        BackendChoice::Sim | BackendChoice::Auto => unreachable!(),
    };

    let mut policy: Box<dyn TuningPolicy> = match cfg.policy_kind {
        PolicyKind::Adaptive => Box::new(AdaptiveController::new()),
        PolicyKind::Fixed { b, k } => {
            Box::new(crate::baselines::FixedPolicy::new(b, k))
        }
        PolicyKind::Heuristic => {
            Box::new(crate::baselines::HeuristicPolicy::paper_default())
        }
    };

    let mut telemetry = match &cfg.telemetry_path {
        Some(p) => Telemetry::to_file(p)?,
        None => Telemetry::disabled(),
    };
    let mut inputs = DriveInputs {
        cfg: &cfg,
        profile,
        gate: Some(gate),
        telemetry: &mut telemetry,
        consts: crate::engine::microbench::CostConstants::default(),
        control: Some(Arc::clone(control)),
    };
    drive(
        backend.as_mut(),
        src_a.as_ref(),
        src_b.as_ref(),
        policy.as_mut(),
        &mut inputs,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::builder::JobBuilder;
    use crate::config::DeltaPath;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;

    fn job(rows: usize, seed: u64) -> JobSpec {
        let (a, b, _) =
            generate_pair(&GenSpec { rows, seed, ..GenSpec::default() });
        JobBuilder::new(
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
        )
        .delta_path(DeltaPath::Native)
        .b_min(200)
        .build()
        .unwrap()
    }

    fn small_caps() -> Caps {
        Caps { mem_cap_bytes: 2_000_000_000, cpu_cap: 2 }
    }

    #[test]
    fn solo_job_runs_and_releases_budget() {
        let session = DiffSession::new(small_caps());
        let mut h = session.submit(job(2_000, 5)).unwrap();
        let r = h.join().unwrap();
        assert_eq!(r.stats.ooms, 0);
        assert!(r.stats.batches > 0);
        assert_eq!(session.active_jobs(), 0);
        assert_eq!(session.committed_bytes(), 0);
        assert_eq!(h.state(), JobState::Done);
        let events = h.events();
        assert_eq!(events.first().map(|e| e.kind()), Some("admitted"));
        assert_eq!(events.last().map(|e| e.kind()), Some("done"));
        let p = h.progress();
        assert!(p.rows_done > 0);
        assert!(p.batches > 0);
        assert!(p.rss_bytes > 0 || p.peak_rss_bytes > 0);
        assert!(!p.backend.is_empty());
    }

    #[test]
    fn sim_backend_is_rejected_typed() {
        let session = DiffSession::new(small_caps());
        let (a, b, _) =
            generate_pair(&GenSpec { rows: 100, seed: 1, ..GenSpec::default() });
        let spec = JobBuilder::new(
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
        )
        .backend(BackendChoice::Sim)
        .build()
        .unwrap();
        let mut h = session.submit(spec).unwrap();
        match h.join() {
            Err(SchedError::Unsupported { .. }) => {}
            other => panic!("expected Unsupported, got {other:?}"),
        }
        assert_eq!(h.state(), JobState::Failed);
    }

    #[test]
    fn budget_resize_is_observable_when_idle() {
        let session = DiffSession::new(small_caps());
        assert_eq!(session.mem_budget(), small_caps().mem_cap_bytes);
        assert!(session.mem_grants().is_empty());
        session.set_mem_budget(1_000_000);
        assert_eq!(session.mem_budget(), 1_000_000);
        // Floored at 1 byte.
        session.set_mem_budget(0);
        assert_eq!(session.mem_budget(), 1);
    }

    #[test]
    fn solo_job_is_granted_the_full_budget() {
        let session = DiffSession::new(small_caps());
        let mut h = session.submit(job(1_000, 7)).unwrap();
        h.join().unwrap();
        let granted = h.events().iter().find_map(|e| match e {
            JobEvent::Admitted { granted_bytes, .. } => Some(*granted_bytes),
            _ => None,
        });
        assert_eq!(granted, Some(small_caps().mem_cap_bytes));
    }

    #[test]
    fn subscribe_replays_history_and_streams_live() {
        let session = DiffSession::new(small_caps());
        // Subscribing before completion sees live events; subscribing
        // after completion replays the full history. Both views coexist
        // with each other and with the destructive drain.
        let mut h = session.submit(job(1_000, 15)).unwrap();
        let live = h.subscribe();
        h.join().unwrap();
        let live_kinds: Vec<&str> = live.try_iter().map(|e| e.kind()).collect();
        assert!(live_kinds.contains(&"admitted"), "{live_kinds:?}");
        assert_eq!(live_kinds.last(), Some(&"done"));

        let replay = h.subscribe();
        let replay_kinds: Vec<&str> =
            replay.try_iter().map(|e| e.kind()).collect();
        assert_eq!(replay_kinds, live_kinds);

        // The legacy destructive queue still holds everything.
        let drained = h.events();
        assert_eq!(drained.len(), live_kinds.len());
        assert!(h.events().is_empty(), "drain is destructive");
    }

    #[test]
    fn second_join_errors() {
        let session = DiffSession::new(small_caps());
        let mut h = session.submit(job(500, 9)).unwrap();
        h.join().unwrap();
        assert!(h.join().is_err());
    }
}
