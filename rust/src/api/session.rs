//! `DiffSession`: a long-lived service facade owning one machine budget
//! (CPU + memory caps) and admitting many concurrent diff jobs into it.
//!
//! The session replaces per-job construction (the old blocking
//! `run_job` free function owned the whole machine for one job) with a
//! scheduler/runtime split:
//!
//! * **Admission control** — every submitted job is pre-flight profiled
//!   and its working set estimated (Eq. 1, the same estimator the
//!   backend gate uses). A job is admitted only while the committed
//!   estimates of running jobs plus its own fit `mem_cap_bytes`;
//!   otherwise it waits in the `Gated` state (FIFO among waiters) and
//!   its handle records a [`JobEvent::Gated`]. Admission bounds the sum
//!   of working-set *charges* by the budget; each admitted job's
//!   accounting cap is the budget unclaimed by other jobs' charges at
//!   its admission, and the per-job safety envelope keeps accounted
//!   usage inside that cap — so jobs cannot fail with accounted OOMs.
//!   A job admitted into an idle session keeps the full budget (legacy
//!   `run_job` parity); shrinking already-running jobs' caps when later
//!   jobs join is future work (see ROADMAP).
//! * **CPU re-partitioning** — the session divides `cpu_cap` evenly
//!   across running jobs and updates each job's share as jobs enter and
//!   leave; the scheduler loop applies the share through
//!   `Backend::set_workers`.
//! * **Job handles** — `submit` returns immediately with a
//!   [`JobHandle`]: `progress()` snapshots, typed `events()`,
//!   `cancel()`, and `join()` for the final `Result<JobResult,
//!   SchedError>`.
//!
//! A solo job admitted into an idle session receives the full budget
//! and runs the exact legacy `run_job` pipeline — which is why
//! `run_job` survives as a thin one-job shim over `DiffSession`.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::api::builder::JobSpec;
use crate::api::error::SchedError;
use crate::api::events::{JobEvent, JobProgress, JobState};
use crate::config::{BackendChoice, Caps, PolicyKind};
use crate::engine::delta::JobPlan;
use crate::engine::schema_align::align_schemas;
use crate::exec::backend::{Backend, JobContext};
use crate::exec::dasklike::DaskLikeBackend;
use crate::exec::inmem::InMemBackend;
use crate::sched::controller::{AdaptiveController, TuningPolicy};
use crate::sched::preflight::preflight;
use crate::sched::scheduler::{drive, DriveInputs, JobResult};
use crate::sched::telemetry::Telemetry;
use crate::sched::working_set::{gate_backend, WorkingSetModel};

/// Shared mutable per-job state: the bridge between a `JobHandle` (the
/// caller's side) and the scheduler loop running the job (the session's
/// side). All methods are lock-cheap and safe to call at any time.
pub struct JobControl {
    job_id: u64,
    cancel: AtomicBool,
    /// Session-granted worker allowance (0 = no session constraint).
    cpu_share: AtomicUsize,
    state: AtomicU8,
    progress: Mutex<JobProgress>,
    events: Mutex<Vec<JobEvent>>,
}

impl JobControl {
    fn new(job_id: u64) -> Arc<Self> {
        Arc::new(JobControl {
            job_id,
            cancel: AtomicBool::new(false),
            cpu_share: AtomicUsize::new(0),
            state: AtomicU8::new(0),
            progress: Mutex::new(JobProgress::default()),
            events: Mutex::new(Vec::new()),
        })
    }

    pub fn job_id(&self) -> u64 {
        self.job_id
    }
    pub fn request_cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
    pub fn cancel_requested(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
    pub fn cpu_share(&self) -> usize {
        self.cpu_share.load(Ordering::Relaxed)
    }
    pub(crate) fn set_cpu_share(&self, share: usize) {
        self.cpu_share.store(share, Ordering::Relaxed);
    }

    pub fn state(&self) -> JobState {
        match self.state.load(Ordering::Relaxed) {
            0 => JobState::Pending,
            1 => JobState::Gated,
            2 => JobState::Running,
            3 => JobState::Done,
            4 => JobState::Failed,
            _ => JobState::Cancelled,
        }
    }
    pub(crate) fn set_state(&self, s: JobState) {
        let v = match s {
            JobState::Pending => 0,
            JobState::Gated => 1,
            JobState::Running => 2,
            JobState::Done => 3,
            JobState::Failed => 4,
            JobState::Cancelled => 5,
        };
        self.state.store(v, Ordering::Relaxed);
    }

    pub fn progress(&self) -> JobProgress {
        self.progress.lock().unwrap().clone()
    }
    pub(crate) fn update_progress(&self, f: impl FnOnce(&mut JobProgress)) {
        f(&mut self.progress.lock().unwrap());
    }

    pub(crate) fn push_event(&self, ev: JobEvent) {
        self.events.lock().unwrap().push(ev);
    }
    /// Drain all recorded events (destructive; order preserved).
    pub fn drain_events(&self) -> Vec<JobEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }
}

/// One admitted, still-running job in the session ledger.
struct RunningJob {
    id: u64,
    charge_bytes: u64,
    control: Arc<JobControl>,
}

#[derive(Default)]
struct AdmissionLedger {
    /// Sum of working-set charges of admitted, unfinished jobs.
    committed_bytes: u64,
    running: Vec<RunningJob>,
    /// Gated jobs in arrival order. Admission is FIFO among waiters:
    /// a later (even smaller) job may not bypass the queue head, so a
    /// large gated job cannot be starved by a stream of small ones.
    waiters: std::collections::VecDeque<u64>,
}

struct SessionInner {
    caps: Caps,
    ws_model: WorkingSetModel,
    ledger: Mutex<AdmissionLedger>,
    cv: Condvar,
    next_job: AtomicU64,
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".into())
}

/// Divide the CPU cap evenly across running jobs (at least 1 worker
/// each) and publish each job's share; the scheduler loops apply it via
/// `Backend::set_workers`.
fn repartition(caps: &Caps, ledger: &AdmissionLedger) {
    let n = ledger.running.len().max(1);
    let share = (caps.cpu_cap / n).max(1);
    for job in &ledger.running {
        job.control.set_cpu_share(share);
    }
}

/// Long-lived multi-job diff service. See the module docs.
pub struct DiffSession {
    inner: Arc<SessionInner>,
}

impl DiffSession {
    /// A session owning the given machine budget.
    pub fn new(caps: Caps) -> DiffSession {
        DiffSession {
            inner: Arc::new(SessionInner {
                caps,
                ws_model: WorkingSetModel::default(),
                ledger: Mutex::new(AdmissionLedger::default()),
                cv: Condvar::new(),
                next_job: AtomicU64::new(0),
            }),
        }
    }

    /// Paper-default budget (64 GB / 32 logical cores).
    pub fn with_defaults() -> DiffSession {
        DiffSession::new(Caps::default())
    }

    pub fn caps(&self) -> Caps {
        self.inner.caps
    }

    /// Number of currently admitted (running) jobs.
    pub fn active_jobs(&self) -> usize {
        self.inner.ledger.lock().unwrap().running.len()
    }

    /// Bytes of the memory budget currently committed to running jobs.
    pub fn committed_bytes(&self) -> u64 {
        self.inner.ledger.lock().unwrap().committed_bytes
    }

    /// Submit a job. Returns immediately with a [`JobHandle`]; the job
    /// runs on a session-owned thread, waiting in the `Gated` state if
    /// its working-set estimate does not currently fit the budget.
    ///
    /// The session's caps supersede the job config's, so the config is
    /// re-validated against them here (e.g. a `policy.k_min` above the
    /// session's `cpu_cap` is a typed `InvalidConfig`, not a panic on
    /// the job thread).
    pub fn submit(&self, job: JobSpec) -> Result<JobHandle, SchedError> {
        let mut effective = job.cfg.clone();
        effective.caps = self.inner.caps;
        effective.validate()?;
        let id = self.inner.next_job.fetch_add(1, Ordering::Relaxed) + 1;
        let control = JobControl::new(id);
        let inner = Arc::clone(&self.inner);
        let thread_control = Arc::clone(&control);
        let thread = std::thread::Builder::new()
            .name(format!("sdiff-job-{id}"))
            .spawn(move || job_thread(&inner, id, job, &thread_control))
            .map_err(|e| SchedError::runtime(format!("spawn job thread: {e}")))?;
        Ok(JobHandle { id, control, thread: Some(thread) })
    }
}

/// Handle to a submitted job. Dropping the handle does not cancel the
/// job; it keeps running to completion on its session thread.
pub struct JobHandle {
    id: u64,
    control: Arc<JobControl>,
    thread: Option<std::thread::JoinHandle<Result<JobResult, SchedError>>>,
}

impl JobHandle {
    pub fn id(&self) -> u64 {
        self.id
    }
    /// Point-in-time snapshot (rows done, current b/k, accounted RSS,
    /// backend).
    pub fn progress(&self) -> JobProgress {
        self.control.progress()
    }
    /// Lifecycle state right now.
    pub fn state(&self) -> JobState {
        self.control.state()
    }
    /// Drain the typed event stream recorded so far (admission,
    /// reconfigs, backpressure, mitigations, completion).
    pub fn events(&self) -> Vec<JobEvent> {
        self.control.drain_events()
    }
    /// Request cooperative cancellation; `join()` then returns
    /// `Err(SchedError::Cancelled)` unless the job already finished.
    pub fn cancel(&self) {
        self.control.request_cancel();
    }
    /// Whether the job's thread has finished (result ready to `join`).
    pub fn is_finished(&self) -> bool {
        self.thread.as_ref().map_or(true, |t| t.is_finished())
    }
    /// Block until the job finishes and take its result. A second call
    /// returns an error (the result is consumed by the first).
    pub fn join(&mut self) -> Result<JobResult, SchedError> {
        match self.thread.take() {
            Some(t) => match t.join() {
                Ok(result) => result,
                Err(payload) => Err(SchedError::runtime(format!(
                    "job thread panicked: {}",
                    panic_message(payload.as_ref())
                ))),
            },
            None => Err(SchedError::runtime("job result already taken")),
        }
    }
}

/// Session-thread body: pre-admission pipeline, admission, execution,
/// release, terminal event/state bookkeeping.
fn job_thread(
    inner: &SessionInner,
    id: u64,
    job: JobSpec,
    control: &Arc<JobControl>,
) -> Result<JobResult, SchedError> {
    let outcome = run_with_admission(inner, id, &job, control);
    match &outcome {
        Ok(r) => {
            control.push_event(JobEvent::Done { ok: r.stats.ooms == 0 });
            control.set_state(JobState::Done);
        }
        Err(SchedError::Cancelled) => {
            control.push_event(JobEvent::Done { ok: false });
            control.set_state(JobState::Cancelled);
        }
        Err(_) => {
            control.push_event(JobEvent::Done { ok: false });
            control.set_state(JobState::Failed);
        }
    }
    outcome
}

fn run_with_admission(
    inner: &SessionInner,
    id: u64,
    job: &JobSpec,
    control: &Arc<JobControl>,
) -> Result<JobResult, SchedError> {
    let a = Arc::clone(&job.a);
    let b = Arc::clone(&job.b);

    // --- pre-admission pipeline (cheap, runs outside the budget) ---
    if matches!(job.cfg.backend, BackendChoice::Sim) {
        return Err(SchedError::unsupported(
            "sim backend is driven via sim::run_sim_job",
        ));
    }
    let aligned = align_schemas(a.schema(), b.schema())?;
    let plan = JobPlan::new(aligned, job.cfg.engine.clone());
    let exec = crate::runtime::make_exec(&job.cfg.engine)?;
    let profile = preflight(
        a.as_ref(),
        b.as_ref(),
        job.cfg.preflight_max_rows,
        job.cfg.preflight_fraction,
    );
    control.update_progress(|p| {
        p.rows_total = a.nrows().max(b.nrows()) as u64;
    });

    // --- admission: Eq. 1 working-set estimate vs the shared budget ---
    let ws = inner.ws_model.estimate(&profile);
    let charge = (ws.max(1.0) as u64).min(inner.caps.mem_cap_bytes);
    let granted = {
        let mut ledger = inner.ledger.lock().unwrap();
        let mut announced_gate = false;
        loop {
            if control.cancel_requested() {
                // Leave the waiter queue so we never block the head slot.
                ledger.waiters.retain(|w| *w != id);
                return Err(SchedError::Cancelled);
            }
            // FIFO among waiters: budget must fit AND nobody older may
            // still be queued (an idle session always admits).
            let fits = ledger.running.is_empty()
                || (ledger.committed_bytes + charge <= inner.caps.mem_cap_bytes
                    && ledger.waiters.front().map_or(true, |w| *w == id));
            if fits {
                break;
            }
            if !announced_gate {
                announced_gate = true;
                ledger.waiters.push_back(id);
                control.set_state(JobState::Gated);
                control.push_event(JobEvent::Gated {
                    ws_bytes: charge,
                    available_bytes: inner
                        .caps
                        .mem_cap_bytes
                        .saturating_sub(ledger.committed_bytes),
                });
            }
            let (l, _) = inner
                .cv
                .wait_timeout(ledger, Duration::from_millis(10))
                .unwrap();
            ledger = l;
        }
        ledger.waiters.retain(|w| *w != id);
        // The job's accounting cap is the budget unclaimed by other
        // jobs' charges at admission. Admission bounds the sum of
        // *charges* by the budget; the per-job safety envelope (Eq. 4)
        // then keeps each job's accounted usage inside its own cap, so
        // accounted OOMs cannot occur. (A job admitted alone keeps the
        // full budget for legacy `run_job` parity; shrinking running
        // jobs' caps when later jobs join is a ROADMAP item.)
        let granted =
            inner.caps.mem_cap_bytes.saturating_sub(ledger.committed_bytes).max(1);
        ledger.committed_bytes += charge;
        ledger.running.push(RunningJob {
            id,
            charge_bytes: charge,
            control: Arc::clone(control),
        });
        repartition(&inner.caps, &ledger);
        control.set_state(JobState::Running);
        control.push_event(JobEvent::Admitted {
            ws_bytes: charge,
            granted_bytes: granted,
            concurrent: ledger.running.len(),
        });
        granted
    };

    // Unwind guard: a panic anywhere in backend/policy/drive must not
    // skip the release block below, or the job's charge would leak and
    // gate later jobs forever.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        execute_admitted(inner, job, &a, &b, plan, exec, profile, granted, control)
    }))
    .unwrap_or_else(|payload| {
        Err(SchedError::runtime(format!(
            "job panicked: {}",
            panic_message(payload.as_ref())
        )))
    });

    // Publish the terminal state BEFORE releasing the budget: observers
    // must never see this job Running concurrently with a job the
    // release is about to un-gate.
    control.set_state(match &result {
        Ok(_) => JobState::Done,
        Err(SchedError::Cancelled) => JobState::Cancelled,
        Err(_) => JobState::Failed,
    });

    // --- release: return the charge, re-partition, wake gated jobs ---
    {
        let mut ledger = inner.ledger.lock().unwrap();
        if let Some(pos) = ledger.running.iter().position(|r| r.id == id) {
            let done = ledger.running.remove(pos);
            ledger.committed_bytes =
                ledger.committed_bytes.saturating_sub(done.charge_bytes);
        }
        repartition(&inner.caps, &ledger);
        inner.cv.notify_all();
    }
    result
}

/// Build backend + policy + telemetry for an admitted job and drive it.
#[allow(clippy::too_many_arguments)]
fn execute_admitted(
    inner: &SessionInner,
    job: &JobSpec,
    a: &Arc<dyn crate::data::io::TableSource>,
    b: &Arc<dyn crate::data::io::TableSource>,
    plan: JobPlan,
    exec: Arc<dyn crate::engine::comparators::NumericDeltaExec>,
    profile: crate::sched::preflight::PreflightProfile,
    granted_bytes: u64,
    control: &Arc<JobControl>,
) -> Result<JobResult, SchedError> {
    let mut cfg = job.cfg.clone();
    cfg.caps = Caps {
        mem_cap_bytes: granted_bytes,
        cpu_cap: inner.caps.cpu_cap,
    };

    let gate = gate_backend(&inner.ws_model, &profile, &cfg.caps, &cfg.policy);
    let choice = match cfg.backend {
        BackendChoice::Auto => gate.backend,
        other => other,
    };

    let ctx = JobContext::new(
        Arc::clone(a),
        Arc::clone(b),
        plan,
        exec,
        cfg.caps.mem_cap_bytes,
    );
    let k0 = (cfg.caps.cpu_cap / 4).max(cfg.policy.k_min);
    let mut backend: Box<dyn Backend> = match choice {
        BackendChoice::InMem => {
            Box::new(InMemBackend::new(ctx, k0, cfg.caps.cpu_cap))
        }
        BackendChoice::DaskLike => {
            // Sub-chunk so one task's decode buffer is ~64 MB at Ŵ.
            let chunk = ((64.0e6 / profile.w_hat.max(1.0)) as usize)
                .clamp(4_096, 1_000_000);
            Box::new(DaskLikeBackend::new(ctx, k0, cfg.caps.cpu_cap, chunk))
        }
        BackendChoice::Sim | BackendChoice::Auto => unreachable!(),
    };

    let mut policy: Box<dyn TuningPolicy> = match cfg.policy_kind {
        PolicyKind::Adaptive => Box::new(AdaptiveController::new()),
        PolicyKind::Fixed { b, k } => {
            Box::new(crate::baselines::FixedPolicy::new(b, k))
        }
        PolicyKind::Heuristic => {
            Box::new(crate::baselines::HeuristicPolicy::paper_default())
        }
    };

    let mut telemetry = match &cfg.telemetry_path {
        Some(p) => Telemetry::to_file(p)?,
        None => Telemetry::disabled(),
    };
    let mut inputs = DriveInputs {
        cfg: &cfg,
        profile,
        gate: Some(gate),
        telemetry: &mut telemetry,
        consts: crate::engine::microbench::CostConstants::default(),
        control: Some(Arc::clone(control)),
    };
    drive(backend.as_mut(), a.as_ref(), b.as_ref(), policy.as_mut(), &mut inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::builder::JobBuilder;
    use crate::config::DeltaPath;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;

    fn job(rows: usize, seed: u64) -> JobSpec {
        let (a, b, _) =
            generate_pair(&GenSpec { rows, seed, ..GenSpec::default() });
        JobBuilder::new(
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
        )
        .delta_path(DeltaPath::Native)
        .b_min(200)
        .build()
        .unwrap()
    }

    fn small_caps() -> Caps {
        Caps { mem_cap_bytes: 2_000_000_000, cpu_cap: 2 }
    }

    #[test]
    fn solo_job_runs_and_releases_budget() {
        let session = DiffSession::new(small_caps());
        let mut h = session.submit(job(2_000, 5)).unwrap();
        let r = h.join().unwrap();
        assert_eq!(r.stats.ooms, 0);
        assert!(r.stats.batches > 0);
        assert_eq!(session.active_jobs(), 0);
        assert_eq!(session.committed_bytes(), 0);
        assert_eq!(h.state(), JobState::Done);
        let events = h.events();
        assert_eq!(events.first().map(|e| e.kind()), Some("admitted"));
        assert_eq!(events.last().map(|e| e.kind()), Some("done"));
        let p = h.progress();
        assert!(p.rows_done > 0);
        assert!(p.batches > 0);
        assert!(p.rss_bytes > 0 || p.peak_rss_bytes > 0);
        assert!(!p.backend.is_empty());
    }

    #[test]
    fn sim_backend_is_rejected_typed() {
        let session = DiffSession::new(small_caps());
        let (a, b, _) =
            generate_pair(&GenSpec { rows: 100, seed: 1, ..GenSpec::default() });
        let spec = JobBuilder::new(
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
        )
        .backend(BackendChoice::Sim)
        .build()
        .unwrap();
        let mut h = session.submit(spec).unwrap();
        match h.join() {
            Err(SchedError::Unsupported { .. }) => {}
            other => panic!("expected Unsupported, got {other:?}"),
        }
        assert_eq!(h.state(), JobState::Failed);
    }

    #[test]
    fn second_join_errors() {
        let session = DiffSession::new(small_caps());
        let mut h = session.submit(job(500, 9)).unwrap();
        h.join().unwrap();
        assert!(h.join().is_err());
    }
}
