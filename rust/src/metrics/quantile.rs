//! Quantile estimation: rolling-window exact quantiles for the control
//! signals (paper §II: "p95 over a rolling window") and weighted job-
//! level aggregation (paper §V measurement protocol).

use std::collections::VecDeque;

/// Fixed-capacity rolling window with exact quantiles (the window is
//  small — 64 batches — so sort-on-read is cheap and exact).
#[derive(Debug, Clone)]
pub struct RollingWindow {
    buf: VecDeque<f64>,
    cap: usize,
}

impl RollingWindow {
    pub fn new(cap: usize) -> Self {
        RollingWindow { buf: VecDeque::with_capacity(cap.max(1)), cap: cap.max(1) }
    }
    pub fn push(&mut self, x: f64) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(x);
    }
    pub fn len(&self) -> usize {
        self.buf.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Exact q-quantile (nearest-rank with linear interpolation).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.buf.is_empty() {
            return None;
        }
        let mut v: Vec<f64> = self.buf.iter().copied().collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        Some(interpolated(&v, q))
    }
    pub fn p50(&self) -> Option<f64> {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> Option<f64> {
        self.quantile(0.95)
    }
    pub fn mean(&self) -> Option<f64> {
        if self.buf.is_empty() {
            None
        } else {
            Some(self.buf.iter().sum::<f64>() / self.buf.len() as f64)
        }
    }
}

fn interpolated(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Weighted quantile over all samples (job-level p95: per-batch values
/// weighted by rows processed, per the paper's aggregation).
pub fn weighted_quantile(samples: &[(f64, f64)], q: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    let mut v: Vec<(f64, f64)> = samples
        .iter()
        .copied()
        .filter(|(_, w)| *w > 0.0)
        .collect();
    if v.is_empty() {
        return None;
    }
    v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let total: f64 = v.iter().map(|(_, w)| w).sum();
    let target = q.clamp(0.0, 1.0) * total;
    let mut acc = 0.0;
    for (x, w) in &v {
        acc += w;
        if acc >= target {
            return Some(*x);
        }
    }
    v.last().map(|p| p.0)
}

/// Plain mean/CI helpers for the bench harness (95% CI via t≈1.96·SE).
pub fn mean_ci95(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, 1.96 * (var / n).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_evicts_oldest() {
        let mut w = RollingWindow::new(3);
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        assert_eq!(w.len(), 3);
        assert_eq!(w.quantile(0.0), Some(2.0));
        assert_eq!(w.quantile(1.0), Some(4.0));
    }

    #[test]
    fn exact_quantiles_small() {
        let mut w = RollingWindow::new(10);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            w.push(x);
        }
        assert_eq!(w.p50(), Some(3.0));
        assert!((w.quantile(0.25).unwrap() - 2.0).abs() < 1e-12);
        assert!((w.p95().unwrap() - 4.8).abs() < 1e-9);
        assert_eq!(w.mean(), Some(3.0));
    }

    #[test]
    fn empty_window_none() {
        let w = RollingWindow::new(4);
        assert!(w.p95().is_none());
        assert!(w.mean().is_none());
    }

    #[test]
    fn weighted_quantile_respects_weights() {
        // 1.0 carries 99% of the weight -> p50 is 1.0.
        let s = [(1.0, 99.0), (100.0, 1.0)];
        assert_eq!(weighted_quantile(&s, 0.5), Some(1.0));
        assert_eq!(weighted_quantile(&s, 0.999), Some(100.0));
        assert_eq!(weighted_quantile(&[], 0.5), None);
        // Zero-weight samples are ignored.
        let s = [(5.0, 0.0), (7.0, 1.0)];
        assert_eq!(weighted_quantile(&s, 0.5), Some(7.0));
    }

    #[test]
    fn mean_ci_reasonable() {
        let (m, ci) = mean_ci95(&[10.0, 12.0, 11.0]);
        assert!((m - 11.0).abs() < 1e-9);
        assert!(ci > 0.0 && ci < 3.0);
        assert_eq!(mean_ci95(&[]), (0.0, 0.0));
        assert_eq!(mean_ci95(&[4.2]).1, 0.0);
    }
}
