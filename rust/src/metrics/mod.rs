//! Metric primitives (S16): rolling quantiles and aggregation helpers.

pub mod quantile;
