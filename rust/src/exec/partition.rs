//! Job partitioning: carve the key-sorted inputs into shards of `b`
//! aligned rows per side (paper §II job decomposition).
//!
//! Shards are key-range aligned: shard i covers A rows [p, p+b) and the
//! B rows whose keys fall in the same key span, so every row lands in
//! exactly one shard regardless of b — that is what makes the merged
//! outcome invariant to batch size. Keyless jobs shard by position.
//!
//! Boundaries are additionally snapped to the end of a *key run*: keys
//! may repeat (duplicates align positionally inside a shard), and a
//! boundary cutting a run of equal A-side keys would strand the later
//! A occurrences in the next shard while every matching B row binds to
//! the earlier one — making the report depend on `b`, which violates
//! the merge-invariance contract in `engine/merge.rs`. Snapping keeps
//! each key run whole (so a shard can exceed `b` by the tail of one
//! run — bounded by the longest duplicate-key run in the input).
//!
//! Partitioning is incremental (`next(b)`) because the controller
//! changes b while the job runs.

use crate::data::io::TableSource;
use crate::data::table::Table;
use crate::exec::backend::ShardSpec;

/// Incremental shard carver over a source pair.
pub struct Partitioner<'a> {
    a: &'a dyn TableSource,
    b: &'a dyn TableSource,
    keyed: bool,
    a_pos: usize,
    b_pos: usize,
    next_id: u64,
}

impl<'a> Partitioner<'a> {
    pub fn new(a: &'a dyn TableSource, b: &'a dyn TableSource) -> Self {
        let keyed = a.nrows() > 0
            && b.nrows() > 0
            && a.key_at(0).is_some()
            && b.key_at(0).is_some();
        Partitioner { a, b, keyed, a_pos: 0, b_pos: 0, next_id: 0 }
    }

    pub fn done(&self) -> bool {
        self.a_pos >= self.a.nrows() && self.b_pos >= self.b.nrows()
    }

    /// Fraction of input rows already carved (progress metric).
    pub fn progress(&self) -> f64 {
        let total = (self.a.nrows() + self.b.nrows()).max(1);
        (self.a_pos + self.b_pos) as f64 / total as f64
    }

    pub fn shards_emitted(&self) -> u64 {
        self.next_id
    }

    /// Carve the next shard of (at most) `batch_rows` A-side rows.
    pub fn next(&mut self, batch_rows: usize) -> Option<ShardSpec> {
        if self.done() {
            return None;
        }
        let batch_rows = batch_rows.max(1);
        let a_n = self.a.nrows();
        let b_n = self.b.nrows();

        let (a_len, b_len) = if !self.keyed {
            // Positional sharding: same ranges both sides.
            let a_len = batch_rows.min(a_n - self.a_pos);
            let b_len = if self.a_pos + a_len >= a_n {
                b_n - self.b_pos // last shard takes the B tail
            } else {
                batch_rows.min(b_n.saturating_sub(self.b_pos))
            };
            (a_len, b_len)
        } else if self.a_pos >= a_n {
            // A exhausted: the rest of B is one trailing added-range.
            (0, (b_n - self.b_pos).min(batch_rows))
        } else {
            let mut a_len = batch_rows.min(a_n - self.a_pos);
            if self.a_pos + a_len < a_n {
                // Snap the cut to the end of the key run: all A rows
                // sharing the boundary key stay in this shard (their
                // matching B rows bind here via the upper bound below).
                let boundary = self
                    .a
                    .key_at(self.a_pos + a_len - 1)
                    .expect("keyed source");
                a_len = upper_bound_key_in(
                    self.a,
                    self.a_pos + a_len,
                    a_n,
                    boundary,
                ) - self.a_pos;
            }
            let b_hi = if self.a_pos + a_len >= a_n {
                b_n // last A shard absorbs the B tail
            } else {
                // First B row whose key exceeds the shard's last A key.
                let boundary = self
                    .a
                    .key_at(self.a_pos + a_len - 1)
                    .expect("keyed source");
                upper_bound_key_in(self.b, self.b_pos, b_n, boundary)
            };
            (a_len, b_hi - self.b_pos)
        };

        let spec = ShardSpec {
            shard_id: self.next_id,
            attempt: 0,
            a_offset: self.a_pos,
            a_len,
            b_offset: self.b_pos,
            b_len,
        };
        self.a_pos += a_len;
        self.b_pos += b_len;
        self.next_id += 1;
        Some(spec)
    }
}

/// Generic upper bound: first index in [lo, hi) where `le` turns false
/// (`le(i)` = "row i's key is <= the boundary"; key-sorted rows make it
/// monotone). Single binary search shared by every boundary derivation
/// — the merge-invariance contract depends on all of them snapping key
/// runs identically.
pub(crate) fn upper_bound_by(
    lo: usize,
    hi: usize,
    le: impl Fn(usize) -> bool,
) -> usize {
    let mut lo = lo;
    let mut hi = hi;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if le(mid) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// First row index in [lo, hi) with key > `key` over a key-sorted
/// source. Used by the partitioner, the worker's sub-chunker, and the
/// scheduler's straggler splitter.
pub(crate) fn upper_bound_key_in(
    src: &dyn TableSource,
    lo: usize,
    hi: usize,
    key: i64,
) -> usize {
    upper_bound_by(lo, hi, |i| matches!(src.key_at(i), Some(k) if k <= key))
}

/// Split decoded shard tables into sub-chunks of at most `chunk_rows`
/// A-side rows (plus the tail of a duplicate-key run straddling a cut —
/// boundaries are snapped to key-run ends just like `Partitioner`),
/// key-range aligned (used by the dask-like backend's finer-grained
/// tasks and by straggler shard splitting).
pub fn partition_tables(
    a: &Table,
    b: &Table,
    chunk_rows: usize,
) -> Vec<((usize, usize), (usize, usize))> {
    let key_a = a.schema.key_indices().first().copied();
    let key_b = b.schema.key_indices().first().copied();
    let chunk_rows = chunk_rows.max(1);
    let cell_key = |t: &Table, col: usize, row: usize| -> i64 {
        match t.column(col).cell(row) {
            crate::data::column::Cell::I64(k) => k,
            _ => i64::MAX,
        }
    };
    let mut out = Vec::new();
    let (mut ap, mut bp) = (0usize, 0usize);
    while ap < a.nrows() || bp < b.nrows() {
        if ap >= a.nrows() {
            out.push(((ap, 0), (bp, b.nrows() - bp)));
            break;
        }
        let mut a_len = chunk_rows.min(a.nrows() - ap);
        if let Some(ka) = key_a {
            if ap + a_len < a.nrows() {
                // Snap to the end of the A-side key run.
                let boundary = cell_key(a, ka, ap + a_len - 1);
                a_len = upper_bound_by(ap + a_len, a.nrows(), |i| {
                    cell_key(a, ka, i) <= boundary
                }) - ap;
            }
        }
        let b_hi = match (key_a, key_b) {
            (Some(ka), Some(kb)) if ap + a_len < a.nrows() => {
                let boundary = cell_key(a, ka, ap + a_len - 1);
                upper_bound_by(bp, b.nrows(), |i| cell_key(b, kb, i) <= boundary)
            }
            _ if ap + a_len < a.nrows() => (bp + a_len).min(b.nrows()),
            _ => b.nrows(),
        };
        out.push(((ap, a_len), (bp, b_hi - bp)));
        ap += a_len;
        bp = b_hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;

    fn sources(rows: usize, seed: u64) -> (InMemorySource, InMemorySource) {
        let (a, b, _) = generate_pair(&GenSpec {
            rows,
            seed,
            ..GenSpec::default()
        });
        (InMemorySource::new(a), InMemorySource::new(b))
    }

    #[test]
    fn shards_cover_both_sides_exactly_once() {
        let (a, b) = sources(5_000, 3);
        let mut p = Partitioner::new(&a, &b);
        let mut a_seen = 0;
        let mut b_seen = 0;
        let mut id = 0;
        while let Some(s) = p.next(700) {
            assert_eq!(s.shard_id, id);
            assert_eq!(s.a_offset, a_seen);
            assert_eq!(s.b_offset, b_seen);
            a_seen += s.a_len;
            b_seen += s.b_len;
            id += 1;
        }
        assert_eq!(a_seen, a.nrows());
        assert_eq!(b_seen, b.nrows());
        assert!(p.done());
        assert_eq!(p.progress(), 1.0);
    }

    #[test]
    fn key_ranges_never_split_a_key_span() {
        // Every B key must fall in the shard whose A key range covers it.
        let (a, b) = sources(3_000, 9);
        let mut p = Partitioner::new(&a, &b);
        while let Some(s) = p.next(311) {
            if s.a_len == 0 {
                continue;
            }
            let a_last = a.key_at(s.a_offset + s.a_len - 1).unwrap();
            if s.b_len > 0 {
                let b_last = b.key_at(s.b_offset + s.b_len - 1).unwrap();
                // b rows in this shard have keys <= a_last (except the
                // final shard which absorbs the tail).
                if s.a_offset + s.a_len < a.nrows() {
                    assert!(b_last <= a_last, "b_last={b_last} a_last={a_last}");
                }
            }
            // The next B row (if any) must be beyond a_last.
            if s.a_offset + s.a_len < a.nrows()
                && s.b_offset + s.b_len < b.nrows()
            {
                let next_b = b.key_at(s.b_offset + s.b_len).unwrap();
                assert!(next_b > a_last);
            }
        }
    }

    #[test]
    fn varying_batch_size_still_covers() {
        let (a, b) = sources(4_000, 5);
        let mut p = Partitioner::new(&a, &b);
        let sizes = [100, 900, 50, 2_000, 317];
        let mut i = 0;
        let (mut a_seen, mut b_seen) = (0, 0);
        while let Some(s) = p.next(sizes[i % sizes.len()]) {
            a_seen += s.a_len;
            b_seen += s.b_len;
            i += 1;
        }
        assert_eq!((a_seen, b_seen), (a.nrows(), b.nrows()));
    }

    #[test]
    fn partition_tables_covers_decoded_pair() {
        let (a, b, _) = generate_pair(&GenSpec {
            rows: 1_000,
            seed: 8,
            ..GenSpec::default()
        });
        let chunks = partition_tables(&a, &b, 137);
        let a_total: usize = chunks.iter().map(|c| c.0 .1).sum();
        let b_total: usize = chunks.iter().map(|c| c.1 .1).sum();
        assert_eq!(a_total, a.nrows());
        assert_eq!(b_total, b.nrows());
        // Contiguity.
        let mut ap = 0;
        let mut bp = 0;
        for ((ao, al), (bo, bl)) in chunks {
            assert_eq!(ao, ap);
            assert_eq!(bo, bp);
            ap += al;
            bp += bl;
        }
    }

    #[test]
    fn duplicate_key_runs_never_split() {
        use crate::data::schema::{ColumnType, Field, Schema};
        use crate::data::table::TableBuilder;
        // A-side keys with runs of 1..6 equal keys; B shares the key
        // universe. No batch size may cut a run: the row after every
        // shard must carry a different key than the shard's last row.
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Int64),
        ]);
        let mk = |runs: &[(i64, usize)]| {
            let mut tb = TableBuilder::new(schema.clone());
            let mut v = 0i64;
            for &(key, n) in runs {
                for _ in 0..n {
                    tb.col(0).push_i64(key);
                    tb.col(1).push_i64(v);
                    v += 1;
                }
            }
            tb.finish()
        };
        let runs_a: Vec<(i64, usize)> =
            (0..400).map(|k| (k, 1 + (k as usize * 7) % 6)).collect();
        let runs_b: Vec<(i64, usize)> =
            (0..400).map(|k| (k, 1 + (k as usize * 5) % 6)).collect();
        let a = InMemorySource::new(mk(&runs_a));
        let b = InMemorySource::new(mk(&runs_b));
        for batch in [1usize, 2, 3, 7, 50, 333] {
            let mut p = Partitioner::new(&a, &b);
            let (mut a_seen, mut b_seen) = (0, 0);
            while let Some(s) = p.next(batch) {
                a_seen += s.a_len;
                b_seen += s.b_len;
                if s.a_len > 0 && s.a_offset + s.a_len < a.nrows() {
                    let last = a.key_at(s.a_offset + s.a_len - 1).unwrap();
                    let next = a.key_at(s.a_offset + s.a_len).unwrap();
                    assert_ne!(
                        last, next,
                        "batch={batch}: shard cut key run {last} at row {}",
                        s.a_offset + s.a_len
                    );
                    if s.b_len > 0 {
                        // Every B row with the boundary key binds here.
                        let b_last =
                            b.key_at(s.b_offset + s.b_len - 1).unwrap();
                        assert!(b_last <= last);
                    }
                    if s.b_offset + s.b_len < b.nrows() {
                        let b_next = b.key_at(s.b_offset + s.b_len).unwrap();
                        assert!(b_next > last, "B row with shard key leaked");
                    }
                }
            }
            assert_eq!((a_seen, b_seen), (a.nrows(), b.nrows()));
        }
    }

    #[test]
    fn partition_tables_snaps_key_runs() {
        use crate::data::column::Cell;
        use crate::data::schema::{ColumnType, Field, Schema};
        use crate::data::table::TableBuilder;
        let schema = Schema::new(vec![Field::key("id", ColumnType::Int64)]);
        let mk = |keys: &[i64]| {
            let mut tb = TableBuilder::new(schema.clone());
            for &k in keys {
                tb.col(0).push_i64(k);
            }
            tb.finish()
        };
        // Run of four 5s straddles every small chunk boundary.
        let a = mk(&[1, 2, 5, 5, 5, 5, 8, 9, 9, 10]);
        let b = mk(&[1, 5, 5, 8, 9, 11]);
        for chunk in [1usize, 2, 3, 4] {
            let parts = partition_tables(&a, &b, chunk);
            let a_total: usize = parts.iter().map(|c| c.0 .1).sum();
            let b_total: usize = parts.iter().map(|c| c.1 .1).sum();
            assert_eq!((a_total, b_total), (a.nrows(), b.nrows()));
            for ((ao, al), _) in &parts {
                if *al > 0 && ao + al < a.nrows() {
                    let last = match a.column(0).cell(ao + al - 1) {
                        Cell::I64(k) => k,
                        _ => unreachable!(),
                    };
                    let next = match a.column(0).cell(ao + al) {
                        Cell::I64(k) => k,
                        _ => unreachable!(),
                    };
                    assert_ne!(last, next, "chunk={chunk} cut a key run");
                }
            }
        }
    }

    #[test]
    fn single_shard_when_b_huge() {
        let (a, b) = sources(100, 2);
        let mut p = Partitioner::new(&a, &b);
        let s = p.next(1_000_000).unwrap();
        assert_eq!(s.a_len, a.nrows());
        assert_eq!(s.b_len, b.nrows());
        assert!(p.done());
        assert!(p.next(10).is_none());
    }
}
