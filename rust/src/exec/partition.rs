//! Job partitioning: carve the key-sorted inputs into shards of `b`
//! aligned rows per side (paper §II job decomposition).
//!
//! Shards are key-range aligned: shard i covers A rows [p, p+b) and the
//! B rows whose (key, occurrence) pairs fall in the same span, so every
//! row lands in exactly one shard regardless of b — that is what makes
//! the merged outcome invariant to batch size. Keyless jobs shard by
//! position.
//!
//! # Occurrence-indexed duplicate alignment
//!
//! Keys may repeat, and duplicates pair *positionally*: the global i-th
//! A occurrence of a key pairs with the global i-th B occurrence.
//! Boundaries are allowed to land anywhere — **including inside a
//! duplicate-key run** — because every cut is *occurrence-bounded*:
//! when the A-side cut consumes the first `c` occurrences of its
//! boundary key, the B side is cut at exactly occurrence `c` of that
//! key too ([`upper_bound_key_occ_in`]). Both fragments of the run then
//! resume with equal global occurrence bases (recorded in
//! `ShardSpec::{a_occ_base, b_occ_base}`), so the per-shard positional
//! pairing of local occurrences `(i, i)` is exactly the global pairing
//! `(base + i, base + i)` restricted to the shard — bit-identical to
//! the solo-shard reference for any b (fuzzed end-to-end in
//! `rust/tests/determinism.rs`).
//!
//! A cut that lands at the *end* of a run instead absorbs every
//! remaining B occurrence of the boundary key (pairs and surplus
//! "added" rows alike), matching the historical key-range rule.
//!
//! This replaces the PR 4 run-*snapping* scheme (which kept runs whole
//! and bounded shards by `max(b, longest run)`): the A side of a shard
//! is now bounded by `b` alone, so a hot key's A-side run spanning more
//! rows than the memory grant no longer forces an accounted OOM — the
//! skew workload the ROADMAP left open. (The B side of one shard is
//! bounded by the pairable mass plus the boundary key's surplus: a key
//! whose *B-only* surplus of added rows exceeds the grant — B-dominant
//! skew with no A counterpart — still lands in one shard, as it always
//! has; see the ROADMAP open item on bounded add-range carving.)
//!
//! Partitioning is incremental (`next(b)`) because the controller
//! changes b while the job runs.

use crate::data::io::TableSource;
use crate::data::table::Table;
use crate::exec::backend::ShardSpec;

/// Incremental shard carver over a source pair.
pub struct Partitioner<'a> {
    a: &'a dyn TableSource,
    b: &'a dyn TableSource,
    keyed: bool,
    a_pos: usize,
    b_pos: usize,
    next_id: u64,
}

impl<'a> Partitioner<'a> {
    pub fn new(a: &'a dyn TableSource, b: &'a dyn TableSource) -> Self {
        let keyed = a.nrows() > 0
            && b.nrows() > 0
            && a.key_at(0).is_some()
            && b.key_at(0).is_some();
        Partitioner { a, b, keyed, a_pos: 0, b_pos: 0, next_id: 0 }
    }

    pub fn done(&self) -> bool {
        self.a_pos >= self.a.nrows() && self.b_pos >= self.b.nrows()
    }

    /// Fraction of input rows already carved (progress metric).
    pub fn progress(&self) -> f64 {
        let total = (self.a.nrows() + self.b.nrows()).max(1);
        (self.a_pos + self.b_pos) as f64 / total as f64
    }

    pub fn shards_emitted(&self) -> u64 {
        self.next_id
    }

    /// Carve the next shard of (at most) `batch_rows` A-side rows.
    pub fn next(&mut self, batch_rows: usize) -> Option<ShardSpec> {
        if self.done() {
            return None;
        }
        let batch_rows = batch_rows.max(1);
        let a_n = self.a.nrows();
        let b_n = self.b.nrows();

        let (a_len, b_len) = if !self.keyed {
            // Positional sharding: same ranges both sides.
            let a_len = batch_rows.min(a_n - self.a_pos);
            let b_len = if self.a_pos + a_len >= a_n {
                b_n - self.b_pos // last shard takes the B tail
            } else {
                batch_rows.min(b_n.saturating_sub(self.b_pos))
            };
            (a_len, b_len)
        } else if self.a_pos >= a_n {
            // A exhausted: the rest of B is one trailing added-range.
            (0, (b_n - self.b_pos).min(batch_rows))
        } else {
            let a_len = batch_rows.min(a_n - self.a_pos);
            let b_hi = if self.a_pos + a_len >= a_n {
                b_n // last A shard absorbs the B tail
            } else {
                let last = self.a_pos + a_len - 1;
                let boundary = self.a.key_at(last).expect("keyed source");
                // Occurrence-bounded cut: if the run continues past the
                // cut, B stops at the same occurrence ordinal so both
                // fragments resume with equal occurrence bases; a
                // completed run absorbs every remaining B occurrence of
                // the boundary key.
                let (occ_cut, _) = occ_cut_at(self.a, last, boundary);
                upper_bound_key_occ_in(self.b, self.b_pos, b_n, boundary, occ_cut)
            };
            (a_len, b_hi - self.b_pos)
        };

        let spec = ShardSpec {
            shard_id: self.next_id,
            attempt: 0,
            a_offset: self.a_pos,
            a_len,
            b_offset: self.b_pos,
            b_len,
            a_occ_base: if a_len > 0 { self.a.occ_at(self.a_pos) } else { 0 },
            b_occ_base: if b_len > 0 { self.b.occ_at(self.b_pos) } else { 0 },
        };
        self.a_pos += a_len;
        self.b_pos += b_len;
        self.next_id += 1;
        Some(spec)
    }
}

/// Generic upper bound: first index in [lo, hi) where `le` turns false
/// (`le(i)` = "row i is consumed by the cut"; key-sorted rows make it
/// monotone). Single binary search shared by every boundary derivation
/// — the merge-invariance contract depends on all of them cutting
/// identically.
pub(crate) fn upper_bound_by(
    lo: usize,
    hi: usize,
    le: impl Fn(usize) -> bool,
) -> usize {
    let mut lo = lo;
    let mut hi = hi;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if le(mid) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// First row index in [lo, hi) past the cut "(key, occurrence) <
/// (`key`, `occ_exclusive`)" over a key-sorted source: rows with a
/// smaller key — or the boundary key at an occurrence ordinal below
/// `occ_exclusive` — are consumed; `u32::MAX` consumes the whole run.
/// This is the single occurrence-bounded boundary rule shared by the
/// partitioner, the worker's sub-chunker, and the scheduler's straggler
/// splitter (it replaces the run-snapping `upper_bound_key_in`).
pub(crate) fn upper_bound_key_occ_in(
    src: &dyn TableSource,
    lo: usize,
    hi: usize,
    key: i64,
    occ_exclusive: u32,
) -> usize {
    upper_bound_by(lo, hi, |i| match src.key_at(i) {
        Some(k) => k < key || (k == key && src.occ_at(i) < occ_exclusive),
        None => false,
    })
}

/// Occurrence cut ordinal for an A-side cut whose last consumed row is
/// `last` with boundary key `key` (requires `last + 1 < src.nrows()` —
/// the cut is interior). If the boundary key's run continues past the
/// cut, the B side must stop at the same ordinal (`occ_at(last) + 1`);
/// a completed run absorbs B's remainder of the key (`u32::MAX`).
/// Returns `(occ_cut, cut_in_run)`. One definition shared by the
/// partitioner, the worker's sub-chunker, and the straggler splitter so
/// the cutters cannot desynchronize.
pub(crate) fn occ_cut_at(
    src: &dyn TableSource,
    last: usize,
    key: i64,
) -> (u32, bool) {
    if src.key_at(last + 1) == Some(key) {
        (src.occ_at(last) + 1, true)
    } else {
        (u32::MAX, false)
    }
}

/// Split decoded shard tables into sub-chunks of at most `chunk_rows`
/// A-side rows, (key, occurrence)-range aligned: cuts may land inside a
/// duplicate-key run, with the B boundary bounded at the A cut's
/// occurrence ordinal exactly like `Partitioner` (used by tests and
/// tools operating on decoded pairs; the worker's source-index
/// sub-chunker is `exec::worker::sub_partition`).
///
/// Occurrence ordinals are computed *locally* over the given tables.
/// That is equivalent to the global rule for any fragment produced by
/// the occurrence-bounded cutters, because such a fragment resumes both
/// sides of a straddling run at equal occurrence bases — the bases
/// cancel out of every local comparison.
pub fn partition_tables(
    a: &Table,
    b: &Table,
    chunk_rows: usize,
) -> Vec<((usize, usize), (usize, usize))> {
    let key_a = a.schema.key_indices().first().copied();
    let key_b = b.schema.key_indices().first().copied();
    let chunk_rows = chunk_rows.max(1);
    // Mirrors `TableSource::key_at`: None for non-i64 (null) key cells,
    // so null-key semantics match the source-index cutters exactly
    // (nulls never extend a run and are never consumed by a key cut).
    let cell_key = |t: &Table, col: usize, row: usize| -> Option<i64> {
        match t.column(col).cell(row) {
            crate::data::column::Cell::I64(k) => Some(k),
            _ => None,
        }
    };
    // Local occurrence ordinals, needed only when both sides are keyed
    // (the only arm that cuts by occurrence). Shares the sources' sweep
    // (`data::io::key_occurrences`) so null-key semantics cannot
    // diverge from the source-index cutters.
    let (occ_a, occ_b): (Vec<u32>, Vec<u32>) = match (key_a, key_b) {
        (Some(ka), Some(kb)) => (
            crate::data::io::key_occurrences(a, ka),
            crate::data::io::key_occurrences(b, kb),
        ),
        _ => (Vec::new(), Vec::new()),
    };
    let mut out = Vec::new();
    let (mut ap, mut bp) = (0usize, 0usize);
    while ap < a.nrows() || bp < b.nrows() {
        if ap >= a.nrows() {
            out.push(((ap, 0), (bp, b.nrows() - bp)));
            break;
        }
        let a_len = chunk_rows.min(a.nrows() - ap);
        let b_hi = match (key_a, key_b) {
            (Some(ka), Some(kb)) if ap + a_len < a.nrows() => {
                let last = ap + a_len - 1;
                let boundary_cell = cell_key(a, ka, last);
                let boundary = boundary_cell.unwrap_or(i64::MAX);
                // Mid-run cut: stop B at the same occurrence ordinal;
                // a completed run absorbs B's remainder of the key.
                let occ_cut = if boundary_cell.is_some()
                    && cell_key(a, ka, ap + a_len) == boundary_cell
                {
                    occ_a[last] + 1
                } else {
                    u32::MAX
                };
                upper_bound_by(bp, b.nrows(), |i| match cell_key(b, kb, i) {
                    Some(k) => {
                        k < boundary || (k == boundary && occ_b[i] < occ_cut)
                    }
                    None => false,
                })
            }
            _ if ap + a_len < a.nrows() => (bp + a_len).min(b.nrows()),
            _ => b.nrows(),
        };
        out.push(((ap, a_len), (bp, b_hi - bp)));
        ap += a_len;
        bp = b_hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;

    fn sources(rows: usize, seed: u64) -> (InMemorySource, InMemorySource) {
        let (a, b, _) = generate_pair(&GenSpec {
            rows,
            seed,
            ..GenSpec::default()
        });
        (InMemorySource::new(a), InMemorySource::new(b))
    }

    #[test]
    fn shards_cover_both_sides_exactly_once() {
        let (a, b) = sources(5_000, 3);
        let mut p = Partitioner::new(&a, &b);
        let mut a_seen = 0;
        let mut b_seen = 0;
        let mut id = 0;
        while let Some(s) = p.next(700) {
            assert_eq!(s.shard_id, id);
            assert_eq!(s.a_offset, a_seen);
            assert_eq!(s.b_offset, b_seen);
            a_seen += s.a_len;
            b_seen += s.b_len;
            id += 1;
        }
        assert_eq!(a_seen, a.nrows());
        assert_eq!(b_seen, b.nrows());
        assert!(p.done());
        assert_eq!(p.progress(), 1.0);
    }

    #[test]
    fn key_ranges_never_split_a_key_span() {
        // Unique-key inputs: every B key must fall in the shard whose A
        // key range covers it (the occurrence rule degenerates to the
        // plain key-range rule when runs have length 1).
        let (a, b) = sources(3_000, 9);
        let mut p = Partitioner::new(&a, &b);
        while let Some(s) = p.next(311) {
            if s.a_len == 0 {
                continue;
            }
            let a_last = a.key_at(s.a_offset + s.a_len - 1).unwrap();
            if s.b_len > 0 {
                let b_last = b.key_at(s.b_offset + s.b_len - 1).unwrap();
                // b rows in this shard have keys <= a_last (except the
                // final shard which absorbs the tail).
                if s.a_offset + s.a_len < a.nrows() {
                    assert!(b_last <= a_last, "b_last={b_last} a_last={a_last}");
                }
            }
            // The next B row (if any) must be beyond a_last.
            if s.a_offset + s.a_len < a.nrows()
                && s.b_offset + s.b_len < b.nrows()
            {
                let next_b = b.key_at(s.b_offset + s.b_len).unwrap();
                assert!(next_b > a_last);
            }
        }
    }

    #[test]
    fn varying_batch_size_still_covers() {
        let (a, b) = sources(4_000, 5);
        let mut p = Partitioner::new(&a, &b);
        let sizes = [100, 900, 50, 2_000, 317];
        let mut i = 0;
        let (mut a_seen, mut b_seen) = (0, 0);
        while let Some(s) = p.next(sizes[i % sizes.len()]) {
            a_seen += s.a_len;
            b_seen += s.b_len;
            i += 1;
        }
        assert_eq!((a_seen, b_seen), (a.nrows(), b.nrows()));
    }

    #[test]
    fn partition_tables_covers_decoded_pair() {
        let (a, b, _) = generate_pair(&GenSpec {
            rows: 1_000,
            seed: 8,
            ..GenSpec::default()
        });
        let chunks = partition_tables(&a, &b, 137);
        let a_total: usize = chunks.iter().map(|c| c.0 .1).sum();
        let b_total: usize = chunks.iter().map(|c| c.1 .1).sum();
        assert_eq!(a_total, a.nrows());
        assert_eq!(b_total, b.nrows());
        // Contiguity.
        let mut ap = 0;
        let mut bp = 0;
        for ((ao, al), (bo, bl)) in chunks {
            assert_eq!(ao, ap);
            assert_eq!(bo, bp);
            ap += al;
            bp += bl;
        }
    }

    /// Build a keyed run table: `(key, n)` per run.
    fn run_source(runs: &[(i64, usize)]) -> InMemorySource {
        use crate::data::schema::{ColumnType, Field, Schema};
        use crate::data::table::TableBuilder;
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Int64),
        ]);
        let mut tb = TableBuilder::new(schema);
        let mut v = 0i64;
        for &(key, n) in runs {
            for _ in 0..n {
                tb.col(0).push_i64(key);
                tb.col(1).push_i64(v);
                v += 1;
            }
        }
        InMemorySource::new(tb.finish())
    }

    fn key_counts(
        s: &dyn TableSource,
        hi: usize,
    ) -> std::collections::HashMap<i64, usize> {
        let mut m = std::collections::HashMap::new();
        for i in 0..hi {
            *m.entry(s.key_at(i).unwrap()).or_insert(0) += 1;
        }
        m
    }

    /// Occurrence alignment invariant for a cut at (a_hi, b_hi): for
    /// every key, the number of A occurrences consumed must equal the
    /// number of B occurrences consumed, capped by the side's total —
    /// that is exactly "global occurrence o of A and B land in the same
    /// fragment whenever both exist". `ta`/`tb` are the whole-side key
    /// counts, computed once by the caller (this runs per boundary).
    fn assert_occurrence_aligned(
        a: &dyn TableSource,
        b: &dyn TableSource,
        a_hi: usize,
        b_hi: usize,
        ta: &std::collections::HashMap<i64, usize>,
        tb: &std::collections::HashMap<i64, usize>,
    ) {
        let (ca, cb) = (key_counts(a, a_hi), key_counts(b, b_hi));
        for (k, &na) in &ca {
            let nb = cb.get(k).copied().unwrap_or(0);
            let tb_k = tb.get(k).copied().unwrap_or(0);
            // B consumed = min(A consumed, B total) unless A's run is
            // fully consumed (then B absorbed its surplus too).
            let a_complete = na == ta[k];
            if a_complete {
                assert_eq!(nb, tb_k, "key {k}: completed run must absorb B");
            } else {
                assert_eq!(nb, na.min(tb_k), "key {k}: occurrence misaligned");
            }
        }
        for (k, &nb) in &cb {
            if !ca.contains_key(k) {
                // B-only keys consumed before the boundary key: fine
                // (added rows); B rows of *later* keys must not leak.
                assert_eq!(nb, tb.get(k).copied().unwrap_or(0));
            }
        }
    }

    #[test]
    fn duplicate_key_runs_split_with_aligned_occurrences() {
        // Runs of 1..6 equal keys on both sides with differing lengths;
        // every batch size must keep each prefix cut occurrence-aligned
        // and cover both sides exactly once.
        let runs_a: Vec<(i64, usize)> =
            (0..400).map(|k| (k, 1 + (k as usize * 7) % 6)).collect();
        let runs_b: Vec<(i64, usize)> =
            (0..400).map(|k| (k, 1 + (k as usize * 5) % 6)).collect();
        let a = run_source(&runs_a);
        let b = run_source(&runs_b);
        let ta = key_counts(&a, a.nrows());
        let tb = key_counts(&b, b.nrows());
        for batch in [1usize, 2, 3, 7, 50, 333] {
            let mut p = Partitioner::new(&a, &b);
            let (mut a_seen, mut b_seen) = (0, 0);
            while let Some(s) = p.next(batch) {
                assert!(
                    s.a_len <= batch,
                    "batch={batch}: shard a_len {} exceeds b",
                    s.a_len
                );
                // Bases recorded from the source occurrence index; equal
                // whenever the same key straddles both starts.
                if s.a_len > 0 {
                    assert_eq!(s.a_occ_base, a.occ_at(s.a_offset));
                }
                if s.b_len > 0 {
                    assert_eq!(s.b_occ_base, b.occ_at(s.b_offset));
                }
                if s.a_len > 0
                    && s.b_len > 0
                    && a.key_at(s.a_offset) == b.key_at(s.b_offset)
                {
                    assert_eq!(
                        s.a_occ_base, s.b_occ_base,
                        "batch={batch}: straddling run with unequal bases"
                    );
                }
                a_seen += s.a_len;
                b_seen += s.b_len;
                if a_seen < a.nrows() {
                    assert_occurrence_aligned(&a, &b, a_seen, b_seen, &ta, &tb);
                }
            }
            assert_eq!((a_seen, b_seen), (a.nrows(), b.nrows()));
        }
    }

    #[test]
    fn single_hot_key_shards_bounded_by_b() {
        // The extreme-join-skew shape the run-snapping scheme could not
        // split: one key spans 100% of both sides. Every shard must stay
        // within b and resume at matching occurrence bases.
        let a = run_source(&[(7, 250)]);
        let b = run_source(&[(7, 180)]);
        for batch in [1usize, 3, 32, 97] {
            let mut p = Partitioner::new(&a, &b);
            let (mut a_seen, mut b_seen) = (0usize, 0usize);
            while let Some(s) = p.next(batch) {
                assert!(s.a_len <= batch);
                if s.a_len > 0 {
                    assert_eq!(s.a_occ_base as usize, s.a_offset);
                }
                if s.b_len > 0 {
                    assert_eq!(s.b_occ_base as usize, s.b_offset);
                    assert_eq!(s.a_occ_base, s.b_occ_base);
                }
                a_seen += s.a_len;
                b_seen += s.b_len;
            }
            assert_eq!((a_seen, b_seen), (a.nrows(), b.nrows()));
        }
    }

    #[test]
    fn partition_tables_cuts_runs_occurrence_aligned() {
        use crate::data::schema::{ColumnType, Field, Schema};
        use crate::data::table::TableBuilder;
        let schema = Schema::new(vec![Field::key("id", ColumnType::Int64)]);
        let mk = |keys: &[i64]| {
            let mut tb = TableBuilder::new(schema.clone());
            for &k in keys {
                tb.col(0).push_i64(k);
            }
            tb.finish()
        };
        // Run of four 5s straddles every small chunk boundary.
        let a = mk(&[1, 2, 5, 5, 5, 5, 8, 9, 9, 10]);
        let b = mk(&[1, 5, 5, 8, 9, 11]);
        for chunk in [1usize, 2, 3, 4] {
            let parts = partition_tables(&a, &b, chunk);
            let a_total: usize = parts.iter().map(|c| c.0 .1).sum();
            let b_total: usize = parts.iter().map(|c| c.1 .1).sum();
            assert_eq!((a_total, b_total), (a.nrows(), b.nrows()));
            for ((_, al), _) in &parts {
                assert!(*al <= chunk, "chunk={chunk}: fragment exceeds chunk");
            }
            // Occurrence alignment at every internal boundary via the
            // source-level checker.
            let sa = InMemorySource::new(a.clone());
            let sb = InMemorySource::new(b.clone());
            let ta = key_counts(&sa, sa.nrows());
            let tb = key_counts(&sb, sb.nrows());
            let (mut ap, mut bp) = (0usize, 0usize);
            for ((_, al), (_, bl)) in &parts {
                ap += al;
                bp += bl;
                if ap < a.nrows() {
                    assert_occurrence_aligned(&sa, &sb, ap, bp, &ta, &tb);
                }
            }
        }
    }

    #[test]
    fn single_shard_when_b_huge() {
        let (a, b) = sources(100, 2);
        let mut p = Partitioner::new(&a, &b);
        let s = p.next(1_000_000).unwrap();
        assert_eq!(s.a_len, a.nrows());
        assert_eq!(s.b_len, b.nrows());
        assert!(p.done());
        assert!(p.next(10).is_none());
    }
}
