//! Job partitioning: carve the key-sorted inputs into shards of `b`
//! aligned rows per side (paper §II job decomposition).
//!
//! Shards are key-range aligned: shard i covers A rows [p, p+b) and the
//! B rows whose (key, occurrence) pairs fall in the same span, so every
//! row lands in exactly one shard regardless of b — that is what makes
//! the merged outcome invariant to batch size. Keyless jobs shard by
//! position.
//!
//! # Occurrence-indexed duplicate alignment
//!
//! Keys may repeat, and duplicates pair *positionally*: the global i-th
//! A occurrence of a key pairs with the global i-th B occurrence.
//! Boundaries are allowed to land anywhere — **including inside a
//! duplicate-key run** — because every cut is *occurrence-bounded*:
//! when the A-side cut consumes the first `c` occurrences of its
//! boundary key, the B side is cut at exactly occurrence `c` of that
//! key too ([`upper_bound_key_occ_in`]). Both fragments of the run then
//! resume with equal global occurrence bases (recorded in
//! `ShardSpec::{a_occ_base, b_occ_base}`), so the per-shard positional
//! pairing of local occurrences `(i, i)` is exactly the global pairing
//! `(base + i, base + i)` restricted to the shard — bit-identical to
//! the solo-shard reference for any b (fuzzed end-to-end in
//! `rust/tests/determinism.rs`).
//!
//! A cut that lands at the *end* of a run absorbs the remaining B
//! occurrences of the boundary key (pairs and surplus "added" rows
//! alike, matching the historical key-range rule) — **unless** the
//! surplus exceeds one batch, in which case it is *carved* instead.
//!
//! # Add-range carving (B-dominant skew)
//!
//! A B range is *pure surplus* when none of its rows can pair with an A
//! row: its keys' A runs are fully consumed and each row's occurrence
//! ordinal is ≥ the key's total A occurrence count
//! ([`run_occ_total`], the surplus-detection sibling of
//! [`upper_bound_key_occ_in`]). Surplus never absorbs more than one
//! batch into a pairing shard; anything larger is emitted as
//! batch-sized `a_len = 0` shards (three arms, in priority order):
//!
//! 1. **A exhausted**: the B tail drains in `min(b_rest, batch)` carved
//!    shards.
//! 2. **Carve prefix**: when more than one batch of B rows at the
//!    cursor has keys strictly below the next A key, one batch is
//!    carved off the front (small interleaved added-runs still ride
//!    along inside the next pairing shard, keeping shard counts stable
//!    on ordinary workloads).
//! 3. **Boundary clamp**: a completed-run / last-shard arm absorbs the
//!    boundary key's (or tail's) surplus only while it fits in one
//!    batch; a larger surplus is left for arms 1–2 to carve batch-wise.
//!
//! A pairing shard whose B side still exceeds `a_len + 2·batch` (an
//! interior B-only run between two A keys) halves `a_len` until the
//! surplus sits at a shard start where arm 2 picks it up. Net bound:
//! **every** shard satisfies `a_len <= batch` and
//! `b_len <= a_len + 2·batch` — the working set is bounded by `b` alone
//! on *both* sides, at any skew (fuzzed in
//! `rust/tests/partition_fuzz.rs`).
//!
//! This replaces the PR 4 run-*snapping* scheme (which kept runs whole
//! and bounded shards by `max(b, longest run)`): a hot key's run
//! spanning more rows than the memory grant no longer forces an
//! accounted OOM on either side — including the B-dominant shape where
//! a key's *B-only* surplus of added rows exceeds the grant.
//!
//! Partitioning is incremental (`next(b)`) because the controller
//! changes b while the job runs.

use crate::data::io::TableSource;
use crate::data::table::Table;
use crate::exec::backend::ShardSpec;

/// Incremental shard carver over a source pair.
pub struct Partitioner<'a> {
    a: &'a dyn TableSource,
    b: &'a dyn TableSource,
    keyed: bool,
    a_pos: usize,
    b_pos: usize,
    next_id: u64,
    carved: u64,
}

impl<'a> Partitioner<'a> {
    pub fn new(a: &'a dyn TableSource, b: &'a dyn TableSource) -> Self {
        let keyed = a.nrows() > 0
            && b.nrows() > 0
            && a.key_at(0).is_some()
            && b.key_at(0).is_some();
        Partitioner { a, b, keyed, a_pos: 0, b_pos: 0, next_id: 0, carved: 0 }
    }

    pub fn done(&self) -> bool {
        self.a_pos >= self.a.nrows() && self.b_pos >= self.b.nrows()
    }

    /// Fraction of input rows already carved (progress metric).
    pub fn progress(&self) -> f64 {
        let total = (self.a.nrows() + self.b.nrows()).max(1);
        (self.a_pos + self.b_pos) as f64 / total as f64
    }

    pub fn shards_emitted(&self) -> u64 {
        self.next_id
    }

    /// Carved add-range shards emitted so far (keyed `a_len = 0` shards
    /// of pure B surplus — see the module docs).
    pub fn carved_shards(&self) -> u64 {
        self.carved
    }

    /// Carve the next shard of (at most) `batch_rows` A-side rows.
    pub fn next(&mut self, batch_rows: usize) -> Option<ShardSpec> {
        if self.done() {
            return None;
        }
        let batch_rows = batch_rows.max(1);
        let a_n = self.a.nrows();
        let b_n = self.b.nrows();

        let (a_len, b_len) = if !self.keyed {
            // Positional sharding: same ranges both sides.
            let a_len = batch_rows.min(a_n - self.a_pos);
            let b_len = if self.a_pos + a_len >= a_n {
                b_n - self.b_pos // last shard takes the B tail
            } else {
                batch_rows.min(b_n.saturating_sub(self.b_pos))
            };
            (a_len, b_len)
        } else if self.a_pos >= a_n {
            // Carve arm 1 — A exhausted: the B tail is pure surplus
            // (every pairable occurrence was consumed by earlier cuts);
            // drain it in batch-sized added-range shards.
            self.carved += 1;
            (0, (b_n - self.b_pos).min(batch_rows))
        } else if self.surplus_prefix_exceeds(batch_rows) {
            // Carve arm 2 — more than one batch of B rows below the
            // next A key: all pure surplus (their A runs, if any, are
            // fully consumed — the cursor's alignment invariant), so
            // carve one batch off the front.
            self.carved += 1;
            (0, batch_rows)
        } else {
            // Pairing shard. Shrink a_len while the B side exceeds
            // a_len + 2·batch: the overflow can only be an interior
            // B-only surplus run, and halving pushes the cut before it
            // so arm 2 carves it at the next call. Terminates because
            // at a_len = 1 the B side is provably within the bound
            // (prefix surplus <= batch since arm 2 did not fire,
            // pairable mass <= a_len, boundary surplus clamped at one
            // batch below).
            let mut a_len = batch_rows.min(a_n - self.a_pos);
            loop {
                let b_hi = self.pairing_b_hi(a_len, batch_rows);
                if b_hi - self.b_pos > a_len + 2 * batch_rows && a_len > 1 {
                    a_len /= 2;
                    continue;
                }
                break (a_len, b_hi - self.b_pos);
            }
        };

        let spec = ShardSpec {
            shard_id: self.next_id,
            attempt: 0,
            a_offset: self.a_pos,
            a_len,
            b_offset: self.b_pos,
            b_len,
            a_occ_base: if a_len > 0 { self.a.occ_at(self.a_pos) } else { 0 },
            b_occ_base: if b_len > 0 { self.b.occ_at(self.b_pos) } else { 0 },
        };
        self.a_pos += a_len;
        self.b_pos += b_len;
        self.next_id += 1;
        Some(spec)
    }

    /// Carve-arm-2 predicate: does more than one batch of B rows at the
    /// cursor carry keys strictly below the next A key? Such rows are
    /// pure surplus: every A run below the cursor key is fully consumed
    /// and its pairable B occurrences were absorbed by earlier cuts.
    fn surplus_prefix_exceeds(&self, batch_rows: usize) -> bool {
        let Some(ka) = self.a.key_at(self.a_pos) else {
            return false; // null-key A row: no key cut to carve against
        };
        let lt_hi =
            upper_bound_key_occ_in(self.b, self.b_pos, self.b.nrows(), ka, 0);
        lt_hi - self.b_pos > batch_rows
    }

    /// B-side boundary for a pairing shard of `a_len` A rows: the
    /// occurrence-bounded cut of the PR 5 rule, with the completed-run /
    /// last-shard absorption clamped at one batch of surplus (carve
    /// arm 3 of the module docs).
    fn pairing_b_hi(&self, a_len: usize, batch_rows: usize) -> usize {
        let a_n = self.a.nrows();
        let b_n = self.b.nrows();
        if self.a_pos + a_len >= a_n {
            // Last A shard: absorb the B tail while the surplus beyond
            // the boundary key's pairable bound fits in one batch;
            // otherwise stop at the bound and let arms 1–2 carve the
            // rest.
            let Some(boundary) = self.a.key_at(a_n - 1) else {
                return b_n;
            };
            let total = run_occ_total(self.a, a_n - 1, boundary);
            let pair_hi = upper_bound_key_occ_in(
                self.b, self.b_pos, b_n, boundary, total,
            );
            if b_n - pair_hi > batch_rows { pair_hi } else { b_n }
        } else {
            let last = self.a_pos + a_len - 1;
            // lint: allow(unwrap) the partitioner is only built over
            // keyed sources (key_at is Some for every row by contract)
            let boundary = self.a.key_at(last).expect("keyed source");
            // Occurrence-bounded cut: if the run continues past the
            // cut, B stops at the same occurrence ordinal so both
            // fragments resume with equal occurrence bases.
            let (occ_cut, in_run) = occ_cut_at(self.a, last, boundary);
            let b_hi = upper_bound_key_occ_in(
                self.b, self.b_pos, b_n, boundary, occ_cut,
            );
            if in_run {
                return b_hi; // mid-run cut absorbs no surplus
            }
            // Completed run: absorb the boundary key's B surplus only
            // while it fits in one batch.
            let total = run_occ_total(self.a, last, boundary);
            let pair_hi = upper_bound_key_occ_in(
                self.b, self.b_pos, b_hi, boundary, total,
            );
            if b_hi - pair_hi > batch_rows { pair_hi } else { b_hi }
        }
    }
}

/// Generic upper bound: first index in [lo, hi) where `le` turns false
/// (`le(i)` = "row i is consumed by the cut"; key-sorted rows make it
/// monotone). Single binary search shared by every boundary derivation
/// — the merge-invariance contract depends on all of them cutting
/// identically.
pub(crate) fn upper_bound_by(
    lo: usize,
    hi: usize,
    le: impl Fn(usize) -> bool,
) -> usize {
    let mut lo = lo;
    let mut hi = hi;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if le(mid) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// First row index in [lo, hi) past the cut "(key, occurrence) <
/// (`key`, `occ_exclusive`)" over a key-sorted source: rows with a
/// smaller key — or the boundary key at an occurrence ordinal below
/// `occ_exclusive` — are consumed; `u32::MAX` consumes the whole run.
/// This is the single occurrence-bounded boundary rule shared by the
/// partitioner, the worker's sub-chunker, and the scheduler's straggler
/// splitter (it replaces the run-snapping `upper_bound_key_in`).
pub(crate) fn upper_bound_key_occ_in(
    src: &dyn TableSource,
    lo: usize,
    hi: usize,
    key: i64,
    occ_exclusive: u32,
) -> usize {
    upper_bound_by(lo, hi, |i| match src.key_at(i) {
        Some(k) => k < key || (k == key && src.occ_at(i) < occ_exclusive),
        None => false,
    })
}

/// Total occurrence count of `key` in `src`, given `run_row` is any row
/// inside the key's run: binary-search the run's end and read the last
/// ordinal off the occurrence index. This is the surplus-detection
/// sibling of [`upper_bound_key_occ_in`]: a B row of `key` with
/// `occ_at >= run_occ_total` is pure surplus (an added row with no A
/// counterpart), which is what add-range carving keys off.
pub(crate) fn run_occ_total(
    src: &dyn TableSource,
    run_row: usize,
    key: i64,
) -> u32 {
    debug_assert_eq!(src.key_at(run_row), Some(key), "run_row outside run");
    let end = upper_bound_by(run_row + 1, src.nrows(), |i| {
        src.key_at(i) == Some(key)
    });
    src.occ_at(end - 1) + 1
}

/// Occurrence cut ordinal for an A-side cut whose last consumed row is
/// `last` with boundary key `key` (requires `last + 1 < src.nrows()` —
/// the cut is interior). If the boundary key's run continues past the
/// cut, the B side must stop at the same ordinal (`occ_at(last) + 1`);
/// a completed run absorbs B's remainder of the key (`u32::MAX`).
/// Returns `(occ_cut, cut_in_run)`. One definition shared by the
/// partitioner, the worker's sub-chunker, and the straggler splitter so
/// the cutters cannot desynchronize.
pub(crate) fn occ_cut_at(
    src: &dyn TableSource,
    last: usize,
    key: i64,
) -> (u32, bool) {
    if src.key_at(last + 1) == Some(key) {
        (src.occ_at(last) + 1, true)
    } else {
        (u32::MAX, false)
    }
}

/// Split decoded shard tables into sub-chunks of at most `chunk_rows`
/// A-side rows, (key, occurrence)-range aligned: cuts may land inside a
/// duplicate-key run, with the B boundary bounded at the A cut's
/// occurrence ordinal exactly like `Partitioner` (used by tests and
/// tools operating on decoded pairs; the worker's source-index
/// sub-chunker is `exec::worker::sub_partition`).
///
/// Occurrence ordinals are computed *locally* over the given tables.
/// That is equivalent to the global rule for any fragment produced by
/// the occurrence-bounded cutters, because such a fragment resumes both
/// sides of a straddling run at equal occurrence bases — the bases
/// cancel out of every local comparison.
pub fn partition_tables(
    a: &Table,
    b: &Table,
    chunk_rows: usize,
) -> Vec<((usize, usize), (usize, usize))> {
    let key_a = a.schema.key_indices().first().copied();
    let key_b = b.schema.key_indices().first().copied();
    let chunk_rows = chunk_rows.max(1);
    // Mirrors `TableSource::key_at`: None for non-i64 (null) key cells,
    // so null-key semantics match the source-index cutters exactly
    // (nulls never extend a run and are never consumed by a key cut).
    let cell_key = |t: &Table, col: usize, row: usize| -> Option<i64> {
        match t.column(col).cell(row) {
            crate::data::column::Cell::I64(k) => Some(k),
            _ => None,
        }
    };
    // Local occurrence ordinals, needed only when both sides are keyed
    // (the only arm that cuts by occurrence). Shares the sources' sweep
    // (`data::io::key_occurrences`) so null-key semantics cannot
    // diverge from the source-index cutters.
    let (occ_a, occ_b): (Vec<u32>, Vec<u32>) = match (key_a, key_b) {
        (Some(ka), Some(kb)) => (
            crate::data::io::key_occurrences(a, ka),
            crate::data::io::key_occurrences(b, kb),
        ),
        _ => (Vec::new(), Vec::new()),
    };
    // Local cut of "(key, occ) < (boundary, occ_cut)" — the decoded-
    // table twin of `upper_bound_key_occ_in`.
    let b_cut = |kb: usize, bp: usize, hi: usize, boundary: i64, occ_cut: u32| {
        upper_bound_by(bp, hi, |i| match cell_key(b, kb, i) {
            Some(k) => k < boundary || (k == boundary && occ_b[i] < occ_cut),
            None => false,
        })
    };
    // Local twin of `run_occ_total`: total occurrences of the key whose
    // run contains `run_row`.
    let a_total = |ka: usize, run_row: usize| -> u32 {
        let key = cell_key(a, ka, run_row);
        let end = upper_bound_by(run_row + 1, a.nrows(), |i| {
            cell_key(a, ka, i) == key
        });
        occ_a[end - 1] + 1
    };
    let mut out = Vec::new();
    let (mut ap, mut bp) = (0usize, 0usize);
    while ap < a.nrows() || bp < b.nrows() {
        if ap >= a.nrows() {
            // Carve arm 1: drain the pure-surplus B tail in
            // chunk-bounded added-range fragments.
            let bl = chunk_rows.min(b.nrows() - bp);
            out.push(((ap, 0), (bp, bl)));
            bp += bl;
            continue;
        }
        if let (Some(ka), Some(kb)) = (key_a, key_b) {
            // Carve arm 2: more than one chunk of B rows below the next
            // A key is pure surplus — carve one chunk off the front.
            if let Some(next_key) = cell_key(a, ka, ap) {
                let lt_hi = b_cut(kb, bp, b.nrows(), next_key, 0);
                if lt_hi - bp > chunk_rows {
                    out.push(((ap, 0), (bp, chunk_rows)));
                    bp += chunk_rows;
                    continue;
                }
            }
        }
        let mut a_len = chunk_rows.min(a.nrows() - ap);
        let b_hi = loop {
            let b_hi = match (key_a, key_b) {
                (Some(ka), Some(kb)) if ap + a_len < a.nrows() => {
                    let last = ap + a_len - 1;
                    let boundary_cell = cell_key(a, ka, last);
                    let boundary = boundary_cell.unwrap_or(i64::MAX);
                    // Mid-run cut: stop B at the same occurrence
                    // ordinal; a completed run absorbs B's remainder of
                    // the key — clamped at one chunk of surplus (carve
                    // arm 3), mirroring `Partitioner::pairing_b_hi`.
                    if boundary_cell.is_some()
                        && cell_key(a, ka, ap + a_len) == boundary_cell
                    {
                        b_cut(kb, bp, b.nrows(), boundary, occ_a[last] + 1)
                    } else {
                        let b_hi = b_cut(kb, bp, b.nrows(), boundary, u32::MAX);
                        if boundary_cell.is_none() {
                            b_hi
                        } else {
                            let pair_hi =
                                b_cut(kb, bp, b_hi, boundary, a_total(ka, last));
                            if b_hi - pair_hi > chunk_rows { pair_hi } else { b_hi }
                        }
                    }
                }
                (Some(ka), Some(kb)) => {
                    // Last A chunk: absorb the tail while its surplus
                    // beyond the boundary's pairable bound fits in one
                    // chunk; otherwise arms 1–2 carve the rest.
                    match cell_key(a, ka, a.nrows() - 1) {
                        Some(boundary) => {
                            let pair_hi = b_cut(
                                kb,
                                bp,
                                b.nrows(),
                                boundary,
                                a_total(ka, a.nrows() - 1),
                            );
                            if b.nrows() - pair_hi > chunk_rows {
                                pair_hi
                            } else {
                                b.nrows()
                            }
                        }
                        None => b.nrows(),
                    }
                }
                _ if ap + a_len < a.nrows() => (bp + a_len).min(b.nrows()),
                _ => b.nrows(),
            };
            // Interior-surplus shrink, mirroring `Partitioner::next`.
            if key_a.is_some()
                && key_b.is_some()
                && b_hi - bp > a_len + 2 * chunk_rows
                && a_len > 1
            {
                a_len /= 2;
                continue;
            }
            break b_hi;
        };
        out.push(((ap, a_len), (bp, b_hi - bp)));
        ap += a_len;
        bp = b_hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;

    fn sources(rows: usize, seed: u64) -> (InMemorySource, InMemorySource) {
        let (a, b, _) = generate_pair(&GenSpec {
            rows,
            seed,
            ..GenSpec::default()
        });
        (InMemorySource::new(a), InMemorySource::new(b))
    }

    #[test]
    fn shards_cover_both_sides_exactly_once() {
        let (a, b) = sources(5_000, 3);
        let mut p = Partitioner::new(&a, &b);
        let mut a_seen = 0;
        let mut b_seen = 0;
        let mut id = 0;
        while let Some(s) = p.next(700) {
            assert_eq!(s.shard_id, id);
            assert_eq!(s.a_offset, a_seen);
            assert_eq!(s.b_offset, b_seen);
            a_seen += s.a_len;
            b_seen += s.b_len;
            id += 1;
        }
        assert_eq!(a_seen, a.nrows());
        assert_eq!(b_seen, b.nrows());
        assert!(p.done());
        assert_eq!(p.progress(), 1.0);
    }

    #[test]
    fn key_ranges_never_split_a_key_span() {
        // Unique-key inputs: every B key must fall in the shard whose A
        // key range covers it (the occurrence rule degenerates to the
        // plain key-range rule when runs have length 1).
        let (a, b) = sources(3_000, 9);
        let mut p = Partitioner::new(&a, &b);
        while let Some(s) = p.next(311) {
            if s.a_len == 0 {
                continue;
            }
            let a_last = a.key_at(s.a_offset + s.a_len - 1).unwrap();
            if s.b_len > 0 {
                let b_last = b.key_at(s.b_offset + s.b_len - 1).unwrap();
                // b rows in this shard have keys <= a_last (except the
                // final shard which absorbs the tail).
                if s.a_offset + s.a_len < a.nrows() {
                    assert!(b_last <= a_last, "b_last={b_last} a_last={a_last}");
                }
            }
            // The next B row (if any) must be beyond a_last.
            if s.a_offset + s.a_len < a.nrows()
                && s.b_offset + s.b_len < b.nrows()
            {
                let next_b = b.key_at(s.b_offset + s.b_len).unwrap();
                assert!(next_b > a_last);
            }
        }
    }

    #[test]
    fn varying_batch_size_still_covers() {
        let (a, b) = sources(4_000, 5);
        let mut p = Partitioner::new(&a, &b);
        let sizes = [100, 900, 50, 2_000, 317];
        let mut i = 0;
        let (mut a_seen, mut b_seen) = (0, 0);
        while let Some(s) = p.next(sizes[i % sizes.len()]) {
            a_seen += s.a_len;
            b_seen += s.b_len;
            i += 1;
        }
        assert_eq!((a_seen, b_seen), (a.nrows(), b.nrows()));
    }

    #[test]
    fn partition_tables_covers_decoded_pair() {
        let (a, b, _) = generate_pair(&GenSpec {
            rows: 1_000,
            seed: 8,
            ..GenSpec::default()
        });
        let chunks = partition_tables(&a, &b, 137);
        let a_total: usize = chunks.iter().map(|c| c.0 .1).sum();
        let b_total: usize = chunks.iter().map(|c| c.1 .1).sum();
        assert_eq!(a_total, a.nrows());
        assert_eq!(b_total, b.nrows());
        // Contiguity.
        let mut ap = 0;
        let mut bp = 0;
        for ((ao, al), (bo, bl)) in chunks {
            assert_eq!(ao, ap);
            assert_eq!(bo, bp);
            ap += al;
            bp += bl;
        }
    }

    /// Build a keyed run table: `(key, n)` per run.
    fn run_source(runs: &[(i64, usize)]) -> InMemorySource {
        use crate::data::schema::{ColumnType, Field, Schema};
        use crate::data::table::TableBuilder;
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Int64),
        ]);
        let mut tb = TableBuilder::new(schema);
        let mut v = 0i64;
        for &(key, n) in runs {
            for _ in 0..n {
                tb.col(0).push_i64(key);
                tb.col(1).push_i64(v);
                v += 1;
            }
        }
        InMemorySource::new(tb.finish())
    }

    fn key_counts(
        s: &dyn TableSource,
        hi: usize,
    ) -> std::collections::HashMap<i64, usize> {
        let mut m = std::collections::HashMap::new();
        for i in 0..hi {
            *m.entry(s.key_at(i).unwrap()).or_insert(0) += 1;
        }
        m
    }

    /// Occurrence alignment invariant for a cut at (a_hi, b_hi): for
    /// every key, the number of A occurrences consumed must equal the
    /// number of B occurrences consumed, capped by the side's total —
    /// that is exactly "global occurrence o of A and B land in the same
    /// fragment whenever both exist". `ta`/`tb` are the whole-side key
    /// counts, computed once by the caller (this runs per boundary).
    fn assert_occurrence_aligned(
        a: &dyn TableSource,
        b: &dyn TableSource,
        a_hi: usize,
        b_hi: usize,
        ta: &std::collections::HashMap<i64, usize>,
        tb: &std::collections::HashMap<i64, usize>,
    ) {
        let (ca, cb) = (key_counts(a, a_hi), key_counts(b, b_hi));
        for (k, &na) in &ca {
            let nb = cb.get(k).copied().unwrap_or(0);
            let tb_k = tb.get(k).copied().unwrap_or(0);
            let a_complete = na == ta[k];
            if a_complete {
                // Completed run: every pairable occurrence is consumed;
                // the key's pure surplus may still be mid-drain (carved
                // batch-wise) at the consumption frontier.
                assert!(
                    nb >= na.min(tb_k) && nb <= tb_k,
                    "key {k}: completed run left pairable B rows behind \
                     (consumed {nb} of {tb_k}, pairable {})",
                    na.min(tb_k)
                );
            } else {
                // Mid-run cut: B stops at exactly the A cut's ordinal —
                // carving never interrupts a pairable run.
                assert_eq!(nb, na.min(tb_k), "key {k}: occurrence misaligned");
            }
        }
        for (k, &nb) in &cb {
            if !ca.contains_key(k) {
                // B-only keys (pure surplus): consumed in key order,
                // possibly partially — carving drains them in
                // batch-sized added-range shards.
                assert!(nb <= tb.get(k).copied().unwrap_or(0));
            }
        }
    }

    #[test]
    fn duplicate_key_runs_split_with_aligned_occurrences() {
        // Runs of 1..6 equal keys on both sides with differing lengths;
        // every batch size must keep each prefix cut occurrence-aligned
        // and cover both sides exactly once.
        let runs_a: Vec<(i64, usize)> =
            (0..400).map(|k| (k, 1 + (k as usize * 7) % 6)).collect();
        let runs_b: Vec<(i64, usize)> =
            (0..400).map(|k| (k, 1 + (k as usize * 5) % 6)).collect();
        let a = run_source(&runs_a);
        let b = run_source(&runs_b);
        let ta = key_counts(&a, a.nrows());
        let tb = key_counts(&b, b.nrows());
        for batch in [1usize, 2, 3, 7, 50, 333] {
            let mut p = Partitioner::new(&a, &b);
            let (mut a_seen, mut b_seen) = (0, 0);
            while let Some(s) = p.next(batch) {
                assert!(
                    s.a_len <= batch,
                    "batch={batch}: shard a_len {} exceeds b",
                    s.a_len
                );
                // Bases recorded from the source occurrence index; equal
                // whenever the same key straddles both starts.
                if s.a_len > 0 {
                    assert_eq!(s.a_occ_base, a.occ_at(s.a_offset));
                }
                if s.b_len > 0 {
                    assert_eq!(s.b_occ_base, b.occ_at(s.b_offset));
                }
                if s.a_len > 0
                    && s.b_len > 0
                    && a.key_at(s.a_offset) == b.key_at(s.b_offset)
                {
                    assert_eq!(
                        s.a_occ_base, s.b_occ_base,
                        "batch={batch}: straddling run with unequal bases"
                    );
                }
                a_seen += s.a_len;
                b_seen += s.b_len;
                if a_seen < a.nrows() {
                    assert_occurrence_aligned(&a, &b, a_seen, b_seen, &ta, &tb);
                }
            }
            assert_eq!((a_seen, b_seen), (a.nrows(), b.nrows()));
        }
    }

    #[test]
    fn single_hot_key_shards_bounded_by_b() {
        // The extreme-join-skew shape the run-snapping scheme could not
        // split: one key spans 100% of both sides. Every shard must stay
        // within b and resume at matching occurrence bases.
        let a = run_source(&[(7, 250)]);
        let b = run_source(&[(7, 180)]);
        for batch in [1usize, 3, 32, 97] {
            let mut p = Partitioner::new(&a, &b);
            let (mut a_seen, mut b_seen) = (0usize, 0usize);
            while let Some(s) = p.next(batch) {
                assert!(s.a_len <= batch);
                if s.a_len > 0 {
                    assert_eq!(s.a_occ_base as usize, s.a_offset);
                }
                if s.b_len > 0 {
                    assert_eq!(s.b_occ_base as usize, s.b_offset);
                    assert_eq!(s.a_occ_base, s.b_occ_base);
                }
                a_seen += s.a_len;
                b_seen += s.b_len;
            }
            assert_eq!((a_seen, b_seen), (a.nrows(), b.nrows()));
        }
    }

    #[test]
    fn partition_tables_cuts_runs_occurrence_aligned() {
        use crate::data::schema::{ColumnType, Field, Schema};
        use crate::data::table::TableBuilder;
        let schema = Schema::new(vec![Field::key("id", ColumnType::Int64)]);
        let mk = |keys: &[i64]| {
            let mut tb = TableBuilder::new(schema.clone());
            for &k in keys {
                tb.col(0).push_i64(k);
            }
            tb.finish()
        };
        // Run of four 5s straddles every small chunk boundary.
        let a = mk(&[1, 2, 5, 5, 5, 5, 8, 9, 9, 10]);
        let b = mk(&[1, 5, 5, 8, 9, 11]);
        for chunk in [1usize, 2, 3, 4] {
            let parts = partition_tables(&a, &b, chunk);
            let a_total: usize = parts.iter().map(|c| c.0 .1).sum();
            let b_total: usize = parts.iter().map(|c| c.1 .1).sum();
            assert_eq!((a_total, b_total), (a.nrows(), b.nrows()));
            for ((_, al), _) in &parts {
                assert!(*al <= chunk, "chunk={chunk}: fragment exceeds chunk");
            }
            // Occurrence alignment at every internal boundary via the
            // source-level checker.
            let sa = InMemorySource::new(a.clone());
            let sb = InMemorySource::new(b.clone());
            let ta = key_counts(&sa, sa.nrows());
            let tb = key_counts(&sb, sb.nrows());
            let (mut ap, mut bp) = (0usize, 0usize);
            for ((_, al), (_, bl)) in &parts {
                ap += al;
                bp += bl;
                if ap < a.nrows() {
                    assert_occurrence_aligned(&sa, &sb, ap, bp, &ta, &tb);
                }
            }
        }
    }

    /// Drive a partitioner to completion asserting the carving bounds
    /// on every shard: `a_len <= batch`, `b_len <= a_len + 2·batch`,
    /// carved shards are pure surplus, and both sides are covered
    /// exactly once. Returns the number of carved shards.
    fn assert_carving_bounds(
        a: &dyn TableSource,
        b: &dyn TableSource,
        batch: usize,
    ) -> u64 {
        let ta = key_counts(a, a.nrows());
        let mut p = Partitioner::new(a, b);
        let (mut a_seen, mut b_seen) = (0usize, 0usize);
        while let Some(s) = p.next(batch) {
            assert!(s.a_len <= batch, "a_len {} > batch {batch}", s.a_len);
            assert!(
                s.b_len <= s.a_len + 2 * batch,
                "b_len {} > a_len {} + 2·batch {batch}",
                s.b_len,
                s.a_len
            );
            if s.a_len == 0 {
                // Carved added-range: batch-bounded and pure surplus —
                // every row's occurrence ordinal is at or past its
                // key's total A occurrence count.
                assert!(s.b_len <= batch, "carved b_len {} > batch", s.b_len);
                for i in s.b_offset..s.b_offset + s.b_len {
                    let k = b.key_at(i).unwrap();
                    let a_total = ta.get(&k).copied().unwrap_or(0);
                    assert!(
                        b.occ_at(i) as usize >= a_total,
                        "carved row {i} (key {k}, occ {}) is pairable",
                        b.occ_at(i)
                    );
                }
            }
            assert_eq!(s.a_offset, a_seen);
            assert_eq!(s.b_offset, b_seen);
            a_seen += s.a_len;
            b_seen += s.b_len;
        }
        assert_eq!((a_seen, b_seen), (a.nrows(), b.nrows()));
        p.carved_shards()
    }

    #[test]
    fn trailing_b_surplus_carved_into_batch_sized_shards() {
        // One B-only key with a 500-row surplus run after a small
        // pairable prefix: the last-shard arm must not absorb it.
        let a = run_source(&[(3, 10)]);
        let b = run_source(&[(3, 10), (9, 500)]);
        let carved = assert_carving_bounds(&a, &b, 32);
        assert!(carved >= 500 / 32, "expected batch-wise carve, got {carved}");
    }

    #[test]
    fn interior_b_surplus_carved_between_pairable_keys() {
        // A 400-row B-only run between two pairable keys: the shrink
        // loop pushes the cut before it and the carve-prefix arm drains
        // it batch-wise.
        let a = run_source(&[(1, 20), (5, 20)]);
        let b = run_source(&[(1, 20), (3, 400), (5, 20)]);
        for batch in [4usize, 16, 64] {
            let carved = assert_carving_bounds(&a, &b, batch);
            assert!(carved > 0, "batch={batch}: interior surplus not carved");
        }
    }

    #[test]
    fn boundary_key_surplus_carved_not_absorbed() {
        // The B-dominant hot key: 4 pairable A occurrences vs 300 B
        // rows. The completed-run arm historically absorbed all 296
        // surplus rows into one shard; the clamp defers them to carved
        // shards.
        let a = run_source(&[(7, 4)]);
        let b = run_source(&[(7, 300)]);
        let carved = assert_carving_bounds(&a, &b, 8);
        assert!(carved >= 290 / 8, "surplus not carved batch-wise: {carved}");
    }

    #[test]
    fn small_surplus_still_absorbed_without_carving() {
        // Surplus at or below one batch rides along in the pairing
        // shard (the historical rule), keeping shard counts stable on
        // ordinary workloads.
        let a = run_source(&[(1, 5), (2, 5), (3, 5)]);
        let b = run_source(&[(1, 5), (2, 9), (3, 5)]);
        let carved = assert_carving_bounds(&a, &b, 10);
        assert_eq!(carved, 0, "sub-batch surplus must not carve");
    }

    #[test]
    fn partition_tables_carves_b_surplus_bounded() {
        use crate::data::schema::{ColumnType, Field, Schema};
        use crate::data::table::TableBuilder;
        let schema = Schema::new(vec![Field::key("id", ColumnType::Int64)]);
        let mk = |runs: &[(i64, usize)]| {
            let mut tb = TableBuilder::new(schema.clone());
            for &(k, n) in runs {
                for _ in 0..n {
                    tb.col(0).push_i64(k);
                }
            }
            tb.finish()
        };
        let a = mk(&[(1, 6), (8, 2)]);
        let b = mk(&[(1, 6), (4, 120), (8, 60)]);
        for chunk in [3usize, 8, 31] {
            let parts = partition_tables(&a, &b, chunk);
            let a_total: usize = parts.iter().map(|c| c.0 .1).sum();
            let b_total: usize = parts.iter().map(|c| c.1 .1).sum();
            assert_eq!((a_total, b_total), (a.nrows(), b.nrows()));
            for ((_, al), (_, bl)) in &parts {
                assert!(*al <= chunk);
                assert!(
                    *bl <= *al + 2 * chunk,
                    "chunk={chunk}: b fragment {bl} exceeds {al} + 2·chunk"
                );
            }
        }
    }

    #[test]
    fn single_shard_when_b_huge() {
        let (a, b) = sources(100, 2);
        let mut p = Partitioner::new(&a, &b);
        let s = p.next(1_000_000).unwrap();
        assert_eq!(s.a_len, a.nrows());
        assert_eq!(s.b_len, b.nrows());
        assert!(p.done());
        assert!(p.next(10).is_none());
    }
}
