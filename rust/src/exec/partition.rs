//! Job partitioning: carve the key-sorted inputs into shards of `b`
//! aligned rows per side (paper §II job decomposition).
//!
//! Shards are key-range aligned: shard i covers A rows [p, p+b) and the
//! B rows whose keys fall in the same key span, so every row lands in
//! exactly one shard regardless of b — that is what makes the merged
//! outcome invariant to batch size. Keyless jobs shard by position.
//!
//! Partitioning is incremental (`next(b)`) because the controller
//! changes b while the job runs.

use crate::data::io::TableSource;
use crate::data::table::Table;
use crate::exec::backend::ShardSpec;

/// Incremental shard carver over a source pair.
pub struct Partitioner<'a> {
    a: &'a dyn TableSource,
    b: &'a dyn TableSource,
    keyed: bool,
    a_pos: usize,
    b_pos: usize,
    next_id: u64,
}

impl<'a> Partitioner<'a> {
    pub fn new(a: &'a dyn TableSource, b: &'a dyn TableSource) -> Self {
        let keyed = a.nrows() > 0
            && b.nrows() > 0
            && a.key_at(0).is_some()
            && b.key_at(0).is_some();
        Partitioner { a, b, keyed, a_pos: 0, b_pos: 0, next_id: 0 }
    }

    pub fn done(&self) -> bool {
        self.a_pos >= self.a.nrows() && self.b_pos >= self.b.nrows()
    }

    /// Fraction of input rows already carved (progress metric).
    pub fn progress(&self) -> f64 {
        let total = (self.a.nrows() + self.b.nrows()).max(1);
        (self.a_pos + self.b_pos) as f64 / total as f64
    }

    pub fn shards_emitted(&self) -> u64 {
        self.next_id
    }

    /// Carve the next shard of (at most) `batch_rows` A-side rows.
    pub fn next(&mut self, batch_rows: usize) -> Option<ShardSpec> {
        if self.done() {
            return None;
        }
        let batch_rows = batch_rows.max(1);
        let a_n = self.a.nrows();
        let b_n = self.b.nrows();

        let (a_len, b_len) = if !self.keyed {
            // Positional sharding: same ranges both sides.
            let a_len = batch_rows.min(a_n - self.a_pos);
            let b_len = if self.a_pos + a_len >= a_n {
                b_n - self.b_pos // last shard takes the B tail
            } else {
                batch_rows.min(b_n.saturating_sub(self.b_pos))
            };
            (a_len, b_len)
        } else if self.a_pos >= a_n {
            // A exhausted: the rest of B is one trailing added-range.
            (0, (b_n - self.b_pos).min(batch_rows))
        } else {
            let a_len = batch_rows.min(a_n - self.a_pos);
            let b_hi = if self.a_pos + a_len >= a_n {
                b_n // last A shard absorbs the B tail
            } else {
                // First B row whose key exceeds the shard's last A key.
                let boundary = self
                    .a
                    .key_at(self.a_pos + a_len - 1)
                    .expect("keyed source");
                upper_bound_key(self.b, self.b_pos, boundary)
            };
            (a_len, b_hi - self.b_pos)
        };

        let spec = ShardSpec {
            shard_id: self.next_id,
            attempt: 0,
            a_offset: self.a_pos,
            a_len,
            b_offset: self.b_pos,
            b_len,
        };
        self.a_pos += a_len;
        self.b_pos += b_len;
        self.next_id += 1;
        Some(spec)
    }
}

/// First row index in [lo, nrows) with key > `key` (binary search over a
/// key-sorted source).
fn upper_bound_key(src: &dyn TableSource, lo: usize, key: i64) -> usize {
    let mut lo = lo;
    let mut hi = src.nrows();
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        match src.key_at(mid) {
            Some(k) if k <= key => lo = mid + 1,
            _ => hi = mid,
        }
    }
    lo
}

/// Split decoded shard tables into sub-chunks of at most `chunk_rows`
/// A-side rows, key-range aligned (used by the dask-like backend's
/// finer-grained tasks and by straggler shard splitting).
pub fn partition_tables(
    a: &Table,
    b: &Table,
    chunk_rows: usize,
) -> Vec<((usize, usize), (usize, usize))> {
    let key_a = a.schema.key_indices().first().copied();
    let key_b = b.schema.key_indices().first().copied();
    let chunk_rows = chunk_rows.max(1);
    let mut out = Vec::new();
    let (mut ap, mut bp) = (0usize, 0usize);
    while ap < a.nrows() || bp < b.nrows() {
        if ap >= a.nrows() {
            out.push(((ap, 0), (bp, b.nrows() - bp)));
            break;
        }
        let a_len = chunk_rows.min(a.nrows() - ap);
        let b_hi = match (key_a, key_b) {
            (Some(ka), Some(kb)) if ap + a_len < a.nrows() => {
                let boundary = match a.column(ka).cell(ap + a_len - 1) {
                    crate::data::column::Cell::I64(k) => k,
                    _ => i64::MAX,
                };
                let mut lo = bp;
                let mut hi = b.nrows();
                while lo < hi {
                    let mid = lo + (hi - lo) / 2;
                    let k = match b.column(kb).cell(mid) {
                        crate::data::column::Cell::I64(k) => k,
                        _ => i64::MAX,
                    };
                    if k <= boundary {
                        lo = mid + 1;
                    } else {
                        hi = mid;
                    }
                }
                lo
            }
            _ if ap + a_len < a.nrows() => (bp + a_len).min(b.nrows()),
            _ => b.nrows(),
        };
        out.push(((ap, a_len), (bp, b_hi - bp)));
        ap += a_len;
        bp = b_hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;

    fn sources(rows: usize, seed: u64) -> (InMemorySource, InMemorySource) {
        let (a, b, _) = generate_pair(&GenSpec {
            rows,
            seed,
            ..GenSpec::default()
        });
        (InMemorySource::new(a), InMemorySource::new(b))
    }

    #[test]
    fn shards_cover_both_sides_exactly_once() {
        let (a, b) = sources(5_000, 3);
        let mut p = Partitioner::new(&a, &b);
        let mut a_seen = 0;
        let mut b_seen = 0;
        let mut id = 0;
        while let Some(s) = p.next(700) {
            assert_eq!(s.shard_id, id);
            assert_eq!(s.a_offset, a_seen);
            assert_eq!(s.b_offset, b_seen);
            a_seen += s.a_len;
            b_seen += s.b_len;
            id += 1;
        }
        assert_eq!(a_seen, a.nrows());
        assert_eq!(b_seen, b.nrows());
        assert!(p.done());
        assert_eq!(p.progress(), 1.0);
    }

    #[test]
    fn key_ranges_never_split_a_key_span() {
        // Every B key must fall in the shard whose A key range covers it.
        let (a, b) = sources(3_000, 9);
        let mut p = Partitioner::new(&a, &b);
        while let Some(s) = p.next(311) {
            if s.a_len == 0 {
                continue;
            }
            let a_last = a.key_at(s.a_offset + s.a_len - 1).unwrap();
            if s.b_len > 0 {
                let b_last = b.key_at(s.b_offset + s.b_len - 1).unwrap();
                // b rows in this shard have keys <= a_last (except the
                // final shard which absorbs the tail).
                if s.a_offset + s.a_len < a.nrows() {
                    assert!(b_last <= a_last, "b_last={b_last} a_last={a_last}");
                }
            }
            // The next B row (if any) must be beyond a_last.
            if s.a_offset + s.a_len < a.nrows()
                && s.b_offset + s.b_len < b.nrows()
            {
                let next_b = b.key_at(s.b_offset + s.b_len).unwrap();
                assert!(next_b > a_last);
            }
        }
    }

    #[test]
    fn varying_batch_size_still_covers() {
        let (a, b) = sources(4_000, 5);
        let mut p = Partitioner::new(&a, &b);
        let sizes = [100, 900, 50, 2_000, 317];
        let mut i = 0;
        let (mut a_seen, mut b_seen) = (0, 0);
        while let Some(s) = p.next(sizes[i % sizes.len()]) {
            a_seen += s.a_len;
            b_seen += s.b_len;
            i += 1;
        }
        assert_eq!((a_seen, b_seen), (a.nrows(), b.nrows()));
    }

    #[test]
    fn partition_tables_covers_decoded_pair() {
        let (a, b, _) = generate_pair(&GenSpec {
            rows: 1_000,
            seed: 8,
            ..GenSpec::default()
        });
        let chunks = partition_tables(&a, &b, 137);
        let a_total: usize = chunks.iter().map(|c| c.0 .1).sum();
        let b_total: usize = chunks.iter().map(|c| c.1 .1).sum();
        assert_eq!(a_total, a.nrows());
        assert_eq!(b_total, b.nrows());
        // Contiguity.
        let mut ap = 0;
        let mut bp = 0;
        for ((ao, al), (bo, bl)) in chunks {
            assert_eq!(ao, ap);
            assert_eq!(bo, bp);
            ap += al;
            bp += bl;
        }
    }

    #[test]
    fn single_shard_when_b_huge() {
        let (a, b) = sources(100, 2);
        let mut p = Partitioner::new(&a, &b);
        let s = p.next(1_000_000).unwrap();
        assert_eq!(s.a_len, a.nrows());
        assert_eq!(s.b_len, b.nrows());
        assert!(p.done());
        assert!(p.next(10).is_none());
    }
}
