//! The backend abstraction: how the scheduler submits shards and
//! observes completions. Both real backends (inmem threads, dask-like
//! task graph) and the discrete-event simulator implement this trait —
//! the scheduler cannot tell them apart, which is what makes the
//! simulator a valid testbed for the control loop (DESIGN.md §4.2).

use std::sync::Arc;

use crate::data::chunkstore::{CacheStats, ChunkStore, Side};
use crate::data::io::TableSource;
use crate::engine::comparators::NumericDeltaExec;
use crate::engine::delta::{JobPlan, ShardMemStats};
use crate::engine::verdict::BatchOutcome;

/// One schedulable shard: contiguous key-aligned row ranges on each side.
///
/// Boundaries may land *inside* a duplicate-key run: each side carries
/// the **global occurrence base** of its first row (the row's ordinal
/// within its run of equal keys), so a fragment of a cut run knows that
/// its local i-th occurrence is global occurrence `base + i`. The
/// occurrence-bounded cut rule (`exec/partition.rs`) guarantees the two
/// bases are equal whenever a run straddles the shard start on both
/// sides, which is what makes per-shard positional duplicate pairing
/// bit-identical to the solo-shard pairing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub shard_id: u64,
    /// Speculative attempt number (0 = primary). The merger keeps the
    /// first completion per shard_id.
    pub attempt: u32,
    pub a_offset: usize,
    pub a_len: usize,
    pub b_offset: usize,
    pub b_len: usize,
    /// Occurrence ordinal of the first A row within its key run (0 when
    /// the shard starts at a run boundary, is empty, or is keyless).
    pub a_occ_base: u32,
    /// Occurrence ordinal of the first B row within its key run.
    pub b_occ_base: u32,
}

impl ShardSpec {
    pub fn rows(&self) -> usize {
        self.a_len.max(self.b_len)
    }
}

/// Why a batch failed.
///
/// Implements [`std::error::Error`]: `Failed` carries an optional typed
/// cause chain (`source()`), so job handles can surface the root cause
/// instead of a flattened string. Equality compares the failure
/// *identity* (variant + message/amounts), not the cause chain.
#[derive(Debug, Clone)]
pub enum BatchError {
    /// Accounted memory exceeded the cap — the failure the safety
    /// envelope (Eq. 4) exists to prevent. Fatal for the job.
    Oom { needed_bytes: u64, cap_bytes: u64 },
    /// Cooperative cancellation (straggler speculation won).
    Cancelled,
    /// Any other execution error, with an optional typed cause.
    Failed {
        message: String,
        source: Option<Arc<dyn std::error::Error + Send + Sync + 'static>>,
    },
}

impl BatchError {
    /// A failure with no structured cause.
    pub fn failed(message: impl Into<String>) -> Self {
        BatchError::Failed { message: message.into(), source: None }
    }
    /// A failure chaining a typed cause (exposed via `source()`).
    pub fn failed_with(
        message: impl Into<String>,
        source: impl std::error::Error + Send + Sync + 'static,
    ) -> Self {
        BatchError::Failed {
            message: message.into(),
            source: Some(Arc::new(source)),
        }
    }
}

impl PartialEq for BatchError {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (
                BatchError::Oom { needed_bytes: n1, cap_bytes: c1 },
                BatchError::Oom { needed_bytes: n2, cap_bytes: c2 },
            ) => n1 == n2 && c1 == c2,
            (BatchError::Cancelled, BatchError::Cancelled) => true,
            (
                BatchError::Failed { message: m1, .. },
                BatchError::Failed { message: m2, .. },
            ) => m1 == m2,
            _ => false,
        }
    }
}

impl std::fmt::Display for BatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BatchError::Oom { needed_bytes, cap_bytes } => write!(
                f,
                "accounted OOM: needed {needed_bytes} bytes, cap {cap_bytes}"
            ),
            BatchError::Cancelled => write!(f, "cancelled"),
            BatchError::Failed { message, .. } => write!(f, "{message}"),
        }
    }
}

impl std::error::Error for BatchError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BatchError::Failed { source: Some(s), .. } => {
                Some(s.as_ref() as &(dyn std::error::Error + 'static))
            }
            _ => None,
        }
    }
}

/// Per-shard pipeline-stage wall times (ns) for the paper's overlap
/// telemetry: how long the shard spent transferring bytes, decoding
/// them, aligning rows, and diffing — plus `stall_ns`, the time the
/// *worker* was blocked waiting for input (with prefetch off this is
/// the whole read+decode; with prefetch on it is only the residual wait
/// on the staged slot, so `stall < read + decode` is the signature of
/// real overlap).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageNanos {
    pub read_ns: u64,
    pub decode_ns: u64,
    pub align_ns: u64,
    pub diff_ns: u64,
    pub stall_ns: u64,
}

impl StageNanos {
    /// Accumulate another shard's (or chunk's) stage times.
    pub fn add(&mut self, other: &StageNanos) {
        self.read_ns += other.read_ns;
        self.decode_ns += other.decode_ns;
        self.align_ns += other.align_ns;
        self.diff_ns += other.diff_ns;
        self.stall_ns += other.stall_ns;
    }

    /// Fraction of read+decode time hidden behind compute, in [0, 1]:
    /// `1 − stall/(read+decode)`. 0 when nothing was prefetched (the
    /// worker stalled for every transferred byte) or nothing was read.
    pub fn overlap_ratio(&self) -> f64 {
        let io = self.read_ns + self.decode_ns;
        if io == 0 {
            return 0.0;
        }
        (1.0 - self.stall_ns as f64 / io as f64).clamp(0.0, 1.0)
    }
}

/// Completion record for one batch (the paper's per-batch telemetry:
/// timestamps, RSS, CPU, I/O, queue depth at completion).
#[derive(Debug, Clone)]
pub struct BatchReport {
    pub shard: ShardSpec,
    pub worker_id: usize,
    /// Backend-clock seconds (virtual for the simulator).
    pub submitted_at: f64,
    pub started_at: f64,
    pub finished_at: f64,
    pub result: Result<BatchOutcome, BatchError>,
    pub mem: ShardMemStats,
    /// Peak accounted RSS of the executing worker during this batch.
    pub worker_rss_peak: u64,
    /// Bytes read for this batch.
    pub io_bytes: u64,
    /// Pipeline-stage wall times for this batch (all zero for backends
    /// that don't instrument stages, e.g. the simulator).
    pub stages: StageNanos,
}

impl BatchReport {
    /// Queueing + execution latency (the paper's per-batch latency).
    pub fn latency(&self) -> f64 {
        self.finished_at - self.submitted_at
    }
    pub fn exec_time(&self) -> f64 {
        self.finished_at - self.started_at
    }
    pub fn is_oom(&self) -> bool {
        matches!(self.result, Err(BatchError::Oom { .. }))
    }
}

/// Shared immutable job state handed to every backend/worker.
pub struct JobContext {
    pub a: Arc<dyn TableSource>,
    pub b: Arc<dyn TableSource>,
    pub plan: Arc<JobPlan>,
    pub exec: Arc<dyn NumericDeltaExec>,
    /// Hard RAM cap (accounting-based; exceeding it is an OOM failure).
    pub mem_cap_bytes: u64,
    /// Baseline resident bytes (source tables etc.) counted against the
    /// cap in addition to per-batch buffers.
    pub base_rss_bytes: u64,
    /// The job's chunk cache, when `a`/`b` are wrapped in
    /// [`CachedSource`](crate::data::chunkstore::CachedSource) (None
    /// with the cache off or for in-memory sources). The pool carves its
    /// residency budget out of the grant and re-caps it on every grant
    /// change; the scheduler reads its gauges for the envelope term and
    /// split hints.
    pub chunk_store: Option<Arc<ChunkStore>>,
}

impl JobContext {
    pub fn new(
        a: Arc<dyn TableSource>,
        b: Arc<dyn TableSource>,
        plan: JobPlan,
        exec: Arc<dyn NumericDeltaExec>,
        mem_cap_bytes: u64,
    ) -> Arc<Self> {
        let base = a.resident_bytes() + b.resident_bytes();
        Arc::new(JobContext {
            a,
            b,
            plan: Arc::new(plan),
            exec,
            mem_cap_bytes,
            base_rss_bytes: base,
            chunk_store: None,
        })
    }

    /// `new`, but with a chunk store attached (sources already wrapped).
    pub fn with_chunk_store(
        a: Arc<dyn TableSource>,
        b: Arc<dyn TableSource>,
        plan: JobPlan,
        exec: Arc<dyn NumericDeltaExec>,
        mem_cap_bytes: u64,
        store: Arc<ChunkStore>,
    ) -> Arc<Self> {
        let base = a.resident_bytes() + b.resident_bytes();
        Arc::new(JobContext {
            a,
            b,
            plan: Arc::new(plan),
            exec,
            mem_cap_bytes,
            base_rss_bytes: base,
            chunk_store: Some(store),
        })
    }
}

/// Execution backend contract. All methods are called from the single
/// scheduler thread; workers live inside the backend.
pub trait Backend {
    fn name(&self) -> &'static str;
    /// Enqueue a shard for execution.
    fn submit(&mut self, shard: ShardSpec);
    /// Non-blocking: drain finished batches.
    fn poll(&mut self) -> Vec<BatchReport>;
    /// Block until at least one batch finishes (or nothing is inflight);
    /// returns all completions currently available.
    fn wait_any(&mut self) -> Vec<BatchReport>;
    /// Request a new worker count (takes effect asap; k is the paper's
    /// control variable).
    fn set_workers(&mut self, k: usize);
    /// Current target worker count.
    fn workers(&self) -> usize;
    /// Request a new job-level memory budget in bytes — the session's
    /// elastic grant, driven like `set_workers`. The backend re-caps its
    /// accounting ledgers (shared pool or per-worker arenas) for new
    /// allocations immediately; it does not evict live buffers, so the
    /// scheduler loop defers *shrink* application until the pipeline has
    /// drained and accounted usage fits under the new budget (otherwise
    /// inflight batches sized for the old budget would spuriously fail
    /// with accounted OOMs).
    fn set_mem_budget(&mut self, bytes: u64);
    /// The memory budget the backend currently enforces, in bytes.
    fn mem_budget(&self) -> u64;
    /// Shards submitted but not yet started.
    fn queue_depth(&self) -> usize;
    /// Shards submitted but not yet finished.
    fn inflight(&self) -> usize;
    /// Backend clock in seconds (virtual for the simulator).
    fn now(&self) -> f64;
    /// Job-level accounted RSS right now: base tables + active batch
    /// buffers + idle per-worker scratch reservations (warmed
    /// `ShardScratch` stays resident between batches and is accounted
    /// here while its worker is idle).
    fn current_rss(&self) -> u64;
    /// CPU utilization since the previous call, as a fraction of the
    /// *CPU cap* (not of k), in [0, 1].
    fn utilization_sample(&mut self, cpu_cap: usize) -> f64;
    /// Cooperatively cancel a shard attempt (straggler speculation).
    fn cancel(&mut self, shard_id: u64);
    /// Bytes currently held in staged (prefetched, not yet consumed)
    /// buffers. Already included in `current_rss` — exposed separately
    /// for telemetry/progress, never added on top.
    fn staged_bytes(&self) -> u64 {
        0
    }
    /// Whether this backend runs the double-buffered prefetch pipeline
    /// (the scheduler prunes batch sizes against 2·b resident shards
    /// per worker when it does).
    fn prefetch_active(&self) -> bool {
        false
    }
    /// Chunk-cache counters and gauges (all zero when no cache is
    /// attached). `resident_bytes` is already part of `current_rss` —
    /// the scheduler subtracts it from the Eq. 4 memory allowance so
    /// batch buffers and cached chunks share the grant honestly, and it
    /// is never added on top.
    fn cache_stats(&self) -> CacheStats {
        CacheStats::default()
    }
    /// Cache-aware straggler-split hint: the row count of the longest
    /// cache-resident strict prefix of `side`'s range, if any. The
    /// scheduler cuts a straggler there so the re-executed left half is
    /// a pure cache hit instead of a fresh decode.
    fn cache_split_hint(
        &self,
        _side: Side,
        _offset: usize,
        _len: usize,
    ) -> Option<usize> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_latency_math() {
        let r = BatchReport {
            shard: ShardSpec {
                shard_id: 0,
                attempt: 0,
                a_offset: 0,
                a_len: 10,
                b_offset: 0,
                b_len: 12,
                a_occ_base: 0,
                b_occ_base: 0,
            },
            worker_id: 0,
            submitted_at: 1.0,
            started_at: 1.5,
            finished_at: 3.0,
            result: Err(BatchError::Cancelled),
            mem: ShardMemStats::default(),
            worker_rss_peak: 0,
            io_bytes: 0,
            stages: StageNanos::default(),
        };
        assert_eq!(r.latency(), 2.0);
        assert_eq!(r.exec_time(), 1.5);
        assert!(!r.is_oom());
        assert_eq!(r.shard.rows(), 12);
    }

    #[test]
    fn stage_overlap_ratio() {
        // No prefetch: the worker stalls for the full read+decode.
        let serial = StageNanos {
            read_ns: 600,
            decode_ns: 400,
            align_ns: 100,
            diff_ns: 900,
            stall_ns: 1_000,
        };
        assert_eq!(serial.overlap_ratio(), 0.0);
        // Perfect prefetch: zero stall.
        let hidden = StageNanos { stall_ns: 0, ..serial };
        assert_eq!(hidden.overlap_ratio(), 1.0);
        // Partial: 25% of the I/O time still stalled the worker.
        let partial = StageNanos { stall_ns: 250, ..serial };
        assert!((partial.overlap_ratio() - 0.75).abs() < 1e-12);
        // Degenerate: nothing read.
        assert_eq!(StageNanos::default().overlap_ratio(), 0.0);
        let mut sum = serial;
        sum.add(&hidden);
        assert_eq!(sum.read_ns, 1_200);
        assert_eq!(sum.stall_ns, 1_000);
    }

    #[test]
    fn batch_error_display_and_source_chain() {
        use std::error::Error;
        let plain = BatchError::failed("decode failed");
        assert_eq!(plain.to_string(), "decode failed");
        assert!(plain.source().is_none());

        let chained = BatchError::failed_with(
            "decode failed",
            std::io::Error::new(std::io::ErrorKind::Other, "short read"),
        );
        assert_eq!(chained.to_string(), "decode failed");
        assert!(chained.source().unwrap().to_string().contains("short read"));
        // Equality is by message, not by cause chain.
        assert_eq!(plain, chained);
        assert_ne!(plain, BatchError::Cancelled);
        assert!(BatchError::Oom { needed_bytes: 1, cap_bytes: 2 }
            .to_string()
            .contains("OOM"));
    }
}
