//! Worker-side shard execution shared by the real backends: metered
//! decode → row-align → Δ → outcome, with accounting-based memory
//! control, cooperative cancellation, and an optional double-buffered
//! prefetch pipeline.
//!
//! # Prefetch pipeline
//!
//! Each pool worker may own a [`Prefetcher`]: a companion thread with a
//! depth-1 staged slot. While the worker aligns/diffs range *j*, the
//! companion reads and decodes range *j+1* into the slot; the worker's
//! `stall_ns` then shrinks from the full read+decode time to the
//! residual wait on the slot. Staged bytes are charged to the worker's
//! [`MemTracker`] **before** the read starts (an estimate from
//! [`TableSource::decoded_bytes_hint`], trued up via
//! [`MemGuard::adjust`] once the tables land), so accounted RSS — and
//! therefore the Eq. 4 envelope and the elastic-grant shrink path —
//! always covers in-flight prefetch. Staging is strictly opportunistic:
//! any failure (charge rejected, read error, slot superseded) falls
//! back to the synchronous read path, so prefetch can never introduce
//! an error the serial execution wouldn't produce.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::data::io::ReadScratch;
use crate::data::table::Table;
use crate::engine::delta::{process_shard_timed, ShardMemStats, ShardScratch};
use crate::engine::merge::Merger;
use crate::engine::verdict::BatchOutcome;
use crate::exec::backend::{BatchError, JobContext, ShardSpec, StageNanos};
use crate::exec::partition::{occ_cut_at, run_occ_total, upper_bound_key_occ_in};

/// Shared accounting for a memory pool (job-wide for inmem; per-worker
/// for the dask-like backend). Exceeding the cap is the OOM failure the
/// scheduler's safety envelope must prevent.
#[derive(Debug)]
pub struct MemTracker {
    current: AtomicU64,
    peak: AtomicU64,
    cap: AtomicU64,
}

impl MemTracker {
    pub fn new(cap_bytes: u64) -> Arc<Self> {
        Arc::new(MemTracker {
            current: AtomicU64::new(0),
            peak: AtomicU64::new(0),
            cap: AtomicU64::new(cap_bytes),
        })
    }
    pub fn set_cap(&self, cap_bytes: u64) {
        self.cap.store(cap_bytes, Ordering::Relaxed);
    }
    pub fn cap(&self) -> u64 {
        self.cap.load(Ordering::Relaxed)
    }
    pub fn current(&self) -> u64 {
        self.current.load(Ordering::Relaxed)
    }
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Account `bytes`; Err(Oom) if it would exceed the cap.
    pub fn alloc(self: &Arc<Self>, bytes: u64) -> Result<MemGuard, BatchError> {
        let prev = self.current.fetch_add(bytes, Ordering::Relaxed);
        let now = prev + bytes;
        if now > self.cap.load(Ordering::Relaxed) {
            self.current.fetch_sub(bytes, Ordering::Relaxed);
            return Err(BatchError::Oom {
                needed_bytes: now,
                cap_bytes: self.cap.load(Ordering::Relaxed),
            });
        }
        self.peak.fetch_max(now, Ordering::Relaxed);
        Ok(MemGuard { tracker: Arc::clone(self), bytes })
    }
}

/// RAII release of accounted bytes.
pub struct MemGuard {
    tracker: Arc<MemTracker>,
    bytes: u64,
}

impl MemGuard {
    /// Re-size the accounted charge in place (the prefetcher charges an
    /// estimate before reading, then trues it up to the decoded size).
    /// A grow is checked against the cap exactly like `alloc` — on
    /// Err(Oom) the original charge stays in force; a shrink always
    /// succeeds.
    pub fn adjust(&mut self, new_bytes: u64) -> Result<(), BatchError> {
        if new_bytes > self.bytes {
            let grow = new_bytes - self.bytes;
            let prev = self.tracker.current.fetch_add(grow, Ordering::Relaxed);
            let now = prev + grow;
            if now > self.tracker.cap.load(Ordering::Relaxed) {
                self.tracker.current.fetch_sub(grow, Ordering::Relaxed);
                return Err(BatchError::Oom {
                    needed_bytes: now,
                    cap_bytes: self.tracker.cap.load(Ordering::Relaxed),
                });
            }
            self.tracker.peak.fetch_max(now, Ordering::Relaxed);
        } else {
            self.tracker
                .current
                .fetch_sub(self.bytes - new_bytes, Ordering::Relaxed);
        }
        self.bytes = new_bytes;
        Ok(())
    }
}

impl Drop for MemGuard {
    fn drop(&mut self) {
        self.tracker.current.fetch_sub(self.bytes, Ordering::Relaxed);
    }
}

/// Cooperative cancellation set (straggler speculation).
#[derive(Debug, Default)]
pub struct CancelSet {
    inner: Mutex<HashSet<u64>>,
}

impl CancelSet {
    pub fn new() -> Arc<Self> {
        Arc::new(CancelSet::default())
    }
    pub fn cancel(&self, shard_id: u64) {
        // lint: allow(unwrap) cancel-set sections are single HashSet
        // ops that cannot panic, so the mutex cannot be poisoned
        self.inner.lock().unwrap().insert(shard_id);
    }
    pub fn is_cancelled(&self, shard_id: u64) -> bool {
        // lint: allow(unwrap) poison unreachable (see cancel)
        self.inner.lock().unwrap().contains(&shard_id)
    }
    pub fn clear(&self, shard_id: u64) {
        // lint: allow(unwrap) poison unreachable (see cancel)
        self.inner.lock().unwrap().remove(&shard_id);
    }
}

/// One key-aligned range pair — the unit the prefetch pipeline stages
/// (a whole shard for the inmem backend, a sub-chunk for dasklike).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RangeSpec {
    pub a_off: usize,
    pub a_len: usize,
    pub b_off: usize,
    pub b_len: usize,
}

/// Telemetry hold on the pool-level staged-bytes gauge: adds on
/// construction, subtracts on drop, so the gauge tracks exactly the
/// bytes sitting in Ready slots.
struct GaugeHold {
    gauge: Arc<AtomicU64>,
    bytes: u64,
}

impl GaugeHold {
    fn new(gauge: Arc<AtomicU64>, bytes: u64) -> Self {
        gauge.fetch_add(bytes, Ordering::Relaxed);
        GaugeHold { gauge, bytes }
    }
}

impl Drop for GaugeHold {
    fn drop(&mut self) {
        self.gauge.fetch_sub(self.bytes, Ordering::Relaxed);
    }
}

/// A decoded range pair staged by the prefetcher. Holds the tracker
/// charge (`guard`) for its decode buffers until consumed or dropped.
struct StagedRange {
    range: RangeSpec,
    a_tbl: Table,
    b_tbl: Table,
    guard: MemGuard,
    /// Decoded heap bytes (the batch's `io_bytes` metric).
    io_bytes: u64,
    read_ns: u64,
    decode_ns: u64,
    _hold: GaugeHold,
}

/// Depth-1 staged-slot state machine shared between a worker and its
/// companion prefetch thread.
enum SlotState {
    Idle,
    /// Worker asked for a range; companion hasn't picked it up yet.
    Requested(RangeSpec),
    /// Companion is reading/decoding this range right now.
    Loading(RangeSpec),
    /// Staged and charged; waiting to be consumed.
    Ready(Box<StagedRange>),
    /// Staging failed (charge rejected or read error): the worker must
    /// fall back to the synchronous path, which reproduces the error
    /// typed — or succeeds, if the failure was a transient charge race.
    Failed(RangeSpec),
    Shutdown,
}

struct SlotSync {
    state: Mutex<SlotState>,
    cv: Condvar,
}

/// Per-worker double-buffer prefetcher: one companion thread, one
/// staged slot. See the module docs for the accounting rules.
pub struct Prefetcher {
    slot: Arc<SlotSync>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn the companion thread. `tracker` must be the same ledger the
    /// owning worker executes against (staged bytes count toward the
    /// same cap); `staged_gauge` is the pool-level telemetry gauge.
    pub fn spawn(
        ctx: Arc<JobContext>,
        tracker: Arc<MemTracker>,
        staged_gauge: Arc<AtomicU64>,
    ) -> Prefetcher {
        let slot = Arc::new(SlotSync {
            state: Mutex::new(SlotState::Idle),
            cv: Condvar::new(),
        });
        let thread_slot = Arc::clone(&slot);
        let handle = std::thread::Builder::new()
            .name("sdiff-prefetch".into())
            .spawn(move || prefetch_loop(ctx, tracker, thread_slot, staged_gauge))
            .ok();
        if handle.is_none() {
            // No companion thread: park the slot in Shutdown so
            // request/consume/drain all no-op instead of waiting on a
            // state transition that will never come.
            // lint: allow(unwrap) slot-state sections only move the
            // enum and clone ranges; a poisoned slot means the state
            // machine is torn mid-transition — fail fast
            *slot.state.lock().unwrap() = SlotState::Shutdown;
        }
        Prefetcher { slot, handle }
    }

    /// Ask the companion to stage `range`. Supersedes any stale slot
    /// content; a no-op if `range` is already staged or in flight.
    pub fn request(&self, range: RangeSpec) {
        {
            // lint: allow(unwrap) slot poison ⇒ fail fast (see new)
            let mut st = self.slot.state.lock().unwrap();
            match &*st {
                SlotState::Shutdown => return,
                SlotState::Ready(s) if s.range == range => return,
                SlotState::Requested(r) | SlotState::Loading(r)
                    if *r == range =>
                {
                    return
                }
                // Overwriting Loading(other) is safe: the companion
                // re-checks the state after its read and drops a result
                // the slot no longer wants.
                _ => *st = SlotState::Requested(range),
            }
        }
        self.slot.cv.notify_all();
    }

    /// Take `range` out of the slot, waiting out an in-flight load of
    /// it. Returns the staged pair (None on miss/failure — caller reads
    /// synchronously) and the nanoseconds this call blocked (the
    /// worker's residual `stall_ns` for a prefetched range).
    fn consume(&self, range: &RangeSpec) -> (Option<Box<StagedRange>>, u64) {
        let t0 = std::time::Instant::now();
        // lint: allow(unwrap) slot poison ⇒ fail fast (see new)
        let mut st = self.slot.state.lock().unwrap();
        loop {
            match &*st {
                SlotState::Requested(r) | SlotState::Loading(r)
                    if r == range =>
                {
                    // lint: allow(unwrap) cv errs only on slot poison
                    st = self.slot.cv.wait(st).unwrap();
                }
                SlotState::Ready(s) if s.range == *range => {
                    let SlotState::Ready(s) =
                        std::mem::replace(&mut *st, SlotState::Idle)
                    else {
                        unreachable!()
                    };
                    drop(st);
                    self.slot.cv.notify_all();
                    return (Some(s), t0.elapsed().as_nanos() as u64);
                }
                SlotState::Shutdown => {
                    return (None, t0.elapsed().as_nanos() as u64);
                }
                // Stale content (wrong range staged/failed/in flight) or
                // an idle slot: clear and miss. A load of another range
                // still running will see the state change back to Idle
                // after its read and drop its result (and charge).
                _ => {
                    *st = SlotState::Idle;
                    drop(st);
                    self.slot.cv.notify_all();
                    return (None, t0.elapsed().as_nanos() as u64);
                }
            }
        }
    }

    /// Empty the slot, waiting out any in-flight load, and release its
    /// charge. After this returns the prefetcher holds zero accounted
    /// bytes (the grant-shrink / OOM-retry path).
    pub fn drain(&self) {
        // lint: allow(unwrap) slot poison ⇒ fail fast (see new)
        let mut st = self.slot.state.lock().unwrap();
        loop {
            match &*st {
                SlotState::Loading(_) => {
                    // lint: allow(unwrap) cv errs only on slot poison
                    st = self.slot.cv.wait(st).unwrap();
                }
                SlotState::Shutdown => return,
                _ => {
                    *st = SlotState::Idle;
                    drop(st);
                    self.slot.cv.notify_all();
                    return;
                }
            }
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        {
            // lint: allow(unwrap) slot poison ⇒ fail fast (see new)
            let mut st = self.slot.state.lock().unwrap();
            *st = SlotState::Shutdown;
        }
        self.slot.cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Companion-thread body: wait for a request, stage it, publish.
fn prefetch_loop(
    ctx: Arc<JobContext>,
    tracker: Arc<MemTracker>,
    slot: Arc<SlotSync>,
    gauge: Arc<AtomicU64>,
) {
    let mut scratch = ReadScratch::default();
    loop {
        let range = {
            // lint: allow(unwrap) slot poison ⇒ fail fast (see new)
            let mut st = slot.state.lock().unwrap();
            loop {
                match &*st {
                    SlotState::Shutdown => return,
                    SlotState::Requested(r) => {
                        let r = *r;
                        *st = SlotState::Loading(r);
                        break r;
                    }
                    // lint: allow(unwrap) cv errs only on slot poison
                    _ => st = slot.cv.wait(st).unwrap(),
                }
            }
        };
        let staged = stage(&ctx, &tracker, range, &mut scratch, &gauge);
        {
            // lint: allow(unwrap) slot poison ⇒ fail fast (see new)
            let mut st = slot.state.lock().unwrap();
            match &*st {
                SlotState::Shutdown => return,
                // Only publish if the slot still wants this range; a
                // supersede/drain while we read means the result (and
                // its charge) is dropped right here.
                SlotState::Loading(r) if *r == range => {
                    *st = match staged {
                        Some(s) => SlotState::Ready(s),
                        None => SlotState::Failed(range),
                    };
                }
                _ => {}
            }
        }
        slot.cv.notify_all();
    }
}

/// Read+decode one range with charge-before-read accounting. None on
/// any failure — staging is opportunistic; the worker's synchronous
/// path is the authority on errors.
fn stage(
    ctx: &JobContext,
    tracker: &Arc<MemTracker>,
    range: RangeSpec,
    scratch: &mut ReadScratch,
    gauge: &Arc<AtomicU64>,
) -> Option<Box<StagedRange>> {
    // Charge the estimate BEFORE the bytes land: a grant shrink or a
    // busy ledger rejects the prefetch here, before any I/O.
    let est = ctx.a.decoded_bytes_hint(range.a_off, range.a_len)
        + ctx.b.decoded_bytes_hint(range.b_off, range.b_len);
    let mut guard = tracker.alloc(est.max(1)).ok()?;
    let a_tbl = ctx.a.read_range_with(range.a_off, range.a_len, scratch).ok()?;
    let (mut read_ns, mut decode_ns) = (scratch.read_ns, scratch.decode_ns);
    let b_tbl = ctx.b.read_range_with(range.b_off, range.b_len, scratch).ok()?;
    read_ns += scratch.read_ns;
    decode_ns += scratch.decode_ns;
    // True the charge up to the decoded size (the estimate only had to
    // be the right order of magnitude).
    let actual = (a_tbl.heap_bytes() + b_tbl.heap_bytes()) as u64;
    guard.adjust(actual.max(1)).ok()?;
    Some(Box::new(StagedRange {
        range,
        a_tbl,
        b_tbl,
        guard,
        io_bytes: actual,
        read_ns,
        decode_ns,
        _hold: GaugeHold::new(Arc::clone(gauge), actual),
    }))
}

/// Result of executing one shard on a worker.
pub struct ShardExecResult {
    pub result: Result<BatchOutcome, BatchError>,
    pub mem: ShardMemStats,
    pub peak_bytes: u64,
    pub io_bytes: u64,
    /// Summed pipeline-stage times over the shard's ranges.
    pub stages: StageNanos,
}

/// Execute one key-aligned range pair with full accounting, reusing the
/// caller's per-worker Δ scratch. When `prefetch` is set, the staged
/// slot is consulted for this range, and `next` (if any) is requested
/// into the slot before compute starts — that request-then-compute
/// ordering is the pipeline overlap.
#[allow(clippy::too_many_arguments)]
fn execute_range(
    ctx: &JobContext,
    shard_id: u64,
    range: RangeSpec,
    tracker: &Arc<MemTracker>,
    scratch: &mut ShardScratch,
    read_scratch: &mut ReadScratch,
    prefetch: Option<&Prefetcher>,
    next: Option<RangeSpec>,
) -> Result<(BatchOutcome, ShardMemStats, u64, StageNanos), BatchError> {
    let RangeSpec { a_off, a_len, b_off, b_len } = range;
    let mut stages = StageNanos::default();
    let staged = prefetch.and_then(|p| {
        let (s, wait_ns) = p.consume(&range);
        // Residual wait on the in-flight load (0 for a slot that was
        // already Ready, the full load time when compute finished first).
        stages.stall_ns += wait_ns;
        s
    });
    let (a_tbl, b_tbl, _decode_guard, decode_bytes) = match staged {
        Some(s) => {
            let StagedRange {
                a_tbl,
                b_tbl,
                guard,
                io_bytes,
                read_ns,
                decode_ns,
                ..
            } = *s;
            stages.read_ns += read_ns;
            stages.decode_ns += decode_ns;
            (a_tbl, b_tbl, guard, io_bytes)
        }
        None => {
            // Synchronous path (prefetch off, miss, or staging failed):
            // the worker stalls for the whole read+decode. Buffers are
            // accounted as soon as they exist; an estimate-first
            // reservation would hide the real number. Read failures
            // (malformed rows, short reads, transient I/O) are typed
            // batch failures — the scheduler retries once, then fails
            // the job with the cause chain — never worker panics.
            let a_tbl =
                ctx.a.read_range_with(a_off, a_len, read_scratch).map_err(
                    |e| {
                        BatchError::failed_with(
                            format!("read A rows {a_off}..{}", a_off + a_len),
                            e,
                        )
                    },
                )?;
            stages.read_ns += read_scratch.read_ns;
            stages.decode_ns += read_scratch.decode_ns;
            let b_tbl =
                ctx.b.read_range_with(b_off, b_len, read_scratch).map_err(
                    |e| {
                        BatchError::failed_with(
                            format!("read B rows {b_off}..{}", b_off + b_len),
                            e,
                        )
                    },
                )?;
            stages.read_ns += read_scratch.read_ns;
            stages.decode_ns += read_scratch.decode_ns;
            stages.stall_ns += stages.read_ns + stages.decode_ns;
            let decode_bytes = (a_tbl.heap_bytes() + b_tbl.heap_bytes()) as u64;
            let guard = tracker.alloc(decode_bytes)?;
            (a_tbl, b_tbl, guard, decode_bytes)
        }
    };
    // Input for this range is in hand and the slot is free: kick off the
    // next range's load so it overlaps the align+diff below.
    if let (Some(p), Some(n)) = (prefetch, next) {
        p.request(n);
    }

    let (outcome, mem, align_ns, diff_ns) = process_shard_timed(
        shard_id, &a_tbl, &b_tbl, &ctx.plan, &ctx.exec, scratch,
    )
    .map_err(BatchError::failed)?;
    stages.align_ns += align_ns;
    stages.diff_ns += diff_ns;
    // Alignment state + Δ scratch live in the reusable per-worker
    // scratch; account them post-hoc against the peak for the window
    // where they coexist with the decode buffers. Between shards the
    // warmed scratch stays resident in the worker (bounded by one
    // shard's scratch per worker) — that idle residency is deliberately
    // outside the per-batch ledger; see the ownership notes in
    // `engine::delta::ShardScratch`.
    let transient = (mem.align_bytes + mem.scratch_bytes) as u64;
    let _transient_guard = tracker.alloc(transient)?;
    Ok((outcome, mem, decode_bytes, stages))
}

/// Execute a shard. `chunk_rows` — if set, the shard is internally
/// re-partitioned into key-aligned sub-chunks processed sequentially
/// (the dask-like backend's finer task granularity: lower peak memory,
/// more per-task overhead); None processes the shard in one piece
/// (inmem).
pub fn execute_shard(
    ctx: &JobContext,
    spec: ShardSpec,
    tracker: &Arc<MemTracker>,
    cancel: &Arc<CancelSet>,
    chunk_rows: Option<usize>,
) -> ShardExecResult {
    let mut scratch = ShardScratch::default();
    let mut read_scratch = ReadScratch::default();
    execute_shard_with(
        ctx,
        spec,
        tracker,
        cancel,
        chunk_rows,
        &mut scratch,
        &mut read_scratch,
        None,
        None,
    )
}

/// Execute a shard reusing per-worker Δ and read scratch. Worker
/// threads keep one `ShardScratch`/`ReadScratch` alive across shards
/// (see `pool::worker_loop`) so steady-state execution performs no
/// scratch allocation; `execute_shard` is the throwaway-scratch
/// convenience wrapper.
///
/// With `prefetch` set, ranges pipeline through the staged slot: range
/// j+1 loads while range j computes, and `next_hint` (the first range
/// of the worker's next claimed task) extends the overlap across shard
/// boundaries. An accounted OOM with an active prefetcher is retried
/// once after draining the slot — the staged charge may be exactly what
/// pushed the ledger over, and the serial path must remain the
/// authority on whether a shard truly fits.
#[allow(clippy::too_many_arguments)]
pub fn execute_shard_with(
    ctx: &JobContext,
    spec: ShardSpec,
    tracker: &Arc<MemTracker>,
    cancel: &Arc<CancelSet>,
    chunk_rows: Option<usize>,
    scratch: &mut ShardScratch,
    read_scratch: &mut ReadScratch,
    prefetch: Option<&Prefetcher>,
    next_hint: Option<RangeSpec>,
) -> ShardExecResult {
    let peak_before = tracker.peak();
    let mut io_bytes = 0u64;
    let mut mem_total = ShardMemStats::default();
    let mut stages_total = StageNanos::default();

    if cancel.is_cancelled(spec.shard_id) {
        return ShardExecResult {
            result: Err(BatchError::Cancelled),
            mem: mem_total,
            peak_bytes: 0,
            io_bytes: 0,
            stages: stages_total,
        };
    }

    // Cross-shard duplicate-alignment contract: the spec's occurrence
    // bases must match the source index, and a run straddling the shard
    // start on *both* sides must resume at equal bases — that equality
    // is what makes the engine's local positional pairing bit-identical
    // to the solo-shard pairing (see `exec/partition.rs`).
    #[cfg(debug_assertions)]
    if spec.a_len > 0 && spec.b_len > 0 {
        debug_assert_eq!(spec.a_occ_base, ctx.a.occ_at(spec.a_offset));
        debug_assert_eq!(spec.b_occ_base, ctx.b.occ_at(spec.b_offset));
        let ka = ctx.a.key_at(spec.a_offset);
        debug_assert!(
            ka.is_none()
                || ka != ctx.b.key_at(spec.b_offset)
                || spec.a_occ_base == spec.b_occ_base,
            "straddling key run with unequal occurrence bases: {spec:?}"
        );
    }
    // Carved added-range shard (`a_len = 0`): its rows never pair, but
    // the B base must still track the source index so any further
    // splitting resumes consistently.
    #[cfg(debug_assertions)]
    if spec.a_len == 0 && spec.b_len > 0 {
        debug_assert_eq!(spec.b_occ_base, ctx.b.occ_at(spec.b_offset));
    }

    // Unified range list: one range for the whole shard (inmem), or the
    // (key, occurrence)-aligned sub-chunks (dasklike). Sub-chunk
    // boundaries need the key spans: consult the source's key index
    // (cheap) rather than decoding the whole shard at once — that is
    // the point of chunking.
    let chunked = chunk_rows.is_some();
    let ranges: Vec<RangeSpec> = match chunk_rows {
        None => vec![RangeSpec {
            a_off: spec.a_offset,
            a_len: spec.a_len,
            b_off: spec.b_offset,
            b_len: spec.b_len,
        }],
        Some(chunk) => sub_partition(ctx, &spec, chunk)
            .into_iter()
            .map(|((a_off, a_len), (b_off, b_len))| RangeSpec {
                a_off,
                a_len,
                b_off,
                b_len,
            })
            .collect(),
    };

    let result: Result<BatchOutcome, BatchError> = (|| {
        let mut merger = Merger::new();
        let n = ranges.len();
        for (j, r) in ranges.iter().enumerate() {
            if j > 0 && cancel.is_cancelled(spec.shard_id) {
                return Err(BatchError::Cancelled);
            }
            // While range j computes, range j+1 loads; on the last
            // range the hint extends the pipeline into the next task.
            let next = if j + 1 < n { Some(ranges[j + 1]) } else { next_hint };
            let attempt = execute_range(
                ctx,
                spec.shard_id,
                *r,
                tracker,
                scratch,
                read_scratch,
                prefetch,
                next,
            );
            let (outcome, mem, io, st) = match attempt {
                Err(BatchError::Oom { .. }) if prefetch.is_some() => {
                    // The staged slot may hold the very bytes that
                    // pushed this range over the cap: drain it and
                    // retry once, fully synchronously, so prefetch
                    // never manufactures an OOM the serial path
                    // wouldn't hit.
                    // lint: allow(unwrap) this arm is guarded by
                    // `prefetch.is_some()` two lines up
                    prefetch.unwrap().drain();
                    execute_range(
                        ctx,
                        spec.shard_id,
                        *r,
                        tracker,
                        scratch,
                        read_scratch,
                        None,
                        None,
                    )?
                }
                other => other?,
            };
            io_bytes += io;
            stages_total.add(&st);
            // Peak is the max over chunks, not the sum — buffers are
            // freed between chunks.
            mem_total.decode_bytes = mem_total.decode_bytes.max(mem.decode_bytes);
            mem_total.align_bytes = mem_total.align_bytes.max(mem.align_bytes);
            mem_total.scratch_bytes =
                mem_total.scratch_bytes.max(mem.scratch_bytes);
            if !chunked {
                // Single whole-shard range: the outcome passes through
                // unmerged (diff-key order preserved bit-identically).
                return Ok(outcome);
            }
            merger.push(outcome);
        }
        // Collapse the merged sub-chunks back into a single
        // BatchOutcome for this shard.
        Ok(collapse(spec.shard_id, merger.finish()))
    })();

    if result.is_err() {
        // Never leave staged bytes behind a failed/cancelled shard: the
        // pool's invariant is that a worker with no claimed next task
        // holds zero staged bytes after its report.
        if let Some(p) = prefetch {
            p.drain();
        }
    }

    ShardExecResult {
        result,
        mem: mem_total,
        peak_bytes: tracker.peak().saturating_sub(peak_before),
        io_bytes,
        stages: stages_total,
    }
}

/// (Key, occurrence)-aligned sub-ranges of a shard, consulting the
/// source key/occurrence indexes. Chunk cuts may land inside a
/// duplicate-key run: the B boundary then stops at the A cut's
/// occurrence ordinal (same rule as `Partitioner` and the straggler
/// splitter), so every sub-chunk is bounded by `chunk` A rows — even
/// when one key's run spans the whole shard — and local positional
/// pairing inside each sub-chunk equals the global pairing.
fn sub_partition(
    ctx: &JobContext,
    spec: &ShardSpec,
    chunk: usize,
) -> Vec<((usize, usize), (usize, usize))> {
    if spec.a_len == 0 || spec.b_len == 0 || ctx.a.key_at(0).is_none() {
        // Degenerate: chunk positionally via the table splitter on a
        // decoded copy would defeat the purpose; just split ranges.
        let mut out = Vec::new();
        let (mut ap, mut bp) = (0usize, 0usize);
        while ap < spec.a_len || bp < spec.b_len {
            let al = chunk.min(spec.a_len - ap);
            let bl = if spec.a_len == 0 {
                // Carved added-range (or keyless empty-A) shard: every
                // row is pure Added, so positional chunking is safe —
                // and required, or a split/shrunk carved shard would
                // decode its whole B side at once.
                chunk.min(spec.b_len - bp)
            } else if ap + al >= spec.a_len {
                spec.b_len - bp
            } else {
                chunk.min(spec.b_len - bp)
            };
            out.push((
                (spec.a_offset + ap, al),
                (spec.b_offset + bp, bl),
            ));
            ap += al;
            bp += bl;
        }
        return out;
    }
    let mut out = Vec::new();
    let (mut ap, mut bp) = (spec.a_offset, spec.b_offset);
    let a_end = spec.a_offset + spec.a_len;
    let b_end = spec.b_offset + spec.b_len;
    while ap < a_end {
        let al = chunk.min(a_end - ap);
        let b_hi = if ap + al >= a_end {
            last_chunk_b_hi(ctx, a_end, bp, b_end, chunk)
        } else {
            let last = ap + al - 1;
            let boundary = ctx.a.key_at(last).unwrap_or(i64::MAX);
            let (occ_cut, _) = occ_cut_at(ctx.a.as_ref(), last, boundary);
            upper_bound_key_occ_in(ctx.b.as_ref(), bp, b_end, boundary, occ_cut)
        };
        out.push(((ap, al), (bp, b_hi - bp)));
        ap += al;
        bp = b_hi;
    }
    // Trailing B rows past the last A cut (a carved shard's surplus or
    // a split remainder): drain them in chunk-bounded added-ranges so
    // the working set stays bounded by `chunk` even here.
    while bp < b_end {
        let bl = chunk.min(b_end - bp);
        out.push(((a_end, 0), (bp, bl)));
        bp += bl;
    }
    out
}

/// B bound for a shard's *final* A chunk: absorb the trailing B rows
/// past the boundary key's pairing bound (pure surplus — the shard only
/// holds them because an absorbing partitioner arm included them) when
/// they fit in one chunk, else stop at the pairing bound so the caller
/// drains them in chunk-bounded added-ranges. Mirrors the partitioner's
/// completed-run / last-shard clamp, keeping every sub-chunk's working
/// set bounded by `chunk` even inside an absorbed-surplus shard.
fn last_chunk_b_hi(
    ctx: &JobContext,
    a_end: usize,
    bp: usize,
    b_end: usize,
    chunk: usize,
) -> usize {
    let Some(boundary) = ctx.a.key_at(a_end - 1) else {
        return b_end;
    };
    let total = run_occ_total(ctx.a.as_ref(), a_end - 1, boundary);
    let pair_hi =
        upper_bound_key_occ_in(ctx.b.as_ref(), bp, b_end, boundary, total);
    if b_end - pair_hi > chunk {
        pair_hi
    } else {
        b_end
    }
}

/// The first range `execute_shard_with` will request for `spec` — used
/// by the pool's claim-ahead path to stage the next shard's opening
/// read while the current shard computes. Mirrors `sub_partition`'s
/// first cut without materializing the whole cut list; a drifted hint
/// is never consumed (the worker falls back to the synchronous read),
/// so a mismatch costs overlap, not correctness.
pub fn first_range(
    ctx: &JobContext,
    spec: &ShardSpec,
    chunk_rows: Option<usize>,
) -> RangeSpec {
    let whole = RangeSpec {
        a_off: spec.a_offset,
        a_len: spec.a_len,
        b_off: spec.b_offset,
        b_len: spec.b_len,
    };
    let Some(chunk) = chunk_rows else { return whole };
    if spec.a_len == 0 || spec.b_len == 0 || ctx.a.key_at(0).is_none() {
        if spec.a_len == 0 && spec.b_len == 0 {
            return whole; // sub_partition yields no ranges; hint is inert
        }
        let al = chunk.min(spec.a_len);
        let bl = if spec.a_len == 0 {
            chunk.min(spec.b_len) // carved added-range: chunk-bounded
        } else if al >= spec.a_len {
            spec.b_len
        } else {
            chunk.min(spec.b_len)
        };
        return RangeSpec {
            a_off: spec.a_offset,
            a_len: al,
            b_off: spec.b_offset,
            b_len: bl,
        };
    }
    let a_end = spec.a_offset + spec.a_len;
    let b_end = spec.b_offset + spec.b_len;
    let (ap, bp) = (spec.a_offset, spec.b_offset);
    let al = chunk.min(a_end - ap);
    let b_hi = if ap + al >= a_end {
        last_chunk_b_hi(ctx, a_end, bp, b_end, chunk)
    } else {
        let last = ap + al - 1;
        let boundary = ctx.a.key_at(last).unwrap_or(i64::MAX);
        let (occ_cut, _) = occ_cut_at(ctx.a.as_ref(), last, boundary);
        upper_bound_key_occ_in(ctx.b.as_ref(), bp, b_end, boundary, occ_cut)
    };
    RangeSpec {
        a_off: ap,
        a_len: al,
        b_off: bp,
        b_len: b_hi - bp,
    }
}

/// Collapse a merged multi-chunk report back into one BatchOutcome.
fn collapse(shard_id: u64, report: crate::engine::merge::JobReport) -> BatchOutcome {
    BatchOutcome {
        shard_id,
        rows_a: report.rows_a,
        rows_b: report.rows_b,
        cells: report.cells,
        rows: report.rows,
        columns: report
            .columns
            .into_iter()
            .map(|(name, agg)| crate::engine::verdict::ColumnOutcome {
                name,
                changed: agg.changed,
                max_abs_delta: agg.max_abs_delta,
            })
            .collect(),
        diff_keys: report.diff_keys,
        diff_keys_truncated: report.diff_keys_truncated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;
    use crate::engine::comparators::NativeExec;
    use crate::engine::delta::JobPlan;
    use crate::engine::schema_align::align_schemas;

    fn ctx(rows: usize, seed: u64, cap: u64) -> Arc<JobContext> {
        let (a, b, _) = generate_pair(&GenSpec { rows, seed, ..GenSpec::default() });
        let aligned = align_schemas(&a.schema, &b.schema).unwrap();
        let plan = JobPlan::new(aligned, EngineConfig::default());
        JobContext::new(
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
            plan,
            Arc::new(NativeExec),
            cap,
        )
    }

    fn whole_shard(ctx: &JobContext) -> ShardSpec {
        ShardSpec {
            shard_id: 0,
            attempt: 0,
            a_offset: 0,
            a_len: ctx.a.nrows(),
            b_offset: 0,
            b_len: ctx.b.nrows(),
            a_occ_base: 0,
            b_occ_base: 0,
        }
    }

    #[test]
    fn memtracker_alloc_free_peak() {
        let t = MemTracker::new(100);
        let g1 = t.alloc(60).unwrap();
        assert_eq!(t.current(), 60);
        assert!(t.alloc(50).is_err()); // would exceed
        drop(g1);
        assert_eq!(t.current(), 0);
        assert_eq!(t.peak(), 60);
        let _g2 = t.alloc(100).unwrap();
        assert_eq!(t.peak(), 100);
    }

    #[test]
    fn chunked_equals_unchunked() {
        let c = ctx(3_000, 21, u64::MAX);
        let tracker = MemTracker::new(u64::MAX);
        let cancel = CancelSet::new();
        let spec = whole_shard(&c);
        let whole = execute_shard(&c, spec, &tracker, &cancel, None);
        let chunked = execute_shard(&c, spec, &tracker, &cancel, Some(257));
        let (w, ch) = (whole.result.unwrap(), chunked.result.unwrap());
        assert_eq!(w.cells, ch.cells);
        assert_eq!(w.rows, ch.rows);
        let mut wk = w.diff_keys.clone();
        wk.sort_unstable();
        assert_eq!(wk, ch.diff_keys); // chunked is pre-sorted by merger
    }

    #[test]
    fn chunked_single_run_shard_matches_whole() {
        // A single duplicate-key run spans the whole shard — the shape
        // run snapping could not sub-chunk at all. The occurrence-
        // bounded sub-chunker must bound every chunk by `chunk` A rows
        // (so peak memory drops) and produce the identical outcome.
        use crate::data::schema::{ColumnType, Field, Schema};
        use crate::data::table::TableBuilder;
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Int64),
        ]);
        let mk = |n: usize, bump: i64| {
            let mut tb = TableBuilder::new(schema.clone());
            for i in 0..n {
                tb.col(0).push_i64(7);
                tb.col(1).push_i64(i as i64 + bump);
            }
            tb.finish()
        };
        let a = mk(1_200, 0);
        let b = mk(900, 5); // shorter run; every pair's payload differs
        let aligned = align_schemas(&a.schema, &b.schema).unwrap();
        let plan = JobPlan::new(aligned, EngineConfig::default());
        let c = JobContext::new(
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
            plan,
            Arc::new(NativeExec),
            u64::MAX,
        );
        let cancel = CancelSet::new();
        let t1 = MemTracker::new(u64::MAX);
        let spec = whole_shard(&c);
        let whole = execute_shard(&c, spec, &t1, &cancel, None);
        let t2 = MemTracker::new(u64::MAX);
        let chunked = execute_shard(&c, spec, &t2, &cancel, Some(100));
        let (w, ch) = (whole.result.unwrap(), chunked.result.unwrap());
        assert_eq!(w.cells, ch.cells);
        assert_eq!(w.rows, ch.rows);
        assert_eq!(w.rows.aligned, 900);
        assert_eq!(w.rows.removed, 300);
        let mut wk = w.diff_keys.clone();
        wk.sort_unstable();
        assert_eq!(wk, ch.diff_keys); // chunked is pre-sorted by merger
        assert!(
            t2.peak() < t1.peak() / 2,
            "sub-chunking must bound peak inside a run: {} vs {}",
            t2.peak(),
            t1.peak()
        );
    }

    #[test]
    fn chunked_peak_memory_is_lower() {
        let c = ctx(5_000, 4, u64::MAX);
        let cancel = CancelSet::new();
        let t1 = MemTracker::new(u64::MAX);
        let whole = execute_shard(&c, whole_shard(&c), &t1, &cancel, None);
        let t2 = MemTracker::new(u64::MAX);
        let chunked =
            execute_shard(&c, whole_shard(&c), &t2, &cancel, Some(500));
        assert!(whole.result.is_ok() && chunked.result.is_ok());
        assert!(
            t2.peak() < t1.peak() / 2,
            "chunked peak {} vs whole {}",
            t2.peak(),
            t1.peak()
        );
    }

    #[test]
    fn oom_when_cap_too_small() {
        let c = ctx(2_000, 6, u64::MAX);
        let tracker = MemTracker::new(10_000); // absurdly small pool
        let cancel = CancelSet::new();
        let r = execute_shard(&c, whole_shard(&c), &tracker, &cancel, None);
        assert!(matches!(r.result, Err(BatchError::Oom { .. })));
    }

    #[test]
    fn cancellation_short_circuits() {
        let c = ctx(1_000, 7, u64::MAX);
        let tracker = MemTracker::new(u64::MAX);
        let cancel = CancelSet::new();
        cancel.cancel(0);
        let r = execute_shard(&c, whole_shard(&c), &tracker, &cancel, None);
        assert!(matches!(r.result, Err(BatchError::Cancelled)));
        assert_eq!(r.io_bytes, 0);
    }

    #[test]
    fn io_bytes_reported() {
        let c = ctx(1_000, 8, u64::MAX);
        let tracker = MemTracker::new(u64::MAX);
        let cancel = CancelSet::new();
        let r = execute_shard(&c, whole_shard(&c), &tracker, &cancel, None);
        assert!(r.io_bytes > 0);
        assert!(r.peak_bytes > 0);
        // The serial path books the full read+decode as worker stall.
        assert_eq!(
            r.stages.stall_ns,
            r.stages.read_ns + r.stages.decode_ns
        );
        assert_eq!(r.stages.overlap_ratio(), 0.0);
        assert!(r.stages.diff_ns > 0);
    }

    #[test]
    fn memguard_adjust_grow_and_shrink() {
        let t = MemTracker::new(100);
        let mut g = t.alloc(10).unwrap();
        g.adjust(80).unwrap();
        assert_eq!(t.current(), 80);
        // Failed grow leaves the original charge in force.
        assert!(g.adjust(150).is_err());
        assert_eq!(t.current(), 80);
        g.adjust(5).unwrap();
        assert_eq!(t.current(), 5);
        drop(g);
        assert_eq!(t.current(), 0);
        assert_eq!(t.peak(), 80);
    }

    #[test]
    fn prefetched_shard_matches_serial() {
        let c = ctx(3_000, 21, u64::MAX);
        let tracker = MemTracker::new(u64::MAX);
        let cancel = CancelSet::new();
        let spec = whole_shard(&c);
        let serial = execute_shard(&c, spec, &tracker, &cancel, None);

        let gauge = Arc::new(AtomicU64::new(0));
        let pf = Prefetcher::spawn(
            Arc::clone(&c),
            Arc::clone(&tracker),
            Arc::clone(&gauge),
        );
        // Stage the shard's whole range ahead of time, then execute
        // with the prefetcher: bit-identical outcome, same io_bytes.
        pf.request(RangeSpec {
            a_off: 0,
            a_len: c.a.nrows(),
            b_off: 0,
            b_len: c.b.nrows(),
        });
        let mut scratch = ShardScratch::default();
        let mut rs = ReadScratch::default();
        let pre = execute_shard_with(
            &c,
            spec,
            &tracker,
            &cancel,
            None,
            &mut scratch,
            &mut rs,
            Some(&pf),
            None,
        );
        assert_eq!(serial.result.unwrap(), pre.result.unwrap());
        assert_eq!(serial.io_bytes, pre.io_bytes);
        assert!(pre.stages.read_ns + pre.stages.decode_ns > 0);
        drop(pf);
        assert_eq!(gauge.load(Ordering::Relaxed), 0, "gauge drained");
        assert_eq!(tracker.current(), 0, "all charges released");
    }

    #[test]
    fn chunked_prefetch_matches_serial() {
        let c = ctx(3_000, 21, u64::MAX);
        let cancel = CancelSet::new();
        let spec = whole_shard(&c);
        let t1 = MemTracker::new(u64::MAX);
        let serial = execute_shard(&c, spec, &t1, &cancel, Some(257));
        let t2 = MemTracker::new(u64::MAX);
        let gauge = Arc::new(AtomicU64::new(0));
        let pf =
            Prefetcher::spawn(Arc::clone(&c), Arc::clone(&t2), Arc::clone(&gauge));
        let mut scratch = ShardScratch::default();
        let mut rs = ReadScratch::default();
        let pre = execute_shard_with(
            &c,
            spec,
            &t2,
            &cancel,
            Some(257),
            &mut scratch,
            &mut rs,
            Some(&pf),
            None,
        );
        assert_eq!(serial.result.unwrap(), pre.result.unwrap());
        assert_eq!(serial.io_bytes, pre.io_bytes);
        drop(pf);
        assert_eq!(t2.current(), 0);
        assert_eq!(gauge.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn drain_releases_staged_charge() {
        let c = ctx(2_000, 9, u64::MAX);
        let tracker = MemTracker::new(u64::MAX);
        let gauge = Arc::new(AtomicU64::new(0));
        let pf = Prefetcher::spawn(
            Arc::clone(&c),
            Arc::clone(&tracker),
            Arc::clone(&gauge),
        );
        pf.request(RangeSpec { a_off: 0, a_len: 1_000, b_off: 0, b_len: 1_000 });
        // Wait for the companion to stage (bounded spin).
        for _ in 0..2_000 {
            if gauge.load(Ordering::Relaxed) > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(gauge.load(Ordering::Relaxed) > 0, "range staged");
        assert!(tracker.current() > 0, "staged bytes charged to tracker");
        pf.drain();
        assert_eq!(gauge.load(Ordering::Relaxed), 0, "drain empties gauge");
        assert_eq!(tracker.current(), 0, "drain releases the charge");
    }

    #[test]
    fn oom_caused_by_staged_slot_is_retried_after_drain() {
        // Cap fits ONE shard's buffers but not shard + staged slot: with
        // the slot pre-loaded for a stale range, execution must drain
        // and succeed rather than OOM.
        let c = ctx(2_000, 6, u64::MAX);
        let cancel = CancelSet::new();
        // Find the serial peak first, then set the cap just above it.
        let probe = MemTracker::new(u64::MAX);
        let serial = execute_shard(&c, whole_shard(&c), &probe, &cancel, None);
        let serial_out = serial.result.unwrap();
        let cap = probe.peak() + probe.peak() / 4;
        let tracker = MemTracker::new(cap);
        let gauge = Arc::new(AtomicU64::new(0));
        let pf = Prefetcher::spawn(
            Arc::clone(&c),
            Arc::clone(&tracker),
            Arc::clone(&gauge),
        );
        // Stage a big stale range the shard will never consume.
        pf.request(RangeSpec { a_off: 0, a_len: 1_500, b_off: 0, b_len: 1_500 });
        for _ in 0..2_000 {
            if gauge.load(Ordering::Relaxed) > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let mut scratch = ShardScratch::default();
        let mut rs = ReadScratch::default();
        let r = execute_shard_with(
            &c,
            whole_shard(&c),
            &tracker,
            &cancel,
            None,
            &mut scratch,
            &mut rs,
            Some(&pf),
            None,
        );
        assert_eq!(r.result.unwrap(), serial_out, "retry after drain");
        assert!(tracker.peak() <= cap, "never exceeded the cap");
    }
}
