//! Worker-side shard execution shared by the real backends: metered
//! decode → row-align → Δ → outcome, with accounting-based memory
//! control and cooperative cancellation.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::delta::{process_shard_with, ShardMemStats, ShardScratch};
use crate::engine::merge::Merger;
use crate::engine::verdict::BatchOutcome;
use crate::exec::backend::{BatchError, JobContext, ShardSpec};
use crate::exec::partition::{occ_cut_at, upper_bound_key_occ_in};

/// Shared accounting for a memory pool (job-wide for inmem; per-worker
/// for the dask-like backend). Exceeding the cap is the OOM failure the
/// scheduler's safety envelope must prevent.
#[derive(Debug)]
pub struct MemTracker {
    current: AtomicU64,
    peak: AtomicU64,
    cap: AtomicU64,
}

impl MemTracker {
    pub fn new(cap_bytes: u64) -> Arc<Self> {
        Arc::new(MemTracker {
            current: AtomicU64::new(0),
            peak: AtomicU64::new(0),
            cap: AtomicU64::new(cap_bytes),
        })
    }
    pub fn set_cap(&self, cap_bytes: u64) {
        self.cap.store(cap_bytes, Ordering::Relaxed);
    }
    pub fn cap(&self) -> u64 {
        self.cap.load(Ordering::Relaxed)
    }
    pub fn current(&self) -> u64 {
        self.current.load(Ordering::Relaxed)
    }
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Account `bytes`; Err(Oom) if it would exceed the cap.
    pub fn alloc(self: &Arc<Self>, bytes: u64) -> Result<MemGuard, BatchError> {
        let prev = self.current.fetch_add(bytes, Ordering::Relaxed);
        let now = prev + bytes;
        if now > self.cap.load(Ordering::Relaxed) {
            self.current.fetch_sub(bytes, Ordering::Relaxed);
            return Err(BatchError::Oom {
                needed_bytes: now,
                cap_bytes: self.cap.load(Ordering::Relaxed),
            });
        }
        self.peak.fetch_max(now, Ordering::Relaxed);
        Ok(MemGuard { tracker: Arc::clone(self), bytes })
    }
}

/// RAII release of accounted bytes.
pub struct MemGuard {
    tracker: Arc<MemTracker>,
    bytes: u64,
}

impl Drop for MemGuard {
    fn drop(&mut self) {
        self.tracker.current.fetch_sub(self.bytes, Ordering::Relaxed);
    }
}

/// Cooperative cancellation set (straggler speculation).
#[derive(Debug, Default)]
pub struct CancelSet {
    inner: Mutex<HashSet<u64>>,
}

impl CancelSet {
    pub fn new() -> Arc<Self> {
        Arc::new(CancelSet::default())
    }
    pub fn cancel(&self, shard_id: u64) {
        self.inner.lock().unwrap().insert(shard_id);
    }
    pub fn is_cancelled(&self, shard_id: u64) -> bool {
        self.inner.lock().unwrap().contains(&shard_id)
    }
    pub fn clear(&self, shard_id: u64) {
        self.inner.lock().unwrap().remove(&shard_id);
    }
}

/// Result of executing one shard on a worker.
pub struct ShardExecResult {
    pub result: Result<BatchOutcome, BatchError>,
    pub mem: ShardMemStats,
    pub peak_bytes: u64,
    pub io_bytes: u64,
}

/// Execute one key-aligned range pair with full accounting, reusing the
/// caller's per-worker Δ scratch.
#[allow(clippy::too_many_arguments)]
fn execute_range(
    ctx: &JobContext,
    shard_id: u64,
    a_off: usize,
    a_len: usize,
    b_off: usize,
    b_len: usize,
    tracker: &Arc<MemTracker>,
    scratch: &mut ShardScratch,
) -> Result<(BatchOutcome, ShardMemStats, u64), BatchError> {
    // Decode (T_read + parse): buffers are accounted as soon as they
    // exist; an estimate-first reservation would hide the real number.
    // Read failures (malformed rows, short reads, transient I/O) are
    // typed batch failures — the scheduler retries once, then fails the
    // job with the cause chain — never worker panics.
    let a_tbl = ctx.a.read_range(a_off, a_len).map_err(|e| {
        BatchError::failed_with(
            format!("read A rows {a_off}..{}", a_off + a_len),
            e,
        )
    })?;
    let b_tbl = ctx.b.read_range(b_off, b_len).map_err(|e| {
        BatchError::failed_with(
            format!("read B rows {b_off}..{}", b_off + b_len),
            e,
        )
    })?;
    let decode_bytes = (a_tbl.heap_bytes() + b_tbl.heap_bytes()) as u64;
    let _decode_guard = tracker.alloc(decode_bytes)?;

    let (outcome, mem) =
        process_shard_with(shard_id, &a_tbl, &b_tbl, &ctx.plan, &ctx.exec, scratch)
            .map_err(BatchError::failed)?;
    // Alignment state + Δ scratch live in the reusable per-worker
    // scratch; account them post-hoc against the peak for the window
    // where they coexist with the decode buffers. Between shards the
    // warmed scratch stays resident in the worker (bounded by one
    // shard's scratch per worker) — that idle residency is deliberately
    // outside the per-batch ledger; see the ownership notes in
    // `engine::delta::ShardScratch`.
    let transient = (mem.align_bytes + mem.scratch_bytes) as u64;
    let _transient_guard = tracker.alloc(transient)?;
    Ok((outcome, mem, decode_bytes))
}

/// Execute a shard. `chunk_rows` — if set, the shard is internally
/// re-partitioned into key-aligned sub-chunks processed sequentially
/// (the dask-like backend's finer task granularity: lower peak memory,
/// more per-task overhead); None processes the shard in one piece
/// (inmem).
pub fn execute_shard(
    ctx: &JobContext,
    spec: ShardSpec,
    tracker: &Arc<MemTracker>,
    cancel: &Arc<CancelSet>,
    chunk_rows: Option<usize>,
) -> ShardExecResult {
    let mut scratch = ShardScratch::default();
    execute_shard_with(ctx, spec, tracker, cancel, chunk_rows, &mut scratch)
}

/// Execute a shard reusing a per-worker Δ scratch. Worker threads keep
/// one `ShardScratch` alive across shards (see `pool::worker_loop`) so
/// steady-state execution performs no scratch allocation; `execute_shard`
/// is the throwaway-scratch convenience wrapper.
pub fn execute_shard_with(
    ctx: &JobContext,
    spec: ShardSpec,
    tracker: &Arc<MemTracker>,
    cancel: &Arc<CancelSet>,
    chunk_rows: Option<usize>,
    scratch: &mut ShardScratch,
) -> ShardExecResult {
    let peak_before = tracker.peak();
    let mut io_bytes = 0u64;
    let mut mem_total = ShardMemStats::default();

    if cancel.is_cancelled(spec.shard_id) {
        return ShardExecResult {
            result: Err(BatchError::Cancelled),
            mem: mem_total,
            peak_bytes: 0,
            io_bytes: 0,
        };
    }

    // Cross-shard duplicate-alignment contract: the spec's occurrence
    // bases must match the source index, and a run straddling the shard
    // start on *both* sides must resume at equal bases — that equality
    // is what makes the engine's local positional pairing bit-identical
    // to the solo-shard pairing (see `exec/partition.rs`).
    #[cfg(debug_assertions)]
    if spec.a_len > 0 && spec.b_len > 0 {
        debug_assert_eq!(spec.a_occ_base, ctx.a.occ_at(spec.a_offset));
        debug_assert_eq!(spec.b_occ_base, ctx.b.occ_at(spec.b_offset));
        let ka = ctx.a.key_at(spec.a_offset);
        debug_assert!(
            ka.is_none()
                || ka != ctx.b.key_at(spec.b_offset)
                || spec.a_occ_base == spec.b_occ_base,
            "straddling key run with unequal occurrence bases: {spec:?}"
        );
    }

    let result: Result<BatchOutcome, BatchError> = (|| {
        match chunk_rows {
            None => {
                let (outcome, mem, io) = execute_range(
                    ctx,
                    spec.shard_id,
                    spec.a_offset,
                    spec.a_len,
                    spec.b_offset,
                    spec.b_len,
                    tracker,
                    scratch,
                )?;
                mem_total = mem;
                io_bytes = io;
                Ok(outcome)
            }
            Some(chunk) => {
                // Sub-chunk boundaries need the key spans: consult the
                // source's key index (cheap) rather than decoding the
                // whole shard at once — that is the point of chunking.
                let sub = sub_partition(ctx, &spec, chunk);
                let mut merger = Merger::new();
                for (i, ((ao, al), (bo, bl))) in sub.iter().enumerate() {
                    if cancel.is_cancelled(spec.shard_id) {
                        return Err(BatchError::Cancelled);
                    }
                    let (outcome, mem, io) = execute_range(
                        ctx,
                        spec.shard_id,
                        *ao,
                        *al,
                        *bo,
                        *bl,
                        tracker,
                        scratch,
                    )?;
                    io_bytes += io;
                    // Peak is the max over chunks, not the sum — buffers
                    // are freed between chunks.
                    mem_total.decode_bytes = mem_total.decode_bytes.max(mem.decode_bytes);
                    mem_total.align_bytes = mem_total.align_bytes.max(mem.align_bytes);
                    mem_total.scratch_bytes =
                        mem_total.scratch_bytes.max(mem.scratch_bytes);
                    let _ = i;
                    merger.push(outcome);
                }
                let report = merger.finish();
                // Collapse the merged sub-chunks back into a single
                // BatchOutcome for this shard.
                Ok(collapse(spec.shard_id, report))
            }
        }
    })();

    ShardExecResult {
        result,
        mem: mem_total,
        peak_bytes: tracker.peak().saturating_sub(peak_before),
        io_bytes,
    }
}

/// (Key, occurrence)-aligned sub-ranges of a shard, consulting the
/// source key/occurrence indexes. Chunk cuts may land inside a
/// duplicate-key run: the B boundary then stops at the A cut's
/// occurrence ordinal (same rule as `Partitioner` and the straggler
/// splitter), so every sub-chunk is bounded by `chunk` A rows — even
/// when one key's run spans the whole shard — and local positional
/// pairing inside each sub-chunk equals the global pairing.
fn sub_partition(
    ctx: &JobContext,
    spec: &ShardSpec,
    chunk: usize,
) -> Vec<((usize, usize), (usize, usize))> {
    if spec.a_len == 0 || spec.b_len == 0 || ctx.a.key_at(0).is_none() {
        // Degenerate: chunk positionally via the table splitter on a
        // decoded copy would defeat the purpose; just split ranges.
        let mut out = Vec::new();
        let (mut ap, mut bp) = (0usize, 0usize);
        while ap < spec.a_len || bp < spec.b_len {
            let al = chunk.min(spec.a_len - ap);
            let bl = if ap + al >= spec.a_len {
                spec.b_len - bp
            } else {
                chunk.min(spec.b_len - bp)
            };
            out.push((
                (spec.a_offset + ap, al),
                (spec.b_offset + bp, bl),
            ));
            ap += al;
            bp += bl;
        }
        return out;
    }
    let mut out = Vec::new();
    let (mut ap, mut bp) = (spec.a_offset, spec.b_offset);
    let a_end = spec.a_offset + spec.a_len;
    let b_end = spec.b_offset + spec.b_len;
    while ap < a_end {
        let al = chunk.min(a_end - ap);
        let b_hi = if ap + al >= a_end {
            b_end
        } else {
            let last = ap + al - 1;
            let boundary = ctx.a.key_at(last).unwrap_or(i64::MAX);
            let (occ_cut, _) = occ_cut_at(ctx.a.as_ref(), last, boundary);
            upper_bound_key_occ_in(ctx.b.as_ref(), bp, b_end, boundary, occ_cut)
        };
        out.push(((ap, al), (bp, b_hi - bp)));
        ap += al;
        bp = b_hi;
    }
    if bp < b_end {
        out.push(((a_end, 0), (bp, b_end - bp)));
    }
    out
}

/// Collapse a merged multi-chunk report back into one BatchOutcome.
fn collapse(shard_id: u64, report: crate::engine::merge::JobReport) -> BatchOutcome {
    BatchOutcome {
        shard_id,
        rows_a: report.rows_a,
        rows_b: report.rows_b,
        cells: report.cells,
        rows: report.rows,
        columns: report
            .columns
            .into_iter()
            .map(|(name, agg)| crate::engine::verdict::ColumnOutcome {
                name,
                changed: agg.changed,
                max_abs_delta: agg.max_abs_delta,
            })
            .collect(),
        diff_keys: report.diff_keys,
        diff_keys_truncated: report.diff_keys_truncated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;
    use crate::engine::comparators::NativeExec;
    use crate::engine::delta::JobPlan;
    use crate::engine::schema_align::align_schemas;

    fn ctx(rows: usize, seed: u64, cap: u64) -> Arc<JobContext> {
        let (a, b, _) = generate_pair(&GenSpec { rows, seed, ..GenSpec::default() });
        let aligned = align_schemas(&a.schema, &b.schema).unwrap();
        let plan = JobPlan::new(aligned, EngineConfig::default());
        JobContext::new(
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
            plan,
            Arc::new(NativeExec),
            cap,
        )
    }

    fn whole_shard(ctx: &JobContext) -> ShardSpec {
        ShardSpec {
            shard_id: 0,
            attempt: 0,
            a_offset: 0,
            a_len: ctx.a.nrows(),
            b_offset: 0,
            b_len: ctx.b.nrows(),
            a_occ_base: 0,
            b_occ_base: 0,
        }
    }

    #[test]
    fn memtracker_alloc_free_peak() {
        let t = MemTracker::new(100);
        let g1 = t.alloc(60).unwrap();
        assert_eq!(t.current(), 60);
        assert!(t.alloc(50).is_err()); // would exceed
        drop(g1);
        assert_eq!(t.current(), 0);
        assert_eq!(t.peak(), 60);
        let _g2 = t.alloc(100).unwrap();
        assert_eq!(t.peak(), 100);
    }

    #[test]
    fn chunked_equals_unchunked() {
        let c = ctx(3_000, 21, u64::MAX);
        let tracker = MemTracker::new(u64::MAX);
        let cancel = CancelSet::new();
        let spec = whole_shard(&c);
        let whole = execute_shard(&c, spec, &tracker, &cancel, None);
        let chunked = execute_shard(&c, spec, &tracker, &cancel, Some(257));
        let (w, ch) = (whole.result.unwrap(), chunked.result.unwrap());
        assert_eq!(w.cells, ch.cells);
        assert_eq!(w.rows, ch.rows);
        let mut wk = w.diff_keys.clone();
        wk.sort_unstable();
        assert_eq!(wk, ch.diff_keys); // chunked is pre-sorted by merger
    }

    #[test]
    fn chunked_single_run_shard_matches_whole() {
        // A single duplicate-key run spans the whole shard — the shape
        // run snapping could not sub-chunk at all. The occurrence-
        // bounded sub-chunker must bound every chunk by `chunk` A rows
        // (so peak memory drops) and produce the identical outcome.
        use crate::data::schema::{ColumnType, Field, Schema};
        use crate::data::table::TableBuilder;
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Int64),
        ]);
        let mk = |n: usize, bump: i64| {
            let mut tb = TableBuilder::new(schema.clone());
            for i in 0..n {
                tb.col(0).push_i64(7);
                tb.col(1).push_i64(i as i64 + bump);
            }
            tb.finish()
        };
        let a = mk(1_200, 0);
        let b = mk(900, 5); // shorter run; every pair's payload differs
        let aligned = align_schemas(&a.schema, &b.schema).unwrap();
        let plan = JobPlan::new(aligned, EngineConfig::default());
        let c = JobContext::new(
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
            plan,
            Arc::new(NativeExec),
            u64::MAX,
        );
        let cancel = CancelSet::new();
        let t1 = MemTracker::new(u64::MAX);
        let spec = whole_shard(&c);
        let whole = execute_shard(&c, spec, &t1, &cancel, None);
        let t2 = MemTracker::new(u64::MAX);
        let chunked = execute_shard(&c, spec, &t2, &cancel, Some(100));
        let (w, ch) = (whole.result.unwrap(), chunked.result.unwrap());
        assert_eq!(w.cells, ch.cells);
        assert_eq!(w.rows, ch.rows);
        assert_eq!(w.rows.aligned, 900);
        assert_eq!(w.rows.removed, 300);
        let mut wk = w.diff_keys.clone();
        wk.sort_unstable();
        assert_eq!(wk, ch.diff_keys); // chunked is pre-sorted by merger
        assert!(
            t2.peak() < t1.peak() / 2,
            "sub-chunking must bound peak inside a run: {} vs {}",
            t2.peak(),
            t1.peak()
        );
    }

    #[test]
    fn chunked_peak_memory_is_lower() {
        let c = ctx(5_000, 4, u64::MAX);
        let cancel = CancelSet::new();
        let t1 = MemTracker::new(u64::MAX);
        let whole = execute_shard(&c, whole_shard(&c), &t1, &cancel, None);
        let t2 = MemTracker::new(u64::MAX);
        let chunked =
            execute_shard(&c, whole_shard(&c), &t2, &cancel, Some(500));
        assert!(whole.result.is_ok() && chunked.result.is_ok());
        assert!(
            t2.peak() < t1.peak() / 2,
            "chunked peak {} vs whole {}",
            t2.peak(),
            t1.peak()
        );
    }

    #[test]
    fn oom_when_cap_too_small() {
        let c = ctx(2_000, 6, u64::MAX);
        let tracker = MemTracker::new(10_000); // absurdly small pool
        let cancel = CancelSet::new();
        let r = execute_shard(&c, whole_shard(&c), &tracker, &cancel, None);
        assert!(matches!(r.result, Err(BatchError::Oom { .. })));
    }

    #[test]
    fn cancellation_short_circuits() {
        let c = ctx(1_000, 7, u64::MAX);
        let tracker = MemTracker::new(u64::MAX);
        let cancel = CancelSet::new();
        cancel.cancel(0);
        let r = execute_shard(&c, whole_shard(&c), &tracker, &cancel, None);
        assert!(matches!(r.result, Err(BatchError::Cancelled)));
        assert_eq!(r.io_bytes, 0);
    }

    #[test]
    fn io_bytes_reported() {
        let c = ctx(1_000, 8, u64::MAX);
        let tracker = MemTracker::new(u64::MAX);
        let cancel = CancelSet::new();
        let r = execute_shard(&c, whole_shard(&c), &tracker, &cancel, None);
        assert!(r.io_bytes > 0);
        assert!(r.peak_bytes > 0);
    }
}
