//! Dask-like local task-graph backend (paper §II backend (ii);
//! substitution documented in DESIGN.md §4.1).
//!
//! Reproduces the scheduler-visible properties of a local Dask cluster:
//!
//! * **task-graph overhead** — every shard is expanded into key-aligned
//!   sub-chunk tasks and tracked through a task-state table (real
//!   bookkeeping on the submit/completion path);
//! * **per-worker memory isolation** — each worker has its own arena
//!   with `total/k` cap (Dask's `memory_limit`), re-split on resize;
//! * **finer-grained preemption** — sub-chunk execution bounds the peak
//!   per-task buffer, so memory behaviour near the cap is much safer
//!   than the shared-heap inmem backend, at the cost of per-task
//!   overhead and worse locality.
//!
//! `current_rss()` sums the per-worker arenas plus the idle-scratch
//! reservations (warmed per-worker `ShardScratch` between batches), and
//! `set_workers` re-splits the arena caps — driven by the controller
//! and, under a `DiffSession`, by the session's budget re-partitioning
//! as jobs enter and leave.

use std::collections::HashMap;
use std::sync::Arc;

use crate::exec::backend::{Backend, BatchReport, JobContext, ShardSpec};
use crate::exec::pool::{Pool, PoolProfile};

/// Default sub-chunk granularity (rows per task).
pub const DEFAULT_CHUNK_ROWS: usize = 65_536;

#[derive(Debug, Clone, Copy)]
enum TaskState {
    Queued,
    Done,
}

pub struct DaskLikeBackend {
    pool: Pool,
    /// Task-state table (graph bookkeeping — the overhead source).
    tasks: HashMap<u64, TaskState>,
    completed: u64,
}

impl DaskLikeBackend {
    pub fn new(
        ctx: Arc<JobContext>,
        initial_workers: usize,
        max_workers: usize,
        chunk_rows: usize,
        prefetch: bool,
    ) -> Self {
        DaskLikeBackend {
            pool: Pool::new(
                ctx,
                PoolProfile {
                    chunk_rows: Some(chunk_rows.max(1)),
                    per_worker_memory: true,
                    prefetch,
                },
                initial_workers,
                max_workers,
            ),
            tasks: HashMap::new(),
            completed: 0,
        }
    }

    pub fn completed_tasks(&self) -> u64 {
        self.completed
    }

    fn track_completions(&mut self, reports: &[BatchReport]) {
        for r in reports {
            if let Some(state) = self.tasks.get_mut(&r.shard.shard_id) {
                *state = TaskState::Done;
            }
            self.tasks.remove(&r.shard.shard_id);
            self.completed += 1;
        }
    }
}

impl Backend for DaskLikeBackend {
    fn name(&self) -> &'static str {
        "dasklike"
    }
    fn submit(&mut self, shard: ShardSpec) {
        self.tasks.insert(shard.shard_id, TaskState::Queued);
        self.pool.submit(shard);
    }
    fn poll(&mut self) -> Vec<BatchReport> {
        let reports = self.pool.poll();
        self.track_completions(&reports);
        reports
    }
    fn wait_any(&mut self) -> Vec<BatchReport> {
        let reports = self.pool.wait_any();
        self.track_completions(&reports);
        reports
    }
    fn set_workers(&mut self, k: usize) {
        self.pool.set_workers(k);
    }
    fn workers(&self) -> usize {
        self.pool.workers()
    }
    fn set_mem_budget(&mut self, bytes: u64) {
        self.pool.set_mem_budget(bytes);
    }
    fn mem_budget(&self) -> u64 {
        self.pool.mem_budget()
    }
    fn queue_depth(&self) -> usize {
        self.pool.queue_depth()
    }
    fn inflight(&self) -> usize {
        self.pool.inflight()
    }
    fn now(&self) -> f64 {
        crate::util::mono_secs()
    }
    fn current_rss(&self) -> u64 {
        self.pool.current_rss()
    }
    fn utilization_sample(&mut self, cpu_cap: usize) -> f64 {
        self.pool.utilization_sample(cpu_cap)
    }
    fn cancel(&mut self, shard_id: u64) {
        self.pool.cancel(shard_id);
    }
    fn staged_bytes(&self) -> u64 {
        self.pool.staged_bytes()
    }
    fn prefetch_active(&self) -> bool {
        self.pool.prefetch_active()
    }
    fn cache_stats(&self) -> crate::data::chunkstore::CacheStats {
        self.pool.cache_stats()
    }
    fn cache_split_hint(
        &self,
        side: crate::data::chunkstore::Side,
        offset: usize,
        len: usize,
    ) -> Option<usize> {
        self.pool.cache_split_hint(side, offset, len)
    }
}
