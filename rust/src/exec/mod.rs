//! Execution backends (DESIGN.md systems S10–S11): the `Backend` trait,
//! the job partitioner, worker-side execution with memory accounting,
//! and the two real backends (inmem threads, dask-like task graph).
//! The discrete-event simulator (`crate::sim`) implements the same
//! trait.

pub mod backend;
pub mod dasklike;
pub mod inmem;
pub mod partition;
pub mod pool;
pub mod worker;
