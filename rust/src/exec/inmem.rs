//! In-memory threaded backend (paper §II backend (i)): single process,
//! shared heap, minimal scheduling overhead, best cache locality — the
//! fast choice when the working set comfortably fits in RAM. Memory is
//! one shared pool; an aggressive (b, k) can genuinely blow the cap,
//! which is exactly the failure mode the working-set gate avoids.
//!
//! `current_rss()` reports the shared pool's live batch buffers plus
//! the per-worker idle-scratch reservations (see `pool::Shared`), so a
//! `DiffSession` job handle sees the true steady-state footprint
//! between batches. Worker-count changes arrive via `set_workers` from
//! both the (b, k) controller and the session's CPU re-partitioning.

use std::sync::Arc;

use crate::exec::backend::{Backend, BatchReport, JobContext, ShardSpec};
use crate::exec::pool::{Pool, PoolProfile};

pub struct InMemBackend {
    pool: Pool,
}

impl InMemBackend {
    pub fn new(
        ctx: Arc<JobContext>,
        initial_workers: usize,
        max_workers: usize,
        prefetch: bool,
    ) -> Self {
        InMemBackend {
            pool: Pool::new(
                ctx,
                PoolProfile {
                    chunk_rows: None,
                    per_worker_memory: false,
                    prefetch,
                },
                initial_workers,
                max_workers,
            ),
        }
    }
}

impl Backend for InMemBackend {
    fn name(&self) -> &'static str {
        "inmem"
    }
    fn submit(&mut self, shard: ShardSpec) {
        self.pool.submit(shard);
    }
    fn poll(&mut self) -> Vec<BatchReport> {
        self.pool.poll()
    }
    fn wait_any(&mut self) -> Vec<BatchReport> {
        self.pool.wait_any()
    }
    fn set_workers(&mut self, k: usize) {
        self.pool.set_workers(k);
    }
    fn workers(&self) -> usize {
        self.pool.workers()
    }
    fn set_mem_budget(&mut self, bytes: u64) {
        self.pool.set_mem_budget(bytes);
    }
    fn mem_budget(&self) -> u64 {
        self.pool.mem_budget()
    }
    fn queue_depth(&self) -> usize {
        self.pool.queue_depth()
    }
    fn inflight(&self) -> usize {
        self.pool.inflight()
    }
    fn now(&self) -> f64 {
        crate::util::mono_secs()
    }
    fn current_rss(&self) -> u64 {
        self.pool.current_rss()
    }
    fn utilization_sample(&mut self, cpu_cap: usize) -> f64 {
        self.pool.utilization_sample(cpu_cap)
    }
    fn cancel(&mut self, shard_id: u64) {
        self.pool.cancel(shard_id);
    }
    fn staged_bytes(&self) -> u64 {
        self.pool.staged_bytes()
    }
    fn prefetch_active(&self) -> bool {
        self.pool.prefetch_active()
    }
    fn cache_stats(&self) -> crate::data::chunkstore::CacheStats {
        self.pool.cache_stats()
    }
    fn cache_split_hint(
        &self,
        side: crate::data::chunkstore::Side,
        offset: usize,
        len: usize,
    ) -> Option<usize> {
        self.pool.cache_split_hint(side, offset, len)
    }
}
