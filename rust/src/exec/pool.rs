//! Shared worker-pool machinery for the real (in-process) backends.
//!
//! A pool owns N worker threads pulling `ShardSpec`s from a condvar
//! queue and pushing `BatchReport`s through a channel. The two backends
//! differ only in their `PoolProfile`: memory accounting scope (shared
//! heap vs per-worker arenas), chunk granularity, and per-task
//! bookkeeping — see `inmem.rs` / `dasklike.rs`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::data::chunkstore::{CacheStats, Side};
use crate::data::io::ReadScratch;
use crate::exec::backend::{BatchReport, JobContext, ShardSpec};
use crate::engine::delta::ShardScratch;
use crate::exec::worker::{
    execute_shard_with, first_range, CancelSet, MemTracker, Prefetcher,
};
use crate::util::mono_secs;

/// Backend-specific execution profile.
#[derive(Clone)]
pub struct PoolProfile {
    /// None → whole-shard execution (shared-heap inmem); Some(rows) →
    /// key-aligned sub-chunk tasks (dask-like granularity).
    pub chunk_rows: Option<usize>,
    /// Shared tracker (inmem) or per-worker arenas (dask-like).
    pub per_worker_memory: bool,
    /// Double-buffered prefetch: each worker gets a companion thread
    /// staging the next range while the current one computes. Staged
    /// bytes are charged to the worker's ledger before the read starts.
    pub prefetch: bool,
}

struct Queued {
    spec: ShardSpec,
    submitted_at: f64,
}

struct Shared {
    ctx: Arc<JobContext>,
    profile: PoolProfile,
    queue: Mutex<VecDeque<Queued>>,
    cv: Condvar,
    target_workers: AtomicUsize,
    queue_len: AtomicUsize,
    inflight: AtomicUsize,
    busy_ns: AtomicU64,
    shutdown: AtomicUsize, // 1 = drain and exit
    /// Job-level memory budget currently enforced (bytes, including the
    /// base table footprint). Starts at `ctx.mem_cap_bytes`; the
    /// session's elastic grant re-partitioning updates it mid-job via
    /// `set_mem_budget`, and `set_workers` re-splits per-worker arenas
    /// against it rather than the construction-time cap.
    mem_budget: AtomicU64,
    /// Shared pool (inmem) — also used as the job-level RSS ledger.
    shared_tracker: Arc<MemTracker>,
    /// Per-worker arenas (dask-like); indexed by worker id.
    worker_trackers: Vec<Arc<MemTracker>>,
    /// Per-worker scratch reservations, indexed by worker id: the
    /// resident bytes of each worker's warmed `ShardScratch`, refreshed
    /// after every batch and held between batches. Summed into
    /// `current_rss()` so the steady-state footprint is visible while
    /// workers are idle (and during decode+Δ, which the batch ledger
    /// only accounts post-hoc).
    idle_scratch: Vec<AtomicU64>,
    /// Bytes currently resident in prefetch staging slots across all
    /// workers. Telemetry-only gauge: staged bytes are charged to the
    /// regular batch ledgers (shared tracker / per-worker arenas), so
    /// adding this into `current_rss()` would double-count.
    staged_gauge: Arc<AtomicU64>,
    cancel: Arc<CancelSet>,
    report_tx: Mutex<Sender<BatchReport>>,
}

pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    report_rx: Receiver<BatchReport>,
    spawned: usize,
    max_workers: usize,
    util_last_t: f64,
    util_last_busy: u64,
}

impl Pool {
    pub fn new(
        ctx: Arc<JobContext>,
        profile: PoolProfile,
        initial_workers: usize,
        max_workers: usize,
    ) -> Pool {
        let (tx, rx) = channel();
        let initial_budget = ctx.mem_cap_bytes;
        let budget = ctx
            .mem_cap_bytes
            .saturating_sub(ctx.base_rss_bytes)
            .max(1);
        let shared_tracker = MemTracker::new(budget);
        let worker_trackers: Vec<Arc<MemTracker>> = (0..max_workers)
            .map(|_| MemTracker::new(budget / initial_workers.max(1) as u64))
            .collect();
        let shared = Arc::new(Shared {
            ctx,
            profile,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            target_workers: AtomicUsize::new(initial_workers),
            queue_len: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            busy_ns: AtomicU64::new(0),
            shutdown: AtomicUsize::new(0),
            mem_budget: AtomicU64::new(initial_budget),
            shared_tracker,
            worker_trackers,
            idle_scratch: (0..max_workers).map(|_| AtomicU64::new(0)).collect(),
            staged_gauge: Arc::new(AtomicU64::new(0)),
            cancel: CancelSet::new(),
            report_tx: Mutex::new(tx),
        });
        let mut pool = Pool {
            shared,
            handles: Vec::new(),
            report_rx: rx,
            spawned: 0,
            max_workers,
            util_last_t: mono_secs(),
            util_last_busy: 0,
        };
        // File-backed sources size their read-handle pools from the
        // worker count (k concurrent readers, k handles).
        pool.shared.ctx.a.set_read_parallelism(initial_workers.max(1));
        pool.shared.ctx.b.set_read_parallelism(initial_workers.max(1));
        // Apply the budget through the single split rule so the chunk
        // cache's carve-out is in place before any worker runs.
        pool.apply_mem_budget(initial_workers.max(1));
        pool.ensure_spawned(initial_workers);
        pool
    }

    fn ensure_spawned(&mut self, target: usize) {
        let target = target.min(self.max_workers);
        while self.spawned < target {
            let id = self.spawned;
            let shared = Arc::clone(&self.shared);
            self.handles.push(
                std::thread::Builder::new()
                    .name(format!("sdiff-worker-{id}"))
                    .spawn(move || worker_loop(id, shared))
                    // lint: allow(unwrap) spawn fails only on OS thread
                    // exhaustion; no useful degraded mode exists there
                    .expect("spawn worker"),
            );
            self.spawned += 1;
        }
    }

    pub fn submit(&mut self, spec: ShardSpec) {
        let q = Queued { spec, submitted_at: mono_secs() };
        {
            // lint: allow(unwrap) queue sections are VecDeque ops that
            // cannot panic, so the mutex cannot be poisoned
            let mut queue = self.shared.queue.lock().unwrap();
            queue.push_back(q);
        }
        self.shared.queue_len.fetch_add(1, Ordering::Relaxed);
        self.shared.inflight.fetch_add(1, Ordering::Relaxed);
        self.shared.cv.notify_one();
    }

    pub fn poll(&mut self) -> Vec<BatchReport> {
        let mut out = Vec::new();
        while let Ok(r) = self.report_rx.try_recv() {
            out.push(r);
        }
        out
    }

    pub fn wait_any(&mut self) -> Vec<BatchReport> {
        loop {
            let got = self.poll();
            if !got.is_empty() || self.inflight() == 0 {
                return got;
            }
            match self
                .report_rx
                .recv_timeout(std::time::Duration::from_millis(20))
            {
                Ok(r) => {
                    let mut out = vec![r];
                    out.extend(self.poll());
                    return out;
                }
                Err(_) => continue,
            }
        }
    }

    pub fn set_workers(&mut self, k: usize) {
        let k = k.clamp(1, self.max_workers);
        self.shared.target_workers.store(k, Ordering::Relaxed);
        self.ensure_spawned(k);
        if self.shared.profile.per_worker_memory {
            self.apply_mem_budget(k);
        }
        // Keep the sources' pooled read handles sized to the live
        // worker count so k readers never serialize on handle churn.
        self.shared.ctx.a.set_read_parallelism(k);
        self.shared.ctx.b.set_read_parallelism(k);
        self.shared.cv.notify_all();
    }

    /// Re-apply the current memory budget to the accounting ledgers:
    /// the shared tracker cap (inmem), or the per-worker arena split at
    /// budget/k (Dask semantics: per-worker memory_limit = total /
    /// n_workers). Single source of truth for the split rule — both
    /// `set_workers` and `set_mem_budget` route through here.
    fn apply_mem_budget(&self, k: usize) {
        let headroom = self
            .shared
            .mem_budget
            .load(Ordering::Relaxed)
            .saturating_sub(self.shared.ctx.base_rss_bytes)
            .max(1);
        // When a chunk store is attached it gets a fixed quarter of the
        // grant headroom; batch ledgers split the rest. The store cap is
        // applied FIRST — set_cap synchronously evicts (spills) down to
        // the new carve-out, so on a grant shrink cached bytes yield
        // before any worker could grow into the freed space, and peak
        // accounted RSS (batch + cache) stays ≤ grant by construction.
        let budget = match &self.shared.ctx.chunk_store {
            Some(store) => {
                let cache_cap = headroom / 4;
                store.set_cap(cache_cap);
                (headroom - cache_cap).max(1)
            }
            None => headroom,
        };
        if self.shared.profile.per_worker_memory {
            for t in &self.shared.worker_trackers {
                t.set_cap(budget / k.max(1) as u64);
            }
        } else {
            self.shared.shared_tracker.set_cap(budget);
        }
    }

    /// Re-cap the job-level memory budget (the session's elastic grant):
    /// the shared tracker (inmem) or the per-worker arena split
    /// (dask-like) is updated for new allocations immediately. Live
    /// buffers are not evicted — callers shrink only after accounted
    /// usage has drained below the new budget.
    pub fn set_mem_budget(&mut self, bytes: u64) {
        self.shared.mem_budget.store(bytes.max(1), Ordering::Relaxed);
        self.apply_mem_budget(self.workers());
    }

    /// The job-level memory budget currently enforced (bytes).
    pub fn mem_budget(&self) -> u64 {
        self.shared.mem_budget.load(Ordering::Relaxed)
    }

    pub fn workers(&self) -> usize {
        self.shared.target_workers.load(Ordering::Relaxed)
    }
    pub fn queue_depth(&self) -> usize {
        self.shared.queue_len.load(Ordering::Relaxed)
    }
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::Relaxed)
    }
    pub fn cancel(&self, shard_id: u64) {
        self.shared.cancel.cancel(shard_id);
    }
    /// Bytes currently held in prefetch staging slots (already charged
    /// to the batch ledgers; exposed for telemetry, not accounting).
    pub fn staged_bytes(&self) -> u64 {
        self.shared.staged_gauge.load(Ordering::Relaxed)
    }
    /// Whether this pool runs the double-buffered prefetch pipeline.
    pub fn prefetch_active(&self) -> bool {
        self.shared.profile.prefetch
    }
    /// Chunk-cache counters/gauges (zeroed when no store is attached).
    pub fn cache_stats(&self) -> CacheStats {
        self.shared
            .ctx
            .chunk_store
            .as_ref()
            .map(|s| s.stats())
            .unwrap_or_default()
    }
    /// Longest cache-resident strict prefix of a side's range (the
    /// scheduler's straggler-split cut preference).
    pub fn cache_split_hint(
        &self,
        side: Side,
        offset: usize,
        len: usize,
    ) -> Option<usize> {
        self.shared
            .ctx
            .chunk_store
            .as_ref()
            .and_then(|s| s.split_hint(side, offset, len))
    }

    /// Job-level accounted RSS: base tables + live batch buffers + idle
    /// per-worker scratch reservations (warmed `ShardScratch` that stays
    /// resident between batches — the ROADMAP memory-model item).
    pub fn current_rss(&self) -> u64 {
        let batch: u64 = if self.shared.profile.per_worker_memory {
            self.shared.worker_trackers.iter().map(|t| t.current()).sum()
        } else {
            self.shared.shared_tracker.current()
        };
        let idle: u64 = self
            .shared
            .idle_scratch
            .iter()
            .map(|s| s.load(Ordering::Relaxed))
            .sum();
        // Cache-resident chunk bytes live on their own ledger (the
        // carve-out), not in the batch trackers — add them so accounted
        // RSS covers everything the job pins.
        let cached: u64 = self
            .shared
            .ctx
            .chunk_store
            .as_ref()
            .map(|s| s.memory_bytes())
            .unwrap_or(0);
        self.shared.ctx.base_rss_bytes + batch + idle + cached
    }

    pub fn utilization_sample(&mut self, cpu_cap: usize) -> f64 {
        let now = mono_secs();
        let busy = self.shared.busy_ns.load(Ordering::Relaxed);
        let dt = (now - self.util_last_t).max(1e-9);
        let db = busy.saturating_sub(self.util_last_busy) as f64 * 1e-9;
        self.util_last_t = now;
        self.util_last_busy = busy;
        (db / (dt * cpu_cap.max(1) as f64)).clamp(0.0, 1.0)
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(1, Ordering::Relaxed);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(id: usize, shared: Arc<Shared>) {
    // One Δ scratch per worker thread, reused across every shard this
    // worker executes: after the first few shards its buffers reach
    // steady-state capacity and shard execution stops allocating.
    let mut scratch = ShardScratch::default();
    let mut read_scratch = ReadScratch::default();
    // Companion prefetch thread (when the profile enables it), spawned
    // lazily on the first task so it binds to this worker's ledger.
    let mut prefetcher: Option<Prefetcher> = None;
    loop {
        // Retire if we are above the target worker count and idle.
        let task = {
            // lint: allow(unwrap) queue poison unreachable (see submit)
            let mut queue = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Relaxed) == 1 {
                    return;
                }
                let active = shared.target_workers.load(Ordering::Relaxed);
                if id < active {
                    if let Some(t) = queue.pop_front() {
                        break Some(t);
                    }
                }
                let (q, _timeout) = shared
                    .cv
                    .wait_timeout(queue, std::time::Duration::from_millis(25))
                    // lint: allow(unwrap) errs only on queue poison,
                    // unreachable (see submit)
                    .unwrap();
                queue = q;
            }
        };
        let Some(task) = task else { continue };
        shared.queue_len.fetch_sub(1, Ordering::Relaxed);
        let mut task = task;

        // Inner loop: execute the claimed task, and (with prefetch on)
        // claim the next task BEFORE computing so its first range can be
        // staged while this one diffs — the cross-shard half of the
        // double buffer. Inflight was counted at submit, so a claimed
        // next task keeps the pool visibly busy until its report lands.
        loop {
            let started_at = mono_secs();
            let t0 = Instant::now();
            let tracker = if shared.profile.per_worker_memory {
                &shared.worker_trackers[id]
            } else {
                &shared.shared_tracker
            };
            if shared.profile.prefetch && prefetcher.is_none() {
                prefetcher = Some(Prefetcher::spawn(
                    Arc::clone(&shared.ctx),
                    Arc::clone(tracker),
                    Arc::clone(&shared.staged_gauge),
                ));
            }
            let next_task = if shared.profile.prefetch {
                let claimed = {
                    // lint: allow(unwrap) queue poison unreachable (see
                    // submit)
                    let mut queue = shared.queue.lock().unwrap();
                    if shared.shutdown.load(Ordering::Relaxed) == 0
                        && id < shared.target_workers.load(Ordering::Relaxed)
                    {
                        queue.pop_front()
                    } else {
                        None
                    }
                };
                if claimed.is_some() {
                    shared.queue_len.fetch_sub(1, Ordering::Relaxed);
                }
                claimed
            } else {
                None
            };
            let next_hint = next_task.as_ref().map(|t| {
                first_range(&shared.ctx, &t.spec, shared.profile.chunk_rows)
            });
            // The scratch reservation stays in place WHILE the batch
            // executes: the warmed scratch is resident throughout, and
            // the batch ledger only accounts it post-hoc (after the Δ
            // returns). Keeping the reservation avoids under-reporting
            // during decode+Δ; the brief overlap with the post-hoc
            // transient guard at batch tail over-counts conservatively.
            let res = execute_shard_with(
                &shared.ctx,
                task.spec,
                tracker,
                &shared.cancel,
                shared.profile.chunk_rows,
                &mut scratch,
                &mut read_scratch,
                prefetcher.as_ref(),
                next_hint,
            );
            shared.idle_scratch[id]
                .store(scratch.heap_bytes() as u64, Ordering::Relaxed);
            shared
                .busy_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            let finished_at = mono_secs();

            let report = BatchReport {
                shard: task.spec,
                worker_id: id,
                submitted_at: task.submitted_at,
                started_at,
                finished_at,
                result: res.result,
                mem: res.mem,
                worker_rss_peak: res.mem.peak() as u64,
                io_bytes: res.io_bytes,
                stages: res.stages,
            };
            // Send BEFORE decrementing inflight: the scheduler treats
            // "inflight == 0" as "every report is visible in the channel".
            // lint: allow(unwrap) report_tx sections are a single
            // channel send and cannot panic, so no poison
            let _ = shared.report_tx.lock().unwrap().send(report);
            shared.inflight.fetch_sub(1, Ordering::Relaxed);

            match next_task {
                Some(t) => task = t,
                None => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::data::io::InMemorySource;
    use crate::engine::comparators::NativeExec;
    use crate::engine::delta::JobPlan;
    use crate::engine::schema_align::align_schemas;
    use crate::exec::partition::Partitioner;

    fn mk_ctx(rows: usize) -> Arc<JobContext> {
        let (a, b, _) =
            generate_pair(&GenSpec { rows, seed: 33, ..GenSpec::default() });
        let aligned = align_schemas(&a.schema, &b.schema).unwrap();
        let plan = JobPlan::new(aligned, EngineConfig::default());
        JobContext::new(
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
            plan,
            Arc::new(NativeExec),
            u64::MAX,
        )
    }

    #[test]
    fn pool_executes_all_shards() {
        let ctx = mk_ctx(2_000);
        let mut pool = Pool::new(
            Arc::clone(&ctx),
            PoolProfile {
                chunk_rows: None,
                per_worker_memory: false,
                prefetch: true,
            },
            2,
            4,
        );
        let mut part = Partitioner::new(ctx.a.as_ref(), ctx.b.as_ref());
        let mut n = 0;
        while let Some(s) = part.next(300) {
            pool.submit(s);
            n += 1;
        }
        let mut done = 0;
        while done < n {
            let got = pool.wait_any();
            for r in &got {
                assert!(r.result.is_ok(), "{:?}", r.result);
                assert!(r.finished_at >= r.started_at);
                assert!(r.worker_rss_peak > 0);
            }
            done += got.len();
        }
        // Reports are sent before the inflight decrement; give the
        // counter a moment to catch up.
        let t0 = std::time::Instant::now();
        while pool.inflight() != 0 && t0.elapsed().as_secs() < 5 {
            std::thread::yield_now();
        }
        assert_eq!(pool.inflight(), 0);
        assert!(pool.utilization_sample(4) >= 0.0);
        // The warmed per-worker scratch stays accounted as a persistent
        // reservation while workers are idle: with no batch executing,
        // current_rss must still exceed the base table footprint.
        assert!(
            pool.current_rss() > ctx.base_rss_bytes,
            "idle scratch reservation missing: rss={} base={}",
            pool.current_rss(),
            ctx.base_rss_bytes
        );
    }

    #[test]
    fn shrunk_budget_ooms_oversized_batch() {
        let ctx = mk_ctx(2_000);
        let mut pool = Pool::new(
            Arc::clone(&ctx),
            PoolProfile {
                chunk_rows: None,
                per_worker_memory: false,
                prefetch: false,
            },
            1,
            2,
        );
        assert_eq!(pool.mem_budget(), u64::MAX);
        // Leave ~10 KB of batch headroom above the base tables: decoding
        // the whole 2k-row table needs far more, so the shrunken ledger
        // must reject it as an accounted OOM.
        pool.set_mem_budget(ctx.base_rss_bytes + 10_000);
        assert_eq!(pool.mem_budget(), ctx.base_rss_bytes + 10_000);
        pool.submit(ShardSpec {
            shard_id: 0,
            attempt: 0,
            a_offset: 0,
            a_len: ctx.a.nrows(),
            b_offset: 0,
            b_len: ctx.b.nrows(),
            a_occ_base: 0,
            b_occ_base: 0,
        });
        let mut got = Vec::new();
        while got.is_empty() {
            got = pool.wait_any();
        }
        assert!(got[0].is_oom(), "expected accounted OOM, got {:?}", got[0].result);
    }

    #[test]
    fn resize_workers_up_and_down() {
        let ctx = mk_ctx(500);
        let mut pool = Pool::new(
            Arc::clone(&ctx),
            PoolProfile {
                chunk_rows: Some(100),
                per_worker_memory: true,
                prefetch: true,
            },
            1,
            4,
        );
        pool.set_workers(4);
        assert_eq!(pool.workers(), 4);
        pool.set_workers(2);
        assert_eq!(pool.workers(), 2);
        // Work still completes after resizing.
        let mut part = Partitioner::new(ctx.a.as_ref(), ctx.b.as_ref());
        let mut n = 0;
        while let Some(s) = part.next(200) {
            pool.submit(s);
            n += 1;
        }
        let mut done = 0;
        while done < n {
            done += pool.wait_any().len();
        }
        assert_eq!(done, n);
    }
}
