//! Type-specific cell comparators for Δ (paper §II) and the native
//! implementation of the numeric batch diff.
//!
//! The numeric batch contract (`NumericBatch` → `NumericDiffOut`) is the
//! cross-layer interface shared by the native comparator here and the
//! PJRT executable produced from the Pallas kernel (`runtime::pjrt`).
//! `native_numeric_diff` mirrors `kernels/ref.py` exactly and is the
//! in-process oracle the PJRT path is cross-checked against.

use crate::config::EngineConfig;
use crate::engine::verdict::Verdict;

/// One numeric batch in kernel layout (row-major R×C matrices).
/// Row slots: aligned pairs first, then removed (ra=1, rb=0), then added
/// (ra=0, rb=1); padding rows have ra=rb=0.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NumericBatch {
    pub rows: usize,
    pub cols: usize,
    pub a: Vec<f64>,
    pub b: Vec<f64>,
    /// Cell presence (1.0 = non-null). Garbage values behind a 0 mask
    /// are allowed — they never reach the compare.
    pub na: Vec<f64>,
    pub nb: Vec<f64>,
    /// Row presence per side.
    pub ra: Vec<f64>,
    pub rb: Vec<f64>,
    /// Per-column tolerances.
    pub atol: Vec<f64>,
    pub rtol: Vec<f64>,
}

fn zero_resize(v: &mut Vec<f64>, n: usize) {
    v.clear();
    v.resize(n, 0.0);
}

impl NumericBatch {
    pub fn zeroed(rows: usize, cols: usize) -> Self {
        let mut nb = NumericBatch::default();
        nb.reset(rows, cols);
        nb
    }

    /// Re-shape to rows×cols with all matrices zeroed, reusing existing
    /// capacity — after warm-up this performs no heap allocation, which
    /// is what makes the per-worker `ShardScratch` allocation-free.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        let n = rows * cols;
        zero_resize(&mut self.a, n);
        zero_resize(&mut self.b, n);
        zero_resize(&mut self.na, n);
        zero_resize(&mut self.nb, n);
        zero_resize(&mut self.ra, rows);
        zero_resize(&mut self.rb, rows);
        zero_resize(&mut self.atol, cols);
        zero_resize(&mut self.rtol, cols);
    }
    /// Scratch footprint in bytes (memory-model input).
    pub fn heap_bytes(&self) -> usize {
        (self.a.capacity()
            + self.b.capacity()
            + self.na.capacity()
            + self.nb.capacity()
            + self.ra.capacity()
            + self.rb.capacity()
            + self.atol.capacity()
            + self.rtol.capacity())
            * 8
    }
}

/// Output of a numeric batch diff (mirrors the L2 graph outputs).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NumericDiffOut {
    /// R×C verdict codes.
    pub verdicts: Vec<i32>,
    /// Verdict histogram [equal, changed, added, removed, absent].
    pub counts: [i64; 5],
    /// Per-column changed-cell counts.
    pub col_changed: Vec<i64>,
    /// Per-column max |a-b| over numerically compared cells.
    pub col_maxabs: Vec<f64>,
    /// Per-row any-diff indicator (changed/added/removed).
    pub changed_rows: Vec<i32>,
}

impl NumericDiffOut {
    /// Scratch footprint in bytes (capacity-based; memory-model input).
    pub fn heap_bytes(&self) -> usize {
        self.verdicts.capacity() * 4
            + self.col_changed.capacity() * 8
            + self.col_maxabs.capacity() * 8
            + self.changed_rows.capacity() * 4
    }
}

/// Executor for numeric batches: native rust or the AOT PJRT executable.
pub trait NumericDeltaExec: Send + Sync {
    fn name(&self) -> &'static str;
    fn diff(&self, batch: &NumericBatch) -> Result<NumericDiffOut, String>;
    /// Buffer-reusing variant: write the result into caller-owned
    /// output buffers. The default falls back to `diff` (one fresh
    /// allocation set); executors on the hot path override it.
    fn diff_into(
        &self,
        batch: &NumericBatch,
        out: &mut NumericDiffOut,
    ) -> Result<(), String> {
        *out = self.diff(batch)?;
        Ok(())
    }
}

/// Canonicalize like the L2 graph: zero masked cells, fold -0.0 → +0.0.
#[inline]
fn canon(x: f64, present: bool) -> f64 {
    if present {
        x + 0.0
    } else {
        0.0
    }
}

/// Pure-rust numeric diff, semantically identical to the Pallas kernel +
/// L2 canonicalization (see python/compile/kernels/ref.py).
pub fn native_numeric_diff(batch: &NumericBatch) -> NumericDiffOut {
    let mut out = NumericDiffOut::default();
    native_numeric_diff_into(batch, &mut out);
    out
}

/// Buffer-reusing form of [`native_numeric_diff`]: output vectors are
/// resized in place (no allocation once capacities have warmed up).
pub fn native_numeric_diff_into(batch: &NumericBatch, out: &mut NumericDiffOut) {
    let (r, c) = (batch.rows, batch.cols);
    out.verdicts.clear();
    out.verdicts.resize(r * c, Verdict::Absent as i32);
    out.counts = [0i64; 5];
    out.col_changed.clear();
    out.col_changed.resize(c, 0);
    out.col_maxabs.clear();
    out.col_maxabs.resize(c, 0.0);
    out.changed_rows.clear();
    out.changed_rows.resize(r, 0);
    let NumericDiffOut { verdicts, counts, col_changed, col_maxabs, changed_rows } =
        out;

    for i in 0..r {
        let ra = batch.ra[i] > 0.5;
        let rb = batch.rb[i] > 0.5;
        let mut row_diff = false;
        for j in 0..c {
            let idx = i * c + j;
            let v = if ra && rb {
                let na = batch.na[idx] > 0.5;
                let nb = batch.nb[idx] > 0.5;
                let a = canon(batch.a[idx], na);
                let b = canon(batch.b[idx], nb);
                if !na && !nb {
                    Verdict::Equal
                } else if na != nb {
                    Verdict::Changed
                } else {
                    // NaN==NaN and exact equality (covers inf==inf, where
                    // a-b is NaN) are equal; else tolerance compare.
                    let nan_eq = a.is_nan() && b.is_nan();
                    let tol = batch.atol[j] + batch.rtol[j] * b.abs();
                    let d = (a - b).abs();
                    if nan_eq || a == b || d <= tol {
                        Verdict::Equal
                    } else {
                        Verdict::Changed
                    }
                }
            } else if ra {
                Verdict::Removed
            } else if rb {
                Verdict::Added
            } else {
                Verdict::Absent
            };
            verdicts[idx] = v as i32;
            counts[v as i32 as usize] += 1;
            match v {
                Verdict::Changed => {
                    col_changed[j] += 1;
                    row_diff = true;
                }
                Verdict::Added | Verdict::Removed => row_diff = true,
                _ => {}
            }
            // maxabs over numerically compared cells only.
            if ra && rb && batch.na[idx] > 0.5 && batch.nb[idx] > 0.5 {
                let a = canon(batch.a[idx], true);
                let b = canon(batch.b[idx], true);
                let d = (a - b).abs();
                if d.is_finite() && d > col_maxabs[j] {
                    col_maxabs[j] = d;
                }
            }
        }
        changed_rows[i] = row_diff as i32;
    }
}

/// Native executor (always available; no artifacts needed).
#[derive(Debug, Default)]
pub struct NativeExec;

impl NumericDeltaExec for NativeExec {
    fn name(&self) -> &'static str {
        "native"
    }
    fn diff(&self, batch: &NumericBatch) -> Result<NumericDiffOut, String> {
        Ok(native_numeric_diff(batch))
    }
    fn diff_into(
        &self,
        batch: &NumericBatch,
        out: &mut NumericDiffOut,
    ) -> Result<(), String> {
        native_numeric_diff_into(batch, out);
        Ok(())
    }
}

// ----- scalar comparators for the non-numeric (native) columns -----

/// Compare two present strings under the engine config.
pub fn compare_str(a: &str, b: &str, cfg: &EngineConfig) -> Verdict {
    let eq = if cfg.string_ci {
        a.eq_ignore_ascii_case(b)
    } else {
        a == b
    };
    if eq {
        Verdict::Equal
    } else {
        Verdict::Changed
    }
}

pub fn compare_bool(a: bool, b: bool) -> Verdict {
    if a == b {
        Verdict::Equal
    } else {
        Verdict::Changed
    }
}

/// Null-aware wrapper: both null = equal, one null = changed, else defer.
pub fn null_aware(
    a_null: bool,
    b_null: bool,
    cmp: impl FnOnce() -> Verdict,
) -> Verdict {
    match (a_null, b_null) {
        (true, true) => Verdict::Equal,
        (true, false) | (false, true) => Verdict::Changed,
        (false, false) => cmp(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_cell(a: f64, b: f64, atol: f64, rtol: f64) -> Verdict {
        let mut nb = NumericBatch::zeroed(1, 1);
        nb.a[0] = a;
        nb.b[0] = b;
        nb.na[0] = 1.0;
        nb.nb[0] = 1.0;
        nb.ra[0] = 1.0;
        nb.rb[0] = 1.0;
        nb.atol[0] = atol;
        nb.rtol[0] = rtol;
        let out = native_numeric_diff(&nb);
        Verdict::from_code(out.verdicts[0]).unwrap()
    }

    #[test]
    fn tolerance_semantics() {
        assert_eq!(one_cell(1.0, 1.0, 0.0, 0.0), Verdict::Equal);
        assert_eq!(one_cell(1.0, 1.1, 0.05, 0.0), Verdict::Changed);
        assert_eq!(one_cell(1.0, 1.1, 0.2, 0.0), Verdict::Equal);
        assert_eq!(one_cell(100.0, 100.5, 0.0, 0.01), Verdict::Equal);
        assert_eq!(one_cell(100.0, 102.0, 0.0, 0.01), Verdict::Changed);
    }

    #[test]
    fn nan_and_negzero() {
        assert_eq!(one_cell(f64::NAN, f64::NAN, 0.0, 0.0), Verdict::Equal);
        assert_eq!(one_cell(f64::NAN, 0.0, 1e18, 1e18), Verdict::Changed);
        assert_eq!(one_cell(-0.0, 0.0, 0.0, 0.0), Verdict::Equal);
        assert_eq!(one_cell(f64::INFINITY, f64::INFINITY, 0.0, 0.0),
                   Verdict::Equal);
        assert_eq!(one_cell(f64::INFINITY, f64::NEG_INFINITY, 1e300, 0.0),
                   Verdict::Changed);
    }

    #[test]
    fn row_presence_codes() {
        let mut nb = NumericBatch::zeroed(4, 2);
        // row 0 aligned, row 1 removed, row 2 added, row 3 padding
        nb.ra[0] = 1.0;
        nb.rb[0] = 1.0;
        nb.ra[1] = 1.0;
        nb.rb[2] = 1.0;
        for j in 0..2 {
            nb.na[j] = 1.0;
            nb.nb[j] = 1.0;
        }
        let out = native_numeric_diff(&nb);
        assert_eq!(out.verdicts[0], Verdict::Equal as i32);
        assert_eq!(out.verdicts[2], Verdict::Removed as i32);
        assert_eq!(out.verdicts[3], Verdict::Removed as i32);
        assert_eq!(out.verdicts[4], Verdict::Added as i32);
        assert_eq!(out.verdicts[6], Verdict::Absent as i32);
        assert_eq!(out.counts.iter().sum::<i64>(), 8);
        assert_eq!(out.changed_rows, vec![0, 1, 1, 0]);
    }

    #[test]
    fn null_cells_in_aligned_rows() {
        let mut nb = NumericBatch::zeroed(1, 3);
        nb.ra[0] = 1.0;
        nb.rb[0] = 1.0;
        // col0: both null -> equal; col1: null vs value -> changed;
        // col2: both present equal.
        nb.nb[1] = 1.0;
        nb.b[1] = 5.0;
        nb.na[2] = 1.0;
        nb.nb[2] = 1.0;
        nb.a[2] = 3.0;
        nb.b[2] = 3.0;
        let out = native_numeric_diff(&nb);
        assert_eq!(out.verdicts, vec![0, 1, 0]);
        assert_eq!(out.col_changed, vec![0, 1, 0]);
        // masked garbage must not pollute maxabs
        assert_eq!(out.col_maxabs, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn string_and_bool_comparators() {
        let cfg = EngineConfig::default();
        assert_eq!(compare_str("a", "a", &cfg), Verdict::Equal);
        assert_eq!(compare_str("a", "A", &cfg), Verdict::Changed);
        let ci = EngineConfig { string_ci: true, ..EngineConfig::default() };
        assert_eq!(compare_str("a", "A", &ci), Verdict::Equal);
        assert_eq!(compare_bool(true, true), Verdict::Equal);
        assert_eq!(compare_bool(true, false), Verdict::Changed);
    }

    #[test]
    fn null_aware_wrapper() {
        assert_eq!(null_aware(true, true, || Verdict::Changed), Verdict::Equal);
        assert_eq!(null_aware(true, false, || Verdict::Equal), Verdict::Changed);
        assert_eq!(null_aware(false, false, || Verdict::Changed),
                   Verdict::Changed);
    }
}
