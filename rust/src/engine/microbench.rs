//! Calibration microbenchmarks (paper §III: "microbenchmarks fit T_Δ per
//! type on 5×10⁴-row shards").
//!
//! Measures the real engine's per-type cost constants on this machine;
//! the discrete-event testbed (`sim/`) consumes these so its batch-time
//! model is anchored to measured reality rather than invented numbers.

use std::sync::Arc;
use std::time::Instant;

use crate::config::EngineConfig;
use crate::data::generator::{generate_pair, GenSpec};
use crate::data::io::{InMemorySource, TableSource};
use crate::engine::comparators::{NativeExec, NumericDeltaExec};
use crate::engine::delta::{process_shard, JobPlan};
use crate::engine::schema_align::align_schemas;

/// Measured per-unit costs (nanoseconds unless noted). All linear-in-b
/// terms from the paper's Eq. 2 decomposition have a constant here.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostConstants {
    /// Read+decode, per byte.
    pub decode_ns_per_byte: f64,
    /// Row alignment (hash build + probe), per row.
    pub align_ns_per_row: f64,
    /// Δ evaluation, per numeric cell (accelerator-path batch).
    pub delta_numeric_ns_per_cell: f64,
    /// Δ evaluation, per native (string/bool) cell.
    pub delta_native_ns_per_cell: f64,
    /// Merge, per batch (sublinear in k; constant per batch here).
    pub merge_ns_per_batch: f64,
    /// Fixed per-batch scheduling cost (submit + bookkeeping).
    pub sched_ns_per_batch: f64,
    /// Effective read bandwidth observed during calibration, bytes/s.
    pub read_bw_bytes_per_s: f64,
}

impl Default for CostConstants {
    /// Fallback constants (order-of-magnitude for a modern core); used
    /// when calibration has not run. Benches always calibrate.
    fn default() -> Self {
        CostConstants {
            decode_ns_per_byte: 0.5,
            align_ns_per_row: 60.0,
            delta_numeric_ns_per_cell: 6.0,
            delta_native_ns_per_cell: 12.0,
            merge_ns_per_batch: 50_000.0,
            sched_ns_per_batch: 20_000.0,
            read_bw_bytes_per_s: 2.0e9,
        }
    }
}

impl CostConstants {
    /// Cost constants of the *paper's* SmartDiff engine (Python +
    /// pandas/Dask), reconstructed from the paper's own numbers: Table
    /// III tops out near 74–79 K rows/s on 32 cores (≈ 400 µs·core/row
    /// at ~16 compared columns) and Table I implies multi-second
    /// per-batch fixed overheads (task spawn, result serialization).
    /// The sim uses these when regenerating the paper's tables so the
    /// control problem lives in the same compute-bound regime; our rust
    /// engine's own (≈100× faster) constants from `calibrate` are used
    /// everywhere else. See DESIGN.md §4.2 / EXPERIMENTS.md.
    pub fn paper_engine() -> Self {
        CostConstants {
            decode_ns_per_byte: 8.0,           // ~32 µs/row at 4 KB rows
            align_ns_per_row: 40_000.0,        // python dict probe + key cmp
            delta_numeric_ns_per_cell: 18_000.0,
            delta_native_ns_per_cell: 30_000.0,
            merge_ns_per_batch: 1.0e9,         // concat + aggregate, ~1 s
            sched_ns_per_batch: 2.0e9,         // task spawn/teardown, ~2 s
            read_bw_bytes_per_s: 2.5e9,
        }
    }
}

/// Calibration shard size (paper: 5e4 rows).
pub const CALIB_ROWS: usize = 50_000;

/// Run the calibration pass on `rows`-row shards (use `CALIB_ROWS` for
/// paper-faithful settings; tests use less).
pub fn calibrate(rows: usize, seed: u64) -> CostConstants {
    let spec = GenSpec {
        rows,
        extra_cols: 7,
        seed,
        ..GenSpec::default()
    };
    let (a, b, _) = generate_pair(&spec);
    // lint: allow(unwrap) generated pairs share a schema by
    // construction; alignment cannot fail on them
    let aligned = align_schemas(&a.schema, &b.schema).unwrap();
    let plan = JobPlan::new(aligned, EngineConfig::default());
    let exec: Arc<dyn NumericDeltaExec> = Arc::new(NativeExec);

    // Decode: metered range reads through the source abstraction.
    let src = InMemorySource::new(a.clone());
    let t0 = Instant::now();
    let mut decoded_bytes = 0u64;
    let chunks = 8.max(rows / 4096);
    let chunk = rows / chunks;
    for i in 0..chunks {
        let t = src
            .read_range(i * chunk, chunk)
            // lint: allow(unwrap) in-memory reads over in-bounds ranges
            // are infallible
            .expect("in-memory calibration reads are infallible");
        decoded_bytes += t.heap_bytes() as u64;
    }
    let decode_ns = t0.elapsed().as_nanos() as f64;
    let decode_ns_per_byte = (decode_ns / decoded_bytes.max(1) as f64).max(1e-3);
    let read_bw = decoded_bytes as f64 / (decode_ns * 1e-9);

    // Full shard Δ (align + numeric + native): measure end-to-end, then
    // attribute by cell counts using a second alignment-only timing.
    let t0 = Instant::now();
    // lint: allow(unwrap) generated tables always row-align under their
    // own plan; a failure is a generator bug worth the panic
    let _al = crate::engine::row_align::align_rows(&a, &b, &plan.aligned).unwrap();
    let align_ns = t0.elapsed().as_nanos() as f64;
    let align_ns_per_row = align_ns / (a.nrows() + b.nrows()) as f64;

    let t0 = Instant::now();
    // lint: allow(unwrap) same argument as align_rows above
    let (outcome, _) = process_shard(0, &a, &b, &plan, &exec).unwrap();
    let total_ns = t0.elapsed().as_nanos() as f64;
    let delta_ns = (total_ns - align_ns).max(1.0);
    let n_numeric = plan.numeric_idx.len() as f64;
    let n_native = plan.native_idx.len() as f64;
    let nrows = (outcome.rows.aligned + outcome.rows.added + outcome.rows.removed)
        as f64;
    // Native cells cost ~2x numeric per cell (string compare + branchy
    // dispatch); solve delta_ns = rows*(n_num*x + n_nat*2x).
    let x = delta_ns / (nrows * (n_numeric + 2.0 * n_native)).max(1.0);
    let delta_numeric_ns_per_cell = x;
    let delta_native_ns_per_cell = 2.0 * x;

    // Merge + scheduling constants: measured over many tiny merges.
    let t0 = Instant::now();
    let reps = 64;
    for _ in 0..reps {
        let mut m = crate::engine::merge::Merger::new();
        m.push(outcome.clone());
        let _ = m.finish();
    }
    let merge_ns_per_batch = t0.elapsed().as_nanos() as f64 / reps as f64;

    CostConstants {
        decode_ns_per_byte,
        align_ns_per_row,
        delta_numeric_ns_per_cell,
        delta_native_ns_per_cell,
        merge_ns_per_batch,
        sched_ns_per_batch: merge_ns_per_batch * 0.4,
        read_bw_bytes_per_s: read_bw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_yields_positive_finite_constants() {
        let c = calibrate(4_000, 1);
        for v in [
            c.decode_ns_per_byte,
            c.align_ns_per_row,
            c.delta_numeric_ns_per_cell,
            c.delta_native_ns_per_cell,
            c.merge_ns_per_batch,
            c.sched_ns_per_batch,
            c.read_bw_bytes_per_s,
        ] {
            assert!(v.is_finite() && v > 0.0, "{c:?}");
        }
    }

    #[test]
    fn native_cells_cost_more_than_numeric() {
        let c = calibrate(2_000, 2);
        assert!(c.delta_native_ns_per_cell > c.delta_numeric_ns_per_cell);
    }
}
