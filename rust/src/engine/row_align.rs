//! Row alignment f: pair up rows of a decoded A-shard and B-shard by
//! key (paper §II: primary keys, composite business keys, or surrogate
//! row index).
//!
//! Implementation: hash join on the key cells with full-key verification
//! (collisions compared cell-by-cell). The hash-table footprint is the
//! paper's "alignment state for f" memory term — `align_state_bytes`
//! reports it for the batch memory accounting.

use std::collections::HashMap;

use crate::data::column::Cell;
use crate::data::table::Table;
use crate::engine::schema_align::AlignedSchema;

/// Result of aligning one shard pair. Indices are rows within the shard
/// tables (not global). Order is deterministic: pairs in A-row order,
/// removed in A-row order, added in B-row order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Alignment {
    pub pairs: Vec<(u32, u32)>,
    pub removed: Vec<u32>,
    pub added: Vec<u32>,
    /// Analytic footprint of the alignment hash state (bytes).
    pub align_state_bytes: usize,
}

/// FNV-1a over a cell's canonical bytes (cheap, deterministic).
fn hash_cell(h: &mut u64, cell: &Cell) {
    const PRIME: u64 = 0x1000_0000_01b3;
    let mut feed = |bytes: &[u8]| {
        for &b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(PRIME);
        }
    };
    match cell {
        Cell::Null => feed(&[0xff]),
        Cell::I64(x) => feed(&x.to_le_bytes()),
        Cell::F64(x) => feed(&x.to_bits().to_le_bytes()),
        Cell::Str(s) => feed(s.as_bytes()),
        Cell::Bool(b) => feed(&[*b as u8]),
        Cell::Date(d) => feed(&d.to_le_bytes()),
        Cell::Ts(t) => feed(&t.to_le_bytes()),
        Cell::Dec { mantissa, scale } => {
            feed(&mantissa.to_le_bytes());
            feed(&[*scale]);
        }
    }
}

fn key_hash(table: &Table, row: usize, key_cols_local: &[(usize, usize)],
            side_b: bool) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &(a_idx, b_idx) in key_cols_local {
        let idx = if side_b { b_idx } else { a_idx };
        hash_cell(&mut h, &table.column(idx).cell(row));
    }
    h
}

fn keys_equal(
    a: &Table,
    arow: usize,
    b: &Table,
    brow: usize,
    key_cols: &[(usize, usize)],
) -> bool {
    key_cols.iter().all(|&(ai, bi)| {
        cells_key_equal(&a.column(ai).cell(arow), &b.column(bi).cell(brow))
    })
}

/// Key equality is *exact* (no tolerance): keys identify rows.
/// Cross-numeric-type keys compare through f64 (documented coercion).
fn cells_key_equal(x: &Cell, y: &Cell) -> bool {
    use Cell::*;
    match (x, y) {
        (Null, Null) => true,
        (I64(a), I64(b)) => a == b,
        (F64(a), F64(b)) => a.to_bits() == b.to_bits(),
        (Str(a), Str(b)) => a == b,
        (Bool(a), Bool(b)) => a == b,
        (Date(a), Date(b)) => a == b,
        (Ts(a), Ts(b)) => a == b,
        (Dec { mantissa: ma, scale: sa }, Dec { mantissa: mb, scale: sb }) => {
            if sa == sb {
                ma == mb
            } else {
                dec_f64(*ma, *sa) == dec_f64(*mb, *sb)
            }
        }
        // Cross-type numeric keys.
        (I64(a), F64(b)) | (F64(b), I64(a)) => *a as f64 == *b,
        (I64(a), Dec { mantissa, scale }) | (Dec { mantissa, scale }, I64(a)) => {
            *a as f64 == dec_f64(*mantissa, *scale)
        }
        (F64(a), Dec { mantissa, scale }) | (Dec { mantissa, scale }, F64(a)) => {
            *a == dec_f64(*mantissa, *scale)
        }
        _ => false,
    }
}

fn dec_f64(mantissa: i128, scale: u8) -> f64 {
    mantissa as f64 / 10f64.powi(scale as i32)
}

/// Align shard tables on the aligned key columns.
///
/// Duplicate keys match positionally (i-th A occurrence ↔ i-th B
/// occurrence), which keeps the outcome multiset deterministic.
pub fn align_rows(
    a: &Table,
    b: &Table,
    aligned: &AlignedSchema,
) -> Result<Alignment, String> {
    let key_cols: Vec<(usize, usize)> = aligned
        .key_pairs()
        .into_iter()
        .map(|i| (aligned.pairs[i].a_idx, aligned.pairs[i].b_idx))
        .collect();
    if key_cols.is_empty() {
        return Ok(align_by_position(a, b));
    }

    // Build hash -> B-row list.
    let mut map: HashMap<u64, Vec<u32>> = HashMap::with_capacity(b.nrows());
    for brow in 0..b.nrows() {
        let h = key_hash(b, brow, &key_cols, true);
        map.entry(h).or_default().push(brow as u32);
    }
    let align_state_bytes = map.capacity()
        * (std::mem::size_of::<u64>() + std::mem::size_of::<Vec<u32>>() + 8)
        + b.nrows() * 4;

    let mut out = Alignment { align_state_bytes, ..Default::default() };
    let mut b_used = vec![false; b.nrows()];
    for arow in 0..a.nrows() {
        let h = key_hash(a, arow, &key_cols, false);
        let mut matched = None;
        if let Some(cands) = map.get(&h) {
            for &brow in cands {
                if !b_used[brow as usize]
                    && keys_equal(a, arow, b, brow as usize, &key_cols)
                {
                    matched = Some(brow);
                    break;
                }
            }
        }
        match matched {
            Some(brow) => {
                b_used[brow as usize] = true;
                out.pairs.push((arow as u32, brow));
            }
            None => out.removed.push(arow as u32),
        }
    }
    for (brow, used) in b_used.iter().enumerate() {
        if !used {
            out.added.push(brow as u32);
        }
    }
    Ok(out)
}

/// Surrogate alignment: i-th row of A ↔ i-th row of B.
fn align_by_position(a: &Table, b: &Table) -> Alignment {
    let n = a.nrows().min(b.nrows());
    let mut out = Alignment {
        pairs: (0..n as u32).map(|i| (i, i)).collect(),
        ..Default::default()
    };
    out.removed = (n as u32..a.nrows() as u32).collect();
    out.added = (n as u32..b.nrows() as u32).collect();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::{ColumnType, Field, Schema};
    use crate::data::table::TableBuilder;
    use crate::engine::schema_align::align_schemas;

    fn keyed_table(keys: &[i64], vals: &[f64]) -> Table {
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Float64),
        ]);
        let mut tb = TableBuilder::new(schema);
        for (k, v) in keys.iter().zip(vals) {
            tb.col(0).push_i64(*k);
            tb.col(1).push_f64(*v);
        }
        tb.finish()
    }

    #[test]
    fn basic_join_with_add_remove() {
        let a = keyed_table(&[1, 2, 3, 4], &[0.0; 4]);
        let b = keyed_table(&[2, 3, 5], &[0.0; 3]);
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs, vec![(1, 0), (2, 1)]);
        assert_eq!(r.removed, vec![0, 3]);
        assert_eq!(r.added, vec![2]);
        assert!(r.align_state_bytes > 0);
    }

    #[test]
    fn duplicate_keys_match_positionally() {
        let a = keyed_table(&[7, 7, 8], &[1.0, 2.0, 3.0]);
        let b = keyed_table(&[7, 7], &[1.0, 2.0]);
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs, vec![(0, 0), (1, 1)]);
        assert_eq!(r.removed, vec![2]);
        assert!(r.added.is_empty());
    }

    #[test]
    fn surrogate_alignment_when_keyless() {
        let schema = Schema::new(vec![Field::new("v", ColumnType::Float64)]);
        let mut ta = TableBuilder::new(schema.clone());
        let mut tb = TableBuilder::new(schema.clone());
        for i in 0..5 {
            ta.col(0).push_f64(i as f64);
        }
        for i in 0..3 {
            tb.col(0).push_f64(i as f64);
        }
        let (a, b) = (ta.finish(), tb.finish());
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs.len(), 3);
        assert_eq!(r.removed, vec![3, 4]);
        assert!(r.added.is_empty());
    }

    #[test]
    fn composite_string_keys() {
        let schema = Schema::new(vec![
            Field::key("region", ColumnType::Utf8),
            Field::key("code", ColumnType::Int64),
            Field::new("v", ColumnType::Float64),
        ]);
        let mk = |rows: &[(&str, i64)]| {
            let mut tb = TableBuilder::new(schema.clone());
            for (s, k) in rows {
                tb.col(0).push_str(s);
                tb.col(1).push_i64(*k);
                tb.col(2).push_f64(0.0);
            }
            tb.finish()
        };
        let a = mk(&[("eu", 1), ("us", 1), ("eu", 2)]);
        let b = mk(&[("us", 1), ("eu", 2), ("ap", 9)]);
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs, vec![(1, 0), (2, 1)]);
        assert_eq!(r.removed, vec![0]);
        assert_eq!(r.added, vec![2]);
    }

    #[test]
    fn null_keys_align_with_null() {
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Float64),
        ]);
        let mut ta = TableBuilder::new(schema.clone());
        ta.col(0).push_null();
        ta.col(1).push_f64(1.0);
        let mut tb = TableBuilder::new(schema.clone());
        tb.col(0).push_null();
        tb.col(1).push_f64(2.0);
        let (a, b) = (ta.finish(), tb.finish());
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs, vec![(0, 0)]);
    }

    #[test]
    fn cross_type_numeric_keys() {
        let sa = Schema::new(vec![Field::key("id", ColumnType::Int64)]);
        let sb = Schema::new(vec![Field::key("id", ColumnType::Float64)]);
        let mut ta = TableBuilder::new(sa);
        ta.col(0).push_i64(42);
        let mut tb = TableBuilder::new(sb);
        tb.col(0).push_f64(42.0);
        let (a, b) = (ta.finish(), tb.finish());
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        // hash differs across types, so cross-type keys fall back to
        // removed/added — exact cross-type joins require same storage
        // type. Verify the equality helper itself, which the verifier
        // uses when hashes do collide.
        assert!(cells_key_equal(&Cell::I64(42), &Cell::F64(42.0)));
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs.len() + r.removed.len(), 1);
    }
}
