//! Row alignment f: pair up rows of a decoded A-shard and B-shard by
//! key (paper §II: primary keys, composite business keys, or surrogate
//! row index).
//!
//! Implementation: hash join on the key columns with full-key
//! verification (hash collisions compared cell-by-cell). Key hashing is
//! *columnar*: each key column is hashed in one typed pass into a
//! per-row `Vec<u64>` accumulator (the type dispatch happens once per
//! column, not once per cell), and the join table is built from the
//! precomputed hashes. The table itself is open-addressed with
//! intrusive next-chains — no per-key `Vec` allocations — and all of it
//! lives in a reusable [`AlignScratch`] so steady-state alignment is
//! allocation-free. The hash-table footprint is the paper's "alignment
//! state for f" memory term — `align_state_bytes` reports it for the
//! batch memory accounting.
//!
//! [`align_rows_ref`] retains the original cell-at-a-time
//! implementation as the oracle for the hot-path parity property tests
//! (`rust/tests/hotpath_parity.rs`); both paths feed identical byte
//! streams into FNV-1a, so they produce identical alignments.

use std::collections::HashMap;

use crate::data::column::{Cell, Column, Values};
use crate::data::table::Table;
use crate::engine::schema_align::AlignedSchema;

/// Result of aligning one shard pair. Indices are rows within the shard
/// tables (not global). Order is deterministic: pairs in A-row order,
/// removed in A-row order, added in B-row order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Alignment {
    pub pairs: Vec<(u32, u32)>,
    pub removed: Vec<u32>,
    pub added: Vec<u32>,
    /// Analytic footprint of the alignment hash state (bytes).
    pub align_state_bytes: usize,
}

impl Alignment {
    /// Total row slots the Δ batch derives from this alignment.
    pub fn nrows(&self) -> usize {
        self.pairs.len() + self.removed.len() + self.added.len()
    }
    fn clear(&mut self) {
        self.pairs.clear();
        self.removed.clear();
        self.added.clear();
        self.align_state_bytes = 0;
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// The 64-bit FNV prime 2^40 + 2^8 + 0xb3.
const FNV_PRIME: u64 = 0x100_0000_01b3;
/// Byte fed for a NULL key cell (distinct from any value payload start).
const NULL_TAG: u8 = 0xff;

#[inline]
fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over a cell's canonical bytes (cheap, deterministic). Used by
/// the per-cell reference path; the columnar pass feeds the same bytes.
fn hash_cell(h: &mut u64, cell: &Cell) {
    match cell {
        Cell::Null => *h = fnv_bytes(*h, &[NULL_TAG]),
        Cell::I64(x) => *h = fnv_bytes(*h, &x.to_le_bytes()),
        Cell::F64(x) => *h = fnv_bytes(*h, &x.to_bits().to_le_bytes()),
        Cell::Str(s) => *h = fnv_bytes(*h, s.as_bytes()),
        Cell::Bool(b) => *h = fnv_bytes(*h, &[*b as u8]),
        Cell::Date(d) => *h = fnv_bytes(*h, &d.to_le_bytes()),
        Cell::Ts(t) => *h = fnv_bytes(*h, &t.to_le_bytes()),
        Cell::Dec { mantissa, scale } => {
            *h = fnv_bytes(*h, &mantissa.to_le_bytes());
            *h = fnv_bytes(*h, &[*scale]);
        }
    }
}

/// Fold one key column into the per-row hash accumulators: the `Values`
/// match happens once here, then each variant runs a tight typed loop.
/// Byte-compatible with `hash_cell` so the columnar and reference
/// alignments are identical.
fn hash_key_column(col: &Column, hashes: &mut [u64]) {
    debug_assert_eq!(col.len(), hashes.len());
    // One whole-column validity test up front; fully-valid key columns
    // (the common case) skip the per-row null branch entirely.
    let dense = col.validity.all_set();
    macro_rules! typed_pass {
        ($data:expr, $feed:expr) => {
            #[allow(clippy::redundant_closure_call)]
            if dense {
                for (i, h) in hashes.iter_mut().enumerate() {
                    *h = ($feed)($data, i, *h);
                }
            } else {
                for (i, h) in hashes.iter_mut().enumerate() {
                    if col.validity.get(i) {
                        *h = ($feed)($data, i, *h);
                    } else {
                        *h = fnv_bytes(*h, &[NULL_TAG]);
                    }
                }
            }
        };
    }
    match &col.values {
        Values::I64(v) => {
            typed_pass!(v, |d: &Vec<i64>, i: usize, h| fnv_bytes(
                h,
                &d[i].to_le_bytes()
            ))
        }
        Values::F64(v) => {
            typed_pass!(v, |d: &Vec<f64>, i: usize, h| fnv_bytes(
                h,
                &d[i].to_bits().to_le_bytes()
            ))
        }
        Values::Str(s) => {
            typed_pass!(s, |d: &crate::data::column::StrData, i: usize, h| {
                fnv_bytes(h, d.bytes_at(i))
            })
        }
        Values::Bool(b) => {
            typed_pass!(b, |d: &crate::data::column::Bitmap, i: usize, h| {
                fnv_bytes(h, &[d.get(i) as u8])
            })
        }
        Values::Date(v) => {
            typed_pass!(v, |d: &Vec<i32>, i: usize, h| fnv_bytes(
                h,
                &d[i].to_le_bytes()
            ))
        }
        Values::Ts(v) => {
            typed_pass!(v, |d: &Vec<i64>, i: usize, h| fnv_bytes(
                h,
                &d[i].to_le_bytes()
            ))
        }
        Values::Dec { mantissa, scale } => {
            let sc = *scale;
            for (i, h) in hashes.iter_mut().enumerate() {
                if col.validity.get(i) {
                    *h = fnv_bytes(*h, &mantissa[i].to_le_bytes());
                    *h = fnv_bytes(*h, &[sc]);
                } else {
                    *h = fnv_bytes(*h, &[NULL_TAG]);
                }
            }
        }
    }
}

fn keys_equal(
    a: &Table,
    arow: usize,
    b: &Table,
    brow: usize,
    key_cols: &[(usize, usize)],
) -> bool {
    key_cols.iter().all(|&(ai, bi)| {
        cells_key_equal(&a.column(ai).cell(arow), &b.column(bi).cell(brow))
    })
}

/// Key equality is *exact* (no tolerance): keys identify rows.
/// Cross-numeric-type keys compare through f64 (documented coercion).
fn cells_key_equal(x: &Cell, y: &Cell) -> bool {
    use Cell::*;
    match (x, y) {
        (Null, Null) => true,
        (I64(a), I64(b)) => a == b,
        (F64(a), F64(b)) => a.to_bits() == b.to_bits(),
        (Str(a), Str(b)) => a == b,
        (Bool(a), Bool(b)) => a == b,
        (Date(a), Date(b)) => a == b,
        (Ts(a), Ts(b)) => a == b,
        (Dec { mantissa: ma, scale: sa }, Dec { mantissa: mb, scale: sb }) => {
            if sa == sb {
                ma == mb
            } else {
                dec_f64(*ma, *sa) == dec_f64(*mb, *sb)
            }
        }
        // Cross-type numeric keys.
        (I64(a), F64(b)) | (F64(b), I64(a)) => *a as f64 == *b,
        (I64(a), Dec { mantissa, scale }) | (Dec { mantissa, scale }, I64(a)) => {
            *a as f64 == dec_f64(*mantissa, *scale)
        }
        (F64(a), Dec { mantissa, scale }) | (Dec { mantissa, scale }, F64(a)) => {
            *a == dec_f64(*mantissa, *scale)
        }
        _ => false,
    }
}

fn dec_f64(mantissa: i128, scale: u8) -> f64 {
    mantissa as f64 / 10f64.powi(scale as i32)
}

/// Sentinel for "no row" in heads/chains.
const NONE: u32 = u32::MAX;

/// Reusable alignment scratch: per-row hash accumulators plus the
/// open-addressed join table. Owned by one worker thread; after warm-up
/// the buffers are only resized within capacity, so steady-state
/// alignment performs no heap allocation.
#[derive(Debug, Default)]
pub struct AlignScratch {
    pub a_hash: Vec<u64>,
    pub b_hash: Vec<u64>,
    /// Open-addressed slots: (key hash, chain head B-row). A slot is
    /// empty iff head == NONE (a real entry always has a head row).
    pub slots: Vec<(u64, u32)>,
    /// Intrusive chains linking B rows that share a key hash, in
    /// ascending row order (positional duplicate matching relies on it).
    pub next: Vec<u32>,
    pub b_used: Vec<bool>,
}

impl AlignScratch {
    /// Bytes currently held by the scratch buffers (capacity-based —
    /// the real resident footprint).
    pub fn heap_bytes(&self) -> usize {
        (self.a_hash.capacity() + self.b_hash.capacity()) * 8
            + self.slots.capacity() * std::mem::size_of::<(u64, u32)>()
            + self.next.capacity() * 4
            + self.b_used.capacity()
    }
}

/// Spread a (already FNV-mixed) key hash over the table's power-of-two
/// index space.
#[inline]
fn probe_start(h: u64, mask: usize) -> usize {
    (h.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize & mask
}

/// Align shard tables on the aligned key columns.
///
/// Duplicate keys match positionally (i-th A occurrence ↔ i-th B
/// occurrence), which keeps the outcome multiset deterministic.
///
/// # Cross-shard occurrence contract
///
/// The pairing above is over *local* occurrences within the shard. A
/// shard may begin mid-run: its fragment of a duplicate-key run starts
/// at a global occurrence base carried in `ShardSpec::{a_occ_base,
/// b_occ_base}`. The occurrence-bounded partition rule
/// (`exec/partition.rs`) guarantees those bases are **equal** whenever
/// the straddling key is present on both sides, so pairing local
/// occurrence `i` with local occurrence `i` is exactly the global rule
/// "global occurrence `base + i` pairs with global occurrence
/// `base + i`" restricted to the shard. That is why this function
/// needs no base arithmetic and the per-shard outcomes still compose
/// bit-identically to the solo-shard reference (`align_rows_ref`) for
/// any fragmentation — the invariant is asserted against the spec in
/// `exec::worker::execute_shard_with` and fuzzed end-to-end in
/// `rust/tests/determinism.rs`.
///
/// Convenience wrapper over [`align_rows_into`] with throwaway scratch.
pub fn align_rows(
    a: &Table,
    b: &Table,
    aligned: &AlignedSchema,
) -> Result<Alignment, String> {
    let mut scratch = AlignScratch::default();
    let mut out = Alignment::default();
    align_rows_into(a, b, aligned, &mut scratch, &mut out)?;
    Ok(out)
}

/// Columnar hash-join alignment writing into caller-owned buffers.
pub fn align_rows_into(
    a: &Table,
    b: &Table,
    aligned: &AlignedSchema,
    scratch: &mut AlignScratch,
    out: &mut Alignment,
) -> Result<(), String> {
    out.clear();
    let key_cols: Vec<(usize, usize)> = aligned
        .key_pairs()
        .into_iter()
        .map(|i| (aligned.pairs[i].a_idx, aligned.pairs[i].b_idx))
        .collect();
    if key_cols.is_empty() {
        align_by_position(a, b, out);
        return Ok(());
    }
    let (na, nb) = (a.nrows(), b.nrows());

    // Columnar hash pass: one typed sweep per key column per side.
    scratch.a_hash.clear();
    scratch.a_hash.resize(na, FNV_OFFSET);
    scratch.b_hash.clear();
    scratch.b_hash.resize(nb, FNV_OFFSET);
    for &(a_idx, b_idx) in &key_cols {
        hash_key_column(a.column(a_idx), &mut scratch.a_hash);
        hash_key_column(b.column(b_idx), &mut scratch.b_hash);
    }

    // Build hash → B-row chains in an open-addressed table. Inserting
    // rows in reverse and prepending keeps each chain in ascending
    // B-row order, which the positional duplicate rule requires.
    let cap = (nb * 2).next_power_of_two().max(16);
    let mask = cap - 1;
    scratch.slots.clear();
    scratch.slots.resize(cap, (0u64, NONE));
    scratch.next.clear();
    scratch.next.resize(nb, NONE);
    for brow in (0..nb).rev() {
        let h = scratch.b_hash[brow];
        let mut idx = probe_start(h, mask);
        loop {
            let slot = &mut scratch.slots[idx];
            if slot.1 == NONE {
                *slot = (h, brow as u32);
                break;
            }
            if slot.0 == h {
                scratch.next[brow] = slot.1;
                slot.1 = brow as u32;
                break;
            }
            idx = (idx + 1) & mask;
        }
    }
    // Probe with precomputed A-side hashes; verify full keys per cell
    // only on hash hits (collision safety).
    scratch.b_used.clear();
    scratch.b_used.resize(nb, false);
    // Snapshot the footprint only after every scratch buffer has been
    // sized for this shard, so cold and warm calls report identically.
    out.align_state_bytes = scratch.heap_bytes();
    for arow in 0..na {
        let h = scratch.a_hash[arow];
        let mut matched = None;
        let mut idx = probe_start(h, mask);
        loop {
            let (sh, head) = scratch.slots[idx];
            if head == NONE {
                break; // hash absent on the B side
            }
            if sh == h {
                let mut cand = head;
                while cand != NONE {
                    if !scratch.b_used[cand as usize]
                        && keys_equal(a, arow, b, cand as usize, &key_cols)
                    {
                        matched = Some(cand);
                        break;
                    }
                    cand = scratch.next[cand as usize];
                }
                break;
            }
            idx = (idx + 1) & mask;
        }
        match matched {
            Some(brow) => {
                scratch.b_used[brow as usize] = true;
                out.pairs.push((arow as u32, brow));
            }
            None => out.removed.push(arow as u32),
        }
    }
    for (brow, used) in scratch.b_used.iter().enumerate() {
        if !used {
            out.added.push(brow as u32);
        }
    }
    Ok(())
}

/// Cell-at-a-time reference alignment (the pre-columnar implementation).
/// Retained as the oracle the property tests compare the hot path
/// against; not used on any execution path.
pub fn align_rows_ref(
    a: &Table,
    b: &Table,
    aligned: &AlignedSchema,
) -> Result<Alignment, String> {
    let key_cols: Vec<(usize, usize)> = aligned
        .key_pairs()
        .into_iter()
        .map(|i| (aligned.pairs[i].a_idx, aligned.pairs[i].b_idx))
        .collect();
    if key_cols.is_empty() {
        let mut out = Alignment::default();
        align_by_position(a, b, &mut out);
        return Ok(out);
    }
    let key_hash = |table: &Table, row: usize, side_b: bool| -> u64 {
        let mut h = FNV_OFFSET;
        for &(a_idx, b_idx) in &key_cols {
            let idx = if side_b { b_idx } else { a_idx };
            hash_cell(&mut h, &table.column(idx).cell(row));
        }
        h
    };

    let mut map: HashMap<u64, Vec<u32>> = HashMap::with_capacity(b.nrows());
    for brow in 0..b.nrows() {
        let h = key_hash(b, brow, true);
        map.entry(h).or_default().push(brow as u32);
    }
    let align_state_bytes = map.capacity()
        * (std::mem::size_of::<u64>() + std::mem::size_of::<Vec<u32>>() + 8)
        + b.nrows() * 4;

    let mut out = Alignment { align_state_bytes, ..Default::default() };
    let mut b_used = vec![false; b.nrows()];
    for arow in 0..a.nrows() {
        let h = key_hash(a, arow, false);
        let mut matched = None;
        if let Some(cands) = map.get(&h) {
            for &brow in cands {
                if !b_used[brow as usize]
                    && keys_equal(a, arow, b, brow as usize, &key_cols)
                {
                    matched = Some(brow);
                    break;
                }
            }
        }
        match matched {
            Some(brow) => {
                b_used[brow as usize] = true;
                out.pairs.push((arow as u32, brow));
            }
            None => out.removed.push(arow as u32),
        }
    }
    for (brow, used) in b_used.iter().enumerate() {
        if !used {
            out.added.push(brow as u32);
        }
    }
    Ok(out)
}

/// Surrogate alignment: i-th row of A ↔ i-th row of B.
fn align_by_position(a: &Table, b: &Table, out: &mut Alignment) {
    let n = a.nrows().min(b.nrows());
    out.pairs.extend((0..n as u32).map(|i| (i, i)));
    out.removed.extend(n as u32..a.nrows() as u32);
    out.added.extend(n as u32..b.nrows() as u32);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::{ColumnType, Field, Schema};
    use crate::data::table::TableBuilder;
    use crate::engine::schema_align::align_schemas;

    fn keyed_table(keys: &[i64], vals: &[f64]) -> Table {
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Float64),
        ]);
        let mut tb = TableBuilder::new(schema);
        for (k, v) in keys.iter().zip(vals) {
            tb.col(0).push_i64(*k);
            tb.col(1).push_f64(*v);
        }
        tb.finish()
    }

    #[test]
    fn fnv_prime_is_the_64bit_prime() {
        // 2^40 + 2^8 + 0xb3 — the canonical 64-bit FNV prime.
        assert_eq!(FNV_PRIME, (1u64 << 40) + (1 << 8) + 0xb3);
    }

    #[test]
    fn basic_join_with_add_remove() {
        let a = keyed_table(&[1, 2, 3, 4], &[0.0; 4]);
        let b = keyed_table(&[2, 3, 5], &[0.0; 3]);
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs, vec![(1, 0), (2, 1)]);
        assert_eq!(r.removed, vec![0, 3]);
        assert_eq!(r.added, vec![2]);
        assert!(r.align_state_bytes > 0);
    }

    #[test]
    fn duplicate_keys_match_positionally() {
        let a = keyed_table(&[7, 7, 8], &[1.0, 2.0, 3.0]);
        let b = keyed_table(&[7, 7], &[1.0, 2.0]);
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs, vec![(0, 0), (1, 1)]);
        assert_eq!(r.removed, vec![2]);
        assert!(r.added.is_empty());
    }

    #[test]
    fn surrogate_alignment_when_keyless() {
        let schema = Schema::new(vec![Field::new("v", ColumnType::Float64)]);
        let mut ta = TableBuilder::new(schema.clone());
        let mut tb = TableBuilder::new(schema.clone());
        for i in 0..5 {
            ta.col(0).push_f64(i as f64);
        }
        for i in 0..3 {
            tb.col(0).push_f64(i as f64);
        }
        let (a, b) = (ta.finish(), tb.finish());
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs.len(), 3);
        assert_eq!(r.removed, vec![3, 4]);
        assert!(r.added.is_empty());
    }

    #[test]
    fn composite_string_keys() {
        let schema = Schema::new(vec![
            Field::key("region", ColumnType::Utf8),
            Field::key("code", ColumnType::Int64),
            Field::new("v", ColumnType::Float64),
        ]);
        let mk = |rows: &[(&str, i64)]| {
            let mut tb = TableBuilder::new(schema.clone());
            for (s, k) in rows {
                tb.col(0).push_str(s);
                tb.col(1).push_i64(*k);
                tb.col(2).push_f64(0.0);
            }
            tb.finish()
        };
        let a = mk(&[("eu", 1), ("us", 1), ("eu", 2)]);
        let b = mk(&[("us", 1), ("eu", 2), ("ap", 9)]);
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs, vec![(1, 0), (2, 1)]);
        assert_eq!(r.removed, vec![0]);
        assert_eq!(r.added, vec![2]);
    }

    #[test]
    fn null_keys_align_with_null() {
        let schema = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Float64),
        ]);
        let mut ta = TableBuilder::new(schema.clone());
        ta.col(0).push_null();
        ta.col(1).push_f64(1.0);
        let mut tb = TableBuilder::new(schema.clone());
        tb.col(0).push_null();
        tb.col(1).push_f64(2.0);
        let (a, b) = (ta.finish(), tb.finish());
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs, vec![(0, 0)]);
    }

    #[test]
    fn cross_type_numeric_keys() {
        let sa = Schema::new(vec![Field::key("id", ColumnType::Int64)]);
        let sb = Schema::new(vec![Field::key("id", ColumnType::Float64)]);
        let mut ta = TableBuilder::new(sa);
        ta.col(0).push_i64(42);
        let mut tb = TableBuilder::new(sb);
        tb.col(0).push_f64(42.0);
        let (a, b) = (ta.finish(), tb.finish());
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        // hash differs across types, so cross-type keys fall back to
        // removed/added — exact cross-type joins require same storage
        // type. Verify the equality helper itself, which the verifier
        // uses when hashes do collide.
        assert!(cells_key_equal(&Cell::I64(42), &Cell::F64(42.0)));
        let r = align_rows(&a, &b, &al).unwrap();
        assert_eq!(r.pairs.len() + r.removed.len(), 1);
    }

    #[test]
    fn columnar_matches_reference_on_mixed_keys() {
        use crate::data::generator::{generate_pair, GenSpec};
        let (a, b, _) = generate_pair(&GenSpec {
            rows: 1_500,
            seed: 99,
            ..GenSpec::default()
        });
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let fast = align_rows(&a, &b, &al).unwrap();
        let slow = align_rows_ref(&a, &b, &al).unwrap();
        assert_eq!(fast.pairs, slow.pairs);
        assert_eq!(fast.removed, slow.removed);
        assert_eq!(fast.added, slow.added);
    }

    #[test]
    fn scratch_reuse_is_stable_and_correct() {
        let a = keyed_table(&[1, 2, 3, 4, 5, 6], &[0.0; 6]);
        let b = keyed_table(&[2, 4, 6, 7], &[0.0; 4]);
        let al = align_schemas(&a.schema, &b.schema).unwrap();
        let mut scratch = AlignScratch::default();
        let mut out = Alignment::default();
        align_rows_into(&a, &b, &al, &mut scratch, &mut out).unwrap();
        let first = out.clone();
        let caps = (
            scratch.a_hash.capacity(),
            scratch.b_hash.capacity(),
            scratch.slots.capacity(),
            scratch.next.capacity(),
            scratch.b_used.capacity(),
        );
        for _ in 0..5 {
            align_rows_into(&a, &b, &al, &mut scratch, &mut out).unwrap();
            assert_eq!(out, first);
        }
        let caps_after = (
            scratch.a_hash.capacity(),
            scratch.b_hash.capacity(),
            scratch.slots.capacity(),
            scratch.next.capacity(),
            scratch.b_used.capacity(),
        );
        assert_eq!(caps, caps_after, "steady-state must not reallocate");
    }
}
