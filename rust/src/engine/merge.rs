//! Merge step: concatenate batch outputs in stable shard order and
//! compute job-level aggregates (paper §II). The merged result is the
//! determinism anchor: it must be invariant to (b, k) and backend.
//!
//! Fragments of one duplicate-key run may arrive as several outcomes
//! (the partitioner cuts runs anywhere; straggler splits assign halves
//! fresh shard ids). They still merge into one deterministic report
//! region: every aggregate here is order-insensitive (sums, maxes,
//! per-column maps), and `diff_keys` — the only list — is globally
//! sorted in `finish()`, so equal-key entries from different fragments
//! coalesce identically no matter how the run was fragmented.

use std::collections::BTreeMap;

use crate::engine::verdict::{BatchOutcome, RowCounts, VerdictCounts};
use crate::util::json::ObjWriter;

/// Job-level diff report.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct JobReport {
    pub batches: u64,
    pub rows_a: u64,
    pub rows_b: u64,
    pub cells: VerdictCounts,
    pub rows: RowCounts,
    /// Per-column aggregates, keyed by aligned column name.
    pub columns: BTreeMap<String, ColumnAgg>,
    /// All diff-row keys, sorted (capped per shard upstream).
    pub diff_keys: Vec<i64>,
    pub diff_keys_truncated: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ColumnAgg {
    pub changed: u64,
    pub max_abs_delta: f64,
}

/// Stable merge: outcomes are sorted by shard id before aggregation so
/// the report is identical regardless of completion order.
pub struct Merger {
    outcomes: Vec<BatchOutcome>,
}

impl Merger {
    pub fn new() -> Self {
        Merger { outcomes: Vec::new() }
    }
    pub fn push(&mut self, outcome: BatchOutcome) {
        self.outcomes.push(outcome);
    }
    pub fn len(&self) -> usize {
        self.outcomes.len()
    }
    pub fn is_empty(&self) -> bool {
        self.outcomes.is_empty()
    }

    pub fn finish(mut self) -> JobReport {
        self.outcomes.sort_by_key(|o| o.shard_id);
        let mut report = JobReport { batches: self.outcomes.len() as u64, ..Default::default() };
        for o in &self.outcomes {
            report.rows_a += o.rows_a;
            report.rows_b += o.rows_b;
            report.cells.merge(&o.cells);
            report.rows.merge(&o.rows);
            for c in &o.columns {
                let agg = report.columns.entry(c.name.clone()).or_default();
                agg.changed += c.changed;
                if c.max_abs_delta > agg.max_abs_delta {
                    agg.max_abs_delta = c.max_abs_delta;
                }
            }
            report.diff_keys.extend_from_slice(&o.diff_keys);
            report.diff_keys_truncated |= o.diff_keys_truncated;
        }
        report.diff_keys.sort_unstable();
        report
    }
}

impl Default for Merger {
    fn default() -> Self {
        Self::new()
    }
}

impl JobReport {
    /// Multiset-equality check used by the determinism property tests:
    /// two reports describe the same diff iff all aggregates and the
    /// sorted key list agree.
    pub fn same_diff(&self, other: &JobReport) -> bool {
        self.cells == other.cells
            && self.rows == other.rows
            && self.columns == other.columns
            && self.diff_keys == other.diff_keys
            && self.rows_a == other.rows_a
            && self.rows_b == other.rows_b
    }

    pub fn to_json(&self) -> String {
        let mut cols = String::from("{");
        for (i, (name, agg)) in self.columns.iter().enumerate() {
            if i > 0 {
                cols.push(',');
            }
            cols.push_str(&crate::util::json::Json::Str(name.clone()).to_string());
            cols.push(':');
            cols.push_str(
                &ObjWriter::new()
                    .int("changed", agg.changed as i64)
                    .num("max_abs_delta", agg.max_abs_delta)
                    .finish(),
            );
        }
        cols.push('}');

        ObjWriter::new()
            .int("batches", self.batches as i64)
            .int("rows_a", self.rows_a as i64)
            .int("rows_b", self.rows_b as i64)
            .int("cells_equal", self.cells.equal as i64)
            .int("cells_changed", self.cells.changed as i64)
            .int("cells_added", self.cells.added as i64)
            .int("cells_removed", self.cells.removed as i64)
            .int("rows_aligned", self.rows.aligned as i64)
            .int("rows_changed", self.rows.changed_rows as i64)
            .int("rows_added", self.rows.added as i64)
            .int("rows_removed", self.rows.removed as i64)
            .int("diff_rows", self.diff_keys.len() as i64)
            .bool("diff_keys_truncated", self.diff_keys_truncated)
            .raw("columns", &cols)
            .finish()
    }

    /// Short human summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "rows: {} aligned ({} changed), {} added, {} removed | cells: \
             {} equal, {} changed | batches: {}",
            self.rows.aligned,
            self.rows.changed_rows,
            self.rows.added,
            self.rows.removed,
            self.cells.equal,
            self.cells.changed,
            self.batches
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::verdict::ColumnOutcome;

    fn outcome(shard: u64, changed: u64, key0: i64) -> BatchOutcome {
        BatchOutcome {
            shard_id: shard,
            rows_a: 10,
            rows_b: 10,
            cells: VerdictCounts { equal: 20 - changed, changed, ..Default::default() },
            rows: RowCounts { aligned: 10, changed_rows: changed.min(10), ..Default::default() },
            columns: vec![ColumnOutcome {
                name: "v".into(),
                changed,
                max_abs_delta: changed as f64,
            }],
            diff_keys: vec![key0, key0 + 1],
            diff_keys_truncated: false,
        }
    }

    #[test]
    fn merge_order_invariant() {
        let mut m1 = Merger::new();
        m1.push(outcome(0, 1, 0));
        m1.push(outcome(1, 2, 10));
        m1.push(outcome(2, 3, 20));
        let r1 = m1.finish();

        let mut m2 = Merger::new();
        m2.push(outcome(2, 3, 20));
        m2.push(outcome(0, 1, 0));
        m2.push(outcome(1, 2, 10));
        let r2 = m2.finish();

        assert!(r1.same_diff(&r2));
        assert_eq!(r1, r2);
        assert_eq!(r1.cells.changed, 6);
        assert_eq!(r1.columns["v"].changed, 6);
        assert_eq!(r1.columns["v"].max_abs_delta, 3.0);
        assert_eq!(r1.diff_keys, vec![0, 1, 10, 11, 20, 21]);
    }

    #[test]
    fn different_diffs_detected() {
        let mut m1 = Merger::new();
        m1.push(outcome(0, 1, 0));
        let mut m2 = Merger::new();
        m2.push(outcome(0, 2, 0));
        assert!(!m1.finish().same_diff(&m2.finish()));
    }

    #[test]
    fn json_emits_parseable_report() {
        let mut m = Merger::new();
        m.push(outcome(0, 1, 5));
        let r = m.finish();
        let j = crate::util::json::parse(&r.to_json()).unwrap();
        assert_eq!(j.get("batches").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("cells_changed").unwrap().as_i64(), Some(1));
        assert!(j.get("columns").unwrap().get("v").is_some());
    }

    #[test]
    fn summary_contains_key_numbers() {
        let mut m = Merger::new();
        m.push(outcome(0, 2, 0));
        let s = m.finish().summary();
        assert!(s.contains("10 aligned"));
        assert!(s.contains("2 changed"));
    }
}
