//! The per-shard Δ pipeline: row-align → numeric batch (accelerator
//! path) + native comparators → `BatchOutcome` with exact memory
//! accounting. This is the work a backend worker executes per batch;
//! the scheduler never looks inside.
//!
//! The hot path is columnar end-to-end: the numeric batch is filled by
//! per-column typed gather loops (one `Values` match per column, then a
//! tight strided write loop), native string/bool columns compare through
//! direct `StrData` byte views / `Bitmap` reads, and every R×C-scale
//! buffer lives in a reusable per-worker [`ShardScratch`] so that
//! steady-state shard execution allocates nothing beyond the returned
//! outcome. [`process_shard_ref`] keeps the original cell-at-a-time
//! implementation as the parity oracle (see `rust/tests/hotpath_parity.rs`
//! and the "Engine hot path" notes in `engine/mod.rs`).
//!
//! A shard may be a *fragment of a duplicate-key run*: the partitioner
//! cuts runs anywhere and bounds both sides at the same occurrence
//! ordinal, so the alignment's local positional pairing is the global
//! pairing shifted by the shard's (equal) occurrence bases — per-shard
//! outcomes therefore merge bit-identically to the solo-shard result
//! regardless of where runs were cut (see `engine/row_align.rs` and
//! `exec/partition.rs`).

use std::sync::Arc;

use crate::config::EngineConfig;
use crate::data::column::{Cell, Column, Values};
use crate::data::schema::ColumnType;
use crate::data::table::Table;
use crate::engine::comparators::{
    compare_bool, compare_str, null_aware, NumericBatch, NumericDeltaExec,
    NumericDiffOut,
};
use crate::engine::row_align::{
    align_rows_into, align_rows_ref, AlignScratch, Alignment,
};
use crate::engine::schema_align::{AlignedSchema, CompareKind};
use crate::engine::verdict::{
    BatchOutcome, ColumnOutcome, RowCounts, Verdict, VerdictCounts,
    KEY_SAMPLE_CAP,
};

/// Immutable per-job plan shared by all shards: schema alignment plus
/// per-column tolerances derived from the engine config.
#[derive(Debug, Clone)]
pub struct JobPlan {
    pub aligned: AlignedSchema,
    pub cfg: EngineConfig,
    /// Indices into `aligned.pairs` of the numeric (accelerator-path)
    /// columns, and their per-column tolerances.
    pub numeric_idx: Vec<usize>,
    pub atol: Vec<f64>,
    pub rtol: Vec<f64>,
    pub native_idx: Vec<usize>,
}

impl JobPlan {
    pub fn new(aligned: AlignedSchema, cfg: EngineConfig) -> JobPlan {
        let numeric_idx = aligned.numeric_pairs();
        let native_idx = aligned.native_pairs();
        let mut atol = Vec::with_capacity(numeric_idx.len());
        let mut rtol = Vec::with_capacity(numeric_idx.len());
        for &pi in &numeric_idx {
            let p = &aligned.pairs[pi];
            // Tolerance policy per type family: exact for integral types,
            // configured atol/rtol for float/decimal, configured
            // microsecond window for timestamps.
            let (a, r) = match (p.a_ty, p.b_ty) {
                (ColumnType::Timestamp, ColumnType::Timestamp) => {
                    (cfg.ts_tolerance_us as f64, 0.0)
                }
                (ColumnType::Int64, ColumnType::Int64)
                | (ColumnType::Date, ColumnType::Date) => (0.0, 0.0),
                _ => (cfg.atol, cfg.rtol),
            };
            atol.push(a);
            rtol.push(r);
        }
        JobPlan { aligned, cfg, numeric_idx, atol, rtol, native_idx }
    }
}

/// Memory accounting for one shard execution (paper §II resource model:
/// decode buffers + alignment state + Δ scratch).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ShardMemStats {
    pub decode_bytes: usize,
    pub align_bytes: usize,
    pub scratch_bytes: usize,
}

impl ShardMemStats {
    pub fn peak(&self) -> usize {
        self.decode_bytes + self.align_bytes + self.scratch_bytes
    }
}

/// Reusable per-worker Δ scratch: alignment state, the numeric batch,
/// kernel outputs, and the row-diff flags. Ownership rule: exactly one
/// `ShardScratch` per worker thread, threaded by `&mut` through
/// `process_shard_with` — never shared across concurrently executing
/// shards. After the first shard of a given shape the buffers are only
/// resized within capacity, so steady-state execution is allocation-free
/// (asserted by the capacity-stability test in `tests/hotpath_parity.rs`).
///
/// Memory-model note: `ShardMemStats.scratch_bytes` reports the
/// capacity-based (real resident) footprint per batch, and the worker
/// accounts it against its `MemTracker` while the batch executes. The
/// warmed scratch also stays resident between shards — at most one
/// shard's scratch per worker — which the per-batch ledger deliberately
/// does not double-count while the worker is idle.
#[derive(Debug, Default)]
pub struct ShardScratch {
    pub align: AlignScratch,
    pub alignment: Alignment,
    pub batch: NumericBatch,
    pub diff: NumericDiffOut,
    pub row_diff: Vec<bool>,
}

impl ShardScratch {
    /// Resident bytes held by a warmed scratch between shards
    /// (capacity-based). The worker pool accounts this as a persistent
    /// per-worker reservation while the worker is idle, so
    /// `Backend::current_rss()` reflects the real steady-state
    /// footprint between batches (during a batch the per-batch ledger
    /// covers the same buffers instead).
    pub fn heap_bytes(&self) -> usize {
        self.align.heap_bytes()
            + self.alignment.pairs.capacity() * 8
            + self.alignment.removed.capacity() * 4
            + self.alignment.added.capacity() * 4
            + self.batch.heap_bytes()
            + self.diff.heap_bytes()
            + self.row_diff.capacity()
    }
}

#[inline]
fn numeric_value(table: &Table, col: usize, row: usize) -> Option<f64> {
    let c = table.column(col);
    if c.is_null(row) {
        return None;
    }
    match c.cell(row) {
        Cell::I64(x) => Some(x as f64),
        Cell::F64(x) => Some(x),
        Cell::Date(d) => Some(d as f64),
        Cell::Ts(t) => Some(t as f64),
        Cell::Dec { mantissa, scale } => {
            Some(mantissa as f64 / 10f64.powi(scale as i32))
        }
        _ => None,
    }
}

/// Gather one column's numeric f64 view into the batch at column `j`,
/// visiting `(slot, row)` pairs. The `Values` match happens once per
/// call; each arm is a tight typed loop writing `vals`/`mask` strided.
/// Value coercion is bit-identical to `numeric_value`.
fn gather_numeric_column(
    col: &Column,
    rows: impl Iterator<Item = (usize, u32)>,
    cols: usize,
    j: usize,
    vals: &mut [f64],
    mask: &mut [f64],
) {
    // One whole-column validity test up front; fully-valid columns (the
    // common case) take the branch-free dense loop.
    let dense = col.validity.all_set();
    macro_rules! typed_gather {
        ($conv:expr) => {
            if dense {
                for (slot, row) in rows {
                    let idx = slot * cols + j;
                    vals[idx] = $conv(row as usize);
                    mask[idx] = 1.0;
                }
            } else {
                for (slot, row) in rows {
                    let r = row as usize;
                    if col.validity.get(r) {
                        let idx = slot * cols + j;
                        vals[idx] = $conv(r);
                        mask[idx] = 1.0;
                    }
                }
            }
        };
    }
    match &col.values {
        Values::I64(v) => typed_gather!(|r: usize| v[r] as f64),
        Values::F64(v) => typed_gather!(|r: usize| v[r]),
        Values::Date(v) => typed_gather!(|r: usize| v[r] as f64),
        Values::Ts(v) => typed_gather!(|r: usize| v[r] as f64),
        Values::Dec { mantissa, scale } => {
            // Same divisor expression as `numeric_value` (division, not
            // reciprocal multiply) so results stay bit-identical.
            let div = 10f64.powi(*scale as i32);
            typed_gather!(|r: usize| mantissa[r] as f64 / div)
        }
        // Non-numeric storage never reaches the accelerator path; the
        // mask stays 0 exactly like `numeric_value` returning None.
        Values::Str(_) | Values::Bool(_) => {}
    }
}

/// Fill the numeric batch for one alignment via per-column typed
/// gathers, reusing `nb`'s buffers. Row slot layout: aligned pairs,
/// then removed (ra=1, rb=0), then added (ra=0, rb=1).
pub fn fill_numeric_batch_into(
    plan: &JobPlan,
    a_tbl: &Table,
    b_tbl: &Table,
    al: &Alignment,
    nb: &mut NumericBatch,
) {
    let rows = al.nrows();
    let cols = plan.numeric_idx.len();
    nb.reset(rows, cols);
    nb.atol.copy_from_slice(&plan.atol);
    nb.rtol.copy_from_slice(&plan.rtol);

    let pairs_n = al.pairs.len();
    let a_rows_n = pairs_n + al.removed.len();
    for s in 0..a_rows_n {
        nb.ra[s] = 1.0;
    }
    for s in 0..pairs_n {
        nb.rb[s] = 1.0;
    }
    for s in a_rows_n..rows {
        nb.rb[s] = 1.0;
    }

    for (j, &pi) in plan.numeric_idx.iter().enumerate() {
        let p = &plan.aligned.pairs[pi];
        gather_numeric_column(
            a_tbl.column(p.a_idx),
            al.pairs
                .iter()
                .map(|&(ar, _)| ar)
                .chain(al.removed.iter().copied())
                .enumerate(),
            cols,
            j,
            &mut nb.a,
            &mut nb.na,
        );
        gather_numeric_column(
            b_tbl.column(p.b_idx),
            al.pairs
                .iter()
                .map(|&(_, br)| br)
                .enumerate()
                .chain(
                    al.added
                        .iter()
                        .copied()
                        .enumerate()
                        .map(|(i, br)| (a_rows_n + i, br)),
                ),
            cols,
            j,
            &mut nb.b,
            &mut nb.nb,
        );
    }
}

/// Cell-at-a-time batch fill (the pre-columnar implementation), kept as
/// the parity oracle for tests and the stage microbench baseline.
pub fn fill_numeric_batch_ref(
    plan: &JobPlan,
    a_tbl: &Table,
    b_tbl: &Table,
    al: &Alignment,
) -> NumericBatch {
    let rows = al.nrows();
    let cols = plan.numeric_idx.len();
    let mut nb = NumericBatch::zeroed(rows, cols);
    nb.atol.copy_from_slice(&plan.atol);
    nb.rtol.copy_from_slice(&plan.rtol);

    let mut fill_row = |slot: usize, arow: Option<u32>, brow: Option<u32>| {
        if let Some(ar) = arow {
            nb.ra[slot] = 1.0;
            for (j, &pi) in plan.numeric_idx.iter().enumerate() {
                let p = &plan.aligned.pairs[pi];
                if let Some(v) = numeric_value(a_tbl, p.a_idx, ar as usize) {
                    nb.a[slot * cols + j] = v;
                    nb.na[slot * cols + j] = 1.0;
                }
            }
        }
        if let Some(br) = brow {
            nb.rb[slot] = 1.0;
            for (j, &pi) in plan.numeric_idx.iter().enumerate() {
                let p = &plan.aligned.pairs[pi];
                if let Some(v) = numeric_value(b_tbl, p.b_idx, br as usize) {
                    nb.b[slot * cols + j] = v;
                    nb.nb[slot * cols + j] = 1.0;
                }
            }
        }
    };

    let mut slot = 0;
    for &(ar, br) in &al.pairs {
        fill_row(slot, Some(ar), Some(br));
        slot += 1;
    }
    for &ar in &al.removed {
        fill_row(slot, Some(ar), None);
        slot += 1;
    }
    for &br in &al.added {
        fill_row(slot, None, Some(br));
        slot += 1;
    }
    nb
}

/// Key of a row (first aligned key column, i64 view) for diff records.
fn row_key(plan: &JobPlan, table: &Table, a_side: bool, row: u32) -> i64 {
    for pi in plan.aligned.key_pairs() {
        let p = &plan.aligned.pairs[pi];
        let col = if a_side { p.a_idx } else { p.b_idx };
        if let Some(v) = numeric_value(table, col, row as usize) {
            return v as i64;
        }
    }
    row as i64
}

/// Compare one native (string/bool) column pair over the aligned rows,
/// with the type dispatch hoisted out of the row loop. Strings compare
/// through direct `StrData` byte views (no `Cell`, no UTF-8 revalidation);
/// equality under `string_ci` is ASCII-case-insensitive, byte-identical
/// in outcome to `compare_str`.
#[allow(clippy::too_many_arguments)]
fn native_column_pass(
    kind: CompareKind,
    ac: &Column,
    bc: &Column,
    cfg: &EngineConfig,
    al: &Alignment,
    cells: &mut VerdictCounts,
    row_diff: &mut [bool],
) -> u64 {
    let mut changed = 0u64;
    match (&ac.values, &bc.values) {
        (Values::Str(sa), Values::Str(sb)) => {
            let ci = cfg.string_ci;
            for (slot, &(ar, br)) in al.pairs.iter().enumerate() {
                let (ar, br) = (ar as usize, br as usize);
                let a_null = ac.is_null(ar);
                let b_null = bc.is_null(br);
                let eq = if a_null || b_null {
                    a_null && b_null
                } else {
                    let xa = sa.bytes_at(ar);
                    let xb = sb.bytes_at(br);
                    if ci {
                        xa.eq_ignore_ascii_case(xb)
                    } else {
                        xa == xb
                    }
                };
                if eq {
                    cells.equal += 1;
                } else {
                    cells.changed += 1;
                    changed += 1;
                    row_diff[slot] = true;
                }
            }
        }
        (Values::Bool(ba), Values::Bool(bb)) => {
            for (slot, &(ar, br)) in al.pairs.iter().enumerate() {
                let (ar, br) = (ar as usize, br as usize);
                let a_null = ac.is_null(ar);
                let b_null = bc.is_null(br);
                let eq = if a_null || b_null {
                    a_null && b_null
                } else {
                    ba.get(ar) == bb.get(br)
                };
                if eq {
                    cells.equal += 1;
                } else {
                    cells.changed += 1;
                    changed += 1;
                    row_diff[slot] = true;
                }
            }
        }
        // Storage/kind mismatch (malformed plan): fall back to the
        // defensive per-cell path, which reports Changed.
        _ => {
            for (slot, &(ar, br)) in al.pairs.iter().enumerate() {
                let v = null_aware(
                    ac.is_null(ar as usize),
                    bc.is_null(br as usize),
                    || match kind {
                        CompareKind::String => {
                            let (Cell::Str(x), Cell::Str(y)) =
                                (ac.cell(ar as usize), bc.cell(br as usize))
                            else {
                                return Verdict::Changed;
                            };
                            compare_str(x, y, cfg)
                        }
                        CompareKind::Bool => {
                            let (Cell::Bool(x), Cell::Bool(y)) =
                                (ac.cell(ar as usize), bc.cell(br as usize))
                            else {
                                return Verdict::Changed;
                            };
                            compare_bool(x, y)
                        }
                        CompareKind::Numeric => unreachable!(),
                    },
                );
                cells.record(v, 1);
                if v == Verdict::Changed {
                    changed += 1;
                    row_diff[slot] = true;
                }
            }
        }
    }
    changed
}

/// Execute Δ over one decoded shard pair with throwaway scratch.
/// Workers on the hot path use [`process_shard_with`] instead, reusing
/// a per-thread [`ShardScratch`].
pub fn process_shard(
    shard_id: u64,
    a_tbl: &Table,
    b_tbl: &Table,
    plan: &JobPlan,
    exec: &Arc<dyn NumericDeltaExec>,
) -> Result<(BatchOutcome, ShardMemStats), String> {
    let mut scratch = ShardScratch::default();
    process_shard_with(shard_id, a_tbl, b_tbl, plan, exec, &mut scratch)
}

/// Execute Δ over one decoded shard pair, reusing `scratch` buffers.
pub fn process_shard_with(
    shard_id: u64,
    a_tbl: &Table,
    b_tbl: &Table,
    plan: &JobPlan,
    exec: &Arc<dyn NumericDeltaExec>,
    scratch: &mut ShardScratch,
) -> Result<(BatchOutcome, ShardMemStats), String> {
    let (outcome, mem, _align_ns, _diff_ns) =
        process_shard_timed(shard_id, a_tbl, b_tbl, plan, exec, scratch)?;
    Ok((outcome, mem))
}

/// [`process_shard_with`] plus the align/diff wall-time split (ns) for
/// stage-level telemetry: the first element times `align_rows_into`,
/// the second everything after it (numeric batch + native passes +
/// outcome assembly).
pub fn process_shard_timed(
    shard_id: u64,
    a_tbl: &Table,
    b_tbl: &Table,
    plan: &JobPlan,
    exec: &Arc<dyn NumericDeltaExec>,
    scratch: &mut ShardScratch,
) -> Result<(BatchOutcome, ShardMemStats, u64, u64), String> {
    // Carved add-range shard (`a_len = 0`, see `exec/partition.rs`):
    // every B row is pure Added, so skip the join build and the numeric
    // batch entirely and emit the outcome directly. Bit-identical to
    // the general path on the same inputs — Added verdicts for every
    // cell, zero per-column change/delta, added keys in B-row order —
    // while touching no alignment or kernel scratch at all.
    if a_tbl.nrows() == 0 && b_tbl.nrows() > 0 {
        let t_diff = std::time::Instant::now();
        let nb = b_tbl.nrows() as u64;
        let ncols = plan.aligned.pairs.len();
        let mut cells = VerdictCounts::default();
        cells.record(Verdict::Added, nb * ncols as u64);
        let columns: Vec<ColumnOutcome> = plan
            .aligned
            .pairs
            .iter()
            .map(|p| ColumnOutcome { name: p.name.clone(), changed: 0, max_abs_delta: 0.0 })
            .collect();
        let mut diff_keys = Vec::new();
        let mut truncated = false;
        for br in 0..b_tbl.nrows() as u32 {
            if diff_keys.len() < KEY_SAMPLE_CAP {
                diff_keys.push(row_key(plan, b_tbl, false, br));
            } else {
                truncated = true;
                break;
            }
        }
        let outcome = BatchOutcome {
            shard_id,
            rows_a: 0,
            rows_b: nb,
            cells,
            rows: RowCounts {
                aligned: 0,
                added: nb,
                removed: 0,
                changed_rows: 0,
            },
            columns,
            diff_keys,
            diff_keys_truncated: truncated,
        };
        let mem = ShardMemStats {
            decode_bytes: b_tbl.heap_bytes(),
            align_bytes: 0,
            scratch_bytes: 0,
        };
        return Ok((outcome, mem, 0, t_diff.elapsed().as_nanos() as u64));
    }

    let ShardScratch { align, alignment, batch, diff, row_diff } = scratch;
    let t_align = std::time::Instant::now();
    align_rows_into(a_tbl, b_tbl, &plan.aligned, align, alignment)?;
    let align_ns = t_align.elapsed().as_nanos() as u64;
    let t_diff = std::time::Instant::now();
    let al: &Alignment = alignment;
    let nrows = al.nrows();
    let ncols = plan.aligned.pairs.len();

    let mut cells = VerdictCounts::default();
    let mut columns: Vec<ColumnOutcome> = plan
        .aligned
        .pairs
        .iter()
        .map(|p| ColumnOutcome { name: p.name.clone(), changed: 0, max_abs_delta: 0.0 })
        .collect();
    row_diff.clear();
    row_diff.resize(nrows, false);
    let mut scratch_bytes = 0usize;

    // --- numeric columns: accelerator-path batch ---
    if !plan.numeric_idx.is_empty() && nrows > 0 {
        fill_numeric_batch_into(plan, a_tbl, b_tbl, al, batch);
        scratch_bytes += batch.heap_bytes();
        exec.diff_into(batch, diff)?;
        scratch_bytes += diff.verdicts.capacity() * 4;
        if diff.counts[Verdict::Absent as i32 as usize] != 0 {
            return Err("kernel reported ABSENT cells for unpadded batch".into());
        }
        cells.merge(&VerdictCounts::from_codes(&diff.counts));
        for (j, &pi) in plan.numeric_idx.iter().enumerate() {
            columns[pi].changed = diff.col_changed[j] as u64;
            columns[pi].max_abs_delta = diff.col_maxabs[j];
        }
        for (i, flag) in diff.changed_rows.iter().enumerate() {
            if *flag != 0 {
                row_diff[i] = true;
            }
        }
    }

    // --- native columns (strings, bools) ---
    for &pi in &plan.native_idx {
        let p = &plan.aligned.pairs[pi];
        let changed = native_column_pass(
            p.kind,
            a_tbl.column(p.a_idx),
            b_tbl.column(p.b_idx),
            &plan.cfg,
            al,
            &mut cells,
            row_diff,
        );
        // Removed/added rows contribute one removed/added cell per column.
        cells.record(Verdict::Removed, al.removed.len() as u64);
        cells.record(Verdict::Added, al.added.len() as u64);
        columns[pi].changed = changed;
    }
    // removed/added rows always differ.
    let pairs_n = al.pairs.len();
    for i in pairs_n..nrows {
        row_diff[i] = true;
    }

    // --- row counts + diff keys ---
    let mut rows = RowCounts {
        aligned: pairs_n as u64,
        added: al.added.len() as u64,
        removed: al.removed.len() as u64,
        changed_rows: 0,
    };
    let mut diff_keys = Vec::new();
    let mut truncated = false;
    let mut push_key = |k: i64| {
        if diff_keys.len() < KEY_SAMPLE_CAP {
            diff_keys.push(k);
        } else {
            truncated = true;
        }
    };
    for (slot, &(ar, _br)) in al.pairs.iter().enumerate() {
        if row_diff[slot] {
            rows.changed_rows += 1;
            push_key(row_key(plan, a_tbl, true, ar));
        }
    }
    for &ar in &al.removed {
        push_key(row_key(plan, a_tbl, true, ar));
    }
    for &br in &al.added {
        push_key(row_key(plan, b_tbl, false, br));
    }

    let expected_cells = (nrows as u64) * (ncols as u64);
    debug_assert_eq!(cells.total(), expected_cells, "cell accounting");

    let outcome = BatchOutcome {
        shard_id,
        rows_a: a_tbl.nrows() as u64,
        rows_b: b_tbl.nrows() as u64,
        cells,
        rows,
        columns,
        diff_keys,
        diff_keys_truncated: truncated,
    };
    let mem = ShardMemStats {
        decode_bytes: a_tbl.heap_bytes() + b_tbl.heap_bytes(),
        align_bytes: al.align_state_bytes,
        scratch_bytes,
    };
    Ok((outcome, mem, align_ns, t_diff.elapsed().as_nanos() as u64))
}

/// Cell-at-a-time reference Δ (the pre-columnar implementation): per-row
/// closures over `Column::cell()` everywhere. Retained as the oracle the
/// parity property tests compare `process_shard` against; not used on
/// any execution path.
pub fn process_shard_ref(
    shard_id: u64,
    a_tbl: &Table,
    b_tbl: &Table,
    plan: &JobPlan,
    exec: &Arc<dyn NumericDeltaExec>,
) -> Result<(BatchOutcome, ShardMemStats), String> {
    let al = align_rows_ref(a_tbl, b_tbl, &plan.aligned)?;
    let nrows = al.nrows();
    let ncols = plan.aligned.pairs.len();

    let mut cells = VerdictCounts::default();
    let mut columns: Vec<ColumnOutcome> = plan
        .aligned
        .pairs
        .iter()
        .map(|p| ColumnOutcome { name: p.name.clone(), changed: 0, max_abs_delta: 0.0 })
        .collect();
    let mut row_diff = vec![false; nrows];
    let mut scratch_bytes = 0usize;

    if !plan.numeric_idx.is_empty() && nrows > 0 {
        let nb = fill_numeric_batch_ref(plan, a_tbl, b_tbl, &al);
        scratch_bytes += nb.heap_bytes();
        let out = exec.diff(&nb)?;
        scratch_bytes += out.verdicts.capacity() * 4;
        if out.counts[Verdict::Absent as i32 as usize] != 0 {
            return Err("kernel reported ABSENT cells for unpadded batch".into());
        }
        cells.merge(&VerdictCounts::from_codes(&out.counts));
        for (j, &pi) in plan.numeric_idx.iter().enumerate() {
            columns[pi].changed = out.col_changed[j] as u64;
            columns[pi].max_abs_delta = out.col_maxabs[j];
        }
        for (i, flag) in out.changed_rows.iter().enumerate() {
            if *flag != 0 {
                row_diff[i] = true;
            }
        }
    }

    for &pi in &plan.native_idx {
        let p = &plan.aligned.pairs[pi];
        let (ac, bc) = (a_tbl.column(p.a_idx), b_tbl.column(p.b_idx));
        let mut changed = 0u64;
        for (slot, &(ar, br)) in al.pairs.iter().enumerate() {
            let v = null_aware(
                ac.is_null(ar as usize),
                bc.is_null(br as usize),
                || match p.kind {
                    CompareKind::String => {
                        let (Cell::Str(x), Cell::Str(y)) =
                            (ac.cell(ar as usize), bc.cell(br as usize))
                        else {
                            return Verdict::Changed;
                        };
                        compare_str(x, y, &plan.cfg)
                    }
                    CompareKind::Bool => {
                        let (Cell::Bool(x), Cell::Bool(y)) =
                            (ac.cell(ar as usize), bc.cell(br as usize))
                        else {
                            return Verdict::Changed;
                        };
                        compare_bool(x, y)
                    }
                    CompareKind::Numeric => unreachable!(),
                },
            );
            cells.record(v, 1);
            if v == Verdict::Changed {
                changed += 1;
                row_diff[slot] = true;
            }
        }
        cells.record(Verdict::Removed, al.removed.len() as u64);
        cells.record(Verdict::Added, al.added.len() as u64);
        columns[pi].changed = changed;
    }
    let pairs_n = al.pairs.len();
    for i in pairs_n..nrows {
        row_diff[i] = true;
    }

    let mut rows = RowCounts {
        aligned: pairs_n as u64,
        added: al.added.len() as u64,
        removed: al.removed.len() as u64,
        changed_rows: 0,
    };
    let mut diff_keys = Vec::new();
    let mut truncated = false;
    let mut push_key = |k: i64| {
        if diff_keys.len() < KEY_SAMPLE_CAP {
            diff_keys.push(k);
        } else {
            truncated = true;
        }
    };
    for (slot, &(ar, _br)) in al.pairs.iter().enumerate() {
        if row_diff[slot] {
            rows.changed_rows += 1;
            push_key(row_key(plan, a_tbl, true, ar));
        }
    }
    for &ar in &al.removed {
        push_key(row_key(plan, a_tbl, true, ar));
    }
    for &br in &al.added {
        push_key(row_key(plan, b_tbl, false, br));
    }

    debug_assert_eq!(cells.total(), (nrows as u64) * (ncols as u64));

    let outcome = BatchOutcome {
        shard_id,
        rows_a: a_tbl.nrows() as u64,
        rows_b: b_tbl.nrows() as u64,
        cells,
        rows,
        columns,
        diff_keys,
        diff_keys_truncated: truncated,
    };
    let mem = ShardMemStats {
        decode_bytes: a_tbl.heap_bytes() + b_tbl.heap_bytes(),
        align_bytes: al.align_state_bytes,
        scratch_bytes,
    };
    Ok((outcome, mem))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::{generate_pair, GenSpec};
    use crate::engine::comparators::NativeExec;
    use crate::engine::schema_align::align_schemas;

    fn run(spec: &GenSpec) -> (BatchOutcome, ShardMemStats) {
        let (a, b, _) = generate_pair(spec);
        let aligned = align_schemas(&a.schema, &b.schema).unwrap();
        let plan = JobPlan::new(aligned, EngineConfig::default());
        let exec: Arc<dyn NumericDeltaExec> = Arc::new(NativeExec);
        process_shard(0, &a, &b, &plan, &exec).unwrap()
    }

    #[test]
    fn identical_tables_all_equal() {
        let spec = GenSpec {
            rows: 300,
            change_rate: 0.0,
            add_rate: 0.0,
            remove_rate: 0.0,
            seed: 5,
            ..GenSpec::default()
        };
        let (out, mem) = run(&spec);
        assert_eq!(out.cells.changed, 0);
        assert_eq!(out.cells.added, 0);
        assert_eq!(out.cells.removed, 0);
        assert_eq!(out.rows.changed_rows, 0);
        assert!(out.diff_keys.is_empty());
        assert!(mem.decode_bytes > 0 && mem.scratch_bytes > 0);
    }

    #[test]
    fn row_counts_match_generator_truth() {
        let spec = GenSpec { rows: 2_000, seed: 17, ..GenSpec::default() };
        let (a, b, truth) = generate_pair(&spec);
        let aligned = align_schemas(&a.schema, &b.schema).unwrap();
        let plan = JobPlan::new(aligned, EngineConfig::default());
        let exec: Arc<dyn NumericDeltaExec> = Arc::new(NativeExec);
        let (out, _) = process_shard(0, &a, &b, &plan, &exec).unwrap();
        assert_eq!(out.rows.aligned as usize, truth.aligned);
        assert_eq!(out.rows.added as usize, truth.added);
        assert_eq!(out.rows.removed as usize, truth.removed);
        // Every generator-perturbed row must be detected (perturbations
        // always change at least one cell); spurious extras impossible.
        assert_eq!(out.rows.changed_rows as usize, truth.changed_rows);
    }

    #[test]
    fn cell_accounting_partitions_grid() {
        let spec = GenSpec { rows: 500, seed: 3, ..GenSpec::default() };
        let (out, _) = run(&spec);
        let nrows = out.rows.aligned + out.rows.added + out.rows.removed;
        assert_eq!(out.cells.total(), nrows * out.columns.len() as u64);
        assert_eq!(out.cells.absent, 0);
    }

    #[test]
    fn diff_keys_are_generator_keys() {
        let spec = GenSpec { rows: 800, seed: 23, ..GenSpec::default() };
        let (out, _) = run(&spec);
        assert_eq!(
            out.diff_keys.len() as u64,
            out.rows.changed_rows + out.rows.added + out.rows.removed
        );
        assert!(!out.diff_keys_truncated);
    }

    #[test]
    fn tolerance_suppresses_small_numeric_changes() {
        let spec = GenSpec {
            rows: 400,
            seed: 9,
            change_rate: 0.3,
            add_rate: 0.0,
            remove_rate: 0.0,
            ..GenSpec::default()
        };
        let (a, b, _) = generate_pair(&spec);
        let aligned = align_schemas(&a.schema, &b.schema).unwrap();
        let strict = JobPlan::new(aligned.clone(), EngineConfig::default());
        let loose = JobPlan::new(
            aligned,
            EngineConfig {
                atol: 1e12,
                rtol: 1.0,
                string_ci: false,
                ts_tolerance_us: i64::MAX / 4,
                ..EngineConfig::default()
            },
        );
        let exec: Arc<dyn NumericDeltaExec> = Arc::new(NativeExec);
        let (s, _) = process_shard(0, &a, &b, &strict, &exec).unwrap();
        let (l, _) = process_shard(0, &a, &b, &loose, &exec).unwrap();
        assert!(l.cells.changed < s.cells.changed);
    }

    #[test]
    fn columnar_matches_reference_end_to_end() {
        for seed in [1u64, 11, 29] {
            let spec = GenSpec { rows: 700, seed, ..GenSpec::default() };
            let (a, b, _) = generate_pair(&spec);
            let aligned = align_schemas(&a.schema, &b.schema).unwrap();
            let plan = JobPlan::new(aligned, EngineConfig::default());
            let exec: Arc<dyn NumericDeltaExec> = Arc::new(NativeExec);
            let (fast, _) = process_shard(0, &a, &b, &plan, &exec).unwrap();
            let (slow, _) = process_shard_ref(0, &a, &b, &plan, &exec).unwrap();
            assert_eq!(fast, slow, "seed {seed}");
        }
    }

    #[test]
    fn scratch_reuse_bit_identical_and_capacity_stable() {
        let spec = GenSpec { rows: 600, seed: 41, ..GenSpec::default() };
        let (a, b, _) = generate_pair(&spec);
        let aligned = align_schemas(&a.schema, &b.schema).unwrap();
        let plan = JobPlan::new(aligned, EngineConfig::default());
        let exec: Arc<dyn NumericDeltaExec> = Arc::new(NativeExec);
        let mut scratch = ShardScratch::default();
        let (first, mem_first) =
            process_shard_with(0, &a, &b, &plan, &exec, &mut scratch).unwrap();
        let caps = (
            scratch.batch.a.capacity(),
            scratch.diff.verdicts.capacity(),
            scratch.row_diff.capacity(),
            scratch.alignment.pairs.capacity(),
        );
        for _ in 0..4 {
            let (again, mem) =
                process_shard_with(0, &a, &b, &plan, &exec, &mut scratch)
                    .unwrap();
            assert_eq!(again, first);
            assert_eq!(mem, mem_first, "mem accounting must stay exact");
        }
        assert_eq!(
            caps,
            (
                scratch.batch.a.capacity(),
                scratch.diff.verdicts.capacity(),
                scratch.row_diff.capacity(),
                scratch.alignment.pairs.capacity(),
            ),
            "steady state must not reallocate"
        );
    }

    #[test]
    fn fill_into_matches_fill_ref() {
        let spec = GenSpec { rows: 400, seed: 77, ..GenSpec::default() };
        let (a, b, _) = generate_pair(&spec);
        let aligned = align_schemas(&a.schema, &b.schema).unwrap();
        let plan = JobPlan::new(aligned, EngineConfig::default());
        let al =
            crate::engine::row_align::align_rows(&a, &b, &plan.aligned).unwrap();
        let reference = fill_numeric_batch_ref(&plan, &a, &b, &al);
        let mut fast = NumericBatch::default();
        fill_numeric_batch_into(&plan, &a, &b, &al, &mut fast);
        assert_eq!(fast, reference);
    }
}
