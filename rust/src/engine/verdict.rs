//! Verdict codes and outcome aggregates.
//!
//! Codes are the cross-layer contract: they must match
//! `python/compile/kernels/diff_kernel.py` (and `ref.py`) exactly — the
//! PJRT path returns raw i32 codes produced by the Pallas kernel.

/// Cell-level verdict (paper §II: typed verdict per aligned row+column).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(i32)]
pub enum Verdict {
    /// Values compare equal (incl. null==null, NaN==NaN, within tolerance).
    Equal = 0,
    /// Aligned row, differing cell (incl. null vs value).
    Changed = 1,
    /// Row present only on the B side.
    Added = 2,
    /// Row present only on the A side.
    Removed = 3,
    /// Padding slot (bucket padding); never counted in outcomes.
    Absent = 4,
}

impl Verdict {
    pub fn from_code(code: i32) -> Option<Verdict> {
        match code {
            0 => Some(Verdict::Equal),
            1 => Some(Verdict::Changed),
            2 => Some(Verdict::Added),
            3 => Some(Verdict::Removed),
            4 => Some(Verdict::Absent),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Verdict::Equal => "equal",
            Verdict::Changed => "changed",
            Verdict::Added => "added",
            Verdict::Removed => "removed",
            Verdict::Absent => "absent",
        }
    }
}

/// Cell-level verdict histogram. `absent` exists only transiently (bucket
/// padding) and must be zero in merged job outcomes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct VerdictCounts {
    pub equal: u64,
    pub changed: u64,
    pub added: u64,
    pub removed: u64,
    pub absent: u64,
}

impl VerdictCounts {
    pub fn total(&self) -> u64 {
        self.equal + self.changed + self.added + self.removed + self.absent
    }
    pub fn record(&mut self, v: Verdict, n: u64) {
        match v {
            Verdict::Equal => self.equal += n,
            Verdict::Changed => self.changed += n,
            Verdict::Added => self.added += n,
            Verdict::Removed => self.removed += n,
            Verdict::Absent => self.absent += n,
        }
    }
    pub fn merge(&mut self, other: &VerdictCounts) {
        self.equal += other.equal;
        self.changed += other.changed;
        self.added += other.added;
        self.removed += other.removed;
        self.absent += other.absent;
    }
    /// From the kernel's (5,) i32 counts vector.
    pub fn from_codes(counts: &[i64; 5]) -> VerdictCounts {
        VerdictCounts {
            equal: counts[0] as u64,
            changed: counts[1] as u64,
            added: counts[2] as u64,
            removed: counts[3] as u64,
            absent: counts[4] as u64,
        }
    }
}

/// Row-level outcome totals for one shard or job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RowCounts {
    pub aligned: u64,
    pub changed_rows: u64,
    pub added: u64,
    pub removed: u64,
}

impl RowCounts {
    pub fn merge(&mut self, o: &RowCounts) {
        self.aligned += o.aligned;
        self.changed_rows += o.changed_rows;
        self.added += o.added;
        self.removed += o.removed;
    }
}

/// Per-column diff summary (merge step: distribution summaries).
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnOutcome {
    pub name: String,
    pub changed: u64,
    /// Max |a-b| among numerically compared cells (0 for non-numeric).
    pub max_abs_delta: f64,
}

/// The output of Δ over one shard. The merged multiset of outcomes is
/// deterministic and invariant to (b, k) and backend (paper §II) —
/// property-tested in rust/tests/.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchOutcome {
    pub shard_id: u64,
    pub rows_a: u64,
    pub rows_b: u64,
    pub cells: VerdictCounts,
    pub rows: RowCounts,
    pub columns: Vec<ColumnOutcome>,
    /// Keys of changed/added/removed rows (capped at `KEY_SAMPLE_CAP`).
    pub diff_keys: Vec<i64>,
    pub diff_keys_truncated: bool,
}

/// Cap on materialized diff-row keys per shard.
pub const KEY_SAMPLE_CAP: usize = 10_000;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_match_python_contract() {
        assert_eq!(Verdict::Equal as i32, 0);
        assert_eq!(Verdict::Changed as i32, 1);
        assert_eq!(Verdict::Added as i32, 2);
        assert_eq!(Verdict::Removed as i32, 3);
        assert_eq!(Verdict::Absent as i32, 4);
        for c in 0..5 {
            assert_eq!(Verdict::from_code(c).unwrap() as i32, c);
        }
        assert!(Verdict::from_code(5).is_none());
    }

    #[test]
    fn counts_merge_and_total() {
        let mut a = VerdictCounts { equal: 10, changed: 2, ..Default::default() };
        let b = VerdictCounts { added: 3, removed: 1, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.total(), 16);
        a.record(Verdict::Changed, 4);
        assert_eq!(a.changed, 6);
    }

    #[test]
    fn from_codes_roundtrip() {
        let c = VerdictCounts::from_codes(&[5, 4, 3, 2, 1]);
        assert_eq!(c.equal, 5);
        assert_eq!(c.absent, 1);
        assert_eq!(c.total(), 15);
    }
}
