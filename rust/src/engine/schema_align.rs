//! Schema alignment: one-to-one attribute mapping between source and
//! target schemas (paper §II, first pipeline stage).
//!
//! Matching is by normalized name (case-, underscore- and dash-
//! insensitive) with type-compatibility constraints; numeric types align
//! across the Int64/Float64/Decimal family. Unmatched attributes are
//! reported (they do not fail the job — the engine diffs the aligned
//! intersection, like SmartDiff).

use crate::api::error::SchedError;
use crate::data::schema::{ColumnType, Schema};

/// How an aligned column pair is compared (dispatch for Δ).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CompareKind {
    /// Int64 / Float64 / Decimal / Date / Timestamp — dense tolerance
    /// compare on the accelerator path (f64 matrix).
    Numeric,
    String,
    Bool,
}

impl CompareKind {
    pub fn of(ty: &ColumnType) -> CompareKind {
        match ty {
            ColumnType::Utf8 => CompareKind::String,
            ColumnType::Bool => CompareKind::Bool,
            ColumnType::Int64
            | ColumnType::Float64
            | ColumnType::Decimal { .. }
            | ColumnType::Date
            | ColumnType::Timestamp => CompareKind::Numeric,
        }
    }
}

/// One aligned attribute pair.
#[derive(Debug, Clone, PartialEq)]
pub struct AlignedCol {
    pub name: String,
    pub a_idx: usize,
    pub b_idx: usize,
    pub a_ty: ColumnType,
    pub b_ty: ColumnType,
    pub kind: CompareKind,
    pub is_key: bool,
}

/// Alignment result: aligned pairs (in A declaration order) plus the
/// unmatched remainder on each side.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct AlignedSchema {
    pub pairs: Vec<AlignedCol>,
    pub a_only: Vec<String>,
    pub b_only: Vec<String>,
}

impl AlignedSchema {
    /// Indices (into `pairs`) of key columns.
    pub fn key_pairs(&self) -> Vec<usize> {
        self.pairs
            .iter()
            .enumerate()
            .filter(|(_, p)| p.is_key)
            .map(|(i, _)| i)
            .collect()
    }
    /// Indices (into `pairs`) of numeric-kind (accelerator path) columns.
    pub fn numeric_pairs(&self) -> Vec<usize> {
        self.pairs
            .iter()
            .enumerate()
            .filter(|(_, p)| p.kind == CompareKind::Numeric)
            .map(|(i, _)| i)
            .collect()
    }
    pub fn native_pairs(&self) -> Vec<usize> {
        self.pairs
            .iter()
            .enumerate()
            .filter(|(_, p)| p.kind != CompareKind::Numeric)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Normalize an attribute name for matching.
pub fn normalize_name(name: &str) -> String {
    name.chars()
        .filter(|c| *c != '_' && *c != '-' && *c != ' ')
        .flat_map(|c| c.to_lowercase())
        .collect()
}

/// Compute the alignment between two schemas.
///
/// Errors if the key columns of A cannot all be aligned (diffing without
/// a consistent row-alignment key is a job-definition error; surrogate
/// keyless mode is handled upstream by synthesizing a row-index key).
pub fn align_schemas(
    a: &Schema,
    b: &Schema,
) -> Result<AlignedSchema, SchedError> {
    let mut out = AlignedSchema::default();
    let mut b_norm: Vec<(String, usize)> = b
        .fields
        .iter()
        .enumerate()
        .map(|(i, f)| (normalize_name(&f.name), i))
        .collect();
    // Detect duplicate normalized names (ambiguous mapping).
    {
        let mut seen = std::collections::HashSet::new();
        for (n, _) in &b_norm {
            if !seen.insert(n.clone()) {
                return Err(SchedError::schema(format!(
                    "ambiguous attribute {n:?} in target schema"
                )));
            }
        }
        let mut seen = std::collections::HashSet::new();
        for f in &a.fields {
            let n = normalize_name(&f.name);
            if !seen.insert(n.clone()) {
                return Err(SchedError::schema(format!(
                    "ambiguous attribute {n:?} in source schema"
                )));
            }
        }
    }

    let mut b_matched = vec![false; b.fields.len()];
    for (ai, af) in a.fields.iter().enumerate() {
        let an = normalize_name(&af.name);
        let hit = b_norm.iter().find(|(bn, _)| *bn == an).map(|(_, bi)| *bi);
        match hit {
            Some(bi) if af.ty.comparable_with(&b.fields[bi].ty) => {
                b_matched[bi] = true;
                out.pairs.push(AlignedCol {
                    name: af.name.clone(),
                    a_idx: ai,
                    b_idx: bi,
                    a_ty: af.ty,
                    b_ty: b.fields[bi].ty,
                    kind: CompareKind::of(&af.ty),
                    is_key: af.key && b.fields[bi].key,
                });
            }
            Some(bi) => {
                // Same name, incompatible type: report on both sides.
                out.a_only.push(af.name.clone());
                out.b_only.push(b.fields[bi].name.clone());
                b_matched[bi] = true;
            }
            None => out.a_only.push(af.name.clone()),
        }
    }
    for (bi, m) in b_matched.iter().enumerate() {
        if !m {
            out.b_only.push(b.fields[bi].name.clone());
        }
    }
    b_norm.clear();

    // Key columns of A must align as keys.
    let a_keys: Vec<&str> = a
        .fields
        .iter()
        .filter(|f| f.key)
        .map(|f| f.name.as_str())
        .collect();
    for k in &a_keys {
        if !out.pairs.iter().any(|p| p.is_key && p.name == *k) {
            return Err(SchedError::schema(format!(
                "key column {k:?} not aligned across schemas"
            )));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::Field;

    #[test]
    fn exact_and_normalized_matching() {
        let a = Schema::new(vec![
            Field::key("order_id", ColumnType::Int64),
            Field::new("Total_Amount", ColumnType::Float64),
            Field::new("note", ColumnType::Utf8),
        ]);
        let b = Schema::new(vec![
            Field::key("OrderID", ColumnType::Int64),
            Field::new("totalamount", ColumnType::Decimal { scale: 2 }),
            Field::new("extra", ColumnType::Bool),
        ]);
        let al = align_schemas(&a, &b).unwrap();
        assert_eq!(al.pairs.len(), 2);
        assert!(al.pairs[0].is_key);
        assert_eq!(al.pairs[1].kind, CompareKind::Numeric);
        assert_eq!(al.a_only, vec!["note"]);
        assert_eq!(al.b_only, vec!["extra"]);
    }

    #[test]
    fn type_conflict_goes_unmatched() {
        let a = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Utf8),
        ]);
        let b = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Float64),
        ]);
        let al = align_schemas(&a, &b).unwrap();
        assert_eq!(al.pairs.len(), 1);
        assert_eq!(al.a_only, vec!["v"]);
        assert_eq!(al.b_only, vec!["v"]);
    }

    #[test]
    fn missing_key_is_error() {
        let a = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Float64),
        ]);
        let b = Schema::new(vec![
            Field::new("other", ColumnType::Int64),
            Field::new("v", ColumnType::Float64),
        ]);
        assert!(align_schemas(&a, &b).is_err());
    }

    #[test]
    fn ambiguous_names_rejected() {
        let a = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("a_b", ColumnType::Float64),
            Field::new("ab", ColumnType::Float64),
        ]);
        let b = Schema::new(vec![Field::key("id", ColumnType::Int64)]);
        assert!(align_schemas(&a, &b).is_err());
    }

    #[test]
    fn compare_kind_dispatch() {
        assert_eq!(CompareKind::of(&ColumnType::Date), CompareKind::Numeric);
        assert_eq!(CompareKind::of(&ColumnType::Timestamp), CompareKind::Numeric);
        assert_eq!(CompareKind::of(&ColumnType::Utf8), CompareKind::String);
        assert_eq!(CompareKind::of(&ColumnType::Bool), CompareKind::Bool);
    }

    #[test]
    fn key_indices_reported() {
        let a = Schema::new(vec![
            Field::key("id", ColumnType::Int64),
            Field::new("v", ColumnType::Float64),
            Field::new("s", ColumnType::Utf8),
        ]);
        let al = align_schemas(&a, &a).unwrap();
        assert_eq!(al.key_pairs(), vec![0]);
        assert_eq!(al.numeric_pairs(), vec![0, 1]);
        assert_eq!(al.native_pairs(), vec![2]);
    }
}
