//! The SmartDiff engine substrate (DESIGN.md systems S5–S9): schema
//! alignment, row alignment f, typed cell-wise Δ, stable merge, and the
//! calibration microbenchmarks. The scheduler treats all of this as the
//! workload; it never changes Δ semantics (paper §II).
//!
//! # Engine hot path
//!
//! The per-shard Δ pipeline is columnar end-to-end; the three contracts
//! below are what every optimization (and every future accelerator
//! backend) must preserve.
//!
//! ## NumericBatch kernel contract
//!
//! All numeric-family columns (i64 / f64 / decimal / date / timestamp)
//! are gathered into one row-major R×C f64 batch
//! ([`comparators::NumericBatch`]): value matrices `a`/`b`, cell
//! presence masks `na`/`nb` (garbage values behind a 0 mask are legal
//! and must never influence results), row presence `ra`/`rb` (slot
//! layout: aligned pairs, then removed, then added; padding rows have
//! `ra == rb == 0`), and per-column `atol`/`rtol`. Executors
//! ([`comparators::NumericDeltaExec`]) map a batch to verdict codes,
//! per-column changed counts and max-|Δ|, and per-row any-diff flags —
//! the native Rust loop and the Pallas/PJRT executable must be
//! observationally identical (`runtime::pjrt` cross-checks them).
//! `diff_into` is the buffer-reusing entry point the hot path uses.
//!
//! ## Columnar gather design
//!
//! Per-cell enum dispatch (`Column::cell()`) is banned from row loops.
//! The batch fill ([`delta::fill_numeric_batch_into`]) matches each
//! column's `Values` storage **once**, then runs a tight typed loop
//! writing strided `a`/`na` slots; native string/bool comparison reads
//! `StrData` byte views and `Bitmap` bits directly. Row alignment
//! ([`row_align::align_rows_into`]) hashes each key column in one typed
//! pass into per-row `u64` accumulators (FNV-1a, null ⇒ a 0xff tag
//! byte), then builds an open-addressed join table keyed by the
//! precomputed hashes with full-key verification on hash hits. The
//! original cell-at-a-time implementations are retained as oracles
//! ([`delta::process_shard_ref`], [`row_align::align_rows_ref`]) and
//! the parity property tests (`rust/tests/hotpath_parity.rs`) pin the
//! two paths to bit-identical `Alignment` and `BatchOutcome`.
//!
//! ## Scratch-reuse ownership rules
//!
//! Every R×C-scale buffer lives in a [`delta::ShardScratch`] (numeric
//! batch, kernel outputs, row-diff flags, alignment state + hash
//! accumulators). Exactly **one** scratch exists per worker thread; it
//! is threaded by `&mut` through `process_shard_with` and never shared
//! across concurrently executing shards. Buffers are resized in place,
//! so steady-state shard execution performs no scratch allocation —
//! while `ShardMemStats` stays exact (capacity-based byte accounting:
//! the scheduler's memory model is calibrated against these numbers, so
//! reporting anything but the real resident footprint is a correctness
//! bug, not a cosmetic one).

pub mod comparators;
pub mod delta;
pub mod merge;
pub mod microbench;
pub mod row_align;
pub mod schema_align;
pub mod verdict;
