//! The SmartDiff engine substrate (DESIGN.md systems S5–S9): schema
//! alignment, row alignment f, typed cell-wise Δ, stable merge, and the
//! calibration microbenchmarks. The scheduler treats all of this as the
//! workload; it never changes Δ semantics (paper §II).

pub mod comparators;
pub mod delta;
pub mod merge;
pub mod microbench;
pub mod row_align;
pub mod schema_align;
pub mod verdict;
