//! Runtime layer (DESIGN.md S23): loads AOT artifacts via the PJRT C
//! API (`xla` crate) and exposes them as a `NumericDeltaExec` the engine
//! workers call on the hot path. Python never runs here — artifacts are
//! HLO text produced once by `make artifacts`.

pub mod manifest;
pub mod pjrt;
pub mod xla_stub;

use std::path::Path;
use std::sync::Arc;

use crate::api::error::SchedError;
use crate::config::{DeltaPath, EngineConfig};
use crate::engine::comparators::{NativeExec, NumericDeltaExec};

/// Build the numeric-Δ executor selected by the engine config.
pub fn make_exec(
    cfg: &EngineConfig,
) -> Result<Arc<dyn NumericDeltaExec>, SchedError> {
    match cfg.delta_path {
        DeltaPath::Native => Ok(Arc::new(NativeExec)),
        DeltaPath::Pjrt => {
            let handle = pjrt::spawn_service(Path::new(&cfg.artifact_dir))?;
            Ok(Arc::new(handle))
        }
        DeltaPath::Check => {
            let handle = pjrt::spawn_service(Path::new(&cfg.artifact_dir))?;
            Ok(Arc::new(pjrt::CheckExec { pjrt: handle }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::comparators::{native_numeric_diff, NumericBatch};
    use crate::util::rng::Rng;

    fn artifact_dir() -> String {
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
    }

    fn have_artifacts() -> bool {
        Path::new(&artifact_dir()).join("manifest.json").exists()
    }

    fn pjrt_cfg() -> EngineConfig {
        EngineConfig {
            delta_path: DeltaPath::Pjrt,
            artifact_dir: artifact_dir(),
            ..EngineConfig::default()
        }
    }

    fn random_batch(rng: &mut Rng, rows: usize, cols: usize) -> NumericBatch {
        let mut nb = NumericBatch::zeroed(rows, cols);
        for i in 0..rows {
            let (ra, rb) = match rng.range_usize(0, 10) {
                0 => (1.0, 0.0),
                1 => (0.0, 1.0),
                _ => (1.0, 1.0),
            };
            nb.ra[i] = ra;
            nb.rb[i] = rb;
            for j in 0..cols {
                let idx = i * cols + j;
                if rng.chance(0.9) {
                    nb.na[idx] = 1.0;
                    nb.a[idx] = rng.normal_ms(0.0, 10.0);
                }
                if rng.chance(0.9) {
                    nb.nb[idx] = 1.0;
                    nb.b[idx] = if rng.chance(0.5) {
                        nb.a[idx]
                    } else {
                        rng.normal_ms(0.0, 10.0)
                    };
                }
            }
        }
        for j in 0..cols {
            nb.atol[j] = 0.01;
            nb.rtol[j] = 0.001;
        }
        nb
    }

    #[test]
    fn pjrt_matches_native_across_shapes() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let exec = make_exec(&pjrt_cfg()).expect("pjrt service");
        let mut rng = Rng::new(77);
        // Exercises: exact bucket, padded rows, padded cols, both.
        for (rows, cols) in
            [(1024, 8), (100, 3), (1500, 8), (1024, 10), (999, 13), (1, 1)]
        {
            let batch = random_batch(&mut rng, rows, cols);
            let got = exec.diff(&batch).expect("pjrt diff");
            let want = native_numeric_diff(&batch);
            assert_eq!(got.counts, want.counts, "{rows}x{cols}");
            assert_eq!(got.verdicts, want.verdicts, "{rows}x{cols}");
            assert_eq!(got.col_changed, want.col_changed, "{rows}x{cols}");
            assert_eq!(got.changed_rows, want.changed_rows, "{rows}x{cols}");
            for (g, w) in got.col_maxabs.iter().zip(&want.col_maxabs) {
                assert!((g - w).abs() < 1e-9, "{rows}x{cols}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn pjrt_row_and_col_chunking() {
        if !have_artifacts() {
            return;
        }
        let exec = make_exec(&pjrt_cfg()).expect("pjrt service");
        let mut rng = Rng::new(99);
        // cols > 32 forces column chunking; rows > 65536 would be slow in
        // interpret mode, so exercise the row-chunk path with a shrunken
        // batch against a small bucket via cols chunking only.
        let batch = random_batch(&mut rng, 200, 40);
        let got = exec.diff(&batch).expect("pjrt diff");
        let want = native_numeric_diff(&batch);
        assert_eq!(got.counts, want.counts);
        assert_eq!(got.verdicts, want.verdicts);
    }

    #[test]
    fn check_exec_agrees() {
        if !have_artifacts() {
            return;
        }
        let cfg = EngineConfig {
            delta_path: DeltaPath::Check,
            artifact_dir: artifact_dir(),
            ..EngineConfig::default()
        };
        let exec = make_exec(&cfg).expect("check exec");
        let mut rng = Rng::new(5);
        let batch = random_batch(&mut rng, 300, 6);
        exec.diff(&batch).expect("check agrees");
    }

    #[test]
    fn empty_batch_ok() {
        if !have_artifacts() {
            return;
        }
        let exec = make_exec(&pjrt_cfg()).expect("pjrt service");
        let batch = NumericBatch::zeroed(0, 0);
        let out = exec.diff(&batch).unwrap();
        assert_eq!(out.counts, [0; 5]);
    }
}
