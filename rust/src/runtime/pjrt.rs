//! PJRT execution service: loads the AOT HLO-text artifacts, compiles
//! them once on the PJRT CPU client, and serves numeric-Δ batches from
//! the L3 hot path.
//!
//! The `xla` crate's wrappers hold raw pointers (not Send/Sync), so a
//! dedicated service thread owns the client and all compiled
//! executables; workers talk to it through a channel-based
//! `PjrtHandle` (Clone + Send) that implements `NumericDeltaExec`.
//! XLA's CPU backend parallelizes inside an execution, so a single
//! service thread does not serialize the math onto one core.
//!
//! Batches whose shape exceeds the largest compiled bucket are chunked
//! (rows, then columns) and the partial results recombined; smaller
//! batches are padded up to the smallest fitting bucket with
//! `ra = rb = 0` rows, which the kernel reports as ABSENT and the
//! unpadding step strips (verified against expectations).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use crate::api::error::SchedError;
use crate::engine::comparators::{NumericBatch, NumericDeltaExec, NumericDiffOut};
use crate::engine::verdict::Verdict;
use crate::runtime::manifest::Manifest;
// Stub mirroring the `xla` crate's API so this service compiles without
// the external dependency; see `xla_stub.rs` for the swap-in note.
use crate::runtime::xla_stub as xla;

struct Request {
    batch: NumericBatch,
    resp: Sender<Result<NumericDiffOut, String>>,
}

/// Handle to the PJRT service thread. Cheap to clone; `diff` is a
/// blocking round-trip.
pub struct PjrtHandle {
    tx: Mutex<Sender<Request>>,
}

impl NumericDeltaExec for PjrtHandle {
    fn name(&self) -> &'static str {
        "pjrt"
    }
    fn diff(&self, batch: &NumericBatch) -> Result<NumericDiffOut, String> {
        let (tx, rx) = channel();
        self.tx
            .lock()
            // lint: allow(unwrap) tx sections are a single channel send
            // and cannot panic, so the mutex cannot be poisoned
            .unwrap()
            .send(Request { batch: batch.clone(), resp: tx })
            .map_err(|_| "pjrt service thread gone".to_string())?;
        rx.recv().map_err(|_| "pjrt service dropped request".to_string())?
    }
}

/// Spawn the PJRT service for `artifact_dir`. Fails fast (before
/// spawning workers) if the manifest or client is unavailable.
pub fn spawn_service(artifact_dir: &Path) -> Result<PjrtHandle, SchedError> {
    let manifest = Manifest::load(artifact_dir).map_err(SchedError::runtime)?;
    let (tx, rx) = channel::<Request>();
    let (ready_tx, ready_rx) = channel::<Result<(), String>>();
    std::thread::Builder::new()
        .name("pjrt-service".into())
        .spawn(move || {
            let mut svc = match Service::new(manifest) {
                Ok(svc) => {
                    let _ = ready_tx.send(Ok(()));
                    svc
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                let out = svc.run(&req.batch);
                let _ = req.resp.send(out);
            }
        })
        .map_err(|e| SchedError::runtime(format!("spawn pjrt service: {e}")))?;
    ready_rx
        .recv()
        .map_err(|_| SchedError::runtime("pjrt service died during init"))?
        .map_err(SchedError::runtime)?;
    Ok(PjrtHandle { tx: Mutex::new(tx) })
}

struct Service {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Compiled executables keyed by artifact name (compiled lazily).
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Service {
    fn new(manifest: Manifest) -> Result<Service, String> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| format!("PjRtClient::cpu: {e:?}"))?;
        Ok(Service { client, manifest, compiled: HashMap::new() })
    }

    fn ensure_compiled(
        &mut self,
        name: &str,
        path: &PathBuf,
    ) -> Result<&xla::PjRtLoadedExecutable, String> {
        if !self.compiled.contains_key(name) {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| format!("load {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| format!("compile {name}: {e:?}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    fn run(&mut self, batch: &NumericBatch) -> Result<NumericDiffOut, String> {
        if batch.rows == 0 || batch.cols == 0 {
            return Ok(empty_out(batch.rows, batch.cols));
        }
        let max = self
            .manifest
            .max_bucket("diff", "f64")
            .ok_or("no f64 diff artifacts")?;
        let (max_rows, max_cols) = (max.rows, max.cols);

        if batch.cols > max_cols {
            return self.run_col_chunked(batch, max_cols);
        }
        if batch.rows > max_rows {
            return self.run_row_chunked(batch, max_rows);
        }

        let meta = self
            .manifest
            .pick_bucket("diff", "f64", batch.rows, batch.cols)
            .ok_or("no fitting bucket")?;
        let (name, path, brows, bcols) =
            (meta.name.clone(), meta.path.clone(), meta.rows, meta.cols);
        let padded = pad_batch(batch, brows, bcols);
        let exe = self.ensure_compiled(&name, &path)?;

        let lit = |v: &[f64], dims: &[i64]| -> Result<xla::Literal, String> {
            xla::Literal::vec1(v)
                .reshape(dims)
                .map_err(|e| format!("literal reshape: {e:?}"))
        };
        let r = brows as i64;
        let c = bcols as i64;
        let args = [
            lit(&padded.a, &[r, c])?,
            lit(&padded.b, &[r, c])?,
            lit(&padded.na, &[r, c])?,
            lit(&padded.nb, &[r, c])?,
            lit(&padded.ra, &[r])?,
            lit(&padded.rb, &[r])?,
            lit(&padded.atol, &[c])?,
            lit(&padded.rtol, &[c])?,
        ];
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| format!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("to_literal: {e:?}"))?;
        let outs = result
            .to_tuple()
            .map_err(|e| format!("to_tuple: {e:?}"))?;
        if outs.len() != 5 {
            return Err(format!("expected 5 outputs, got {}", outs.len()));
        }
        let verdicts_p: Vec<i32> = outs[0]
            .to_vec()
            .map_err(|e| format!("verdicts: {e:?}"))?;
        let counts_p: Vec<i32> =
            outs[1].to_vec().map_err(|e| format!("counts: {e:?}"))?;
        let col_changed_p: Vec<i32> = outs[2]
            .to_vec()
            .map_err(|e| format!("col_changed: {e:?}"))?;
        let col_maxabs_p: Vec<f64> = outs[3]
            .to_vec()
            .map_err(|e| format!("col_maxabs: {e:?}"))?;
        let changed_rows_p: Vec<i32> = outs[4]
            .to_vec()
            .map_err(|e| format!("changed_rows: {e:?}"))?;

        unpad_out(
            batch,
            brows,
            bcols,
            verdicts_p,
            counts_p,
            col_changed_p,
            col_maxabs_p,
            changed_rows_p,
        )
    }

    /// Column-chunk oversized batches; each chunk sees all rows.
    fn run_col_chunked(
        &mut self,
        batch: &NumericBatch,
        max_cols: usize,
    ) -> Result<NumericDiffOut, String> {
        let mut combined = empty_out(batch.rows, 0);
        combined.verdicts = vec![0; batch.rows * batch.cols];
        combined.col_changed = vec![0; batch.cols];
        combined.col_maxabs = vec![0.0; batch.cols];
        combined.changed_rows = vec![0; batch.rows];
        let mut first = true;
        let mut c0 = 0;
        while c0 < batch.cols {
            let cn = max_cols.min(batch.cols - c0);
            let sub = slice_cols(batch, c0, cn);
            let out = self.run(&sub)?;
            for i in 0..batch.rows {
                for j in 0..cn {
                    combined.verdicts[i * batch.cols + c0 + j] =
                        out.verdicts[i * cn + j];
                }
                if out.changed_rows[i] != 0 {
                    combined.changed_rows[i] = 1;
                }
            }
            combined.col_changed[c0..c0 + cn]
                .copy_from_slice(&out.col_changed);
            combined.col_maxabs[c0..c0 + cn].copy_from_slice(&out.col_maxabs);
            for k in 0..5 {
                combined.counts[k] += out.counts[k];
            }
            first = false;
            c0 += cn;
        }
        let _ = first;
        Ok(combined)
    }

    /// Row-chunk oversized batches; each chunk sees all columns.
    fn run_row_chunked(
        &mut self,
        batch: &NumericBatch,
        max_rows: usize,
    ) -> Result<NumericDiffOut, String> {
        let mut combined = empty_out(0, batch.cols);
        combined.col_changed = vec![0; batch.cols];
        combined.col_maxabs = vec![0.0; batch.cols];
        let mut r0 = 0;
        while r0 < batch.rows {
            let rn = max_rows.min(batch.rows - r0);
            let sub = slice_rows(batch, r0, rn);
            let out = self.run(&sub)?;
            combined.verdicts.extend_from_slice(&out.verdicts);
            combined.changed_rows.extend_from_slice(&out.changed_rows);
            for k in 0..5 {
                combined.counts[k] += out.counts[k];
            }
            for j in 0..batch.cols {
                combined.col_changed[j] += out.col_changed[j];
                if out.col_maxabs[j] > combined.col_maxabs[j] {
                    combined.col_maxabs[j] = out.col_maxabs[j];
                }
            }
            r0 += rn;
        }
        Ok(combined)
    }
}

fn empty_out(rows: usize, cols: usize) -> NumericDiffOut {
    NumericDiffOut {
        verdicts: vec![0; rows * cols],
        counts: [0; 5],
        col_changed: vec![0; cols],
        col_maxabs: vec![0.0; cols],
        changed_rows: vec![0; rows],
    }
}

fn pad_batch(batch: &NumericBatch, brows: usize, bcols: usize) -> NumericBatch {
    if batch.rows == brows && batch.cols == bcols {
        return batch.clone();
    }
    let mut p = NumericBatch::zeroed(brows, bcols);
    for i in 0..batch.rows {
        let src = i * batch.cols;
        let dst = i * bcols;
        p.a[dst..dst + batch.cols].copy_from_slice(&batch.a[src..src + batch.cols]);
        p.b[dst..dst + batch.cols].copy_from_slice(&batch.b[src..src + batch.cols]);
        p.na[dst..dst + batch.cols]
            .copy_from_slice(&batch.na[src..src + batch.cols]);
        p.nb[dst..dst + batch.cols]
            .copy_from_slice(&batch.nb[src..src + batch.cols]);
    }
    p.ra[..batch.rows].copy_from_slice(&batch.ra);
    p.rb[..batch.rows].copy_from_slice(&batch.rb);
    p.atol[..batch.cols].copy_from_slice(&batch.atol);
    p.rtol[..batch.cols].copy_from_slice(&batch.rtol);
    p
}

fn slice_cols(batch: &NumericBatch, c0: usize, cn: usize) -> NumericBatch {
    let mut s = NumericBatch::zeroed(batch.rows, cn);
    for i in 0..batch.rows {
        let src = i * batch.cols + c0;
        let dst = i * cn;
        s.a[dst..dst + cn].copy_from_slice(&batch.a[src..src + cn]);
        s.b[dst..dst + cn].copy_from_slice(&batch.b[src..src + cn]);
        s.na[dst..dst + cn].copy_from_slice(&batch.na[src..src + cn]);
        s.nb[dst..dst + cn].copy_from_slice(&batch.nb[src..src + cn]);
    }
    s.ra.copy_from_slice(&batch.ra);
    s.rb.copy_from_slice(&batch.rb);
    s.atol.copy_from_slice(&batch.atol[c0..c0 + cn]);
    s.rtol.copy_from_slice(&batch.rtol[c0..c0 + cn]);
    s
}

fn slice_rows(batch: &NumericBatch, r0: usize, rn: usize) -> NumericBatch {
    let c = batch.cols;
    let mut s = NumericBatch::zeroed(rn, c);
    s.a.copy_from_slice(&batch.a[r0 * c..(r0 + rn) * c]);
    s.b.copy_from_slice(&batch.b[r0 * c..(r0 + rn) * c]);
    s.na.copy_from_slice(&batch.na[r0 * c..(r0 + rn) * c]);
    s.nb.copy_from_slice(&batch.nb[r0 * c..(r0 + rn) * c]);
    s.ra.copy_from_slice(&batch.ra[r0..r0 + rn]);
    s.rb.copy_from_slice(&batch.rb[r0..r0 + rn]);
    s.atol.copy_from_slice(&batch.atol);
    s.rtol.copy_from_slice(&batch.rtol);
    s
}

/// Strip padding and verify its accounting: padding rows must be ABSENT;
/// padded columns contribute per-row-presence verdicts that are
/// subtracted from the counts.
#[allow(clippy::too_many_arguments)]
fn unpad_out(
    batch: &NumericBatch,
    brows: usize,
    bcols: usize,
    verdicts_p: Vec<i32>,
    counts_p: Vec<i32>,
    col_changed_p: Vec<i32>,
    col_maxabs_p: Vec<f64>,
    changed_rows_p: Vec<i32>,
) -> Result<NumericDiffOut, String> {
    let (r, c) = (batch.rows, batch.cols);
    let mut out = empty_out(r, c);

    for i in 0..r {
        let src = i * bcols;
        out.verdicts[i * c..(i + 1) * c]
            .copy_from_slice(&verdicts_p[src..src + c]);
    }
    out.col_changed
        .copy_from_slice(&col_changed_p[..c].iter().map(|&x| x as i64)
            .collect::<Vec<_>>());
    out.col_maxabs.copy_from_slice(&col_maxabs_p[..c]);
    out.changed_rows.copy_from_slice(&changed_rows_p[..r]);

    // Count padding contributions to subtract.
    let mut aligned = 0i64;
    let mut removed = 0i64;
    let mut added = 0i64;
    for i in 0..r {
        match (batch.ra[i] > 0.5, batch.rb[i] > 0.5) {
            (true, true) => aligned += 1,
            (true, false) => removed += 1,
            (false, true) => added += 1,
            (false, false) => {}
        }
    }
    let pad_cols = (bcols - c) as i64;
    let pad_row_cells = ((brows - r) as i64) * bcols as i64;
    let mut counts = [0i64; 5];
    for k in 0..5 {
        counts[k] = counts_p[k] as i64;
    }
    // Padded columns on real rows: aligned rows read null==null -> EQUAL.
    counts[Verdict::Equal as usize] -= aligned * pad_cols;
    counts[Verdict::Removed as usize] -= removed * pad_cols;
    counts[Verdict::Added as usize] -= added * pad_cols;
    // Padding rows are ABSENT across all bucket columns; real rows with
    // ra=rb=0 (none by construction) would also be absent.
    counts[Verdict::Absent as usize] -= pad_row_cells;
    if counts.iter().any(|&x| x < 0) {
        return Err(format!(
            "padding accounting underflow: {counts:?} (bucket {brows}x{bcols}, \
             batch {r}x{c})"
        ));
    }
    out.counts = counts;

    // changed_rows for padding rows must be 0; sanity-check a prefix.
    debug_assert!(changed_rows_p[r..].iter().all(|&x| x == 0));
    Ok(out)
}

/// Cross-checking executor: runs both native and PJRT paths and asserts
/// they agree (config `engine.delta_path = "check"`).
pub struct CheckExec {
    pub pjrt: PjrtHandle,
}

impl NumericDeltaExec for CheckExec {
    fn name(&self) -> &'static str {
        "check"
    }
    fn diff(&self, batch: &NumericBatch) -> Result<NumericDiffOut, String> {
        let native = crate::engine::comparators::native_numeric_diff(batch);
        let pjrt = self.pjrt.diff(batch)?;
        if native.verdicts != pjrt.verdicts || native.counts != pjrt.counts {
            return Err(format!(
                "pjrt/native divergence: counts {:?} vs {:?}",
                pjrt.counts, native.counts
            ));
        }
        Ok(pjrt)
    }
}
