//! Build-time stub for the `xla` PJRT bindings.
//!
//! The crate builds with zero external dependencies (the image's
//! offline crate cache has no `xla` facade), but the PJRT service in
//! [`super::pjrt`] is written against the `xla` crate's API. This
//! module mirrors exactly the surface `pjrt.rs` uses —
//! `PjRtClient::cpu`, `compile`, `execute`, `HloModuleProto`,
//! `XlaComputation`, `Literal` — so the service compiles unchanged and
//! fails *at runtime, typed and early*: `PjRtClient::cpu()` returns an
//! error, `spawn_service` surfaces it before any worker spawns, and the
//! engine falls back to the bit-identical native Δ path
//! (`DeltaPath::Native`, the default).
//!
//! Swapping in the real bindings is a two-line change: add the `xla`
//! dependency and replace the `use crate::runtime::xla_stub as xla;`
//! import in `pjrt.rs`. No other code changes.

use std::path::Path;

/// Error type standing in for `xla::Error` (only ever `Debug`-formatted
/// by the service layer).
#[derive(Debug)]
pub struct XlaError(pub String);

fn unavailable() -> XlaError {
    XlaError(
        "xla PJRT bindings are not built into this binary \
         (engine.delta_path = \"native\" is the supported path)"
            .to_string(),
    )
}

pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the stub: there is no PJRT runtime to attach to.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(
        _path: P,
    ) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f64]) -> Literal {
        Literal
    }
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_and_typed() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let err = Literal::vec1(&[1.0]).reshape(&[1]).unwrap_err();
        assert!(format!("{err:?}").contains("not built"));
    }
}
