//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. `manifest.json` lists every AOT-lowered HLO text
//! artifact with its shape bucket; the runtime selects the smallest
//! bucket that fits a batch and pads up to it.

use std::path::{Path, PathBuf};

use crate::util::json;

#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: String,
    pub path: PathBuf,
    pub rows: usize,
    pub cols: usize,
    pub dtype: String,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub tile_r: usize,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let doc = json::parse(&text)?;
        let version = doc
            .get("version")
            .and_then(|v| v.as_i64())
            .ok_or("manifest: missing version")?;
        if version != 1 {
            return Err(format!("manifest: unsupported version {version}"));
        }
        let tile_r = doc
            .get("tile_r")
            .and_then(|v| v.as_usize())
            .ok_or("manifest: missing tile_r")?;
        let mut artifacts = Vec::new();
        for a in doc
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or("manifest: missing artifacts")?
        {
            let get_s = |k: &str| {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| format!("manifest: artifact missing {k}"))
            };
            let get_n = |k: &str| {
                a.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| format!("manifest: artifact missing {k}"))
            };
            let outputs = a
                .get("outputs")
                .and_then(|v| v.as_arr())
                .map(|xs| {
                    xs.iter()
                        .filter_map(|x| x.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default();
            artifacts.push(ArtifactMeta {
                name: get_s("name")?,
                kind: get_s("kind")?,
                path: dir.join(get_s("path")?),
                rows: get_n("rows")?,
                cols: get_n("cols")?,
                dtype: get_s("dtype")?,
                outputs,
            });
        }
        if artifacts.is_empty() {
            return Err("manifest: no artifacts".into());
        }
        Ok(Manifest { tile_r, artifacts })
    }

    /// Smallest bucket (by padded cell count) of `kind`/`dtype` with
    /// rows ≥ r and cols ≥ c. None if no bucket is big enough (callers
    /// then chunk rows/cols down to the largest bucket).
    pub fn pick_bucket(
        &self,
        kind: &str,
        dtype: &str,
        r: usize,
        c: usize,
    ) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == kind && a.dtype == dtype && a.rows >= r && a.cols >= c
            })
            .min_by_key(|a| a.rows * a.cols)
    }

    /// Largest available bucket for kind/dtype (row/col chunk target).
    pub fn max_bucket(&self, kind: &str, dtype: &str) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.dtype == dtype)
            .max_by_key(|a| (a.rows, a.cols))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifact_dir()).unwrap();
        assert_eq!(m.tile_r, 256);
        assert!(m.artifacts.len() >= 16);
        for a in &m.artifacts {
            assert!(a.path.exists(), "{:?}", a.path);
            assert!(a.rows % m.tile_r == 0);
        }
    }

    #[test]
    fn bucket_selection_smallest_fit() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifact_dir()).unwrap();
        let b = m.pick_bucket("diff", "f64", 1000, 5).unwrap();
        assert_eq!((b.rows, b.cols), (1024, 8));
        let b = m.pick_bucket("diff", "f64", 1025, 8).unwrap();
        assert_eq!((b.rows, b.cols), (4096, 8));
        let b = m.pick_bucket("diff", "f64", 1, 9).unwrap();
        assert_eq!((b.rows, b.cols), (1024, 32));
        assert!(m.pick_bucket("diff", "f64", usize::MAX, 1).is_none());
        let mx = m.max_bucket("diff", "f64").unwrap();
        assert_eq!((mx.rows, mx.cols), (65536, 32));
    }

    #[test]
    fn rejects_missing_manifest() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }
}
