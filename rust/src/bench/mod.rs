//! Bench harness (criterion substitute, DESIGN.md §4.5): trial
//! aggregation with mean ± 95% CI (the paper's protocol: 3 trials per
//! configuration) and fixed-width table rendering matching the paper's
//! table layout. The actual experiment drivers live in `tables.rs` and
//! are shared by `rust/benches/*` and the CLI `reproduce` subcommand.

pub mod tables;

use crate::metrics::quantile::mean_ci95;

/// mean ± ci, formatted like the paper ("13.9±0.4").
pub fn fmt_ci(mean: f64, ci: f64, decimals: usize) -> String {
    format!("{mean:.decimals$}±{ci:.decimals$}")
}

/// Aggregate one metric over trials.
pub fn agg<T>(trials: &[T], f: impl Fn(&T) -> f64) -> (f64, f64) {
    let xs: Vec<f64> = trials.iter().map(f).collect();
    mean_ci95(&xs)
}

/// Minimal fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells);
    }
    pub fn render(&self) -> String {
        // Char counts, not byte lengths ("±" is multi-byte).
        let w_of = |s: &str| s.chars().count();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| w_of(h)).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(w_of(c));
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
            }
            s.trim_end().to_string()
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Quick mode (smaller workloads / fewer trials) for CI and smoke runs:
/// set env `SDIFF_BENCH_QUICK=1`.
pub fn quick_mode() -> bool {
    std::env::var("SDIFF_BENCH_QUICK").map_or(false, |v| v == "1")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Workload", "Adaptive"]);
        t.row(vec!["1M".into(), "13.9±0.4".into()]);
        t.row(vec!["20M".into(), "242.7±4.8".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("Workload"));
        assert!(lines[2].contains("13.9"));
        // All rows equal display width.
        assert_eq!(lines[0].chars().count(), lines[2].chars().count());
    }

    #[test]
    fn fmt_ci_matches_paper_style() {
        assert_eq!(fmt_ci(13.94, 0.42, 1), "13.9±0.4");
        assert_eq!(fmt_ci(74.1, 0.0, 1), "74.1±0.0");
    }

    #[test]
    fn agg_computes_mean_ci() {
        let (m, ci) = agg(&[1.0f64, 2.0, 3.0], |x| *x);
        assert!((m - 2.0).abs() < 1e-12);
        assert!(ci > 0.0);
    }
}
