//! Experiment drivers that regenerate every table and ablation in the
//! paper's evaluation (DESIGN.md §3 per-experiment index) on the
//! simulated 32-core/64 GB testbed. Each `table*` / `ablate_*` function
//! returns the rendered table plus the paper's reference values so the
//! shape comparison is visible in one place; `rust/benches/*.rs` and
//! `smartdiff-sched reproduce` are thin wrappers.

use crate::bench::{agg, fmt_ci, Table};
use crate::config::{PolicyKind, SchedulerConfig};
use crate::engine::microbench::CostConstants;
use crate::sched::scheduler::JobStats;
use crate::sim::{run_sim_job, SimWorkload};

/// Paper workloads (name, rows/side). Quick mode shrinks rows 10× (same
/// gating thresholds are exercised by scaling Ŵ instead — see
/// `workload_for`).
pub fn workloads(quick: bool) -> Vec<(&'static str, usize)> {
    if quick {
        vec![("1M", 100_000), ("5M", 500_000), ("10M", 1_000_000),
             ("20M", 2_000_000)]
    } else {
        vec![
            ("1M", 1_000_000),
            ("5M", 5_000_000),
            ("10M", 10_000_000),
            ("20M", 20_000_000),
        ]
    }
}

/// Build the SimWorkload: quick mode keeps the paper's *working-set
/// ratios* by widening rows 10× so the gate decisions match full scale.
pub fn workload_for(name: &str, rows: usize, quick: bool, seed: u64) -> SimWorkload {
    let mut wl = SimWorkload::paper(rows, seed);
    if quick {
        wl.w_hat *= 10.0;
    }
    let _ = name;
    wl
}

pub fn paper_cfg() -> SchedulerConfig {
    SchedulerConfig::default() // κ=0.7 η=0.9 γ=0.6 τ=2 m=2, 64 GB / 32c
}

/// Skew scenario family (Zipf-hot-key duplicate runs): the bench
/// trajectory's join-skew axis, from mild skew to the adversarial
/// one-key-spans-everything shape the occurrence-indexed partitioner
/// opened — plus the B-dominant shape (one key's B-only surplus of
/// added rows dwarfing |A|) that add-range carving opened. Shared by
/// the `micro_hotpath` bench (stage timings + JSON dump) so skew
/// numbers are captured per PR alongside the hot-path stages;
/// `hot_key_mass` is the top key's share of all rows, `b_surplus_mass`
/// the pure-surplus B rows as a fraction of |A|.
pub fn skew_family() -> Vec<(&'static str, crate::data::generator::SkewSpec)> {
    use crate::data::generator::SkewSpec;
    let base = SkewSpec { rows: 30_000, seed: 7, ..SkewSpec::default() };
    vec![
        ("skew_mild", SkewSpec { hot_key_mass: 0.1, ..base.clone() }),
        ("skew_hot", SkewSpec { hot_key_mass: 0.5, ..base.clone() }),
        ("skew_one_key", SkewSpec { hot_key_mass: 1.0, ..base.clone() }),
        (
            "skew_b_surplus",
            SkewSpec { hot_key_mass: 0.2, b_surplus_mass: 1.0, ..base },
        ),
    ]
}

/// Trials per configuration (paper: 3).
pub const TRIALS: usize = 3;

/// Results for one workload across the three policies.
pub struct WorkloadResults {
    pub name: &'static str,
    pub rows: usize,
    pub fixed_grid: Vec<((usize, usize), Vec<JobStats>)>,
    pub heuristic: Vec<JobStats>,
    pub adaptive: Vec<JobStats>,
}

impl WorkloadResults {
    /// Representative fixed config: the grid config with the best mean
    /// *throughput* — what offline tuning for production throughput
    /// would deploy (the paper's baselines are tuned; a throughput-
    /// tuned fixed config is the strongest credible one). The full grid
    /// is printed by the bench binaries; see EXPERIMENTS.md.
    pub fn fixed_median(&self) -> &Vec<JobStats> {
        let (_, stats) = self
            .fixed_grid
            .iter()
            .max_by(|a, b| {
                agg(&a.1, |s| s.throughput_rows_per_s)
                    .0
                    .partial_cmp(&agg(&b.1, |s| s.throughput_rows_per_s).0)
                    // lint: allow(unwrap) agg means over finite stats
                    // are never NaN
                    .unwrap()
            })
            // lint: allow(unwrap) fixed_grid is a non-empty built-in
            .unwrap();
        stats
    }
    /// Best fixed config by mean p95 (the strongest fixed baseline).
    pub fn fixed_best(&self) -> (&(usize, usize), &Vec<JobStats>) {
        let (cfg, stats) = self
            .fixed_grid
            .iter()
            .min_by(|a, b| {
                agg(&a.1, |s| s.p95_latency)
                    .0
                    .partial_cmp(&agg(&b.1, |s| s.p95_latency).0)
                    // lint: allow(unwrap) agg means over finite stats
                    // are never NaN
                    .unwrap()
            })
            // lint: allow(unwrap) fixed_grid is a non-empty built-in
            .unwrap();
        (cfg, stats)
    }
}

pub struct Matrix {
    pub rows: Vec<WorkloadResults>,
    pub quick: bool,
}

fn run_trials(
    cfg: &SchedulerConfig,
    wl: &SimWorkload,
    consts: &CostConstants,
    trials: usize,
) -> Vec<JobStats> {
    (0..trials)
        .map(|t| {
            let mut w = *wl;
            w.seed = wl.seed.wrapping_add(1000 * t as u64 + 1);
            run_sim_job(cfg, &w, consts)
                // lint: allow(unwrap) sim jobs over generated workloads
                // fail only on config bugs; the bench wants the panic
                .expect("sim job")
                .stats
        })
        .collect()
}

/// Fixed grid (paper §V): full 4×3 at paper scale, 2×2 subset in quick.
fn fixed_grid(quick: bool) -> Vec<(usize, usize)> {
    if quick {
        vec![(25_000, 8), (100_000, 8), (100_000, 16), (250_000, 16)]
    } else {
        crate::baselines::FixedPolicy::paper_grid()
    }
}

/// Run the whole policy × workload matrix (Tables I–III share it).
pub fn run_matrix(quick: bool, trials: usize) -> Matrix {
    let consts = CostConstants::paper_engine();
    let mut rows = Vec::new();
    for (wi, (name, nrows)) in workloads(quick).into_iter().enumerate() {
        let wl = workload_for(name, nrows, quick, 17 * (wi as u64 + 1));
        let mut fixed_results = Vec::new();
        for (b, k) in fixed_grid(quick) {
            let mut cfg = paper_cfg();
            cfg.policy_kind = PolicyKind::Fixed { b, k };
            fixed_results.push(((b, k), run_trials(&cfg, &wl, &consts, trials)));
        }
        let mut cfg = paper_cfg();
        cfg.policy_kind = PolicyKind::Heuristic;
        let heuristic = run_trials(&cfg, &wl, &consts, trials);
        let cfg = paper_cfg();
        let adaptive = run_trials(&cfg, &wl, &consts, trials);
        rows.push(WorkloadResults {
            name,
            rows: nrows,
            fixed_grid: fixed_results,
            heuristic,
            adaptive,
        });
    }
    Matrix { rows, quick }
}

/// Paper Table I reference values (p95 seconds + backend decision).
pub const PAPER_T1: [(&str, f64, f64, f64, &str); 4] = [
    ("1M", 21.7, 18.2, 13.9, "in-mem"),
    ("5M", 83.5, 72.9, 53.8, "in-mem"),
    ("10M", 186.2, 161.4, 115.6, "Dask"),
    ("20M", 401.7, 336.2, 242.7, "Dask"),
];
/// Paper Table II (peak memory GB).
pub const PAPER_T2: [(&str, f64, f64, f64); 4] = [
    ("1M", 9.6, 8.4, 7.1),
    ("5M", 34.2, 30.6, 23.9),
    ("10M", 41.8, 36.4, 28.6),
    ("20M", 53.1, 47.3, 39.7),
];
/// Paper Table III (throughput K rows/s + reconfigs/job).
pub const PAPER_T3: [(&str, f64, f64, f64, u64); 4] = [
    ("1M", 74.1, 76.3, 78.8, 5),
    ("5M", 71.5, 72.0, 73.9, 7),
    ("10M", 66.4, 68.8, 69.1, 9),
    ("20M", 60.2, 62.5, 62.0, 10),
];

fn backend_label(stats: &[JobStats]) -> &'static str {
    match stats.first().map(|s| s.backend.as_str()) {
        Some("sim-inmem") | Some("inmem") => "in-mem",
        Some("sim-dasklike") | Some("dasklike") => "Dask",
        _ => "?",
    }
}

/// Table I: p95 latency (s), Fixed / Heur. / Adaptive + backend.
pub fn table1(m: &Matrix) -> String {
    let mut t = Table::new(&[
        "Workload", "Fixed", "Heur.", "Adaptive", "Backend",
        "vsHeur", "vsFixed",
    ]);
    for w in &m.rows {
        let (fm, fc) = agg(w.fixed_median(), |s| s.p95_latency);
        let (hm, hc) = agg(&w.heuristic, |s| s.p95_latency);
        let (am, ac) = agg(&w.adaptive, |s| s.p95_latency);
        t.row(vec![
            w.name.to_string(),
            fmt_ci(fm, fc, 1),
            fmt_ci(hm, hc, 1),
            fmt_ci(am, ac, 1),
            backend_label(&w.adaptive).to_string(),
            format!("{:+.0}%", 100.0 * (am / hm - 1.0)),
            format!("{:+.0}%", 100.0 * (am / fm - 1.0)),
        ]);
    }
    let mut out = String::from(
        "Table I — p95 latency (s), mean±95% CI, lower is better\n",
    );
    out.push_str(&t.render());
    out.push_str("\npaper reference (Fixed / Heur. / Adaptive, backend):\n");
    for (n, f, h, a, b) in PAPER_T1 {
        out.push_str(&format!(
            "  {n:>3}: {f:6.1} / {h:6.1} / {a:6.1}  {b}  \
             (-{:.0}% vs heur, -{:.0}% vs fixed)\n",
            100.0 * (1.0 - a / h),
            100.0 * (1.0 - a / f)
        ));
    }
    out
}

/// Table II: peak memory (GB).
pub fn table2(m: &Matrix) -> String {
    let gb = 1e-9;
    let mut t = Table::new(&[
        "Workload", "Fixed", "Heur.", "Adaptive", "vsHeur", "vsFixed",
    ]);
    for w in &m.rows {
        let (fm, fc) = agg(w.fixed_median(), |s| s.peak_rss_bytes as f64 * gb);
        let (hm, hc) = agg(&w.heuristic, |s| s.peak_rss_bytes as f64 * gb);
        let (am, ac) = agg(&w.adaptive, |s| s.peak_rss_bytes as f64 * gb);
        t.row(vec![
            w.name.to_string(),
            fmt_ci(fm, fc, 1),
            fmt_ci(hm, hc, 1),
            fmt_ci(am, ac, 1),
            format!("{:+.0}%", 100.0 * (am / hm - 1.0)),
            format!("{:+.0}%", 100.0 * (am / fm - 1.0)),
        ]);
    }
    let mut out = String::from(
        "Table II — peak memory (GB), mean±95% CI, lower is better\n",
    );
    out.push_str(&t.render());
    out.push_str("\npaper reference (Fixed / Heur. / Adaptive):\n");
    for (n, f, h, a) in PAPER_T2 {
        out.push_str(&format!(
            "  {n:>3}: {f:5.1} / {h:5.1} / {a:5.1}  \
             (-{:.0}% vs heur, -{:.0}% vs fixed)\n",
            100.0 * (1.0 - a / h),
            100.0 * (1.0 - a / f)
        ));
    }
    out
}

/// Table III: throughput (K rows/s) + reconfigs/job, plus the measured
/// control-loop overhead per job (the scheduler half of the
/// overhead/useful-work decomposition — ms of drive-loop time outside
/// `wait_any`).
pub fn table3(m: &Matrix) -> String {
    let mut t = Table::new(&[
        "Workload", "Fixed", "Heur.", "Adaptive", "Reconfigs", "OOMs",
        "Sched ms",
    ]);
    for w in &m.rows {
        let (fm, _) = agg(w.fixed_median(), |s| s.throughput_rows_per_s / 1e3);
        let (hm, _) = agg(&w.heuristic, |s| s.throughput_rows_per_s / 1e3);
        let (am, _) = agg(&w.adaptive, |s| s.throughput_rows_per_s / 1e3);
        let (rc, _) = agg(&w.adaptive, |s| s.reconfigs as f64);
        let ooms: u64 = w.adaptive.iter().map(|s| s.ooms).sum();
        let (so, _) = agg(&w.adaptive, |s| s.sched_overhead_ns as f64 / 1e6);
        t.row(vec![
            w.name.to_string(),
            format!("{fm:.1}"),
            format!("{hm:.1}"),
            format!("{am:.1}"),
            format!("{rc:.0}"),
            format!("{ooms}"),
            format!("{so:.1}"),
        ]);
    }
    let mut out = String::from(
        "Table III — throughput (K rows/s), stability (reconfigs/job), \
         control-loop overhead (ms/job)\n",
    );
    out.push_str(&t.render());
    out.push_str("\npaper reference (Fixed / Heur. / Adaptive, reconfigs):\n");
    for (n, f, h, a, r) in PAPER_T3 {
        out.push_str(&format!("  {n:>3}: {f:5.1} / {h:5.1} / {a:5.1}   {r}\n"));
    }
    out
}

// ---------------- ablations (§VII / §VIII) ----------------

/// Guard (η) and drop (γ) ablation on the 5M workload.
pub fn ablate_guard(quick: bool, trials: usize) -> String {
    let consts = CostConstants::paper_engine();
    let rows = if quick { 500_000 } else { 5_000_000 };
    let wl = workload_for("5M", rows, quick, 99);
    let mut t = Table::new(&["eta", "gamma", "p95(s)", "peak(GB)", "OOMs"]);
    for eta in [0.90, 0.99] {
        for gamma in [0.5, 0.6, 0.7] {
            let mut cfg = paper_cfg();
            // Tightened cap so the envelope binds at sim scale: the
            // latency objective alone caps b near 4 GB of batch state,
            // so at 64 GB the guard would never engage (the paper's
            // engine holds ~6x more per-worker state; see DESIGN.md).
            cfg.caps.mem_cap_bytes = 4_000_000_000;
            cfg.policy.eta = eta;
            cfg.policy.gamma = gamma;
            let stats = run_trials(&cfg, &wl, &consts, trials);
            let (p95, ci) = agg(&stats, |s| s.p95_latency);
            let (peak, pci) = agg(&stats, |s| s.peak_rss_bytes as f64 * 1e-9);
            let ooms: u64 = stats.iter().map(|s| s.ooms).sum();
            t.row(vec![
                format!("{eta:.2}"),
                format!("{gamma:.1}"),
                fmt_ci(p95, ci, 1),
                fmt_ci(peak, pci, 1),
                format!("{ooms}"),
            ]);
        }
    }
    let mut out = String::from(
        "Ablation — guard η and drop γ (5M workload, cap tightened to \
         4 GB so the envelope binds; see header comment). Paper: η=0.90 \
         cuts peaks 2–4 GB for +1–2% latency; η=0.99 produced one OOM.\n",
    );
    out.push_str(&t.render());
    out
}

/// Working-set factor κ ablation: backend decisions on narrow/wide rows.
pub fn ablate_kappa(quick: bool, trials: usize) -> String {
    let consts = CostConstants::paper_engine();
    let mut t = Table::new(&[
        "kappa", "rows", "width", "backend", "p95(s)", "peak(GB)",
    ]);
    for kappa in [0.6, 0.7, 0.8] {
        for (name, nrows) in workloads(quick) {
            for (wname, wmul) in [("narrow", 0.5), ("wide", 1.0)] {
                let mut wl = workload_for(name, nrows, quick, 7);
                wl.w_hat *= wmul;
                let mut cfg = paper_cfg();
                cfg.policy.kappa = kappa;
                let stats = run_trials(&cfg, &wl, &consts, trials.min(1).max(1));
                let (p95, _) = agg(&stats, |s| s.p95_latency);
                let (peak, _) = agg(&stats, |s| s.peak_rss_bytes as f64 * 1e-9);
                t.row(vec![
                    format!("{kappa:.1}"),
                    name.to_string(),
                    wname.to_string(),
                    stats[0].backend.replace("sim-", ""),
                    format!("{p95:.1}"),
                    format!("{peak:.1}"),
                ]);
            }
        }
    }
    let mut out = String::from(
        "Ablation — working-set factor κ (paper: κ=0.6 gates only 1M/5M \
         in-mem; κ=0.8 pulls 10M/narrow in-mem with higher peaks, still \
         under guard)\n",
    );
    out.push_str(&t.render());
    out
}

/// Hysteresis m ablation: reconfigs/job and p95.
pub fn ablate_hysteresis(quick: bool, trials: usize) -> String {
    let consts = CostConstants::paper_engine();
    let mut t = Table::new(&["m", "workload", "reconfigs", "p95(s)"]);
    for m_h in [1u32, 2, 3] {
        for (name, nrows) in workloads(quick) {
            let wl = workload_for(name, nrows, quick, 31);
            let mut cfg = paper_cfg();
            cfg.policy.hysteresis_m = m_h;
            let stats = run_trials(&cfg, &wl, &consts, trials);
            let (rc, rcci) = agg(&stats, |s| s.reconfigs as f64);
            let (p95, ci) = agg(&stats, |s| s.p95_latency);
            t.row(vec![
                format!("{m_h}"),
                name.to_string(),
                fmt_ci(rc, rcci, 1),
                fmt_ci(p95, ci, 1),
            ]);
        }
    }
    let mut out = String::from(
        "Ablation — hysteresis m (paper: m=3 removes 1–2 reconfigs/job, \
         negligible p95 impact)\n",
    );
    out.push_str(&t.render());
    out
}

/// Smoothing factor ρ ablation (paper §III: ρ∈[0.1,0.4]).
pub fn ablate_rho(quick: bool, trials: usize) -> String {
    let consts = CostConstants::paper_engine();
    let rows = if quick { 500_000 } else { 5_000_000 };
    let wl = workload_for("5M", rows, quick, 55);
    let mut t = Table::new(&["rho", "p95(s)", "reconfigs", "peak(GB)"]);
    for rho in [0.1, 0.2, 0.3, 0.4] {
        let mut cfg = paper_cfg();
        cfg.policy.rho_smooth = rho;
        let stats = run_trials(&cfg, &wl, &consts, trials);
        let (p95, ci) = agg(&stats, |s| s.p95_latency);
        let (rc, _) = agg(&stats, |s| s.reconfigs as f64);
        let (peak, _) = agg(&stats, |s| s.peak_rss_bytes as f64 * 1e-9);
        t.row(vec![
            format!("{rho:.1}"),
            fmt_ci(p95, ci, 1),
            format!("{rc:.0}"),
            format!("{peak:.1}"),
        ]);
    }
    let mut out = String::from(
        "Ablation — EWMA smoothing ρ (paper: ρ=0.2 balances stability \
         and responsiveness)\n",
    );
    out.push_str(&t.render());
    out
}

/// §VIII safety: OOM rate under the guard, fraction of actions kept.
pub fn safety_envelope(quick: bool, trials: usize) -> String {
    let consts = CostConstants::paper_engine();
    let mut t = Table::new(&[
        "eta", "workload", "OOMs", "actions_kept", "peak/cap",
    ]);
    let cap_gb = 4.0;
    for eta in [0.90, 0.99] {
        for (name, nrows) in workloads(quick) {
            let wl = workload_for(name, nrows, quick, 71);
            let mut cfg = paper_cfg();
            cfg.caps.mem_cap_bytes = 4_000_000_000; // envelope in play
            cfg.policy.eta = eta;
            let stats = run_trials(&cfg, &wl, &consts, trials);
            let ooms: u64 = stats.iter().map(|s| s.ooms).sum();
            let (kept, _) = agg(&stats, |s| s.actions_kept);
            let (peak, _) = agg(&stats, |s| s.peak_rss_bytes as f64 * 1e-9);
            t.row(vec![
                format!("{eta:.2}"),
                name.to_string(),
                format!("{ooms}"),
                format!("{kept:.2}"),
                format!("{:.2}", peak / cap_gb),
            ]);
        }
    }
    let mut out = String::from(
        "Safety envelope (§VIII): Pr[OOM] bounded by the interval \
         pruning; paper kept >85% of candidate actions at 0% OOM under \
         the default guard.\n",
    );
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_matrix_has_expected_shape() {
        let m = run_matrix(true, 1);
        assert_eq!(m.rows.len(), 4);
        for w in &m.rows {
            assert_eq!(w.adaptive.len(), 1);
            assert!(!w.fixed_grid.is_empty());
            let _ = w.fixed_median();
            let _ = w.fixed_best();
        }
        let t1 = table1(&m);
        assert!(t1.contains("Table I"));
        assert!(t1.contains("paper reference"));
        let t2 = table2(&m);
        assert!(t2.contains("GB"));
        let t3 = table3(&m);
        assert!(t3.contains("Reconfigs"));
    }

    #[test]
    fn quick_gating_matches_paper_decisions() {
        let m = run_matrix(true, 1);
        assert_eq!(backend_label(&m.rows[0].adaptive), "in-mem"); // 1M
        assert_eq!(backend_label(&m.rows[1].adaptive), "in-mem"); // 5M
        assert_eq!(backend_label(&m.rows[2].adaptive), "Dask"); // 10M
        assert_eq!(backend_label(&m.rows[3].adaptive), "Dask"); // 20M
    }
}
