//! Minimal JSON reader/writer (serde substitute — the image's crate
//! cache has no serde facade; DESIGN.md §4.5).
//!
//! The writer covers telemetry/report emission; the reader covers
//! `artifacts/manifest.json` and config round-trips. It is a strict
//! subset of JSON: no surrogate-pair escapes beyond \uXXXX BMP, numbers
//! are f64/i64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Parsed JSON value. Object keys are sorted (BTreeMap) so that
/// re-serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() && x == x.trunc() && x.abs() < 9.0e15 {
        let _ = write!(out, "{}", x as i64);
    } else if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Incremental object writer for telemetry lines (avoids building maps on
/// the hot path).
pub struct ObjWriter {
    buf: String,
    first: bool,
}

impl ObjWriter {
    pub fn new() -> Self {
        ObjWriter { buf: String::from("{"), first: true }
    }
    fn sep(&mut self, key: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        write_escaped(&mut self.buf, key);
        self.buf.push(':');
    }
    pub fn num(mut self, key: &str, x: f64) -> Self {
        self.sep(key);
        write_num(&mut self.buf, x);
        self
    }
    pub fn int(self, key: &str, x: i64) -> Self {
        self.num(key, x as f64)
    }
    pub fn str(mut self, key: &str, s: &str) -> Self {
        self.sep(key);
        write_escaped(&mut self.buf, s);
        self
    }
    pub fn bool(mut self, key: &str, b: bool) -> Self {
        self.sep(key);
        self.buf.push_str(if b { "true" } else { "false" });
        self
    }
    pub fn raw(mut self, key: &str, json: &str) -> Self {
        self.sep(key);
        self.buf.push_str(json);
        self
    }
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

impl Default for ObjWriter {
    fn default() -> Self {
        Self::new()
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'n' => lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("bad \\u escape".into());
                        }
                        let hex =
                            std::str::from_utf8(&b[*pos + 1..*pos + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape".to_string())?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // Consume one UTF-8 scalar.
                let s = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| "invalid utf-8".to_string())?;
                // lint: allow(unwrap) slice is non-empty (loop guard
                // `*pos < b.len()`) and just UTF-8 validated
                let ch = s.chars().next().unwrap();
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // [
    let mut items = Vec::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b']' {
            *pos += 1;
            return Ok(Json::Arr(items));
        }
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {}
            _ => return Err(format!("expected , or ] at byte {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // {
    let mut map = BTreeMap::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b'}' {
            *pos += 1;
            return Ok(Json::Obj(map));
        }
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected key at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at byte {pos}", pos = *pos));
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {}
            _ => return Err(format!("expected , or }} at byte {pos}", pos = *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,"x\n"],"c":{"d":-2.5}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap().as_f64(),
            Some(-2.5)
        );
        // Reparse what we emit.
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_nested_arrays() {
        let v = parse("[[1,2],[3,[4]]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0].as_i64(),
                   Some(4));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]x").is_err());
        assert!(parse("nope").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn escapes_in_writer() {
        let s = ObjWriter::new().str("k", "a\"b\\c\nd").finish();
        let v = parse(&s).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""éx""#).unwrap();
        assert_eq!(v.as_str(), Some("éx"));
    }

    #[test]
    fn objwriter_types() {
        let line = ObjWriter::new()
            .int("i", -3)
            .num("f", 1.25)
            .bool("b", true)
            .raw("r", "[1,2]")
            .finish();
        let v = parse(&line).unwrap();
        assert_eq!(v.get("i").unwrap().as_i64(), Some(-3));
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.25));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("r").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        let line = ObjWriter::new().num("x", f64::NAN).finish();
        assert_eq!(line, r#"{"x":null}"#);
    }
}
