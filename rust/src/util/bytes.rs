//! Byte-size formatting/parsing helpers used by configs, reports and
//! telemetry (GB in the paper's tables are decimal gigabytes).

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;
pub const GB: u64 = 1_000_000_000;

/// Human-readable binary size ("1.50 GiB").
pub fn human(bytes: u64) -> String {
    let b = bytes as f64;
    if bytes >= GIB {
        format!("{:.2} GiB", b / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.2} MiB", b / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.2} KiB", b / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// Decimal gigabytes, as reported in the paper's Table II.
pub fn to_gb(bytes: u64) -> f64 {
    bytes as f64 / GB as f64
}

/// Parse "64GB", "512MiB", "4096", "1.5GiB" (case-insensitive).
pub fn parse(s: &str) -> Result<u64, String> {
    let t = s.trim().to_ascii_lowercase();
    let (num, mult) = if let Some(p) = t.strip_suffix("gib") {
        (p, GIB as f64)
    } else if let Some(p) = t.strip_suffix("mib") {
        (p, MIB as f64)
    } else if let Some(p) = t.strip_suffix("kib") {
        (p, KIB as f64)
    } else if let Some(p) = t.strip_suffix("gb") {
        (p, GB as f64)
    } else if let Some(p) = t.strip_suffix("mb") {
        (p, 1e6)
    } else if let Some(p) = t.strip_suffix("kb") {
        (p, 1e3)
    } else if let Some(p) = t.strip_suffix('b') {
        (p, 1.0)
    } else {
        (t.as_str(), 1.0)
    };
    num.trim()
        .parse::<f64>()
        .map(|x| (x * mult) as u64)
        .map_err(|e| format!("bad size {s:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_units() {
        assert_eq!(parse("64GB").unwrap(), 64 * GB);
        assert_eq!(parse("512MiB").unwrap(), 512 * MIB);
        assert_eq!(parse("4096").unwrap(), 4096);
        assert_eq!(parse("1.5gib").unwrap(), (1.5 * GIB as f64) as u64);
        assert_eq!(parse(" 2 kb ").unwrap(), 2000);
        assert!(parse("abc").is_err());
    }

    #[test]
    fn human_readable() {
        assert_eq!(human(10), "10 B");
        assert_eq!(human(2 * KIB), "2.00 KiB");
        assert_eq!(human(3 * MIB), "3.00 MiB");
        assert_eq!(human(GIB + GIB / 2), "1.50 GiB");
    }

    #[test]
    fn gb_is_decimal() {
        assert!((to_gb(64 * GB) - 64.0).abs() < 1e-9);
    }
}
