//! Self-contained utility layer (the offline crate cache has no serde /
//! rand / proptest; DESIGN.md §4.5 documents each substitution).

pub mod bytes;
pub mod json;
pub mod prop;
pub mod rng;

/// Monotonic seconds since process start (cheap wall-clock for telemetry).
pub fn mono_secs() -> f64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Current process RSS in bytes from /proc/self/statm (Linux). Ground
/// truth used to sanity-check the analytic memory accounting.
pub fn process_rss_bytes() -> Option<u64> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let rss_pages: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    Some(rss_pages * 4096)
}

#[cfg(test)]
mod tests {
    #[test]
    fn rss_is_positive_on_linux() {
        let rss = super::process_rss_bytes().expect("linux /proc");
        assert!(rss > 1024 * 1024);
    }

    #[test]
    fn mono_secs_monotonic() {
        let a = super::mono_secs();
        let b = super::mono_secs();
        assert!(b >= a);
    }
}
