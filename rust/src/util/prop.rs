//! Tiny property-testing harness (proptest substitute — DESIGN.md §4.5).
//!
//! `forall` runs a closure over `n` independently seeded RNGs and, on the
//! first failure, retries with the same seed to confirm, then reports the
//! seed so the case is replayable (`PROP_SEED=<seed> cargo test ...`).
//! There is no structural shrinking; generators should be written so a
//! seed fully determines the case (everything in this repo is).

use crate::util::rng::Rng;

/// Run `check` for `n` cases. `check` returns Err(msg) on violation.
///
/// The base seed can be pinned with the `PROP_SEED` env var to replay a
/// reported failure deterministically.
pub fn forall<F>(name: &str, n: usize, mut check: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base: u64 = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..n {
        let seed = base
            .wrapping_add((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = check(&mut rng) {
            panic!(
                "property `{name}` failed on case {case} \
                 (replay with PROP_SEED={base} and case index {case}): {msg}"
            );
        }
    }
}

/// Convenience assertion helpers returning Result for use in `forall`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($arg:tt)+) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} (left={:?}, right={:?})",
                format!($($arg)+), a, b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("u64 parity roundtrip", 50, |rng| {
            let x = rng.next_u64();
            prop_assert_eq!(x ^ x, 0u64, "xor self");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn reports_failing_seed() {
        forall("always-fails", 3, |_| Err("boom".into()));
    }
}
