//! Deterministic PRNG (SplitMix64 seeding + xoshiro256**).
//!
//! Every source of randomness in the repo — workload generation, noise in
//! the simulator, property tests — flows through this module so that runs
//! are reproducible from a single seed (DESIGN.md §6 Determinism).

/// xoshiro256** generator seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-worker / per-shard RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — hi must be > lo.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % ((hi - lo) as u64)) as i64
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with the *multiplicative* sigma of the underlying normal.
    /// `lognormal(0.1)` returns values centered near 1 with ~10% spread —
    /// the simulator's batch-time noise model.
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Random alphanumeric string of length `len`.
    pub fn alnum(&mut self, len: usize) -> String {
        const CHARS: &[u8] =
            b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789";
        (0..len)
            .map(|_| CHARS[self.range_usize(0, CHARS.len())] as char)
            .collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Zipf-ish skewed index in [0, n): P(i) ∝ 1/(i+1)^s (s ≠ 1),
    /// via inverse CDF of the continuous power-law approximation
    /// P(X ≤ x) = (x^(1-s) - 1) / (n^(1-s) - 1).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!((s - 1.0).abs() > 1e-9, "s=1 unsupported");
        let u = self.f64();
        let p = 1.0 - s;
        let x = (1.0 + u * ((n as f64).powf(p) - 1.0)).powf(1.0 / p);
        (x.floor() as usize).saturating_sub(0).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.range_u64(10, 20);
            assert!((10..20).contains(&v));
            let w = r.range_i64(-5, 5);
            assert!((-5..5).contains(&w));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(8);
        for _ in 0..1000 {
            assert!(r.lognormal(0.2) > 0.0);
        }
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(9);
        let mut lo = 0usize;
        for _ in 0..5000 {
            let i = r.zipf(100, 1.2);
            assert!(i < 100);
            if i < 10 {
                lo += 1;
            }
        }
        assert!(lo > 2200, "zipf should concentrate mass at low ranks: {lo}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
