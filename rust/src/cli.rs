//! Hand-rolled CLI (clap substitute, DESIGN.md §4.5): subcommands +
//! `--key value` / `--flag` options with typed accessors.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse argv (excluding argv[0]). `flag_names` lists boolean flags
    /// (everything else starting with `--` consumes a value).
    pub fn parse(
        argv: &[String],
        flag_names: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| format!("--{name} expects a value"))?;
                    out.options.insert(name.to_string(), val.clone());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg.clone());
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }
    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        self.get(name)
            .map(|v| {
                v.replace('_', "")
                    .parse()
                    .map_err(|_| format!("--{name}: expected integer, got {v:?}"))
            })
            .transpose()
    }
    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, String> {
        self.get(name)
            .map(|v| {
                v.replace('_', "")
                    .parse()
                    .map_err(|_| format!("--{name}: expected integer, got {v:?}"))
            })
            .transpose()
    }
    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        self.get(name)
            .map(|v| {
                v.parse()
                    .map_err(|_| format!("--{name}: expected number, got {v:?}"))
            })
            .transpose()
    }
    /// Error on unknown options (catch typos early).
    pub fn expect_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!("unknown option --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            &argv("run --rows 10_000 --backend dask --quick input.csv"),
            &["quick"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get_usize("rows").unwrap(), Some(10_000));
        assert_eq!(a.get("backend"), Some("dask"));
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["input.csv"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv("run --rows"), &[]).is_err());
    }

    #[test]
    fn unknown_option_detected() {
        let a = Args::parse(&argv("run --typo 1"), &[]).unwrap();
        assert!(a.expect_known(&["rows"]).is_err());
        assert!(a.expect_known(&["typo"]).is_ok());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&argv("x --rows abc"), &[]).unwrap();
        assert!(a.get_usize("rows").is_err());
        let a = Args::parse(&argv("x --eta 0.9"), &[]).unwrap();
        assert_eq!(a.get_f64("eta").unwrap(), Some(0.9));
        let a = Args::parse(&argv("x --seed 42"), &[]).unwrap();
        assert_eq!(a.get_u64("seed").unwrap(), Some(42));
    }
}
