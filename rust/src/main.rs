//! smartdiff-sched launcher.
//!
//! Subcommands:
//!   diff       — diff two CSV files (--schema describes the columns;
//!                `key` marks row-alignment key components)
//!   run        — synthetic workload through the full pipeline
//!   serve      — multi-job DiffSession demo: N concurrent jobs admitted
//!                against one shared CPU/memory budget, with live
//!                progress + typed event streaming
//!   profile    — pre-flight profile + gate decision only
//!   reproduce  — regenerate the paper's Tables I–III on the sim testbed
//!   ablate     — run one §VII/§VIII ablation (guard|kappa|hysteresis|rho|safety)
//!   calibrate  — engine microbenchmarks (cost-model constants)

use std::sync::Arc;

use smartdiff_sched::api::{DiffSession, JobBuilder};
use smartdiff_sched::bench::tables;
use smartdiff_sched::cli::Args;
use smartdiff_sched::config::{BackendChoice, DeltaPath, PolicyKind, SchedulerConfig};
use smartdiff_sched::data::generator::{generate_pair, GenSpec};
use smartdiff_sched::data::io::{CsvFileSource, InMemorySource};
use smartdiff_sched::data::schema::{ColumnType, Field, Schema};
use smartdiff_sched::engine::microbench;
use smartdiff_sched::sched::preflight::preflight;
use smartdiff_sched::sched::scheduler::run_job;
use smartdiff_sched::sched::working_set::{gate_backend, WorkingSetModel};

const USAGE: &str = "\
smartdiff-sched — adaptive execution scheduler for SmartDiff

USAGE:
  smartdiff-sched diff <a.csv> <b.csv> --schema id:key:int64,amount:float64,...
                       [--config cfg.toml] [--backend auto|inmem|dask]
                       [--telemetry out.jsonl] [--pjrt]
  smartdiff-sched run [--rows N] [--seed S] [--policy adaptive|heuristic|fixed]
                      [--b N --k N] [--backend ...] [--config cfg.toml] [--pjrt]
  smartdiff-sched serve [--jobs N] [--rows N] [--seed S] [--config cfg.toml]
  smartdiff-sched profile [--rows N] [--config cfg.toml]
  smartdiff-sched reproduce [--quick] [--trials N]
  smartdiff-sched ablate <guard|kappa|hysteresis|rho|safety> [--quick]
  smartdiff-sched analyze <telemetry.jsonl>
  smartdiff-sched calibrate [--rows N]
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e}");
        eprintln!("{USAGE}");
        std::process::exit(1);
    }
}

fn load_cfg(args: &Args) -> Result<SchedulerConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => SchedulerConfig::from_file(path)?,
        None => {
            let mut c = SchedulerConfig::default();
            c.caps.cpu_cap = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2);
            c.caps.mem_cap_bytes = 8_000_000_000;
            c.policy.b_min = 1_000;
            c
        }
    };
    if let Some(b) = args.get("backend") {
        cfg.backend = BackendChoice::parse(b)?;
    }
    if let Some(t) = args.get("telemetry") {
        cfg.telemetry_path = Some(t.to_string());
    }
    if args.flag("pjrt") {
        cfg.engine.delta_path = DeltaPath::Pjrt;
    }
    match args.get("policy") {
        Some("adaptive") | None => {}
        Some("heuristic") => cfg.policy_kind = PolicyKind::Heuristic,
        Some("fixed") => {
            let b = args.get_usize("b")?.ok_or("--policy fixed needs --b")?;
            let k = args.get_usize("k")?.ok_or("--policy fixed needs --k")?;
            cfg.policy_kind = PolicyKind::Fixed { b, k };
        }
        Some(other) => return Err(format!("unknown policy {other:?}")),
    }
    Ok(cfg)
}

fn print_result(r: &smartdiff_sched::sched::scheduler::JobResult) {
    println!("{}", r.report.summary());
    let s = &r.stats;
    println!(
        "backend={} policy={} batches={} p50={:.3}s p95={:.3}s \
         peak_rss={:.1}MB throughput={:.0} rows/s reconfigs={} ooms={}",
        s.backend,
        s.policy,
        s.batches,
        s.p50_latency,
        s.p95_latency,
        s.peak_rss_bytes as f64 / 1e6,
        s.throughput_rows_per_s,
        s.reconfigs,
        s.ooms
    );
    let st = &s.stages;
    println!(
        "pipeline: read={:.3}s decode={:.3}s align={:.3}s diff={:.3}s \
         stall={:.3}s overlap={:.2} sched_overhead={:.3}s",
        st.read_ns as f64 / 1e9,
        st.decode_ns as f64 / 1e9,
        st.align_ns as f64 / 1e9,
        st.diff_ns as f64 / 1e9,
        st.stall_ns as f64 / 1e9,
        st.overlap_ratio(),
        s.sched_overhead_ns as f64 / 1e9
    );
    println!("report: {}", r.report.to_json());
}

fn dispatch(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, &["quick", "pjrt"])?;
    let known = [
        "config", "backend", "telemetry", "policy", "b", "k", "rows",
        "seed", "trials", "schema", "jobs",
    ];
    args.expect_known(&known)?;
    match args.subcommand.as_deref() {
        Some("diff") => {
            if args.positional.len() != 2 {
                return Err("diff needs exactly two csv paths".into());
            }
            let cfg = load_cfg(&args)?;
            let schema = match args.get("schema") {
                Some(spec) => parse_schema(spec)?,
                None => {
                    return Err(
                        "--schema is required for csv diff \
                         (e.g. --schema id:key:int64,amount:float64,name:utf8)"
                            .into(),
                    )
                }
            };
            let a = CsvFileSource::open(
                std::path::Path::new(&args.positional[0]),
                schema.clone(),
            )?;
            let b = CsvFileSource::open(
                std::path::Path::new(&args.positional[1]),
                schema,
            )?;
            let r = run_job(&cfg, Arc::new(a), Arc::new(b))?;
            print_result(&r);
            Ok(())
        }
        Some("run") => {
            let cfg = load_cfg(&args)?;
            let rows = args.get_usize("rows")?.unwrap_or(100_000);
            let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
            let (a, b, truth) =
                generate_pair(&GenSpec { rows, seed, ..GenSpec::default() });
            println!(
                "generated pair: {rows} rows (truth: {} changed, {} added, {} removed)",
                truth.changed_rows, truth.added, truth.removed
            );
            let r = run_job(
                &cfg,
                Arc::new(InMemorySource::new(a)),
                Arc::new(InMemorySource::new(b)),
            )?;
            print_result(&r);
            Ok(())
        }
        Some("serve") => {
            let cfg = load_cfg(&args)?;
            let jobs = args.get_usize("jobs")?.unwrap_or(4).max(1);
            let rows = args.get_usize("rows")?.unwrap_or(50_000);
            let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
            serve(&cfg, jobs, rows, seed)
        }
        Some("profile") => {
            let cfg = load_cfg(&args)?;
            let rows = args.get_usize("rows")?.unwrap_or(100_000);
            let (a, b, _) = generate_pair(&GenSpec {
                rows,
                seed: 1,
                ..GenSpec::default()
            });
            let (sa, sb) = (InMemorySource::new(a), InMemorySource::new(b));
            let p = preflight(
                &sa,
                &sb,
                cfg.preflight_max_rows,
                cfg.preflight_fraction,
            )?;
            println!(
                "preflight: w_hat={:.1} B/row  b_read={:.2} GB/s  sampled={} rows",
                p.w_hat,
                p.b_read / 1e9,
                p.sampled_rows
            );
            let g =
                gate_backend(&WorkingSetModel::default(), &p, &cfg.caps, &cfg.policy);
            println!(
                "gate: ws={:.2} MB threshold={:.2} MB -> {}",
                g.ws_bytes / 1e6,
                g.threshold_bytes / 1e6,
                g.backend.name()
            );
            Ok(())
        }
        Some("reproduce") => {
            let quick = args.flag("quick");
            let trials = args.get_usize("trials")?.unwrap_or(tables::TRIALS);
            eprintln!(
                "running policy × workload matrix (quick={quick}, trials={trials})..."
            );
            let m = tables::run_matrix(quick, trials);
            println!("{}", tables::table1(&m));
            println!("{}", tables::table2(&m));
            println!("{}", tables::table3(&m));
            Ok(())
        }
        Some("ablate") => {
            let quick = args.flag("quick");
            let trials = if quick { 1 } else { tables::TRIALS };
            let which = args
                .positional
                .first()
                .map(|s| s.as_str())
                .ok_or("ablate needs a target")?;
            let out = match which {
                "guard" => tables::ablate_guard(quick, trials),
                "kappa" => tables::ablate_kappa(quick, trials),
                "hysteresis" => tables::ablate_hysteresis(quick, trials),
                "rho" => tables::ablate_rho(quick, trials),
                "safety" => tables::safety_envelope(quick, trials),
                other => return Err(format!("unknown ablation {other:?}")),
            };
            println!("{out}");
            Ok(())
        }
        Some("analyze") => {
            let path = args
                .positional
                .first()
                .ok_or("analyze needs a telemetry file")?;
            let log = smartdiff_sched::report::TelemetryLog::load(path)?;
            print!("{}", smartdiff_sched::report::analyze(&log));
            Ok(())
        }
        Some("calibrate") => {
            let rows = args.get_usize("rows")?.unwrap_or(microbench::CALIB_ROWS);
            let c = microbench::calibrate(rows, 1);
            println!("{c:#?}");
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand {other:?}")),
        None => Err("missing subcommand".into()),
    }
}

/// Multi-job service demo: submit N synthetic jobs into one
/// `DiffSession` budget, stream typed events and progress while they
/// run, then join and summarize each.
fn serve(
    cfg: &SchedulerConfig,
    jobs: usize,
    rows: usize,
    seed: u64,
) -> Result<(), String> {
    let session = DiffSession::new(cfg.caps);
    println!(
        "session: mem_cap={:.2} GB cpu_cap={} — submitting {jobs} jobs of \
         {rows} rows each",
        cfg.caps.mem_cap_bytes as f64 / 1e9,
        cfg.caps.cpu_cap
    );
    let mut handles = Vec::new();
    for j in 0..jobs {
        let (a, b, _) = generate_pair(&GenSpec {
            rows,
            seed: seed + j as u64,
            ..GenSpec::default()
        });
        let job = JobBuilder::from_config(
            cfg.clone(),
            Arc::new(InMemorySource::new(a)),
            Arc::new(InMemorySource::new(b)),
        )
        .build()?;
        let handle = session.submit(job)?;
        println!("job {}: submitted", handle.id());
        handles.push(handle);
    }

    // Event/progress pump: drain typed events as they arrive until every
    // job's thread has finished.
    loop {
        let mut all_done = true;
        for h in &handles {
            for ev in h.events() {
                println!("job {}: {ev}", h.id());
            }
            if !h.is_finished() {
                all_done = false;
            }
        }
        if all_done {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }

    // Join every job — one failure must not abandon the others' results.
    let mut failures = 0usize;
    for h in &mut handles {
        for ev in h.events() {
            println!("job {}: {ev}", h.id());
        }
        let id = h.id();
        match h.join() {
            Ok(r) => {
                let s = &r.stats;
                println!(
                    "job {id}: changed={} added={} removed={} | backend={} \
                     batches={} p95={:.3}s peak_rss={:.1}MB reconfigs={} ooms={}",
                    r.report.rows.changed_rows,
                    r.report.rows.added,
                    r.report.rows.removed,
                    s.backend,
                    s.batches,
                    s.p95_latency,
                    s.peak_rss_bytes as f64 / 1e6,
                    s.reconfigs,
                    s.ooms
                );
            }
            Err(e) => {
                failures += 1;
                println!("job {id}: FAILED: {e}");
            }
        }
    }
    if failures > 0 {
        return Err(format!("{failures} of {jobs} jobs failed"));
    }
    println!("serve OK: {jobs} jobs completed under one shared budget");
    Ok(())
}

/// Parse "name[:key]:type,..." schema specs for csv diff.
fn parse_schema(spec: &str) -> Result<Schema, String> {
    let mut fields = Vec::new();
    for part in spec.split(',') {
        let bits: Vec<&str> = part.split(':').collect();
        let (name, key, ty_name) = match bits.as_slice() {
            [n, t] => (*n, false, *t),
            [n, "key", t] => (*n, true, *t),
            _ => return Err(format!("bad schema field {part:?}")),
        };
        let ty = match ty_name {
            "int64" => ColumnType::Int64,
            "float64" => ColumnType::Float64,
            "utf8" => ColumnType::Utf8,
            "bool" => ColumnType::Bool,
            "date" => ColumnType::Date,
            "timestamp" => ColumnType::Timestamp,
            other => {
                if let Some(scale) = other
                    .strip_prefix("decimal(")
                    .and_then(|s| s.strip_suffix(')'))
                {
                    ColumnType::Decimal {
                        scale: scale
                            .parse()
                            .map_err(|_| format!("bad decimal scale {other:?}"))?,
                    }
                } else {
                    return Err(format!("unknown type {other:?}"));
                }
            }
        };
        fields.push(if key {
            Field::key(name, ty)
        } else {
            Field::new(name, ty)
        });
    }
    Ok(Schema::new(fields))
}
